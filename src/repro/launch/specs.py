"""Abstract input/parameter/cache specs + sharding rules per (arch × shape).

Everything here is allocation-free: params come from ``jax.eval_shape`` over
the real initializers, inputs are ShapeDtypeStructs, and shardings are
divisibility-guarded PartitionSpec trees.  launch/dryrun.py composes these
into lower+compile calls for every dry-run cell.

Sharding policy (DESIGN.md §6):
  * params: FSDP over (pod,data) on the d_model-ish dim + TP over `model`
    on heads/ffn/vocab/experts (Megatron layout), guarded by divisibility;
  * batch inputs: (pod,data); batch==1 long-context remaps sequence->data;
  * KV caches: batch->data, sequence->model (decode_32k) or
    sequence->(data,model) (long_500k, batch=1); SSM states: heads->model.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import LMConfig, OptimizerConfig, ShapeSpec
from repro.launch.mesh import fsdp_axes
from repro.models import encdec as encdec_lib
from repro.models.transformer import init_caches_abstract, init_lm


# ---------------------------------------------------------------------------
# Abstract parameters / optimizer state
# ---------------------------------------------------------------------------


def abstract_params(cfg: LMConfig):
    if cfg.family == "audio":
        return jax.eval_shape(
            lambda: encdec_lib.init_encdec(cfg, jax.random.PRNGKey(0)))
    return jax.eval_shape(lambda: init_lm(cfg, jax.random.PRNGKey(0)))


def abstract_state(cfg: LMConfig, opt: OptimizerConfig):
    from repro.optim.optimizer import TrainState
    p = abstract_params(cfg)
    mdt = jnp.dtype(opt.moment_dtype)
    mom = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, mdt), p)
    return TrainState(step=jax.ShapeDtypeStruct((), jnp.int32), params=p,
                      m=mom, v=mom)


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs (path-based rules + divisibility guard)
# ---------------------------------------------------------------------------


def _guard(parts, shape, mesh: Mesh) -> P:
    out = []
    for dim, part in zip(shape, parts):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if not axes or dim % size != 0:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def _param_rule(path: str, ndim: int, fsdp) -> Tuple:
    """Returns per-dim mesh-axis parts for the TRAILING dims of the leaf."""
    m = "model"
    if path.endswith(("embed/table", "lm_head/table")):
        return (m, fsdp)
    if any(path.endswith(s) for s in ("wq/w", "wk/w", "wv/w")):
        return (fsdp, m)
    if path.endswith("wo/w") and "moe/" not in path.rsplit("wo/w")[0][-6:]:
        # attention out-proj and dense-mlp down-proj share layout
        pass
    if "moe/" in path and ndim == 3:
        if path.endswith(("wi", "wg")):
            return (m, fsdp, None)
        if path.endswith("wo"):
            return (m, None, fsdp)
    if path.endswith(("wi/w", "wg/w")):
        return (fsdp, m)
    if path.endswith("wo/w"):
        return (m, fsdp)
    if path.endswith("router/w"):
        return (fsdp, None)
    if path.endswith(("in_proj/w", "z_proj/w", "xbc_proj/w", "dt_proj/w")):
        return (fsdp, m)
    if path.endswith("out_proj/w"):
        return (m, fsdp)
    if path.endswith("conv_w"):
        return (m, None)
    return tuple(None for _ in range(ndim))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def param_pspecs(params_abstract, mesh: Mesh, attn_tp: bool = True):
    """PartitionSpecs for a param tree.

    ``attn_tp=False`` (head count doesn't divide the model axis): attention
    projections fall back to FSDP-only so activations can run
    context-parallel without per-layer resharding churn.
    """
    fsdp = fsdp_axes(mesh)
    fsdp = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)

    def rule(path, leaf):
        s = _path_str(path)
        shape = leaf.shape
        stacked = s.startswith("blocks/") or s.startswith(("enc/", "dec/"))
        trail = shape[1:] if stacked and len(shape) > 1 else shape
        parts = _param_rule(s, len(trail), fsdp)
        if not attn_tp:
            # sequence-parallel profile: rank-2 weights FSDP-only (experts
            # keep EP over model); embedding tables FSDP on the vocab dim.
            if s.endswith(("embed/table", "lm_head/table")):
                parts = (fsdp, None)
            elif len(trail) == 2 and not ("moe/" in s and len(trail) == 3):
                parts = (fsdp,) + tuple(None for _ in trail[1:])
        if len(parts) != len(trail):  # scalar-ish leaves
            parts = tuple(None for _ in trail)
        full = ((None,) + parts) if stacked and len(shape) > 1 else parts
        return _guard(full, shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, params_abstract)


def arch_attn_tp(cfg: LMConfig, mesh: Mesh) -> bool:
    a = cfg.attention
    tp = mesh.shape.get("model", 1)
    return a is None or a.num_heads % tp == 0


def state_pspecs(state_abstract, mesh: Mesh, attn_tp: bool = True):
    from repro.optim.optimizer import TrainState
    ps = param_pspecs(state_abstract.params, mesh, attn_tp)
    return TrainState(step=P(), params=ps, m=ps, v=ps)


def named(mesh: Mesh, tree):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Input specs per (arch, shape)
# ---------------------------------------------------------------------------

VLM_PATCH_TOKENS = 256


def _batch_part(mesh: Mesh, b: int):
    axes = fsdp_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if b % size == 0:
        return axes if len(axes) > 1 else axes[0]
    if "data" in mesh.axis_names and b % mesh.shape["data"] == 0:
        return "data"
    return None


def _seq_part_for_long(mesh: Mesh):
    return "data" if "data" in mesh.axis_names else None


def input_specs(cfg: LMConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model

    if cfg.family == "audio":
        frames = jax.ShapeDtypeStruct((b, min(s, 4096), d), dt)
        if shape.kind == "train":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if shape.kind == "prefill":
            return {"frames": frames,
                    "tokens": jax.ShapeDtypeStruct((b, s), i32)}
        caches = encdec_lib.init_dec_caches_abstract(cfg, b, s)
        return {"token": jax.ShapeDtypeStruct((b, 1), i32),
                "caches": caches,
                "memory": jax.ShapeDtypeStruct((b, min(s, 4096), d), dt),
                "length": jax.ShapeDtypeStruct((), i32)}

    embeds = None
    n_tok = s
    if cfg.frontend_stub:  # vlm: patch embeddings occupy the first positions
        embeds = jax.ShapeDtypeStruct((b, VLM_PATCH_TOKENS, d), dt)
        n_tok = s - VLM_PATCH_TOKENS

    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((b, n_tok), i32),
               "labels": jax.ShapeDtypeStruct((b, n_tok), i32)}
        if embeds is not None:
            out["embeds"] = embeds
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, n_tok), i32)}
        if embeds is not None:
            out["embeds"] = embeds
        return out
    # decode: one new token against a seq_len cache
    caches = init_caches_abstract(cfg, b, s)
    return {"token": jax.ShapeDtypeStruct((b, 1), i32),
            "caches": caches,
            "length": jax.ShapeDtypeStruct((), i32)}


def input_pspecs(cfg: LMConfig, shape: ShapeSpec, mesh: Mesh
                 ) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    bp = _batch_part(mesh, b)
    long_ctx = b == 1

    def tok_spec():
        if long_ctx:
            return P(None, _seq_part_for_long(mesh))
        return P(bp, None)

    specs = input_specs(cfg, shape)
    out: Dict[str, Any] = {}
    for k, v in specs.items():
        if k in ("tokens", "labels"):
            out[k] = tok_spec()
        elif k in ("embeds", "frames", "memory"):
            out[k] = P(bp, None, None)
        elif k == "token":
            out[k] = P(bp, None)
        elif k == "length":
            out[k] = P()
        elif k == "caches":
            out[k] = jax.tree.map(
                functools.partial(_cache_pspec, mesh=mesh,
                                  long_ctx=long_ctx, bp=bp), v)
    return out


def serve_out_pspecs(cfg: LMConfig, shape: ShapeSpec, mesh: Mesh):
    """Output PartitionSpecs for prefill/decode steps (logits, caches, ...).

    Without these, GSPMD materializes the returned KV caches sharded only
    over batch (25 GiB/device at deepseek prefill_32k); the cache must leave
    the step sharded exactly like the decode step expects it.
    """
    b, s = shape.global_batch, shape.seq_len
    bp = _batch_part(mesh, b)
    long_ctx = b == 1
    vp = "model" if cfg.padded_vocab % mesh.shape.get("model", 1) == 0 \
        else None
    logits = P(bp, None, vp)
    length = P()
    if cfg.family == "audio":
        caches = jax.tree.map(
            functools.partial(_cache_pspec, mesh=mesh, long_ctx=long_ctx,
                              bp=bp),
            encdec_lib.init_dec_caches_abstract(cfg, b, s))
        if shape.kind == "prefill":
            memory = P(bp, None, None)
            return (logits, caches, memory, length)
        return (logits, caches, length)
    caches = jax.tree.map(
        functools.partial(_cache_pspec, mesh=mesh, long_ctx=long_ctx, bp=bp),
        init_caches_abstract(cfg, b, s))
    if shape.kind == "prefill":
        return (logits, caches, length)
    return (logits, caches, length)


def _cache_pspec(leaf, *, mesh: Mesh, long_ctx: bool, bp):
    shape = leaf.shape
    if len(shape) == 5 and shape[-1] != 0 and shape[-2] >= 128:
        # KV cache (n_rep, B, Hkv, S, hd): seq -> model (+data when batch=1)
        seq = ("data", "model") if long_ctx else "model"
        seq = tuple(a for a in (seq if isinstance(seq, tuple) else (seq,))
                    if a in mesh.axis_names)
        size = int(np.prod([mesh.shape[a] for a in seq])) if seq else 1
        seq_part = (seq if len(seq) > 1 else seq[0]) if seq and \
            shape[3] % size == 0 else None
        return P(None, bp if not long_ctx else None, None, seq_part, None)
    if len(shape) == 5:
        # SSM state (n_rep, B, H, N, P): heads -> model
        h = shape[2]
        hp = "model" if h % mesh.shape["model"] == 0 else None
        return P(None, bp if not long_ctx else None, hp, None, None)
    if len(shape) == 4:
        # conv tail (n_rep, B, conv_dim, K-1)
        return P(None, bp if not long_ctx else None, None, None)
    return P(*(None,) * len(shape))
