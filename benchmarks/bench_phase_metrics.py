"""Paper Table 3 + Fig. 2/3: hybrid execution patterns per phase.

Characterizes Aggregation vs Combination (vs PageRank and MLP-MNIST
baselines) with architecture-neutral metrics:

  * bytes / FLOPs / arithmetic intensity + memory-vs-compute classification
    (Table 3's "Execution Bound" row),
  * bytes-per-op (Table 3's "DRAM Byte per Operation"),
  * LRU reuse-distance hit ratios at L2-like capacities (Fig. 2(g): the
    6.9% vs 56.2% L2 story, restated capacity-neutrally),
  * the atomic-collision model (Fig. 2(f): 1.1 vs 17.9 txn/request).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_graph, emit
from repro.core.characterize import MACHINE_BALANCE, phase_report
from repro.core.phases import aggregate_cost, combine_cost
from repro.graph.datasets import make_synthetic_graph
from repro.graph.reorder import atomic_collision_model, reuse_distance_stats
from repro.models.mlp import mlp_cost
from repro.models.pagerank import pagerank_cost


def run():
    spec = bench_graph("reddit", max_vertices=8192)
    g = make_synthetic_graph(spec)

    # --- Table 3: the hybrid pattern ---------------------------------------
    agg = aggregate_cost(g, feature_len=128)      # SAG post-combination
    comb = combine_cost(g.num_vertices, (602, 128))
    rep = phase_report(agg, comb)
    emit("table3/aggregation", 0.0,
         arithmetic_intensity=round(rep["aggregation"][
             "arithmetic_intensity"], 4),
         bytes_per_op=round(rep["aggregation"]["bytes_per_op"], 3),
         bound=rep["aggregation"]["bound"],
         bound_v5e=rep["aggregation"]["bound_v5e"],
         paper_reference="memory-bound, 2.35 B/op")
    emit("table3/combination", 0.0,
         arithmetic_intensity=round(rep["combination"][
             "arithmetic_intensity"], 2),
         bytes_per_op=round(rep["combination"]["bytes_per_op"], 4),
         bound=rep["combination"]["bound"],
         bound_v5e=rep["combination"]["bound_v5e"],
         paper_reference="compute-bound, 0.01 B/op",
         v5e_note="balance 240 F/B: lone 602x128 GEMM is memory-bound on "
                  "v5e -- fuse or widen (see fused_agg_combine)")

    # --- PageRank / MLP baselines ------------------------------------------
    pgr = pagerank_cost(g)
    emit("table3/pagerank", 0.0,
         arithmetic_intensity=round(pgr["arithmetic_intensity"], 4),
         bytes_per_op=round(1 / max(pgr["arithmetic_intensity"], 1e-9), 2))
    mlp = mlp_cost()
    emit("table3/mlp_mnist", 0.0,
         arithmetic_intensity=round(mlp["arithmetic_intensity"], 2),
         param_reuse=mlp["param_reuse"])

    # --- Fig 2(g): reuse distance (L2 hit-rate restatement) -----------------
    # A 6 MiB L2 holds ~1.5M scalar ranks (PGR) but only ~2.5K 602-float
    # rows.  The scaled graph preserves the BUDGET/|V| ratio of full Reddit
    # (2.6K rows / 233K vertices), so the hit-rate collapse reproduces.
    from repro.config import GRAPHS
    full_v = GRAPHS["reddit"].num_vertices
    scale = g.num_vertices / full_v
    stream = np.asarray(g.src)[:200_000]
    gcn_budget = max(4, int(6 * 2 ** 20 // (602 * 4) * scale))
    pgr_budget = min(int(6 * 2 ** 20 // 4 * scale), g.num_vertices)
    st = reuse_distance_stats(stream, budgets=(gcn_budget, pgr_budget))
    emit("fig2g/reuse_distance", 0.0,
         gcn_hit_ratio=round(st[f"hit_ratio@{gcn_budget}"], 3),
         pgr_hit_ratio=round(st[f"hit_ratio@{pgr_budget}"], 3),
         gcn_rows_budget=gcn_budget, pgr_rows_budget=pgr_budget,
         mean_reuse_distance=round(st["mean_reuse_distance"], 1),
         paper_reference="6.9% vs 56.2%")

    # --- Fig 2(f): atomic collisions ----------------------------------------
    dst = np.asarray(g.dst)
    gcn_c = atomic_collision_model(dst, feature_len=602)
    pgr_c = atomic_collision_model(dst, feature_len=1)
    emit("fig2f/atomic_collisions", 0.0,
         gcn_txn_per_request=round(gcn_c["atomic_txn_per_request"], 2),
         pgr_txn_per_request=round(pgr_c["atomic_txn_per_request"], 2),
         paper_reference="1.1 vs 17.9",
         tpu_note="sorted-segment layout eliminates the hazard entirely")


if __name__ == "__main__":
    run()
