"""The hypothesis STUB's own behavioral contract (tests/_hypothesis_stub.py).

The property suites (test_kernels.py, test_phases.py, test_dtype.py) claim
coverage properties -- "endpoints always exercised", "every sampled element
seen", "deterministic replay" -- that hold only if the stub delivers them.
This file tests the stub module DIRECTLY (loaded from its path, bypassing
conftest's real-hypothesis preference), so the contract is pinned even on
machines where real hypothesis shadows the stub.
"""

import importlib.util
from pathlib import Path

import numpy as np
import pytest


@pytest.fixture(scope="module")
def stub():
    spec = importlib.util.spec_from_file_location(
        "_hypothesis_stub_under_test",
        Path(__file__).parent / "_hypothesis_stub.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_integers_endpoints_first(stub):
    s = stub.strategies.integers(3, 9)
    d = s.draws(np.random.default_rng(0), 10)
    assert d[:2] == [3, 9]
    assert len(d) == 10 and all(3 <= v <= 9 for v in d)
    # degenerate range collapses to the single point
    assert stub.strategies.integers(5, 5).draws(
        np.random.default_rng(0), 3) == [5, 5, 5]


def test_floats_endpoints_first_and_bounded(stub):
    s = stub.strategies.floats(-1.5, 2.5)
    d = s.draws(np.random.default_rng(0), 12)
    assert d[:2] == [-1.5, 2.5]
    assert all(isinstance(v, float) and -1.5 <= v <= 2.5 for v in d)
    # hypothesis-style kwargs are accepted (and ignored) by the stub
    stub.strategies.floats(0.0, 1.0, allow_nan=False, allow_infinity=False,
                           width=32)


def test_sampled_from_cycles_whole_vocabulary(stub):
    els = ["f32", "bf16", "int8-agg"]
    s = stub.strategies.sampled_from(els)
    d = s.draws(np.random.default_rng(0), 8)
    assert d[:3] == els          # every element before any repeat
    assert all(v in els for v in d)
    with pytest.raises(AssertionError):
        stub.strategies.sampled_from([])


def test_draws_are_deterministic(stub):
    for make in (lambda st: st.integers(0, 100),
                 lambda st: st.floats(0.0, 1.0),
                 lambda st: st.sampled_from("abcde")):
        a = make(stub.strategies).draws(np.random.default_rng(0), 20)
        b = make(stub.strategies).draws(np.random.default_rng(0), 20)
        assert a == b


def test_composite_builder_and_endpoint_indexing(stub):
    st = stub.strategies

    @st.composite
    def pair(draw, scale):
        n = draw(st.integers(1, 4))
        f = draw(st.floats(0.0, 1.0))
        return (n * scale, f)

    d = pair(10).draws(np.random.default_rng(0), 6)
    assert len(d) == 6
    # example 0 sees each inner strategy's first draw-column entries:
    # integers(1,4) column starts [1, 4, ...]; the second draw within the
    # example advances one position in the floats column [0.0, 1.0, ...]
    assert d[0] == (10, 1.0)
    assert all(n in (10, 20, 30, 40) and 0.0 <= f <= 1.0 for n, f in d)


def test_given_runs_max_examples_with_composite(stub):
    st = stub.strategies

    @st.composite
    def vec(draw):
        n = draw(st.integers(1, 3))
        return [draw(st.floats(-1.0, 1.0)) for _ in range(n)]

    seen = []

    @stub.given(vec(), st.sampled_from(["a", "b"]))
    @stub.settings(max_examples=7, deadline=None)
    def prop(v, tag):
        assert isinstance(v, list) and 1 <= len(v) <= 3
        assert tag in ("a", "b")
        seen.append((tuple(v), tag))

    prop()          # the runner pytest would invoke
    assert len(seen) == 7
    assert {t for _, t in seen} == {"a", "b"}   # vocabulary fully cycled


def test_given_replay_is_deterministic(stub):
    st = stub.strategies
    runs = []
    for _ in range(2):
        got = []

        @stub.given(st.integers(0, 50), st.floats(0.0, 5.0))
        @stub.settings(max_examples=9, deadline=None)
        def prop(i, f):
            got.append((i, f))

        prop()
        runs.append(got)
    assert runs[0] == runs[1]
