"""GQA attention: projections, chunked (flash-style) XLA path, decode path.

Three execution paths, selected by the model layer:

  * ``direct``      -- materialize (Sq, Sk) scores; small sequences/tests.
  * ``xla_chunked`` -- double-blocked online softmax (lax.map over q chunks,
    lax.scan over kv chunks).  O(chunk^2) live memory; this is what the
    32k-prefill dry-runs lower, keeping peak activation memory in bounds.
    Mirrors the Pallas flash kernel tile-for-tile so the TPU kernel can be
    swapped in (``impl="pallas"``) without touching the model.
  * ``decode``      -- one new token against a padded KV cache (kv_len marks
    validity); pure memory-bound cache sweep.

All paths support GQA grouping WITHOUT materializing repeated K/V (einsum
over a (B, Hkv, G, ...) view) -- with KV sharded over the model axis this
keeps the cache read local.  Causal masking uses decode-style right
alignment (see kernels/flash_attention.py).
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import AttentionConfig
from repro.launch.sharding import constrain
from repro.nn.layers import apply_rope, init_dense, softcap

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jnp.ndarray       # (B, Hkv, Smax, D)
    v: jnp.ndarray       # (B, Hkv, Smax, D)
    length: jnp.ndarray  # () int32 -- valid entries (uniform across batch)


def init_attention(key, d_model: int, cfg: AttentionConfig,
                   dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], d_model, cfg.q_dim, dtype),
        "wk": init_dense(ks[1], d_model, cfg.kv_dim, dtype),
        "wv": init_dense(ks[2], d_model, cfg.kv_dim, dtype),
        "wo": init_dense(ks[3], cfg.q_dim, d_model, dtype,
                         scale=cfg.q_dim ** -0.5),
    }


def _project(params, x, cfg: AttentionConfig, positions):
    """x: (B, S, D) -> q (B,Hq,S,hd), k/v (B,Hkv,S,hd), rope applied."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,df->bsf", x, params["wq"]["w"].astype(x.dtype))
    k = jnp.einsum("bsd,df->bsf", x, params["wk"]["w"].astype(x.dtype))
    v = jnp.einsum("bsd,df->bsf", x, params["wv"]["w"].astype(x.dtype))
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # TP layout: heads over `model` where divisible; otherwise the rules
    # remap to context-parallel (q sequence over `model`, KV replicated).
    q = constrain(q, "batch", "heads", "seq_q", None)
    k = constrain(k, "batch", "kv_heads", None, None)
    v = constrain(v, "batch", "kv_heads", None, None)
    return q, k, v


def _grouped(q, hkv):
    b, hq, s, d = q.shape
    return q.reshape(b, hkv, hq // hkv, s, d)


def direct_attention(q, k, v, *, causal: bool, window: int, cap: float,
                     kv_len=None) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    qg = _grouped(q, hkv).astype(jnp.float32) * d ** -0.5
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    s = softcap(s, cap)
    kvl = jnp.asarray(sk if kv_len is None else kv_len, jnp.int32)
    qpos = jnp.arange(sq) + (kvl - sq)
    kpos = jnp.arange(sk)
    m = kpos[None, :] < kvl
    if causal:
        m = m & (kpos[None, :] <= qpos[:, None])
    if window > 0:
        m = m & (kpos[None, :] > qpos[:, None] - window)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "q_chunk", "kv_chunk"))
def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      cap: float = 0.0, q_chunk: int = 2048,
                      kv_chunk: int = 1024) -> jnp.ndarray:
    """Blockwise online-softmax attention; O(q_chunk*kv_chunk) live scores."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    assert sq % q_chunk == 0 and sk % kv_chunk == 0, (sq, q_chunk, sk, kv_chunk)
    nq, nk = sq // q_chunk, sk // kv_chunk
    scale = d ** -0.5
    q_off = sk - sq

    qs = q.reshape(b, hkv, g, nq, q_chunk, d).astype(jnp.float32) * scale
    ks = k.reshape(b, hkv, nk, kv_chunk, d).astype(jnp.float32)
    vs = v.reshape(b, hkv, nk, kv_chunk, d).astype(jnp.float32)

    def per_q_chunk(qi):
        qc = qs[:, :, :, qi]                             # (b,hkv,g,qc,d)
        qpos = q_off + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kc = jax.lax.dynamic_index_in_dim(ks, ki, 2, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vs, ki, 2, keepdims=False)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc)
            s = softcap(s, cap)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            m = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                m &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                m &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(m[None, None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m_run, m_cur)
            m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
            p = jnp.exp(s - m_safe)
            p = jnp.where(m[None, None, None], p, 0.0)
            alpha = jnp.exp(jnp.where(m_run <= NEG_INF / 2, NEG_INF,
                                      m_run - m_safe))
            l_new = l_run * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vc)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, q_chunk, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk))
        return acc / jnp.where(l_f == 0.0, 1.0, l_f)

    out = jax.lax.map(per_q_chunk, jnp.arange(nq))       # (nq,b,hkv,g,qc,d)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, sq, d)
    return out.astype(q.dtype)


def flash_attention_xla(q, k, v, *, causal: bool = True, window: int = 0,
                        cap: float = 0.0, q_chunk: int = 2048,
                        kv_chunk: int = 1024) -> jnp.ndarray:
    """custom-VJP flash attention (nn/flash_vjp.py) on (B,Hq,S,D) layout.

    Under a context-parallel sharding profile (see sharding.rules_for) the
    kernel runs inside shard_map: each `model` shard owns a contiguous slab
    of query positions and attends to the full (replicated) KV.  Chunked
    scans then slice LOCAL arrays only -- GSPMD never sees a dynamic slice
    across a sharded dim (which it would resolve with full gathers).
    """
    from repro.launch.sharding import ctx_parallel_info
    from repro.nn.flash_vjp import flash_mha
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    qg = _grouped(q, hkv) * (d ** -0.5)

    info = ctx_parallel_info()
    if info is not None and sq % info.tp == 0 and (sq // info.tp) >= 128:
        mesh, tp, batch_axes = info.mesh, info.tp, info.batch
        local_sq = sq // tp
        qc = min(q_chunk, local_sq)
        kc = min(kv_chunk, sk)

        def local_attn(qg_l, k_l, v_l):
            idx = jax.lax.axis_index("model").astype(jnp.float32)
            q_start = (sk - sq) + idx * local_sq
            return flash_mha(qg_l, k_l, v_l, q_start, causal, window, cap,
                             qc, kc)

        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        bp = batch_axes if batch_axes else None
        out = shard_map(
            local_attn, mesh=mesh,
            in_specs=(P(bp, None, None, "model", None),
                      P(bp, None, None, None),
                      P(bp, None, None, None)),
            out_specs=P(bp, None, None, "model", None),
            check_rep=False)(qg, k, v)
        return out.reshape(b, hq, sq, d)

    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    # cap the live tile footprint (b * heads * qc * kc): many-KV-head archs
    # (MHA kv=16) would otherwise hold multi-GiB recompute tiles
    while b * hq * qc * kc > (1 << 27) and (qc > 256 or kc > 256):
        if qc >= kc and qc > 256:
            qc //= 2
        elif kc > 256:
            kc //= 2
        else:
            break
    while sq % qc != 0 and qc > 1:
        qc //= 2
    while sk % kc != 0 and kc > 1:
        kc //= 2
    assert sq % qc == 0 and sk % kc == 0, (sq, qc, sk, kc)
    out = flash_mha(qg, k, v, jnp.float32(sk - sq), causal, window, cap,
                    qc, kc)
    return out.reshape(b, hq, sq, d)


def decode_attention(q, cache: KVCache, *, causal: bool = True,
                     window: int = 0, cap: float = 0.0) -> jnp.ndarray:
    """q: (B, Hq, 1, D) against the padded cache; returns (B, Hq, 1, D).

    ``cache.length`` is () for a uniform batch (dry-run decode cells) or
    (B,) for per-slot lengths (serving engine continuous batching).
    """
    b, hq, _, d = q.shape
    hkv, smax = cache.k.shape[1], cache.k.shape[2]
    qg = _grouped(q, hkv).astype(jnp.float32) * d ** -0.5
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, cache.k.astype(jnp.float32))
    s = softcap(s, cap)
    kpos = jnp.arange(smax)
    length = jnp.broadcast_to(cache.length, (b,))
    m = kpos[None, :] < length[:, None]                      # (B, Smax)
    if window > 0:
        m = m & (kpos[None, :] > (length[:, None] - 1 - window))
    s = jnp.where(m[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, cache.v.astype(jnp.float32))
    return o.reshape(b, hq, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block (project -> attend -> out-project), cache-aware
# ---------------------------------------------------------------------------


def attention_block(params: Dict, x: jnp.ndarray, cfg: AttentionConfig, *,
                    layer_window: int = 0, cache: Optional[KVCache] = None,
                    make_cache: bool = False, cache_size: int = 0,
                    impl: str = "auto",
                    ) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Returns (output (B,S,D), new/updated cache or None).

    Modes:
      * train/eval:   cache=None, make_cache=False.
      * prefill:      cache=None, make_cache=True, cache_size=Smax.
      * decode:       cache=KVCache, S must be 1; cache is updated in place
                      (functionally) at position cache.length.
    """
    b, s, _ = x.shape
    decode = cache is not None
    if decode:
        if jnp.ndim(cache.length) == 0:
            positions = (cache.length + jnp.arange(s))[None, :]
        else:  # per-slot lengths: (B,) -> (B, 1) position of the new token
            positions = cache.length[:, None] + jnp.arange(s)[None, :]
            positions = positions[:, None, :]  # broadcast over heads
    else:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project(params, x, cfg, positions)

    new_cache = None
    if decode:
        assert s == 1, "decode path is single-token"
        if jnp.ndim(cache.length) == 0:
            pos = cache.length
            k_full = jax.lax.dynamic_update_slice_in_dim(cache.k, k, pos,
                                                         axis=2)
            v_full = jax.lax.dynamic_update_slice_in_dim(cache.v, v, pos,
                                                         axis=2)
        else:  # scatter each slot's row at its own position
            bidx = jnp.arange(b)
            k_full = cache.k.at[bidx, :, cache.length].set(k[:, :, 0])
            v_full = cache.v.at[bidx, :, cache.length].set(v[:, :, 0])
        new_cache = KVCache(k_full, v_full, cache.length + 1)
        o = decode_attention(q, KVCache(k_full, v_full, cache.length + 1),
                             window=layer_window,
                             cap=cfg.attn_logit_softcap)
    else:
        if impl == "pallas":
            from repro.kernels import ops as kops
            o = kops.flash_attention(q, k, v, causal=cfg.causal,
                                     window=layer_window,
                                     softcap=cfg.attn_logit_softcap)
        elif s <= 2048 or impl == "direct":
            o = direct_attention(q, k, v, causal=cfg.causal,
                                 window=layer_window,
                                 cap=cfg.attn_logit_softcap)
        else:
            # flash path with custom VJP: O(chunk^2) memory fwd AND bwd
            o = flash_attention_xla(q, k, v, causal=cfg.causal,
                                    window=layer_window,
                                    cap=cfg.attn_logit_softcap)
        if make_cache:
            assert cache_size >= s
            pad = ((0, 0), (0, 0), (0, cache_size - s), (0, 0))
            new_cache = KVCache(jnp.pad(k, pad), jnp.pad(v, pad),
                                jnp.asarray(s, jnp.int32))

    b_, hq, s_, d_ = q.shape
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * d_)
    out = jnp.einsum("bsf,fd->bsd", o, params["wo"]["w"].astype(o.dtype))
    return out, new_cache


def cross_attention_block(params: Dict, x: jnp.ndarray, memory: jnp.ndarray,
                          cfg: AttentionConfig) -> jnp.ndarray:
    """Encoder-decoder cross attention (no rope, no causal mask)."""
    b, s, _ = x.shape
    _, sm, _ = memory.shape
    q = jnp.einsum("bsd,df->bsf", x, params["wq"]["w"].astype(x.dtype))
    k = jnp.einsum("bsd,df->bsf", memory, params["wk"]["w"].astype(x.dtype))
    v = jnp.einsum("bsd,df->bsf", memory, params["wv"]["w"].astype(x.dtype))
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, sm, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, sm, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    if s <= 2048 and sm <= 2048:
        o = direct_attention(q, k, v, causal=False, window=0, cap=0.0)
    else:  # flash path: O(S*Sm) scores never materialize (custom VJP)
        o = flash_attention_xla(q, k, v, causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
    return jnp.einsum("bsf,fd->bsd", o, params["wo"]["w"].astype(o.dtype))
