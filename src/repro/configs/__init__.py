"""Assigned-architecture registry: importing this package registers all archs.

Each module defines the EXACT published config from the assignment table plus
a ``reduced()`` smoke-test variant (same family, tiny dims).
"""

from repro.configs import (arctic_480b, deepseek_67b, gemma2_9b, gemma_7b,
                           granite_3_8b, internvl2_1b, jamba_1_5_large,
                           kimi_k2, mamba2_2_7b, seamless_m4t_medium)

ASSIGNED_ARCHS = (
    "kimi-k2-1t-a32b",
    "arctic-480b",
    "deepseek-67b",
    "gemma2-9b",
    "gemma-7b",
    "granite-3-8b",
    "jamba-1.5-large-398b",
    "internvl2-1b",
    "seamless-m4t-medium",
    "mamba2-2.7b",
)
