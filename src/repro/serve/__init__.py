"""repro.serve: the inference-serving layer.

One slot-based continuous-batching core (``SlotServeCore``) with two
engines on top: ``ServeEngine`` (LM decode over a static KV cache) and
``GraphServeEngine`` (GCN node prediction through bucketed compiled
plans -- sample, pad into a shape bucket, replay the bucket's single
``plan.compile(dynamic=True)`` callable).  See docs/serving.md.
"""

from repro.serve.core import SlotServeCore
from repro.serve.engine import Request, ServeEngine
from repro.serve.graph_engine import (Bucket, GraphRequest, GraphServeEngine,
                                      default_buckets)

__all__ = [
    "SlotServeCore", "ServeEngine", "Request",
    "GraphServeEngine", "GraphRequest", "Bucket", "default_buckets",
]
