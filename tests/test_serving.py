"""Serving: prefill/decode consistency, engine continuous batching."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (gemma2_9b, granite_3_8b, jamba_1_5_large,
                           kimi_k2, mamba2_2_7b, seamless_m4t_medium)
from repro.models import encdec
from repro.models.transformer import (init_lm, lm_decode_step, lm_forward,
                                      lm_prefill)
from repro.serve.engine import Request, ServeEngine


def _fp32(mod, cap=8.0):
    cfg = dataclasses.replace(mod.reduced(), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cap))
    return cfg


@pytest.mark.parametrize("mod", [granite_3_8b, gemma2_9b, kimi_k2,
                                 jamba_1_5_large, mamba2_2_7b])
def test_decode_matches_full_forward(mod):
    cfg = _fp32(mod)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full, _ = lm_forward(params, cfg, toks)
    lg, caches, length = lm_prefill(params, cfg, toks[:, :S - 1],
                                    cache_size=S + 4)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, -2]), rtol=1e-3, atol=1e-3)
    lg2, caches, length = lm_decode_step(params, cfg, toks[:, S - 1:S],
                                         caches, length)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(full[:, -1]), rtol=1e-3, atol=1e-3)


def test_decode_multi_step_consistency():
    cfg = _fp32(granite_3_8b)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 24), 0,
                              cfg.vocab_size)
    full, _ = lm_forward(params, cfg, toks)
    lg, caches, length = lm_prefill(params, cfg, toks[:, :16],
                                    cache_size=32)
    for t in range(16, 24):
        lg, caches, length = lm_decode_step(params, cfg, toks[:, t:t + 1],
                                            caches, length)
        np.testing.assert_allclose(np.asarray(lg[0, 0]),
                                   np.asarray(full[0, t]), rtol=1e-3,
                                   atol=1e-3)


def test_encdec_decode_consistency():
    cfg = _fp32(seamless_m4t_medium)
    p = encdec.init_encdec(cfg, jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    memory = encdec.encode(p, cfg, frames)
    full, _ = encdec.decode_stack(p, cfg, toks, memory)
    lg, caches, mem, length = encdec.encdec_prefill(p, cfg, frames,
                                                    toks[:, :11],
                                                    cache_size=16)
    lg2, caches, length = encdec.encdec_decode_step(p, cfg, toks[:, 11:12],
                                                    caches, mem, length)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(full[:, -1]), rtol=1e-3, atol=1e-3)


@pytest.fixture(scope="module")
def engine_setup():
    cfg = _fp32(granite_3_8b)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_greedy_matches_naive(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, max_batch=2, cache_size=48)
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                    max_tokens=6) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 3
    for r in done:
        toks = list(r.prompt)
        for _ in range(r.max_tokens):
            logits, _ = lm_forward(params, cfg,
                                   jnp.asarray([toks], jnp.int32))
            toks.append(int(np.asarray(logits)[0, -1].argmax()))
        assert toks[len(r.prompt):] == r.output[:r.max_tokens]


def test_engine_continuous_batching_slot_reuse(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, max_batch=2, cache_size=64)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=np.arange(3) % cfg.vocab_size,
                           max_tokens=3 + i))
    done = eng.run()
    assert len(done) == 5
    assert {r.rid for r in done} == set(range(5))
    # slots were reused: max concurrent = 2 but 5 requests served
    assert eng.stats()["decode_steps"] < sum(3 + i for i in range(5))


def test_engine_eos_stop(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, max_batch=1, cache_size=64)
    # find the greedy first token, then use it as EOS: generation stops at 1
    eng.submit(Request(rid=0, prompt=np.arange(4), max_tokens=32))
    done = eng.run()
    first = done[0].output[0]
    eng2 = ServeEngine(cfg, params, max_batch=1, cache_size=64)
    eng2.submit(Request(rid=1, prompt=np.arange(4), max_tokens=32,
                        eos_id=first))
    done2 = eng2.run()
    assert len(done2[0].output) == 1
