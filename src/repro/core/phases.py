"""Aggregation and Combination as first-class composable phases (paper F1).

The paper decomposes every GCN layer into:

  * **Aggregation**  -- per-vertex reduce over in-neighbor feature rows
    (irregular gather + segmented reduction; memory-bound).
  * **Combination**  -- dense transform of per-vertex features by an MLP
    (GEMM; compute-bound).

Both are exposed here as pure functions over a destination-sorted ``Graph``.
Aggregation is implemented as a *sorted segmented sum*: collision-free (the
logical endpoint of the paper's "only inter-warp collisions / vectorize
atomics" analysis -- see DESIGN.md §2) and expressible either as
``jax.ops.segment_sum`` (XLA path) or via the Pallas ``seg_agg`` kernel.

The backward pass of Aggregation is Aggregation on the transpose graph; JAX
derives it automatically from this formulation (gather/scatter-add adjoints),
so training inherits the paper's phase structure for free.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.backend import is_pallas, resolve_backend
from repro.graph.structure import Graph

AGGREGATORS = ("sum", "mean", "max")


def _mm(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Matmul that accumulates f32 for reduced-precision operands.

    f32 x f32 stays the plain ``@`` (bitwise-identical to the pre-dtype
    path -- the guard is what keeps f32 plans golden); anything narrower
    (bf16 plan operands) runs with ``preferred_element_type=float32`` so
    the MXU/tensor-core accumulator is full precision.
    """
    if a.dtype == jnp.float32 and b.dtype == jnp.float32:
        return a @ b
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def quantize_int8(x: jnp.ndarray) -> jnp.ndarray:
    """Per-row symmetric int8 fake-quantization of an aggregation operand.

    Each row is scaled by ``max|row| / 127`` (zero rows get scale 1),
    rounded to the int8 grid, and returned dequantized in f32 -- every
    value is exactly int8-representable times its row scale, which is what
    a real int8 gather + f32 accumulate + dequant pipeline computes, while
    staying a pure traceable f32 computation on this container.  The plan
    dtype ``"int8-agg"`` applies this ONLY to the aggregation input; the
    1-byte wire/HBM width is priced analytically (``profile.machine``).
    """
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127.0, 127.0)
    return q * scale


# ---------------------------------------------------------------------------
# Aggregation phase
# ---------------------------------------------------------------------------


def aggregate(g: Graph, x: jnp.ndarray, op: str = "mean",
              edge_weight: Optional[jnp.ndarray] = None,
              edge_mask: Optional[jnp.ndarray] = None,
              include_self: bool = True,
              backend: Optional[str] = None,
              layout=None, dedup=None) -> jnp.ndarray:
    """h_v = reduce_{u in N(v) (+ v)} x_u              (paper Eq. 1/2 inner term)

    Args:
      g: destination-sorted graph.
      x: (V, F) vertex features.
      op: "sum" | "mean" | "max".  mean divides by |N(v)|+1 (paper's GCN/SAG),
        matching ``mean({N(v)} ∪ {v})``.
      edge_weight: optional (E,) per-edge scalar (e.g. sym-norm GCN weights).
      edge_mask: optional (E,) 1/0 mask for padded edge lists.
      include_self: add the vertex's own row to the reduction.
      backend: "xla" (segment_sum) or a Pallas tier ("pallas-tpu" |
        "pallas-gpu"; legacy "pallas" = platform's native tier); None = xla.
        Normally resolved by the execution planner (core/plan.py).
      layout: plan-owned ``core.dataflow.BlockedGraph`` for the Pallas
        tiers.  With a layout the Pallas dispatch is TRACE-PURE
        (``kernels.ops.seg_agg_planned``: the O(E) regrouping was done once
        at plan-build time); without one, one-off Pallas calls fall back to
        the slow ad-hoc ``kernels.ops.seg_agg``, which regroups on the host
        per call and cannot run under jit.  Plans always pass it
        (``LayerPlan.agg_layout``).
      dedup: plan-owned ``graph.dedup.DedupLayout`` two-level layout.
        When given (sum/mean, unweighted/unmasked only — the planner
        guarantees this), aggregation runs redundancy-eliminated: level 1
        computes each matched pair's partial sum once, level 2 segment-sums
        the shortened edge list over ``[x ; partials]``.  The f32 result is
        bitwise-identical to the naive fold (see graph/dedup.py).
    """
    assert op in AGGREGATORS, op
    v, f = x.shape
    w = None
    if edge_weight is not None:
        w = edge_weight
    if edge_mask is not None:
        w = edge_mask if w is None else w * edge_mask

    use_pallas = backend is not None and is_pallas(backend)

    if dedup is not None and dedup.num_pairs > 0 and op in ("sum", "mean") \
            and w is None:
        # Two-level redundancy-eliminated path (graph/dedup.py).  Cast the
        # operand to f32 FIRST (exact for bf16/int8-agg inputs) so the pair
        # partials are the same f32 adds the naive fold's accumulator does.
        xf = x if x.dtype == jnp.float32 else x.astype(jnp.float32)
        partials = jnp.take(xf, dedup.pair_left, axis=0) + \
            jnp.take(xf, dedup.pair_right, axis=0)
        xp = jnp.concatenate([xf, partials], axis=0)
        if use_pallas and dedup.blocked is not None:
            from repro.kernels import ops as kops
            summed = kops.seg_agg_planned(dedup.blocked, xp, None,
                                          backend=resolve_backend(backend))
        else:
            gathered2 = jnp.take(xp, dedup.src2, axis=0)
            summed = jax.ops.segment_sum(gathered2, dedup.dst2,
                                         num_segments=v)
        if include_self:
            summed = summed + x
        if op == "mean":
            denom = g.in_deg.astype(summed.dtype) + \
                (1.0 if include_self else 0.0)
            summed = summed * (1.0 / jnp.maximum(denom, 1.0))[:, None]
        return summed
    if op == "max" or not use_pallas:
        gathered = jnp.take(x, g.src, axis=0)  # (E, F) -- indexSelect kernel

    if op == "max":
        if w is not None:
            gathered = jnp.where((w > 0)[:, None], gathered, -jnp.inf)
        out = jax.ops.segment_max(gathered, g.dst, num_segments=v)
        self_term = x if include_self else jnp.full_like(x, -jnp.inf)
        out = jnp.maximum(out, self_term)
        return jnp.where(jnp.isfinite(out), out, 0.0)

    if use_pallas:
        from repro.kernels import ops as kops
        if layout is not None:
            summed = kops.seg_agg_planned(layout, x, w,
                                          backend=resolve_backend(backend))
        else:
            gathered = jnp.take(x, g.src, axis=0)
            if w is not None:
                gathered = gathered * w[:, None].astype(gathered.dtype)
            summed = kops.seg_agg(gathered, g.dst, v,
                                  backend=resolve_backend(backend))
    else:
        if w is not None:
            gathered = gathered * w[:, None].astype(gathered.dtype)
        if gathered.dtype != jnp.float32:
            # reduced-precision plan operand (bf16): the segmented reduce
            # must still accumulate f32 -- the plan rounds the phase
            # OUTPUT back down, never the accumulator.  f32 inputs skip
            # the cast entirely (bitwise-golden default path).
            gathered = gathered.astype(jnp.float32)
        summed = jax.ops.segment_sum(gathered, g.dst, num_segments=v)

    if include_self:
        summed = summed + x
    if op == "mean":
        denom = g.in_deg.astype(summed.dtype) + \
            (1.0 if include_self else 0.0)
        # reciprocal-multiply, not broadcast division: XLA's jitted fusion
        # rewrites (V,F)/(V,1) division non-bitwise-reproducibly vs eager;
        # the (V,1) reciprocal + multiply is identical in both, which is
        # what keeps plan.compile() bit-for-bit equal to the eager path
        summed = summed * (1.0 / jnp.maximum(denom, 1.0))[:, None]
    return summed


def aggregate_cost(g: Graph, feature_len: int, dtype_bytes: int = 4,
                   include_self: bool = True) -> dict:
    """Analytic data-access/computation counts for the Aggregation phase.

    Reproduces the accounting behind paper Table 4: bytes = read one feature
    row per edge + write one row per vertex (+ self reads); ops = one add per
    element per edge.  Independent of the *input* feature length when run
    after Combination -- the paper's Fig.5 observation.
    """
    e, v = g.num_edges, g.num_vertices
    reads = (e + (v if include_self else 0)) * feature_len * dtype_bytes
    writes = v * feature_len * dtype_bytes
    index_reads = e * 8  # src+dst ids
    flops = (e + (v if include_self else 0)) * feature_len
    return {"bytes": reads + writes + index_reads, "flops": flops,
            "gathered_rows": e, "arithmetic_intensity":
            flops / max(1, reads + writes + index_reads)}


# ---------------------------------------------------------------------------
# Combination phase
# ---------------------------------------------------------------------------


def combine(x: jnp.ndarray, weights, activation: Optional[str] = "relu",
            final_activation: bool = False) -> jnp.ndarray:
    """Dense per-vertex MLP (the sgemm kernels in paper Fig. 1).

    ``weights`` is a list of (W, b) tuples -- one entry for GCN/SAG
    (|h|->128), two for GIN (|h|->128->128), matching paper Table 1.
    """
    h = x
    n = len(weights)
    for i, (wmat, b) in enumerate(weights):
        h = _mm(h, wmat)  # f32-accumulating for reduced-precision operands
        if b is not None:
            h = h + b
        if activation and (i < n - 1 or final_activation):
            h = _act(activation)(h)
    return h


def _act(name: str):
    return {"relu": jax.nn.relu, "gelu": jax.nn.gelu, "tanh": jnp.tanh,
            "none": lambda x: x}[name]


def combine_cost(num_vertices: int, dims, dtype_bytes: int = 4) -> dict:
    """Analytic GEMM cost: 2*V*in*out flops per matmul; bytes for X, W, Y."""
    flops = 0
    byt = 0
    for din, dout in zip(dims[:-1], dims[1:]):
        flops += 2 * num_vertices * din * dout
        byt += (num_vertices * din + din * dout + num_vertices * dout) * dtype_bytes
    return {"bytes": byt, "flops": flops,
            "arithmetic_intensity": flops / max(1, byt)}


# ---------------------------------------------------------------------------
# A full phase-ordered layer (paper F2)
# ---------------------------------------------------------------------------


def phase_ordered_layer(g: Graph, x: jnp.ndarray, weights, *,
                        order: Optional[str] = None, agg_op: str = "mean",
                        edge_weight=None, activation: str = "relu",
                        plan=None) -> jnp.ndarray:
    """One graph-conv layer with explicit (or planned) phase ordering.

    ``order`` = "combine_first" (GCN/SAG style; shrinks the feature length the
    sparse phase must move -- Table 4's 4.7x) or "aggregate_first" (GIN
    semantics); None lets the planner's cost model choose.  For *linear*
    combination + sum/mean aggregation the two orderings are mathematically
    equivalent; the framework exploits that to reorder GCN/SAG for
    performance while GIN (MLP with interior nonlinearity) is pinned to
    aggregate_first to preserve semantics.

    Dispatches through a ``GraphExecutionPlan`` (built and cached per
    (graph, dims, order, agg_op) when ``plan`` is not given), so backend and
    fusion decisions live in ONE place (core/plan.py).
    """
    assert order in ("combine_first", "aggregate_first", None), order
    if plan is None:
        from repro.core.plan import plan_for_phases
        plan = plan_for_phases(g, weights, order=order, agg_op=agg_op)
    return plan.run_phases(x, weights, edge_weight=edge_weight,
                           activation=activation)
