"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts ``while``-loop bodies ONCE,
regardless of trip count (verified empirically: a scan of N matmuls reports
the flops of one).  Every layer stack in this framework is a ``lax.scan``, so
the built-in counter under-reports by ~num_layers x.  This module re-derives
FLOPs / bytes-accessed / collective-bytes by walking the HLO module:

  * computations are parsed into symbol tables (name -> shape),
  * ``dot``/``convolution`` FLOPs use the standard 2*elems(out)*K convention,
  * fusions recurse into their called computation for FLOPs and count their
    own operands/results for bytes (the fused-execution byte model),
  * ``while`` multiplies body cost by the trip count extracted from the
    condition computation (jax scans emit ``compare(counter, constant(N))``),
  * collectives are priced by result-shape bytes, x enclosing trip counts.

Validated against known-size programs in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_elems(dims: str) -> int:
    if not dims.strip():
        return 1
    return int(np.prod([int(d) for d in dims.split(",") if d]))


def _parse_shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shapes_bytes(text: str) -> int:
    return sum(_DTYPE_BYTES[dt] * int(np.prod(dims)) if dims else
               _DTYPE_BYTES[dt] for dt, dims in _parse_shapes(text))


@dataclass
class Instruction:
    name: str
    result_text: str       # shape text between '=' and opcode
    opcode: str
    operands: List[str]
    attrs: str              # trailing attribute text
    line: str


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> shape text


_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if cur is None:
            m = _COMP_HEAD.match(line)
            if m and line.endswith("{"):
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = m.group(2)
                continue
        else:
            if line == "}":
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR.match(line)
            if not m:
                continue
            name, result, opcode, rest = m.groups()
            depth, end = 1, len(rest)
            for i, ch in enumerate(rest):
                depth += (ch == "(") - (ch == ")")
                if depth == 0:
                    end = i
                    break
            operand_text = rest[:end]
            attrs = rest[end + 1:]
            # Older XLA prints operands with inline shapes
            # ("f32[512,1024]{1,0} %Arg_0.1"); newer prints bare names.
            # Take the last token as the name and harvest the inline shape
            # into the symbol table (covers entry params too).
            operands = []
            for o in _split_top(operand_text):
                if " " in o:
                    shape_txt, name_tok = o.rsplit(" ", 1)
                    name_tok = name_tok.lstrip("%")
                    cur.symbols.setdefault(name_tok, shape_txt.strip())
                    operands.append(name_tok)
                else:
                    operands.append(o.lstrip("%"))
            inst = Instruction(name, result, opcode, operands, attrs, line)
            cur.instructions.append(inst)
            cur.symbols[name] = result
    return comps, entry


def _split_top(s: str) -> List[str]:
    out, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(s[start:i])
            start = i + 1
    if s[start:].strip():
        out.append(s[start:])
    return [x.strip() for x in out]


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=lambda: {
        k: 0.0 for k in COLLECTIVES})
    transcendentals: float = 0.0
    #: flops from dot/convolution ops only -- the GEMM term the analytic
    #: combine_cost models, so WorkloadReports can be cross-checked against
    #: compiled HLO without the (platform-dependent) scatter lowering noise
    dot_flops: float = 0.0

    def __add__(self, o: "HloCost") -> "HloCost":
        return HloCost(
            self.flops + o.flops,
            self.bytes_accessed + o.bytes_accessed,
            self.collective_bytes + o.collective_bytes,
            {k: self.collectives[k] + o.collectives[k] for k in COLLECTIVES},
            self.transcendentals + o.transcendentals,
            self.dot_flops + o.dot_flops)

    def scale(self, k: float) -> "HloCost":
        return HloCost(self.flops * k, self.bytes_accessed * k,
                       self.collective_bytes * k,
                       {kk: v * k for kk, v in self.collectives.items()},
                       self.transcendentals * k,
                       self.dot_flops * k)


_CALLED = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


class Analyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_hlo(text)
        self._memo: Dict[str, HloCost] = {}

    # -- trip count from a while condition computation ----------------------
    def _trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        consts = []
        for inst in comp.instructions:
            if inst.opcode == "constant":
                m = re.search(r"constant\((-?\d+)", inst.line)
                if m:
                    consts.append(int(m.group(1)))
        pos = [c for c in consts if c > 0]
        return max(pos) if pos else 1

    def _operand_shape(self, comp: Computation, name: str) -> str:
        return comp.symbols.get(name, "")

    _TRANSPARENT = ("bitcast", "reshape", "copy", "transpose", "convert")

    def _sliced_params(self, comp_name: str) -> Dict[int, int]:
        """Param index -> touched bytes, for params that are ONLY consumed by
        slice-like ops inside the fused computation.  Follows transparent
        (bitcast/reshape/copy/transpose/convert) chains: scan xs buffers are
        typically bitcast THEN dynamic-sliced."""
        comp = self.comps.get(comp_name)
        if comp is None:
            return {}
        param_of = {}
        for inst in comp.instructions:
            if inst.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", inst.line)
                if m:
                    param_of[inst.name] = int(m.group(1))
        # alias set: names transparently derived from each param
        alias: Dict[str, int] = dict(param_of.items())
        for inst in comp.instructions:
            if inst.opcode in self._TRANSPARENT and inst.operands and \
                    inst.operands[0] in alias:
                alias[inst.name] = alias[inst.operands[0]]
        touched: Dict[int, int] = {}
        full: set = set()
        for inst in comp.instructions:
            if inst.opcode in self._TRANSPARENT:
                continue  # transparent links accounted via alias
            for o in inst.operands:
                if o not in alias:
                    continue
                idx = alias[o]
                if inst.opcode in ("dynamic-slice", "slice", "gather"):
                    touched[idx] = touched.get(idx, 0) + \
                        2 * _shapes_bytes(inst.result_text)
                elif inst.opcode == "dynamic-update-slice":
                    # update region ~ update operand size
                    if len(inst.operands) > 1 and inst.operands[0] == o:
                        upd = _shapes_bytes(self._operand_shape(
                            comp, inst.operands[1]))
                        touched[idx] = touched.get(idx, 0) + 2 * upd
                    elif inst.operands.index(o) >= 2:
                        pass  # an index operand: negligible
                    else:
                        full.add(idx)
                else:
                    full.add(idx)
        return {i: b for i, b in touched.items() if i not in full}

    def cost_of(self, comp_name: str) -> HloCost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        total = HloCost()
        if comp is None:
            return total
        self._memo[comp_name] = total  # break cycles defensively
        fused = comp_name.startswith("fused_") or ".fused" in comp_name
        for inst in comp.instructions:
            total = total + self._inst_cost(comp, inst, fused)
        self._memo[comp_name] = total
        return total

    def _inst_cost(self, comp: Computation, inst: Instruction,
                   in_fusion: bool) -> HloCost:
        op = inst.opcode
        c = HloCost()
        res_bytes = _shapes_bytes(inst.result_text)
        res_elems = sum(int(np.prod(d)) if d else 1
                        for _, d in _parse_shapes(inst.result_text))

        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all", "iota", "partition-id",
                  "replica-id"):
            return c

        # bytes: operands + result (top-level ops only; fusion insides are
        # register traffic, not HBM).  Slice-like ops touch only the
        # slice-sized region, not the full operand (scan xs-slicing would
        # otherwise over-count by the trip count).  Control-flow ops
        # (while/call/conditional) pass buffers BY REFERENCE -- their
        # boundary tuples are already counted at the producing/consuming
        # fusions; counting them again inflated loop-heavy programs ~2x.
        if not in_fusion and op not in ("while", "call", "conditional"):
            if op in ("dynamic-slice", "slice", "gather"):
                c.bytes_accessed += 2.0 * res_bytes
            elif op in ("dynamic-update-slice", "scatter"):
                upd = _shapes_bytes(self._operand_shape(
                    comp, inst.operands[1])) if len(inst.operands) > 1 else 0
                c.bytes_accessed += 3.0 * upd  # read+write region + source
            elif op == "fusion":
                # per-parameter byte model: a fusion parameter consumed by a
                # dynamic-slice/gather inside the fused computation only
                # touches the slice-sized region (scan xs etc.), not the
                # whole operand.
                m = _CALLED.search(inst.attrs) or _CALLED.search(inst.line)
                sliced = self._sliced_params(m.group(1)) if m else {}
                for i, o in enumerate(inst.operands):
                    ob = _shapes_bytes(self._operand_shape(comp, o))
                    if i in sliced:
                        c.bytes_accessed += min(ob, sliced[i])
                    else:
                        c.bytes_accessed += ob
                c.bytes_accessed += res_bytes
            else:
                opb = sum(_shapes_bytes(self._operand_shape(comp, o))
                          for o in inst.operands)
                c.bytes_accessed += opb + res_bytes

        base = op.replace("-start", "")
        if base in COLLECTIVES:
            c.collective_bytes += res_bytes
            c.collectives[base] += res_bytes
            return c

        if op == "while":
            m = _COND.search(inst.attrs) or _COND.search(inst.line)
            body = _CALLED.search(inst.attrs) or _CALLED.search(inst.line)
            trip = self._trip_count(m.group(1)) if m else 1
            if body:
                inner = self.cost_of(body.group(1))
                return c + inner.scale(trip)
            return c

        if op in ("fusion", "call", "custom-call", "map", "reduce",
                  "reduce-window", "sort", "scatter", "select-and-scatter",
                  "conditional"):
            m = _CALLED.findall(inst.attrs) or _CALLED.findall(inst.line)
            for callee in m:
                c = c + self.cost_of(callee)
            if op == "reduce":
                c.flops += res_elems  # reduction adds ~1 op/elem
            return c

        if op == "dot":
            contract = _CONTRACT.search(inst.attrs)
            lhs_shape = self._operand_shape(comp, inst.operands[0]) if \
                inst.operands else ""
            kdim = 1
            if contract and lhs_shape:
                dims = _parse_shapes(lhs_shape)
                if dims:
                    lhs_dims = dims[0][1]
                    for idx in [int(x) for x in
                                contract.group(1).split(",") if x]:
                        if idx < len(lhs_dims):
                            kdim *= lhs_dims[idx]
            c.flops += 2.0 * res_elems * kdim
            c.dot_flops += 2.0 * res_elems * kdim
            return c

        if op == "convolution":
            # only depthwise causal convs exist in this codebase (mamba2):
            # per-output-element work = 2 * spatial kernel size (last dims)
            rhs_shape = self._operand_shape(
                comp, inst.operands[1]) if len(inst.operands) > 1 else ""
            shp = _parse_shapes(rhs_shape)
            k_spatial = int(np.prod(shp[0][1][2:])) if shp and \
                len(shp[0][1]) > 2 else 1
            c.flops += 2.0 * res_elems * max(1, k_spatial)
            c.dot_flops += 2.0 * res_elems * max(1, k_spatial)
            return c

        if op in ("exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                  "logistic", "sine", "cosine", "erf"):
            c.transcendentals += res_elems
            c.flops += res_elems
            return c

        # generic elementwise / select / compare / convert / dus / ds ...
        c.flops += res_elems
        return c

    def entry_cost(self) -> HloCost:
        if self.entry is None:
            # fall back: largest computation
            biggest = max(self.comps, key=lambda k:
                          len(self.comps[k].instructions))
            return self.cost_of(biggest)
        return self.cost_of(self.entry)


def analyze_hlo(text: str) -> HloCost:
    return Analyzer(text).entry_cost()
