"""Trip-count-aware HLO cost analyzer vs known-cost programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_cost import analyze_hlo, parse_hlo


def _cost(f, *args):
    c = jax.jit(f).lower(*args).compile()
    return analyze_hlo(c.as_text())


def test_plain_matmul_flops():
    a = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
    b = jax.ShapeDtypeStruct((1024, 256), jnp.float32)
    hc = _cost(lambda a, b: a @ b, a, b)
    assert hc.flops == pytest.approx(2 * 512 * 1024 * 256, rel=0.01)


@pytest.mark.parametrize("n", [1, 4, 16])
def test_scan_trip_count_scaling(n):
    """THE defect this module exists for: XLA counts while bodies once."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=n)
        return y
    m = 128
    hc = _cost(f, jax.ShapeDtypeStruct((m, m), jnp.float32),
               jax.ShapeDtypeStruct((m, m), jnp.float32))
    assert hc.flops == pytest.approx(2 * m ** 3 * n, rel=0.02)


def test_nested_scan():
    def g(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            y, _ = jax.lax.scan(inner, c, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y
    hc = _cost(g, jax.ShapeDtypeStruct((64, 64), jnp.float32),
               jax.ShapeDtypeStruct((64, 64), jnp.float32))
    assert hc.flops == pytest.approx(2 * 64 ** 3 * 15, rel=0.02)


def test_dynamic_slice_bytes_not_overcounted():
    """Slicing a big stacked array per scan step counts slice bytes only."""
    big = jax.ShapeDtypeStruct((64, 128, 128), jnp.float32)

    def f(xs):
        def body(c, i):
            return c + jax.lax.dynamic_index_in_dim(xs, i, 0,
                                                    keepdims=False), None
        out, _ = jax.lax.scan(body, jnp.zeros((128, 128)), jnp.arange(64))
        return out
    hc = _cost(f, big)
    full = 64 * 128 * 128 * 4
    # must be O(n_steps * slice) ~ full array once-ish, NOT steps * full
    assert hc.bytes_accessed < 20 * full


def test_collective_bytes_from_sharded_program():
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path
    src = str(Path(__file__).resolve().parents[1] / "src")
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.hlo_cost import analyze_hlo
        mesh = jax.make_mesh((8,), ("d",))
        def f(x):
            return jax.lax.with_sharding_constraint(
                x.sum(0, keepdims=True), NamedSharding(mesh, P()))
        xs = jax.ShapeDtypeStruct((64, 1024), jnp.float32)
        with mesh:
            c = jax.jit(f, in_shardings=NamedSharding(mesh, P("d", None))
                        ).lower(xs).compile()
        hc = analyze_hlo(c.as_text())
        assert hc.collective_bytes > 0, "expected an all-reduce"
        print("COLL", hc.collective_bytes)
    """)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env={"PYTHONPATH": src, "HOME": "/root",
                                          "PATH": "/usr/bin:/bin"},
                         timeout=600)  # 8 fake-device startup is slow on CI
    assert res.returncode == 0, res.stderr[-2000:]
    assert "COLL" in res.stdout


def test_parse_hlo_structure():
    c = jax.jit(lambda a, b: jnp.tanh(a @ b)).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    comps, entry = parse_hlo(c.as_text())
    assert entry is not None
    assert entry in comps
    assert len(comps[entry].instructions) > 0
