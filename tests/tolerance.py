"""The ONE per-dtype tolerance table for the whole suite.

The reduced-precision plan contract (core/plan.py ``build_plan(dtype=)``)
is two-sided: f32 plans are BITWISE-golden (no tolerance at all), reduced
dtypes are equivalent within a band that is a property of the *dtype*, not
of the individual test.  Ad-hoc ``atol=``/``rtol=`` literals scattered
through test files hide which side of that contract a comparison sits on
-- and drift independently when someone loosens one.  So the bands live
here, once:

  * ``f32``      -- (1e-5, 1e-5): accumulation-order noise only (different
    reduction shapes between a kernel and its jnp oracle).  A *same-path*
    f32 comparison (eager vs ``plan.compile()``) must instead use
    ``bitwise=True`` -- zero tolerance.
  * ``bf16``     -- (3e-2, 3e-2): 8-bit mantissa storage at phase
    boundaries, f32 accumulation.
  * ``int8-agg`` -- (2e-2, 2e-2): per-row symmetric int8 grid on the
    aggregation operand only (phases.quantize_int8), f32 everywhere else.

``scale`` expresses a test-specific slack factor (deeper compositions
accumulate more rounding) while keeping the base band shared -- a reviewer
reads ``scale=10`` as "10x the dtype's unit band", not a fresh magic
number.  Tests import this module directly (``import tolerance``; tests/
has no __init__.py so pytest puts this directory on sys.path) or take the
``tol`` fixture from conftest.
"""

from __future__ import annotations

import numpy as np

#: dtype -> (rtol, atol) unit band.  Keys are the plan-dtype vocabulary.
DTYPE_BANDS = {
    "f32": (1e-5, 1e-5),
    "bf16": (3e-2, 3e-2),
    "int8-agg": (2e-2, 2e-2),
}


def _band_key(dtype) -> str:
    """Normalize a plan-dtype string or an array dtype to a band key."""
    if isinstance(dtype, str) and dtype in DTYPE_BANDS:
        return dtype
    name = np.dtype(dtype).name if not hasattr(dtype, "name") else dtype.name
    # jnp.bfloat16 has dtype name "bfloat16"; jnp.float32 -> "float32"
    if "bfloat16" in str(name):
        return "bf16"
    if "float32" in str(name):
        return "f32"
    if "int8" in str(name):
        return "int8-agg"
    raise KeyError(f"no tolerance band for dtype {dtype!r}")


def assert_allclose_dtype(actual, desired, dtype="f32", *, scale: float = 1.0,
                          bitwise: bool = False, err_msg: str = "") -> None:
    """Assert equivalence at the dtype's shared band (or bitwise).

    ``dtype`` is a plan-dtype string ("f32" | "bf16" | "int8-agg") or an
    array dtype (jnp.float32 / jnp.bfloat16).  ``bitwise=True`` asserts
    exact equality regardless of dtype -- the f32 eager-vs-compiled
    contract.  ``scale`` multiplies both rtol and atol.
    """
    a = np.asarray(actual, np.float32)
    d = np.asarray(desired, np.float32)
    if bitwise:
        np.testing.assert_array_equal(a, d, err_msg=err_msg)
        return
    rtol, atol = DTYPE_BANDS[_band_key(dtype)]
    np.testing.assert_allclose(a, d, rtol=rtol * scale, atol=atol * scale,
                               err_msg=err_msg)
