"""Minimal stand-in for the ``hypothesis`` API surface this suite uses.

The container image has no ``hypothesis`` wheel and nothing may be pip
installed, so ``conftest.py`` registers this module under
``sys.modules["hypothesis"]`` when the real package is missing.  It covers
exactly what the tests import -- ``given``, ``settings``,
``strategies.integers`` / ``floats`` / ``sampled_from`` / ``composite`` --
by running each property against a deterministic sample of draws
(endpoints / every element first, then seeded-random interior points).
Installing real hypothesis transparently takes precedence.

The stub's own behavioral contract (endpoint-first coverage, full-cycle
sampled_from, deterministic replay, composite draw indexing) is unit
tested in tests/test_hypothesis_stub.py -- the property suites lean on
those guarantees for their coverage claims.
"""

from __future__ import annotations

import itertools

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class _IntStrategy:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def draws(self, rng: np.random.Generator, n: int):
        fixed = [self.lo, self.hi] if self.hi > self.lo else [self.lo]
        rand = [int(rng.integers(self.lo, self.hi + 1))
                for _ in range(max(0, n - len(fixed)))]
        return (fixed + rand)[:n]


class _FloatStrategy:
    def __init__(self, lo: float, hi: float):
        self.lo, self.hi = float(lo), float(hi)

    def draws(self, rng: np.random.Generator, n: int):
        fixed = [self.lo, self.hi] if self.hi > self.lo else [self.lo]
        rand = [float(rng.uniform(self.lo, self.hi))
                for _ in range(max(0, n - len(fixed)))]
        return (fixed + rand)[:n]


class _SampledStrategy:
    def __init__(self, elements):
        self.elements = list(elements)
        assert self.elements, "sampled_from of an empty collection"

    def draws(self, rng: np.random.Generator, n: int):
        # every element appears before any repeats: n >= len(elements)
        # guarantees the property saw the whole vocabulary
        els = self.elements
        rand = [els[int(rng.integers(0, len(els)))]
                for _ in range(max(0, n - len(els)))]
        return (els + rand)[:n]


class _DrawFn:
    """The ``draw`` callable a @composite builder receives for example i.

    ``draw(strategy)`` indexes the strategy's deterministic draw column at
    this example's position -- so example 0 sees every inner strategy's
    first (endpoint) value, example 1 the second, and later examples the
    seeded-random interior.  Repeated draws of the same strategy within
    one example advance through the column (offset by call count) so they
    are not forced equal.
    """

    def __init__(self, rng: np.random.Generator, idx: int):
        self.rng, self.idx = rng, idx
        self.calls = 0

    def __call__(self, strategy):
        i = self.idx + self.calls
        self.calls += 1
        return strategy.draws(self.rng, i + 1)[i]


class _CompositeStrategy:
    def __init__(self, fn, args, kwargs):
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def draws(self, rng: np.random.Generator, n: int):
        return [self.fn(_DrawFn(rng, i), *self.args, **self.kwargs)
                for i in range(n)]


class strategies:  # noqa: N801 - mimics the hypothesis module name
    @staticmethod
    def integers(min_value: int, max_value: int) -> _IntStrategy:
        return _IntStrategy(min_value, max_value)

    @staticmethod
    def floats(min_value: float, max_value: float,
               **_ignored) -> _FloatStrategy:
        # allow_nan / allow_infinity / width are accepted and ignored:
        # the stub only ever draws finite values inside [lo, hi]
        return _FloatStrategy(min_value, max_value)

    @staticmethod
    def sampled_from(elements) -> _SampledStrategy:
        return _SampledStrategy(elements)

    @staticmethod
    def composite(fn):
        """``@st.composite`` builder: ``fn(draw, *args)`` -> one example.
        Calling the decorated function returns a strategy whose example i
        hands the builder a ``draw`` indexed at i (endpoints-first)."""
        def build(*args, **kwargs):
            return _CompositeStrategy(fn, args, kwargs)
        build.__name__ = getattr(fn, "__name__", "composite")
        return build


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        # NOT functools.wraps: pytest must see a zero-arg signature, or it
        # would treat the drawn parameters as fixture requests.
        def runner():
            n = getattr(fn, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(0)
            columns = [s.draws(rng, n) for s in strats]
            for drawn in itertools.islice(zip(*columns), n):
                fn(*drawn)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco
