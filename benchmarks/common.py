"""DEPRECATED shim over ``repro.profile.bench`` (the shared bench harness).

The timing / CSV / scaled-dataset halves every bench module used to import
from here live in ``repro.profile.bench`` now; bench modules are
``BenchSpec`` declarations executed by ``repro.profile.bench.run_specs``
(which owns warmup, timing, the stdout echo, and the CSV artifact under
``experiments/bench/``).  This module re-exports the primitives for one
release so external callers keep working.

``emit`` still prints the legacy ``name,us,k=v`` line and appends to
``ROWS``; ``flush_csv`` writes those rows as a real CSV artifact (header
row, stable column order) -- use it if you drive ``emit`` directly instead
of going through ``run_specs``.
"""

from __future__ import annotations

from pathlib import Path
from typing import Dict, List

# Re-exports (deprecated import path; prefer repro.profile.bench).
from repro.profile.bench import (BENCH_ARTIFACT_DIR,  # noqa: F401
                                 bench_graph, csv_columns, format_row,
                                 make_row, timeit, write_csv)

#: rows collected by direct ``emit`` calls (legacy path)
ROWS: List[Dict] = []

CSV_DIR = BENCH_ARTIFACT_DIR  # deprecated alias


def emit(name: str, us_per_call: float, **derived) -> Dict:
    """DEPRECATED: record+print one row (prefer ``BenchContext.emit``)."""
    row = make_row(name, us_per_call, **derived)
    ROWS.append(row)
    print(format_row(row))
    return row


def flush_csv(path=None):
    """Write every ``emit``-ed row as a CSV artifact and clear the buffer."""
    target = Path(path) if path is not None else CSV_DIR / "emit.csv"
    out = write_csv(ROWS, target)
    ROWS.clear()
    return out
