"""Characterization machinery: paper metrics + roofline terms from compiled HLO.

The paper's V100 counters (L2 hit rate, occupancy, IPC...) do not exist here;
the architecture-neutral quantities behind them do.  This module derives:

  * per-phase FLOPs / bytes / arithmetic intensity  (Table 3),
  * bound classification against a ``Machine`` balance point,
  * HLO-level cost extraction (``cost_analysis``) for any jitted step,
  * collective-byte extraction by parsing lowered HLO text (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute),
  * the three roofline terms, parameterized by a ``repro.profile.Machine``
    (presets: TPU_V5E / A100 / the paper's V100), per DESIGN.md §7.

Hardware numbers live on ``repro.profile.machine.Machine`` presets;
``roofline`` / ``phase_report`` take a ``machine=`` argument (default
``TPU_V5E``, the repo's historical behavior).  The module-level constants
below are DEPRECATED shims derived from the presets, kept for one release;
new code should pass a Machine instead.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.profile.machine import TPU_V5E, V100, Machine


# ---------------------------------------------------------------------------
# HLO parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

_COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(tok_dtype: str, tok_dims: str) -> int:
    if tok_dims.strip() == "":
        n = 1
    else:
        n = int(np.prod([int(d) for d in tok_dims.split(",") if d]))
    return n * _DTYPE_BYTES[tok_dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in lowered/compiled HLO text.

    Returns {op_name: bytes, ..., "total": bytes}.  Counts the bytes each
    collective *moves in* (operand side), matching the roofline convention of
    DESIGN.md §7.  Start ops (``all-gather-start``) are counted; matching
    ``-done`` ops are skipped to avoid double counting, as are fusion-internal
    mentions of collectives inside metadata strings.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    count: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        # HLO instruction lines look like:  %name = TYPE[dims] op-name(operands...)
        m = re.match(r"%?[\w\.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        opname = m.group(2)
        base = None
        for c in _COLLECTIVE_OPS:
            if opname == c or opname == c + "-start":
                base = c
                break
        if base is None:
            continue
        # operand shapes: everything inside the call parens
        call = s[s.index(opname + "(") + len(opname) + 1:]
        depth, end = 1, 0
        for i, ch in enumerate(call):
            depth += (ch == "(") - (ch == ")")
            if depth == 0:
                end = i
                break
        operands = call[:end]
        b = sum(shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(operands))
        out[base] += b
        count[base] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVE_OPS)
    out["counts"] = dict(count)  # type: ignore[assignment]
    return out


# ---------------------------------------------------------------------------
# Compiled-step cost extraction
# ---------------------------------------------------------------------------


@dataclass
class StepCost:
    flops: float
    hbm_bytes: float
    collective: Dict[str, int] = field(default_factory=dict)
    peak_memory_per_device: Optional[float] = None
    output_bytes: Optional[float] = None

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(1.0, self.hbm_bytes)


def cost_from_compiled(compiled, lowered=None) -> StepCost:
    """Extract FLOPs/bytes from ``compiled.cost_analysis()`` + HLO collectives."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    coll = {}
    try:
        coll = collective_bytes(compiled.as_text())
    except Exception:
        if lowered is not None:
            coll = collective_bytes(lowered.as_text())
    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = float(getattr(ma, "temp_size_in_bytes", 0) +
                     getattr(ma, "argument_size_in_bytes", 0) +
                     getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    return StepCost(flops=flops, hbm_bytes=byt, collective=coll,
                    peak_memory_per_device=peak)


def cost_of(fn, *args, static_argnums=(), **jit_kw) -> StepCost:
    """Lower+compile ``fn(*args)`` (abstract -- args may be ShapeDtypeStructs)."""
    lowered = jax.jit(fn, static_argnums=static_argnums, **jit_kw).lower(*args)
    compiled = lowered.compile()
    return cost_from_compiled(compiled, lowered)


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    chips: int
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops: float = 0.0
    machine: Machine = TPU_V5E

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def step_time_s(self) -> float:
        """Lower bound on step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound step time (the score we hillclimb).

        Uses MODEL_FLOPS (6ND, already per-device here) when available so
        redundant compiled compute (remat, dispatch overhead) counts
        against us, per the brief.
        """
        useful = self.model_flops or self.flops
        ideal = useful / self.machine.peak_flops
        return ideal / max(self.step_time_s, 1e-30)

    @property
    def mfu(self) -> float:
        return self.roofline_fraction

    def row(self) -> Dict[str, Any]:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_ratio": (self.model_flops / self.flops) if self.flops else 0,
            "roofline_fraction": self.roofline_fraction,
            "machine": self.machine.name,
        }


def roofline(cost: StepCost, chips: int, model_flops: float = 0.0,
             machine: Machine = TPU_V5E) -> Roofline:
    """Three-term roofline per DESIGN.md §7, against one ``Machine``.

    Conventions (verified empirically on this backend, see EXPERIMENTS.md
    §Dry-run methodology): the compiled module is the PER-DEVICE SPMD
    program, so ``cost`` carries per-device FLOPs/bytes/collective-bytes
    (trip-count-aware, via core.hlo_cost).  Terms are therefore per-device
    quantities over per-chip peaks; ``model_flops`` is the GLOBAL 6ND number
    and is divided by ``chips`` for the useful-compute comparison.
    ``machine`` supplies the three peaks (default TPU_V5E, the historical
    constants).
    """
    flops = cost.flops
    byt = cost.hbm_bytes
    coll = float(cost.collective.get("total", 0))
    return Roofline(
        compute_s=flops / machine.peak_flops,
        memory_s=byt / machine.hbm_bw,
        collective_s=coll / machine.interconnect_total,
        chips=chips, flops=flops, hbm_bytes=byt, collective_bytes=coll,
        model_flops=model_flops / max(chips, 1), machine=machine)


# ---------------------------------------------------------------------------
# Paper Table 3: hybrid execution pattern report
# ---------------------------------------------------------------------------


def phase_report(agg_cost: dict, comb_cost: dict,
                 machine: Machine = TPU_V5E) -> Dict[str, Any]:
    """Classify each phase against machine balance (Table 3 reproduction).

    Each phase is classified twice: against the PAPER's V100 balance
    (``"bound"`` -- paper-faithful Table 3) and against ``machine``
    (``"bound_machine"``).  ``"bound_v5e"`` is a deprecated alias kept for
    one release (always the TPU_V5E classification, independent of
    ``machine``).
    """
    def classify(c):
        ai = c["arithmetic_intensity"]
        return {
            "arithmetic_intensity": ai,
            # paper-faithful classification (V100 balance)
            "bound": V100.classify(ai),
            "bound_machine": machine.classify(ai),
            # DEPRECATED alias (pre-Machine behavior)
            "bound_v5e": TPU_V5E.classify(ai),
            "bytes": c["bytes"], "flops": c["flops"],
            # paper's "DRAM bytes per operation"
            "bytes_per_op": c["bytes"] / max(1, c["flops"]),
        }
    return {"aggregation": classify(agg_cost),
            "combination": classify(comb_cost),
            "machine": machine.name,
            "machine_balance": machine.balance,
            "machine_balance_v100": V100.balance,
            "machine_balance_v5e": TPU_V5E.balance}
