"""int8 error-feedback gradient compression (distributed-optimization trick).

At 1000-node scale the data-parallel gradient reduction is collective-bound
for large dense models; 4x compression (f32 -> s8) cuts the dominant wire
bytes proportionally.  Error feedback keeps the compression UNBIASED OVER
TIME: the per-step quantization residual is added back into the next step's
gradient, so SGD-style convergence guarantees survive (Karimireddy et al.).

Implemented as an explicit shard_map all-reduce so the quantized
representation actually crosses the wire (a jnp-level quantize around an
implicit psum would decompress before reducing).  Scheme per leaf:

  g_eff = g + residual
  scale = max|g_eff| / 127        (per-leaf scalar, f32, reduced exactly)
  q     = round(g_eff / scale)    (int8)
  wire  = all_reduce(q)  as int32 sum (values <= 127*P fit easily)
  g_out = wire * scale_mean ;  residual' = g_eff - q * scale

Used by the trainer when ``OptimizerConfig.grad_compression == "int8_ef"``;
tests assert exactness-over-time on quadratic objectives.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def _quantize(g: jnp.ndarray, residual: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    g_eff = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(g_eff)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g_eff / scale), -127, 127).astype(jnp.int8)
    new_residual = g_eff - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def compressed_psum_leaf(g, residual, axis_name: str):
    """Inside shard_map: all-reduce one gradient leaf in int8."""
    q, scale, new_residual = _quantize(g, residual)
    wire = jax.lax.psum(q.astype(jnp.int32), axis_name)        # int on wire
    scale_sum = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # each shard contributed q_i * scale_i; using the mean scale is exact
    # when scales agree and a bounded approximation otherwise -- the error
    # lands in the residual either way on the next step.
    g_out = wire.astype(jnp.float32) * (scale_sum / n) / n
    return g_out.astype(g.dtype), new_residual


def make_compressed_allreduce(mesh: Mesh, axis: str = "data"):
    """Returns fn(grads, residuals) -> (mean_grads, new_residuals).

    grads are expected REPLICATED along ``axis`` shards' other dims (the
    usual DP layout after per-shard backward).  Used by the GCN distributed
    trainer; the pjit LM path keeps XLA-native reductions (documented).
    """
    from jax.experimental.shard_map import shard_map

    def leaf_fn(g, r):
        return compressed_psum_leaf(g, r, axis)

    def allreduce(grads: Any, residuals: Any):
        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(residuals)
        outs_g, outs_r = [], []
        for g, r in zip(flat_g, flat_r):
            spec = P(*(None,) * g.ndim)
            fn = shard_map(leaf_fn, mesh=mesh, in_specs=(spec, spec),
                           out_specs=(spec, spec), check_rep=False)
            og, orr = fn(g, r)
            outs_g.append(og)
            outs_r.append(orr)
        return (jax.tree.unflatten(treedef, outs_g),
                jax.tree.unflatten(treedef, outs_r))

    return allreduce


def init_residuals(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)


def compression_wire_bytes(params_count: int, dp: int) -> dict:
    """Analytic wire-byte comparison for EXPERIMENTS.md (ring all-reduce)."""
    ring = 2 * (dp - 1) / dp
    return {
        "fp32_bytes": 4 * params_count * ring,
        "bf16_bytes": 2 * params_count * ring,
        "int8_ef_bytes": 1 * params_count * ring,
        "reduction_vs_fp32": 4.0,
    }
