import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Distributed GCN training demo: shard_map vertex partitioning + int8
error-feedback gradient compression (DESIGN.md §6).

8 placeholder devices on CPU (the same code drives a real (data,) mesh):
  * graph partitioned into 8 edge-balanced vertex blocks,
  * each step: ring-halo aggregation (combine-first: halo moves 16-wide
    projected rows, not 64-wide inputs -- the Table 4 collective saving),
  * per-shard gradients reduced with int8 error feedback (4x wire bytes
    reduction vs fp32; unbiased over time).

  PYTHONPATH=src python examples/distributed_gcn.py
"""

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.config import CORA, reduced_graph  # noqa: E402
from repro.core.distributed import (distributed_gcn_layer,  # noqa: E402
                                    halo_bytes, pad_features)
from repro.graph.datasets import (make_features, make_labels,  # noqa: E402
                                  make_synthetic_graph)
from repro.graph.partition import partition_1d  # noqa: E402
from repro.optim.compression import (compression_wire_bytes,  # noqa: E402
                                     init_residuals,
                                     make_compressed_allreduce)


def main():
    spec = reduced_graph(CORA, max_vertices=512, max_feature=64)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    y = make_labels(spec)
    x = x.at[:, :spec.num_classes].add(
        4.0 * jax.nn.one_hot(y, spec.num_classes))

    mesh = jax.make_mesh((8,), ("data",))
    pg = partition_1d(g, 8, edge_balanced=False)
    xp = pad_features(x, pg.block_size, 8)
    hb_in = halo_bytes(pg, spec.feature_len)["min_halo_bytes"]
    hb_out = halo_bytes(pg, 16)["min_halo_bytes"]
    print(f"partition: 8 shards x {pg.block_size} vertices, "
          f"halo {hb_in:,} B (agg-first) vs {hb_out:,} B (combine-first) "
          f"-> {hb_in / hb_out:.1f}x collective saving")

    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params = {
        "w1": jax.random.normal(k1, (spec.feature_len, 16)) * 0.15,
        "b1": jnp.zeros(16),
        "w2": jax.random.normal(k2, (16, spec.num_classes)) * 0.3,
        "b2": jnp.zeros(spec.num_classes),
    }
    yp = jnp.pad(y, (0, pg.block_size * 8 - spec.num_vertices))
    vmask = (jnp.arange(pg.block_size * 8) < spec.num_vertices
             ).astype(jnp.float32)

    def loss_fn(p):
        h = distributed_gcn_layer(pg, xp, p["w1"], p["b1"], g.in_deg, mesh,
                                  order="combine_first", strategy="ring")
        h = jax.nn.relu(h)
        logits = distributed_gcn_layer(pg, h, p["w2"], p["b2"], g.in_deg,
                                       mesh, order="aggregate_first",
                                       strategy="ring")
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, yp[:, None], axis=-1)[:, 0]
        return (nll * vmask).sum() / vmask.sum()

    allreduce = make_compressed_allreduce(mesh, "data")
    residuals = init_residuals(params)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    wire = compression_wire_bytes(
        sum(int(np.prod(v.shape)) for v in params.values()), dp=8)
    print(f"grad wire bytes/step: fp32 {wire['fp32_bytes']:,.0f} -> "
          f"int8+EF {wire['int8_ef_bytes']:,.0f} "
          f"({wire['reduction_vs_fp32']:.0f}x)")

    lr = 0.25
    with mesh:
        for step in range(30):
            loss, grads = grad_fn(params)
            grads, residuals = allreduce(grads, residuals)  # int8 EF wire
            params = jax.tree.map(lambda p_, g_: p_ - lr * g_, params,
                                  grads)
            if step % 5 == 0:
                print(f" step {step:2d}  loss {float(loss):.4f}")

    h = distributed_gcn_layer(pg, xp, params["w1"], params["b1"], g.in_deg,
                              mesh, order="combine_first")
    logits = distributed_gcn_layer(pg, jax.nn.relu(h), params["w2"],
                                   params["b2"], g.in_deg, mesh,
                                   order="aggregate_first")
    acc = float(((jnp.argmax(logits, -1) == yp) * vmask).sum() /
                vmask.sum())
    print(f"final accuracy {acc:.3f} (chance {1 / spec.num_classes:.3f})")


if __name__ == "__main__":
    main()
