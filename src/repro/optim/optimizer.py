"""AdamW optimizer with warmup+cosine schedule and global-norm clipping.

Hand-rolled (no optax in the container).  Moments can be stored in bf16
(``moment_dtype``) -- at kimi-k2 scale fp32 moments alone exceed HBM
(EXPERIMENTS.md §Dry-run memory notes); bf16 moments + stochastic-free
rounding is the standard trillion-param compromise.

State is a plain dict pytree so checkpointing/sharding treat it uniformly;
moment trees mirror the parameter tree so param PartitionSpecs apply verbatim.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig


class TrainState(NamedTuple):
    step: jnp.ndarray          # () int32
    params: Any
    m: Any
    v: Any


def make_train_state(params, cfg: OptimizerConfig) -> TrainState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_init(params, cfg: OptimizerConfig) -> TrainState:
    return make_train_state(params, cfg)


def cosine_lr(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * (0.5 * (1.0 + jnp.cos(jnp.pi * t)))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(state: TrainState, grads, cfg: OptimizerConfig
                 ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.asarray(1.0)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:  # no decay on norms/scalars
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(state.params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return TrainState(step, new_p, new_m, new_v), metrics
