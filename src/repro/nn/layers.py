"""Core NN layers: norms, dense/MLP variants, embeddings, rotary positions.

Functional style: ``init_*`` returns a params pytree of plain jnp arrays,
``apply`` functions are pure.  Compute dtype is bf16-by-default with fp32
accumulation (preferred_element_type) -- the TPU-native convention.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def _split(key, n):
    return jax.random.split(key, n)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int) -> Dict:
    return {"scale": jnp.zeros((d,), jnp.float32)}  # (1 + scale) * x-hat


def rmsnorm(params: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xn = xf * jax.lax.rsqrt(var + eps)
    return (xn * (1.0 + params["scale"])).astype(dt)


def gated_rmsnorm(params: Dict, x: jnp.ndarray, z: jnp.ndarray,
                  eps: float = 1e-6) -> jnp.ndarray:
    """Mamba2's norm-then-gate: RMSNorm(x * silu(z))."""
    return rmsnorm(params, x * jax.nn.silu(z.astype(x.dtype)), eps)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------


def init_dense(key, din: int, dout: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> Dict:
    s = scale if scale is not None else din ** -0.5
    return {"w": (jax.random.normal(key, (din, dout), jnp.float32) * s
                  ).astype(dtype)}


def dense(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,df->...f", x, params["w"].astype(x.dtype),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def init_mlp(key, d_model: int, d_ff: int, activation: str,
             dtype=jnp.float32) -> Dict:
    ks = _split(key, 3)
    p = {"wi": init_dense(ks[0], d_model, d_ff, dtype),
         "wo": init_dense(ks[1], d_ff, d_model, dtype,
                          scale=d_ff ** -0.5)}
    if activation in ("swiglu", "geglu"):
        p["wg"] = init_dense(ks[2], d_model, d_ff, dtype)
    return p


def mlp(params: Dict, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    h = dense(params["wi"], x)
    if activation == "swiglu":
        h = jax.nn.silu(dense(params["wg"], x)) * h
    elif activation == "geglu":
        h = jax.nn.gelu(dense(params["wg"], x), approximate=True) * h
    elif activation == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    elif activation == "relu":
        h = jax.nn.relu(h)
    else:
        raise ValueError(activation)
    return dense(params["wo"], h)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> Dict:
    # d^-0.5 keeps tied-unembed logits O(1) at init (gemma's sqrt(d) embed
    # scaling restores unit-variance activations on the way in).
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * d ** -0.5).astype(dtype)}


def embed(params: Dict, ids: jnp.ndarray, scale_by_sqrt_d: bool = False
          ) -> jnp.ndarray:
    out = jnp.take(params["table"], ids, axis=0)
    if scale_by_sqrt_d:
        out = out * (params["table"].shape[1] ** 0.5)
    return out


def unembed(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,vd->...v", x,
                      params["table"].astype(x.dtype),
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, D) with positions (..., S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / (10000.0 ** (dim / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
