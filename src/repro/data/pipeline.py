"""Deterministic, resumable, sharded data pipelines.

Production posture: every batch is a pure function of (seed, step), so

  * any host can regenerate any shard of any step (no coordinator state),
  * checkpoint-resume is exact: the pipeline state IS the step counter,
  * elastic restarts that change data-parallel size keep determinism --
    the GLOBAL batch for step t is identical, only its slicing changes.

``TokenPipeline`` synthesizes LM token streams (container has no corpora);
the synthesis is a stand-in for a tokenized-shard reader with identical
interface: ``batch_at(step)`` + ``state_dict()/load_state_dict()``.
``GraphPipeline`` yields GraphSAGE-style sampled mini-batches (paper side).
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.config import GraphSpec, LMConfig, ShapeSpec
from repro.graph.sampling import two_hop_batch
from repro.graph.structure import Graph


class TokenPipeline:
    """Synthetic token batches with a Zipf unigram distribution.

    The Zipf marginal matters: CE losses and router/top-k behavior under a
    realistic token skew exercise the same code paths real corpora do
    (uniform tokens make MoE routing degenerate).
    """

    def __init__(self, cfg: LMConfig, shape: ShapeSpec, seed: int = 0,
                 frontend_tokens: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.step = 0
        self.frontend_tokens = frontend_tokens
        # precomputed Zipf CDF over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        w = ranks ** -1.1
        self._cdf = np.cumsum(w) / w.sum()

    def _tokens(self, rng: np.random.Generator, n: Tuple[int, ...]):
        u = rng.random(n)
        return np.searchsorted(self._cdf, u).astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b = self.shape.global_batch
        s = self.shape.seq_len - self.frontend_tokens
        toks = self._tokens(rng, (b, s))
        batch: Dict[str, np.ndarray] = {
            "tokens": toks,
            # next-token labels, pre-shifted; last position masked
            "labels": np.concatenate(
                [toks[:, 1:], np.full((b, 1), -100, np.int32)], axis=1),
        }
        if self.frontend_tokens:
            d = self.cfg.d_model
            batch["embeds"] = rng.standard_normal(
                (b, self.frontend_tokens, d)).astype(np.float32) * 0.02
        if self.cfg.family == "audio":
            d = self.cfg.d_model
            batch["frames"] = rng.standard_normal(
                (b, min(self.shape.seq_len, 4096), d)
            ).astype(np.float32) * 0.02
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            out = self.batch_at(self.step)
            self.step += 1
            yield out

    # resumability ----------------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, st: Dict[str, Any]) -> None:
        self.step = int(st["step"])
        self.seed = int(st["seed"])


class GraphPipeline:
    """GraphSAGE mini-batches: seed vertices + sampled 2-hop blocks."""

    def __init__(self, graph: Graph, spec: GraphSpec, batch_size: int,
                 fanouts: Tuple[int, int] = (10, 25), seed: int = 0):
        self.graph = graph
        self.spec = spec
        self.batch_size = batch_size
        self.fanouts = fanouts
        self.seed = seed
        self.step = 0

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        seeds = rng.choice(self.spec.num_vertices,
                           size=min(self.batch_size,
                                    self.spec.num_vertices),
                           replace=False).astype(np.int32)
        hop2, hop1 = two_hop_batch(self.graph, seeds, self.fanouts,
                                   seed=int(rng.integers(2 ** 31)))
        return {"seeds": seeds, "hop1": hop1, "hop2": hop2}

    def __iter__(self):
        while True:
            out = self.batch_at(self.step)
            self.step += 1
            yield out

    def state_dict(self):
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, st):
        self.step = int(st["step"])
        self.seed = int(st["seed"])


def shard_batch(batch: Dict[str, np.ndarray], shardings: Dict[str, Any]
                ) -> Dict[str, Any]:
    """Place a host batch onto devices per the given shardings."""
    import jax
    return {k: jax.device_put(v, shardings[k]) if k in shardings else v
            for k, v in batch.items()}
