import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape x mesh) cell:
  * builds abstract params/state/inputs (ShapeDtypeStructs, no allocation),
  * jit(step, in_shardings=..., out_shardings=...).lower(...).compile(),
  * prints memory_analysis() (fits check) and cost_analysis() (FLOPs/bytes),
  * extracts collective bytes from the compiled HLO,
  * writes one JSON record per cell under experiments/dryrun/.

The XLA_FLAGS line above MUST precede any jax import (device count locks on
first backend init) and is intentionally NOT set in conftest.py/pyproject --
smoke tests and benches see the single real CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                      # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
      --shape train_4k --mesh single                                 # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --list                # cell list
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.config import (OptimizerConfig, SHAPES_BY_NAME,  # noqa: E402
                          get_config)
from repro.configs import ASSIGNED_ARCHS  # noqa: E402
from repro.core.characterize import (Roofline, StepCost,  # noqa: E402
                                     roofline)
from repro.core.hlo_cost import analyze_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh, num_chips  # noqa: E402
from repro.launch.sharding import rules_for, sharding_rules  # noqa: E402
from repro.launch.specs import (abstract_params, abstract_state,  # noqa: E402
                                arch_attn_tp, input_pspecs, input_specs,
                                param_pspecs, serve_out_pspecs, state_pspecs)
from repro.launch.steps import (make_decode_step, make_prefill_step,  # noqa: E402
                                make_train_step)

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); fwd-only for serve."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens


def build_cell(arch: str, shape_name: str, mesh, *, remat: str = "auto",
               opt: OptimizerConfig | None = None, microbatch: int = 0):
    """Returns (jitted_fn, abstract_args) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if remat == "auto":  # production default: full remat for training
        remat = "full" if shape.kind == "train" else "none"
    opt = opt or default_opt(cfg)
    batch = input_specs(cfg, shape)
    batch_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            input_pspecs(cfg, shape, mesh),
                            is_leaf=lambda x: isinstance(x, P))

    attn_tp = arch_attn_tp(cfg, mesh)
    if shape.kind == "train":
        state = abstract_state(cfg, opt)
        state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                state_pspecs(state, mesh, attn_tp),
                                is_leaf=lambda x: isinstance(x, P))
        fn = make_train_step(cfg, opt, remat=remat, microbatch=microbatch)
        jf = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))
        return jf, (state, batch), cfg, shape
    params = abstract_params(cfg)
    params_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                             param_pspecs(params, mesh, attn_tp),
                             is_leaf=lambda x: isinstance(x, P))
    out_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          serve_out_pspecs(cfg, shape, mesh),
                          is_leaf=lambda x: isinstance(x, P))
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg)
    else:
        fn = make_decode_step(cfg)
        jf = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                     out_shardings=out_sh,
                     donate_argnames=("batch",))
        return jf, (params, batch), cfg, shape
    jf = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                 out_shardings=out_sh)
    return jf, (params, batch), cfg, shape


def default_opt(cfg) -> OptimizerConfig:
    # bf16 moments above ~100B params: fp32 Adam state alone would exceed
    # 16 GiB/chip HBM at kimi-k2 scale (see EXPERIMENTS.md §Dry-run).
    big = cfg.param_count() > 100e9
    return OptimizerConfig(moment_dtype="bfloat16" if big else "float32")


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             remat: str = "auto", tag: str = "baseline",
             rules_override=None, microbatch: int = 0,
             verbose: bool = True):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "tag": tag, "remat": remat, "status": "ok"}
    try:
        cfg0 = get_config(arch)
        rules = rules_for(cfg0, mesh)
        if rules_override:
            rules.update(rules_override)
            rec["rules_override"] = {k: list(v) if v else None
                                     for k, v in rules_override.items()}
        with mesh, sharding_rules(mesh, rules):
            jf, args, cfg, shape = build_cell(arch, shape_name, mesh,
                                              remat=remat,
                                              microbatch=microbatch)
            rec["microbatch"] = microbatch
            lowered = jf.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            # trip-count-aware per-device cost (XLA's counter counts while
            # bodies once -- see core/hlo_cost.py)
            hc = analyze_hlo(compiled.as_text())
            coll = dict(hc.collectives)
            coll["total"] = hc.collective_bytes
            chips = num_chips(mesh)
            cost = StepCost(flops=hc.flops, hbm_bytes=hc.bytes_accessed,
                            collective=coll)
            mf = model_flops(cfg, shape)
            rl = roofline(cost, chips, model_flops=mf)

            per_dev = {
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
            }
            peak = sum(v for k, v in per_dev.items()
                       if v and k in ("output_bytes", "temp_bytes",
                                      "argument_bytes"))
            if per_dev.get("alias_bytes"):
                peak -= per_dev["alias_bytes"]
            rec.update({
                "chips": chips,
                "flops": cost.flops, "hbm_bytes": cost.hbm_bytes,
                "collective": {k: v for k, v in coll.items()
                               if k != "counts"},
                "raw_cost_analysis": {
                    "flops": float(ca.get("flops", 0.0)),
                    "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                    "note": "XLA counts while bodies once; see hlo_cost",
                },
                "memory_per_device": per_dev,
                "peak_bytes_per_device": peak,
                "fits_16g": bool(peak and peak < 16 * 2 ** 30),
                "model_flops": mf,
                "roofline": rl.row(),
                "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1),
            })
            if verbose:
                print(f"[{arch} x {shape_name} x {mesh_kind}] "
                      f"peak/dev={peak / 2**30:.2f} GiB "
                      f"flops={cost.flops:.3e} coll={coll['total']:.3e} "
                      f"dom={rl.dominant} frac={rl.roofline_fraction:.3f} "
                      f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
                print("  memory_analysis:", per_dev)
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_kind}] FAILED: {e}")
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    suffix = "" if tag == "baseline" else f"_{tag}"
    path = OUT_DIR / f"{arch}_{shape_name}_{mesh_kind}{suffix}.json"
    path.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def all_cells():
    cells = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in cfg.shapes():
            cells.append((arch, shape.name))
        for skipped in cfg.shape_skips:
            cells.append((arch, skipped + ":SKIP"))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--remat", default="auto")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--rules-override", default=None,
                    help="comma list key=axes (axes '+'-joined, 'none' "
                         "clears), e.g. heads=none,seq=model")
    ap.add_argument("--microbatch", type=int, default=0)
    args = ap.parse_args()
    rules_override = None
    if args.rules_override:
        rules_override = {}
        for kv in args.rules_override.split(","):
            k, v = kv.split("=")
            rules_override[k] = None if v == "none" else tuple(v.split("+"))

    if args.list:
        for arch, shape in all_cells():
            print(arch, shape)
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_ok = n_fail = 0
    for arch, shape in all_cells():
        if args.arch and arch != args.arch:
            continue
        if shape.endswith(":SKIP"):
            if not args.arch or not args.shape:
                print(f"[{arch} x {shape[:-5]}] SKIP "
                      f"({get_config(arch).skip_reason})")
            continue
        if args.shape and shape != args.shape:
            continue
        for mk in meshes:
            suffix = "" if args.tag == "baseline" else f"_{args.tag}"
            path = OUT_DIR / f"{arch}_{shape}_{mk}{suffix}.json"
            if args.skip_existing and path.exists():
                prev = json.loads(path.read_text())
                if prev.get("status") == "ok":
                    continue
            rec = run_cell(arch, shape, mk, remat=args.remat, tag=args.tag,
                           rules_override=rules_override,
                           microbatch=args.microbatch)
            n_ok += rec["status"] == "ok"
            n_fail += rec["status"] != "ok"
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
