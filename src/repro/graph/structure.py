"""Graph containers with jit-friendly static shapes.

The canonical in-memory form is a *destination-sorted edge list* (``src``,
``dst`` sorted by ``dst``).  Sorting by destination is the TPU adaptation of
the paper's atomic-scatter elimination (DESIGN.md F3): the reduce step becomes
a contiguous segmented sum with no write collisions at all, and each
destination's incoming feature rows land in one contiguous stretch, which is
exactly what a VMEM row-accumulator wants.

All arrays are plain jnp arrays so a Graph can be donated/sharded/captured in
jit without host callbacks.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np


class Graph(NamedTuple):
    """Destination-sorted COO graph (== CSR without materialized row_ptr).

    Attributes:
      src:      (E,) int32 source vertex of each edge, sorted by dst.
      dst:      (E,) int32 destination vertex of each edge (non-decreasing).
      in_deg:   (V,) int32 in-degree (number of incoming edges per vertex).
      out_deg:  (V,) int32 out-degree.
      num_vertices: static python int.
      row_ptr:  (V+1,) int32 CSR offsets into src/dst (host-side convenience).
    """

    src: jnp.ndarray
    dst: jnp.ndarray
    in_deg: jnp.ndarray
    out_deg: jnp.ndarray
    num_vertices: int
    row_ptr: Optional[jnp.ndarray] = None

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    # -- normalization terms used by the GCN models -------------------------
    def mean_norm(self) -> jnp.ndarray:
        """1 / (in_deg + 1)  -- mean over {N(v)} ∪ {v} (paper Eq. 1)."""
        return 1.0 / (self.in_deg.astype(jnp.float32) + 1.0)

    def sym_norm_edge(self) -> jnp.ndarray:
        """Kipf symmetric normalization per edge: 1/sqrt((d_u+1)(d_v+1))."""
        d = self.in_deg.astype(jnp.float32) + 1.0
        return jnp.take(jnp.sqrt(1.0 / d), self.src) * jnp.take(
            jnp.sqrt(1.0 / d), self.dst)


def graph_from_coo(src, dst, num_vertices: int, sort: bool = True,
                   build_row_ptr: bool = True) -> Graph:
    """Build a destination-sorted Graph from arbitrary COO arrays (host-side)."""
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    assert src.shape == dst.shape and src.ndim == 1
    if sort:
        order = np.argsort(dst, kind="stable")
        src, dst = src[order], dst[order]
    in_deg = np.bincount(dst, minlength=num_vertices).astype(np.int32)
    out_deg = np.bincount(src, minlength=num_vertices).astype(np.int32)
    row_ptr = None
    if build_row_ptr:
        row_ptr = np.zeros(num_vertices + 1, dtype=np.int32)
        np.cumsum(in_deg, out=row_ptr[1:])
    return Graph(
        src=jnp.asarray(src), dst=jnp.asarray(dst),
        in_deg=jnp.asarray(in_deg), out_deg=jnp.asarray(out_deg),
        num_vertices=int(num_vertices),
        row_ptr=jnp.asarray(row_ptr) if row_ptr is not None else None)


def to_dense_adj(g: Graph, norm: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Dense (V, V) adjacency -- test oracle only (O(V^2) memory)."""
    a = jnp.zeros((g.num_vertices, g.num_vertices), dtype=jnp.float32)
    vals = jnp.ones_like(g.src, dtype=jnp.float32) if norm is None else norm
    return a.at[g.dst, g.src].add(vals)


def add_self_loops(g: Graph) -> Graph:
    """Return a new graph with v->v edges appended (and re-sorted)."""
    v = np.arange(g.num_vertices, dtype=np.int32)
    src = np.concatenate([np.asarray(g.src), v])
    dst = np.concatenate([np.asarray(g.dst), v])
    return graph_from_coo(src, dst, g.num_vertices)


def pad_edges(g: Graph, target_edges: int, pad_vertex: Optional[int] = None
              ) -> Graph:
    """Pad the edge list to a static size with self-edges on a sink vertex.

    Padded edges point at ``pad_vertex`` (default: an extra phantom vertex is
    NOT added; we reuse vertex V-1 with zero weight downstream).  Downstream
    aggregation multiplies by an edge mask, so padding never changes results.
    """
    e = g.num_edges
    assert target_edges >= e
    pv = g.num_vertices - 1 if pad_vertex is None else pad_vertex
    pad = target_edges - e
    src = np.concatenate([np.asarray(g.src), np.full(pad, pv, np.int32)])
    dst = np.concatenate([np.asarray(g.dst), np.full(pad, pv, np.int32)])
    # keep degrees of the REAL graph; mask is (length e) ones then zeros
    out = graph_from_coo(src, dst, g.num_vertices)
    return out._replace(in_deg=g.in_deg, out_deg=g.out_deg)


def edge_mask(real_edges: int, total_edges: int) -> jnp.ndarray:
    return (jnp.arange(total_edges) < real_edges).astype(jnp.float32)
