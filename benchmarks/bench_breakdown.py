"""Paper Fig. 1: execution-time breakdown (gather / reduce / GEMM kernels).

Reproduces the figure's structure on scaled datasets: for each model x
dataset, times the three dominant kernels -- indexSelect (gather), scatter
(segmented reduce), sgemm (combination GEMM) -- and reports their shares.

Expected paper phenomena, asserted in derived columns:
  * GIN (aggregate-first, raw-width features) spends a LARGER share in
    aggregation than GCN/SAG (combine-first, 128-wide rows);
  * combination share grows with dataset feature length (CS > CR > PB).

Declared as one ``BenchSpec`` per dataset sweeping the model axis; the
shared harness (``repro.profile.bench``) owns timing and CSV emission.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.gcn import make_paper_model
from repro.profile.bench import BenchSpec, run_specs

DATASETS = ("cora", "citeseer", "pubmed", "reddit")
MODELS = ("gcn", "sage", "gin")


def _measure(ctx, model_name):
    spec, g, x = ctx.spec, ctx.g, ctx.x
    m = make_paper_model(model_name, spec)
    p = m.init(jax.random.PRNGKey(0))
    conv = m.convs[0]
    order = conv.resolve_order(g)
    w = p["conv0"]["lin"]["w"] if model_name != "gin" else \
        p["conv0"]["mlp1"]["w"]
    agg_len_x = x @ w if order == "combine_first" else x

    gather = jax.jit(lambda h: jnp.take(h, g.src, axis=0))
    reduce_ = jax.jit(lambda rows: jax.ops.segment_sum(
        rows, g.dst, num_segments=g.num_vertices))
    gemm = jax.jit(lambda h: h @ w)

    t_gather = ctx.time(gather, agg_len_x)
    t_reduce = ctx.time(reduce_, gather(agg_len_x))
    t_gemm = ctx.time(gemm, x)
    total = t_gather + t_reduce + t_gemm
    ctx.emit(f"breakdown/{spec.name}/{model_name}", total,
             order=order,
             gather_pct=round(100 * t_gather / total, 1),
             reduce_pct=round(100 * t_reduce / total, 1),
             sgemm_pct=round(100 * t_gemm / total, 1),
             agg_pct=round(100 * (t_gather + t_reduce) / total, 1))


SPECS = [
    BenchSpec(name=f"breakdown/{ds}", graph=ds, max_vertices=4096,
              sweep=MODELS, measure=_measure)
    for ds in DATASETS
]


def run():
    from repro.profile.bench import BENCH_ARTIFACT_DIR
    run_specs(SPECS, csv=BENCH_ARTIFACT_DIR / "bench_breakdown.csv")


if __name__ == "__main__":
    run()
