"""Typed findings core for the static analysis layer.

Every rule in :mod:`repro.analysis.jaxpr_lint` and
:mod:`repro.analysis.ast_lint` emits :class:`Finding` records into an
:class:`AnalysisReport`; ``scripts/analyze.py`` renders the report as
JSON or markdown and gates on ``report.ok(strict=True)`` (zero
error-severity findings).

Severity levels (most to least severe):

  * ``error``   -- a broken contract; fails the ``--strict`` gate.
  * ``warning`` -- a likely hazard that needs a human look.
  * ``info``    -- a contract that could not be proven either way
    (e.g. donation declared but no output can alias the buffer).

Suppressions are source pragmas consumed by the AST front end --
``# analysis: allow(rule-id)`` on (or one line above) the offending
line, ``# analysis: allow-file(rule-id)`` anywhere in the file -- see
``docs/analysis.md`` for the catalog and worked examples.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Tuple

SEVERITIES: Tuple[str, ...] = ("error", "warning", "info")


@dataclass(frozen=True)
class Finding:
    """One verified contract violation (or unprovable contract).

    ``rule`` is the stable rule id (``docs/analysis.md`` catalog),
    ``where`` locates it (``path:line`` for source findings, a plan
    cell label like ``plan[backend=xla,dtype=bf16,...]`` for traced
    findings), ``message`` states the defect in one line and
    ``detail`` carries the evidence (extracted vs expected bytes,
    the offending source line, ...).
    """

    rule: str
    severity: str
    where: str
    message: str
    detail: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}; "
                             f"expected one of {SEVERITIES}")

    def to_dict(self) -> Dict[str, str]:
        return {"rule": self.rule, "severity": self.severity,
                "where": self.where, "message": self.message,
                "detail": self.detail}

    def render(self) -> str:
        tail = f"  [{self.detail}]" if self.detail else ""
        return (f"{self.severity.upper():7s} {self.rule:18s} "
                f"{self.where}: {self.message}{tail}")


@dataclass
class AnalysisReport:
    """An ordered collection of :class:`Finding` records.

    Reports merge (``merge``), filter (``errors`` / ``by_rule``), and
    render (``to_json`` / ``to_markdown``); the CI gate is
    ``ok(strict=True)`` -- True only with zero error-severity findings.
    """

    findings: List[Finding] = field(default_factory=list)

    def add(self, rule: str, severity: str, where: str, message: str,
            detail: str = "") -> None:
        """Append one finding (validates the severity level)."""
        self.findings.append(Finding(rule, severity, where, message, detail))

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def merge(self, other: "AnalysisReport") -> "AnalysisReport":
        """Fold another report's findings into this one (returns self)."""
        self.findings.extend(other.findings)
        return self

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    def counts(self) -> Dict[str, int]:
        """Severity -> number of findings (all severities present)."""
        out = {s: 0 for s in SEVERITIES}
        for f in self.findings:
            out[f.severity] += 1
        return out

    def by_rule(self) -> Dict[str, List[Finding]]:
        out: Dict[str, List[Finding]] = {}
        for f in self.findings:
            out.setdefault(f.rule, []).append(f)
        return out

    def ok(self, strict: bool = True) -> bool:
        """Gate predicate: strict=True fails on any error finding,
        strict=False additionally fails on warnings."""
        if strict:
            return not self.errors
        return not self.errors and not self.warnings

    def to_json(self, indent: int = 2) -> str:
        """Render as a stable JSON document (counts + findings)."""
        return json.dumps({"counts": self.counts(),
                           "findings": [f.to_dict() for f in self.findings]},
                          indent=indent)

    def to_markdown(self) -> str:
        """Render as a markdown table grouped by rule, worst first."""
        lines = ["# Static analysis report", ""]
        c = self.counts()
        lines.append(f"{c['error']} error(s), {c['warning']} warning(s), "
                     f"{c['info']} info.")
        if not self.findings:
            lines.append("")
            lines.append("No findings.")
            return "\n".join(lines)
        lines += ["", "| severity | rule | where | message |",
                  "|---|---|---|---|"]
        order = {s: i for i, s in enumerate(SEVERITIES)}
        for f in sorted(self.findings,
                        key=lambda f: (order[f.severity], f.rule, f.where)):
            msg = f.message.replace("|", "\\|")
            lines.append(f"| {f.severity} | {f.rule} | {f.where} | {msg} |")
        return "\n".join(lines)

    def render(self) -> str:
        return "\n".join(f.render() for f in self.findings)
