from repro.train.trainer import Trainer
