"""seamless-m4t-medium -- enc-dec, multimodal.  [arXiv:2308.11596; hf]

12L (encoder) + 12L (decoder) d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.

The speech frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings for the encoder (enc frames = min(seq, 4096)).
Enc-dec (not encoder-only) -> decode shapes run; long_500k skipped
(full-attention decoder).
"""

import dataclasses

from repro.config import AttentionConfig, LMConfig, register

MAX_ENC_FRAMES = 4096


def enc_frames(seq_len: int) -> int:
    return min(seq_len, MAX_ENC_FRAMES)


def _base() -> LMConfig:
    return LMConfig(
        name="seamless-m4t-medium",
        family="audio",
        num_layers=12,
        encoder_layers=12,
        d_model=1024,
        d_ff=4096,
        vocab_size=256206,
        attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64),
        mlp_activation="gelu",
        tie_embeddings=True,
        frontend_stub=True,
        shape_skips=("long_500k",),
        skip_reason="full-attention decoder; 500k decode needs sub-quadratic",
        source="arXiv:2308.11596",
    )


@register("seamless-m4t-medium")
def config() -> LMConfig:
    return _base()


def reduced() -> LMConfig:
    c = _base()
    return dataclasses.replace(
        c, name=c.name + "-smoke", num_layers=2, encoder_layers=2,
        d_model=64, d_ff=128, vocab_size=256,
        attention=dataclasses.replace(c.attention, num_heads=4,
                                      num_kv_heads=4, head_dim=16))
