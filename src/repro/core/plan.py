"""GraphExecutionPlan: one planning/dispatch layer for GCN execution.

Everything the paper shows must be decided *together* -- and that the rest
of this repo used to decide per-call with ad-hoc flags -- is decided here
ONCE per (graph, model, device) and then replayed on every forward/backward:

  * **Phase ordering (paper F2, Table 4).**  Per layer, the analytic cost
    model (``scheduler.choose_ordering``) picks combine-first when the
    projection shrinks the feature length the sparse phase must move
    (Reddit 602->128: 4.7x fewer aggregation bytes), and honors semantic
    pins (GIN's interior ReLU forces aggregate-first).
  * **Collision-free aggregation backend (paper F3).**  XLA
    ``segment_sum`` vs a specialized Pallas kernel tier, chosen by
    platform ("auto" = pallas-tpu on TPU, pallas-gpu on GPU, XLA on CPU --
    ``backend.resolve_backend``); interpret mode is auto-detected per tier
    (``backend.interpret_for``) instead of the old hardcoded
    ``interpret=True``, so every tier validates on a CPU container.
  * **Inter-phase dataflow fusion (paper F5, §5.1-3).**  The fused
    aggregate->combine tile executor needs a ``BlockedGraph`` regrouping
    of the edge list and a VMEM-budgeted ``tile_m``; the plan builds both
    once (cached per graph -- see ``_blocked_for``) instead of per call.
    GIN layers fuse aggregation with the *first* MLP matmul (previously
    the fused path was silently ignored for GIN).
  * **Shard partition (DESIGN.md §8.5).**  With a 1-D mesh, the plan owns
    the ``partition_1d`` vertex partition and routes layers through the
    ring / all-gather halo aggregation, with ordering still chosen by the
    same cost model (combine-first shrinks the *collective* term by the
    same in/out ratio).  With a 2-D mesh (two named axes, e.g.
    ``jax.make_mesh((4, 2), ("node", "feat"))``), the plan builds the
    ``partition_2d`` node x feature partition instead and routes layers
    through ``distributed_gcn_layer_2d`` -- per-device halo bytes shrink a
    further Q-fold (the multi-host tier; see docs/planner.md).

  * **Locality reordering (paper F4, §5.1 guideline 1).**  Built with
    ``reorder="degree"`` (or ``"auto"``, priced by ``choose_reorder``
    against the plan's ``Machine``), the plan renumbers vertices once at
    build time (``graph.reorder.degree_reorder``) so high-degree rows
    cluster; features are permuted at ingress and logits un-permuted at
    egress *inside* the traced forward -- callers always see the natural
    vertex order.

Every dispatch path is TRACE-PURE: all host-side work (block regrouping,
reordering, partitioning) happens at plan-build time, so the whole forward
compiles.  ``plan.compile()`` returns the single jitted callable
(``CompiledPlan``, with a retrace guard); ``run_model(..., compiled=True)``
is the sugar.

Public surface:

  ``build_plan(g, cfg, in_dim, num_classes, ...)``  -> GraphExecutionPlan
  ``plan.run_model(params, x)``     full forward through all planned layers
  ``plan.compile(donate=...)``      ONE jitted callable for the forward
  ``plan.run_layer(params_i, x, layer=i)``  one layer (conv param subtree)
  ``plan.run_phases(x, weights, ...)``      raw weight-list layer (the
                                            ``phase_ordered_layer`` path)
  ``plan.describe()`` / ``plan.layer_costs(i)``  decisions + analytic costs
  ``plan.instrument(machine=...)``  characterization wrapper: one run_model
                                    yields a typed WorkloadReport
                                    (repro.profile.instrument)

Layer APIs (``GCNModel.apply``, ``GCNConv.apply``, ``phase_ordered_layer``,
the distributed example) all dispatch through plans; none of them takes raw
``impl=`` / ``blocked=`` flags anymore.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import phases
from repro.core.backend import (AUTO, PALLAS_GPU, PALLAS_TPU, XLA,
                                interpret_for, is_pallas, resolve_backend,
                                resolve_interpret)
from repro.core.dataflow import (BlockedGraph, block_graph, fused_gcn_layer,
                                 suggest_tile_m)
from repro.core.scheduler import (AGGREGATE_FIRST, COMBINE_FIRST,
                                  choose_ordering, ordering_cost)
from repro.graph.structure import Graph

# ---------------------------------------------------------------------------
# Plan data model
# ---------------------------------------------------------------------------


@dataclass(frozen=True, eq=False)
class LayerPlan:
    """All decisions for one graph-conv layer, frozen at plan-build time."""

    index: int
    kind: str                 # "gcn" | "sage" | "gin" | "phase"
    dims: Tuple[int, ...]     # (din, [hidden...,] dout) of the combination MLP
    agg_op: str               # "sum" | "mean" | "max"
    include_self: bool
    order: str                # COMBINE_FIRST | AGGREGATE_FIRST (resolved)
    backend: str              # "xla" | "pallas-tpu" | "pallas-gpu"
                              # (resolved, never "auto"/"pallas")
    fused: bool               # inter-phase dataflow fusion (F5)
    tile_m: int               # fused tile rows (0 when unfused)
    blocked: Optional[BlockedGraph]  # shared BlockedGraph (None when unfused)
    #: plan-owned blocked layout for UNFUSED Pallas aggregation -- built for
    #: every Pallas-tier layer so the seg_agg dispatch is trace-pure
    #: (kernels/ops.seg_agg_planned), including call-time fusion fallbacks.
    agg_layout: Optional[BlockedGraph] = None

    @property
    def din(self) -> int:
        return self.dims[0]

    @property
    def dout(self) -> int:
        return self.dims[-1]

    @property
    def n_mlp(self) -> int:
        return len(self.dims) - 1


class GraphExecutionPlan:
    """Precomputed execution recipe for a model over one fixed graph."""

    def __init__(self, g: Graph, layers: Sequence[LayerPlan], *,
                 interpret: bool, mesh=None, partition=None,
                 strategy: str = "ring", axis: str = "data",
                 axes: Tuple[str, str] = ("node", "feat"), machine=None,
                 reorder: str = "none", perm=None, overlap: str = "none",
                 dtype: str = "f32", dedup: str = "none",
                 dedup_layout=None):
        self.g = g                   # the EXECUTION graph (renumbered when
                                     # reorder="degree")
        self.layers: Tuple[LayerPlan, ...] = tuple(layers)
        self.interpret = interpret
        self.mesh = mesh
        self.partition = partition   # None | PartitionedGraph | Partition2D
        self.strategy = strategy
        self.axis = axis             # 1-D partition: the single mesh axis
        self.axes = axes             # 2-D partition: (node, feature) axes
        self.machine = machine       # Optional[repro.profile.Machine]
        self.reorder = reorder       # "none" | "degree" (resolved)
        self.overlap = overlap       # "none" | "pipelined" (resolved halo
                                     # schedule; "auto" never survives build)
        self.dtype = dtype           # "f32" | "bf16" | "int8-agg" (resolved
                                     # execution precision; never "auto")
        self.dedup = dedup           # "none" | "pairs" (resolved two-level
                                     # redundancy elimination; never "auto",
                                     # and never "pairs" with zero matches)
        self.dedup_layout = dedup_layout  # graph.dedup.DedupLayout | None
        # perm[old_id] = new_id (graph.reorder.degree_reorder contract);
        # inv[new_id] = old_id.  Device constants the traced ingress/egress
        # gathers close over -- never recomputed per call.
        if perm is not None:
            perm = np.asarray(perm)
            inv = np.empty_like(perm)
            inv[perm] = np.arange(len(perm))
            self.perm, self.inv = jnp.asarray(perm), jnp.asarray(inv)
        else:
            self.perm = self.inv = None
        self._compiled: Dict = {}    # (donate, layer) -> CompiledPlan

    # -- properties ---------------------------------------------------------

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    @property
    def distributed(self) -> bool:
        return self.partition is not None

    @property
    def partition_kind(self) -> str:
        """"none" | "1d" | "2d" -- which shard partition the plan owns."""
        from repro.graph.partition import Partition2D
        if self.partition is None:
            return "none"
        return "2d" if isinstance(self.partition, Partition2D) else "1d"

    @property
    def compile_supported(self) -> bool:
        """True when every layer's dispatch is trace-pure -- i.e. every
        Pallas-tier layer owns a plan-built blocked layout, so
        ``plan.compile()`` traces with zero host transfers.  Plans built by
        the public entry points always qualify; False only for hand-built
        plans missing ``agg_layout``."""
        return all(not is_pallas(lp.backend) or lp.agg_layout is not None
                   for lp in self.layers)

    # -- parameter helpers --------------------------------------------------

    def init(self, key) -> Dict:
        """Init a params pytree matching ``run_model`` ({"conv<i>": ...})."""
        from repro.core.gcn_layers import _dense_init
        keys = jax.random.split(key, max(self.num_layers, 1))
        out: Dict = {}
        for lp, k in zip(self.layers, keys):
            if lp.n_mlp == 1:
                out[f"conv{lp.index}"] = {
                    "lin": _dense_init(k, lp.dims[0], lp.dims[1])}
            else:
                ks = jax.random.split(k, lp.n_mlp)
                out[f"conv{lp.index}"] = {
                    f"mlp{j + 1}": _dense_init(ks[j], lp.dims[j],
                                               lp.dims[j + 1])
                    for j in range(lp.n_mlp)}
        return out

    @staticmethod
    def _split_params(lp: LayerPlan, params: Dict):
        """Conv param subtree -> (weights list, post-aggregation bias)."""
        if "lin" in params:
            return [(params["lin"]["w"], None)], params["lin"]["b"]
        weights = []
        j = 1
        while f"mlp{j}" in params:
            weights.append((params[f"mlp{j}"]["w"], params[f"mlp{j}"]["b"]))
            j += 1
        return weights, None

    # -- execution ----------------------------------------------------------

    def run_layer(self, params: Dict, x: jnp.ndarray, *, layer: int = 0,
                  _probe=None, graph: Optional[Graph] = None,
                  dedup_layout=None) -> jnp.ndarray:
        """One planned layer from its conv param subtree ({"lin": ...} or
        {"mlp1": ..., "mlp2": ...}).  Operates in the plan's EXECUTION
        layout: in distributed plans ``x`` must be padded to the partition
        layout, in reordered plans rows follow the renumbered vertex ids
        (``run_model`` handles both via its ingress/egress).  ``graph``
        overrides the plan's graph for this dispatch (the dynamic serving
        path -- see ``compile(dynamic=True)``); only valid for plain XLA
        unfused local plans, whose dispatch reads nothing but the edge
        arrays.  ``dedup_layout`` likewise substitutes runtime dedup
        arrays for the plan's baked two-level layout (the dynamic
        minibatch path); the plan's own layout never applies to an
        overridden graph."""
        lp = self.layers[layer]
        weights, bias_post = self._split_params(lp, params)
        if self.distributed:
            return self._run_distributed(lp, x, weights, bias_post,
                                         probe=_probe)
        dedup = dedup_layout if graph is not None or dedup_layout is not None \
            else self.dedup_layout
        return _execute_layer(self.g if graph is None else graph, lp, x,
                              weights, bias_post=bias_post, probe=_probe,
                              dtype=self.dtype, dedup=dedup)

    def _ingress(self, x: jnp.ndarray, *, _probe=None) -> jnp.ndarray:
        """Natural (V, F) features -> the plan's execution layout: the
        planned vertex renumbering (reorder), then the partition padding.
        Pure gathers/pads over plan-time constants -- trace-pure."""
        v = self.g.num_vertices
        if self.inv is not None:
            if x.shape[0] != v:
                raise ValueError(
                    f"reordered plans take features in the natural (V, F) "
                    f"layout; got {tuple(x.shape)} for V={v}")
            x = jnp.take(x, self.inv, axis=0)  # x_new[j] = x_old[inv[j]]
            if _probe is not None:
                _probe.note_reorder()
        if self.distributed and x.shape[0] == v:
            if self.partition_kind == "2d":
                from repro.core.distributed import pad_features_2d
                x = pad_features_2d(x, self.partition)
            else:
                from repro.core.distributed import pad_features
                x = pad_features(x, self.partition.block_size,
                                 self.partition.num_shards)
        return x

    def _egress(self, h: jnp.ndarray) -> jnp.ndarray:
        """Execution layout -> natural order: trim partition padding, then
        un-apply the vertex renumbering (out_old[i] = h_new[perm[i]])."""
        v = self.g.num_vertices
        if self.partition_kind == "2d":
            h = h[:v, :self.layers[-1].dout]
        elif self.distributed:
            h = h[:v]
        if self.perm is not None:
            h = jnp.take(h, self.perm, axis=0)
        return h

    def run_model(self, params: Dict, x: jnp.ndarray, *,
                  _probe=None, compiled: bool = False,
                  graph: Optional[Graph] = None,
                  dedup_layout=None) -> jnp.ndarray:
        """Full forward: planned layers with ReLU between them.

        Accepts ``x`` in the natural (V, F) layout.  Distributed plans pad
        it into the partition layout (rows for 1-D; rows and feature
        columns for 2-D -- pad columns stay exact zeros through every
        layer) and trim the padding off the final output; reordered plans
        permute rows at ingress and un-permute the logits at egress, all
        inside the (traceable) forward.

        ``compiled=True`` routes through ``plan.compile()`` -- the cached
        single jitted callable -- instead of the eager per-phase loop.

        ``graph=`` substitutes another graph's edge arrays for this
        dispatch while replaying the SAME planned decisions (the serving
        path: one plan per shape bucket, many sampled blocks through it --
        see ``compile(dynamic=True)``).  Only plain XLA unfused local
        plans accept it; ``x`` rows must match the substitute graph.
        """
        if compiled:
            if _probe is not None:
                raise ValueError(
                    "per-phase instrumentation needs eager phase "
                    "boundaries; InstrumentedPlan times the compiled "
                    "path separately (run_model(..., compiled=True))")
            if graph is not None:
                return self.compile(dynamic=True)(params, x, graph,
                                                  dedup=dedup_layout)
            return self.compile()(params, x)
        if graph is not None:
            self._check_dynamic_ok()
            if self.dedup == "pairs" and dedup_layout is None:
                raise ValueError(
                    "this plan's dedup='pairs' layout was matched on its "
                    "template graph; dynamic dispatch over a substitute "
                    "graph needs that block's own layout (pass "
                    "dedup_layout=, padded to the template's shapes)")
        h = self._ingress(x, _probe=_probe)
        for i in range(self.num_layers):
            h = self.run_layer(params[f"conv{i}"], h, layer=i, _probe=_probe,
                               graph=graph, dedup_layout=dedup_layout)
            if i < self.num_layers - 1:
                h = jax.nn.relu(h)
        return self._egress(h)

    def _check_dynamic_ok(self) -> None:
        """Dynamic (graph-as-argument) dispatch preconditions: nothing in
        the traced path may depend on the EDGE CONTENT the plan was built
        with.  XLA unfused layers qualify (segment ops read the arrays as
        data); Pallas/fused layers bake host-built blocked layouts, and
        partition/reorder bake edge-derived permutations -- all rejected."""
        problems = []
        if self.distributed:
            problems.append("partitioned plans bake edge-derived shards")
        if self.perm is not None:
            problems.append("reordered plans bake an edge-derived permute")
        for lp in self.layers:
            if is_pallas(lp.backend) or lp.fused:
                problems.append(
                    f"layer {lp.index} ({lp.backend}"
                    f"{', fused' if lp.fused else ''}) bakes a host-built "
                    "blocked layout")
        if problems:
            raise ValueError(
                "dynamic graph dispatch needs edge-content-free tracing: "
                + "; ".join(problems)
                + " (build the bucket plan with backend='xla', "
                "fused=False, reorder='none', mesh=None)")

    def compile(self, *, donate: bool = False,
                layer: Optional[int] = None,
                dynamic: bool = False) -> "CompiledPlan":
        """ONE jitted callable for the planned forward (the production
        entry point).

        Local plans trace ``run_model`` under ``jax.jit``; distributed
        plans trace the same path, whose shard_map halo bodies carry their
        mesh explicitly -- either way the result is a single compiled
        executable with zero host transfers inside the traced region (all
        host-side work -- block regrouping, reordering, partitioning --
        happened at plan-build time).  Exact eager equivalence and a
        retrace-count guard are part of the contract: the returned
        ``CompiledPlan`` counts traces (``num_traces``) and raises if a
        second trace happens for an input signature it has already seen.

        Args:
          donate: donate the feature buffer to the computation
            (``jax.jit(donate_argnums=...)``) -- frees the input's memory
            on accelerators for inference serving; leave False when the
            caller reuses ``x``.
          layer: compile a single planned layer instead of the full model
            (``(conv_params, h) -> h'`` in the plan's execution layout) --
            what per-layer compiled timing in ``repro.profile`` uses.
          dynamic: compile the forward with the GRAPH as a runtime
            argument instead of a baked constant -- the serving-bucket
            mode (``repro.serve.graph_engine``).  The callable signature
            becomes ``(params, x, graph)`` where ``graph`` is any
            ``Graph`` whose ``src``/``dst``/``in_deg`` shapes match the
            plan's template graph; edge CONTENT varies per call with zero
            retraces, so one compiled callable serves every sampled block
            padded into the bucket's shape.  Requires edge-content-free
            tracing: plain XLA, unfused, local, unreordered plans only
            (``_check_dynamic_ok``); incompatible with ``layer=``.

        Compiled callables are cached per (donate, layer, dynamic) on the
        plan, so ``plan.compile()(params, x)`` in a loop never re-jits.

        Worked example::

            >>> plan = build_plan(g, cfg, in_dim, classes)
            >>> fwd = plan.compile()
            >>> out = fwd(params, x)          # traces + compiles once
            >>> out = fwd(params, x)          # cached executable
            >>> fwd.num_traces
            1
        """
        if not self.compile_supported:
            raise ValueError(
                "plan.compile() needs trace-pure dispatch on every layer; "
                "a Pallas-tier layer is missing its plan-owned blocked "
                "layout (build plans through build_plan/plan_for_* rather "
                "than by hand)")
        if dynamic:
            if layer is not None:
                raise ValueError("dynamic compilation covers the full "
                                 "forward; layer= is incompatible")
            self._check_dynamic_ok()
        key = (bool(donate), layer, bool(dynamic))
        fn = self._compiled.get(key)
        if fn is None:
            fn = self._compiled[key] = CompiledPlan(self, donate=donate,
                                                    layer=layer,
                                                    dynamic=dynamic)
        return fn

    def run_phases(self, x: jnp.ndarray, weights, *, layer: int = 0,
                   edge_weight=None, activation: str = "relu",
                   bias_post=None, _probe=None) -> jnp.ndarray:
        """Raw weight-list execution (the ``phase_ordered_layer`` entry).

        ``weights`` is a list of (W, b) tuples with biases applied *inside*
        the combination MLP (``phases.combine`` semantics); ``bias_post``
        is an optional extra bias added after aggregation (conv semantics).
        Like ``run_model``, takes and returns the natural vertex order: on
        a reordered plan rows are permuted in and un-permuted out (but
        per-edge ``edge_weight`` is rejected there -- the caller's edge
        order does not survive the renumbering's re-sort).
        """
        if self.perm is not None:
            if edge_weight is not None:
                raise ValueError(
                    "edge_weight is indexed by the caller's edge order, "
                    "which a reordered plan re-sorts; use reorder='none' "
                    "or fold the weights into the graph at plan build")
            # reorder permute ONLY -- run_phases always executes the local
            # path, so partition padding (_ingress's other job) must not
            # apply even on distributed plans
            x = jnp.take(x, self.inv, axis=0)
            if _probe is not None:
                _probe.note_reorder()
        h = _execute_layer(self.g, self.layers[layer], x, weights,
                           edge_weight=edge_weight, activation=activation,
                           bias_post=bias_post, probe=_probe,
                           dtype=self.dtype, dedup=self.dedup_layout)
        if self.perm is not None:
            h = jnp.take(h, self.perm, axis=0)
        return h

    def _run_distributed(self, lp: LayerPlan, x, weights, bias_post, *,
                         probe=None):
        from repro.core.distributed import (distributed_gcn_layer,
                                            distributed_gcn_layer_2d)
        (w, b_inline), = weights  # build_plan guarantees single-matmul layers
        bias = bias_post if bias_post is not None else b_inline
        if bias is None:
            bias = jnp.zeros((w.shape[1],), x.dtype)
        if self.partition_kind == "2d":
            thunk = lambda: distributed_gcn_layer_2d(  # noqa: E731
                self.partition, x, w, bias, self.g.in_deg, self.mesh,
                order=lp.order, strategy=self.strategy, axes=self.axes,
                overlap=self.overlap, dtype=self.dtype)
        else:
            thunk = lambda: distributed_gcn_layer(  # noqa: E731
                self.partition, x, w, bias, self.g.in_deg, self.mesh,
                order=lp.order, strategy=self.strategy, axis=self.axis,
                overlap=self.overlap, dtype=self.dtype)
        # halo feature length: what the exchange moves under this ordering;
        # overlap rides along so the probe prices the schedule that
        # actually dispatched (exposed vs. overlapped collective time);
        # the quant error reported for reduced plans is the layer-ingress
        # operand's (the per-shard exchange operand is shard_map-internal)
        agg_len = lp.din if lp.order == AGGREGATE_FIRST else lp.dout
        qerr = 0.0
        if probe is not None and self.dtype != "f32":
            qerr = _quant_err(x, _reduce_in(x, self.dtype))
        return _phase(probe, "distributed", thunk, lp=lp,
                      feature_len=agg_len, overlap=self.overlap,
                      quant_error=qerr)

    def instrument(self, machine=None, warmup: int = 0):
        """Wrap this plan for characterization (``repro.profile``).

        Returns an ``InstrumentedPlan`` whose ``run_model`` / ``run_layer``
        / ``run_phases`` execute the SAME dispatch path as this plan while
        recording per-layer, per-phase FLOPs / bytes / wall time into a
        ``WorkloadReport`` (with ``to_json()`` / ``to_markdown()``).

        ``machine`` is a ``repro.profile.Machine`` (or registry name, e.g.
        ``"a100"``); defaults to the plan's own machine or the first layer
        backend's natural preset.

        Worked example (the one-call characterization path)::

            >>> report = build_plan(g, cfg, in_dim, classes).instrument(
            ...     machine=A100).run_model(params, x)
            >>> report.output.shape            # the forward result
            (220, 7)
            >>> print(report.to_markdown())    # Table-3/4-style breakdown
        """
        from repro.profile.instrument import InstrumentedPlan
        from repro.profile.machine import get_machine
        if machine is not None:
            machine = get_machine(machine)
        return InstrumentedPlan(self, machine=machine, warmup=warmup)

    # -- introspection ------------------------------------------------------

    def describe(self) -> List[Dict]:
        """One dict per layer: every planned decision + modeled agg cost.

        ``reorder`` is the resolved locality decision ("none" | "degree"),
        ``dtype`` the resolved execution precision ("f32" | "bf16" |
        "int8-agg" -- never "auto"),
        and ``compiled`` the trace-purity capability (``plan.compile()``
        works iff True -- always, for plans built by the public entry
        points).  N.B. one-off Pallas aggregation on an UN-planned graph
        (``kernels.ops.seg_agg`` without a layout) still pays host-side
        regrouping per call and cannot trace -- route repeated work
        through a plan.
        """
        out = []
        compiled_ok = self.compile_supported
        for lp in self.layers:
            oc = ordering_cost(self.g, lp.din, lp.dout, lp.order)
            out.append({
                "layer": lp.index, "kind": lp.kind,
                "din": lp.din, "dout": lp.dout,
                "order": lp.order, "backend": lp.backend,
                "fused": lp.fused, "tile_m": lp.tile_m,
                "interpret": self.interpret,
                "distributed": self.distributed,
                "partition": self.partition_kind,
                "overlap": self.overlap, "dtype": self.dtype,
                "reorder": self.reorder, "compiled": compiled_ok,
                "dedup": self.dedup,
                "agg_bytes": oc.agg_bytes, "agg_flops": oc.agg_flops,
            })
        return out

    def layer_costs(self, layer: int = 0) -> Dict:
        """Analytic per-phase costs of one planned layer (Table 3/4)."""
        lp = self.layers[layer]
        agg_len = lp.din if lp.order == AGGREGATE_FIRST else lp.dout
        return {
            "order": lp.order,
            "aggregation": phases.aggregate_cost(self.g, agg_len),
            "combination": phases.combine_cost(self.g.num_vertices, lp.dims),
            "ordering_cost": ordering_cost(self.g, lp.din, lp.dout, lp.order),
        }


class CompiledPlan:
    """A plan's forward as ONE jitted callable, with a retrace guard.

    Built by ``plan.compile()``.  ``__call__(params, x)`` runs the compiled
    executable; the first call per input signature traces (``num_traces``
    counts), and a re-trace for a signature that was already traced raises
    ``RuntimeError`` -- the guard that catches accidental cache-busting
    (e.g. weak types or recreated plans) instead of silently recompiling
    every step.
    """

    def __init__(self, plan: "GraphExecutionPlan", *, donate: bool = False,
                 layer: Optional[int] = None, dynamic: bool = False):
        self.plan = plan
        self.donate = donate
        self.layer = layer
        self.dynamic = dynamic
        self._num_traces = 0
        self._seen = set()

        def fwd(params, x):
            self._num_traces += 1   # runs at TRACE time only
            if layer is None:
                return plan.run_model(params, x)
            return plan.run_layer(params, x, layer=layer)

        def fwd_dynamic(params, x, src, dst, in_deg, *ded):
            self._num_traces += 1   # runs at TRACE time only
            g = plan.g._replace(src=src, dst=dst, in_deg=in_deg,
                                row_ptr=None)
            lay = None
            if ded:
                # runtime two-level dedup arrays (shapes fixed by the
                # plan's template layout; content varies per block)
                pl, pr, s2, d2 = ded
                lay = plan.dedup_layout._replace(
                    pair_left=pl, pair_right=pr, src2=s2, dst2=d2,
                    blocked=None)
            return plan.run_model(params, x, graph=g, dedup_layout=lay)

        if dynamic:
            self._fn = jax.jit(fwd_dynamic,
                               donate_argnums=(1,) if donate else ())
        else:
            self._fn = jax.jit(fwd, donate_argnums=(1,) if donate else ())

    @property
    def num_traces(self) -> int:
        """How many times the callable has been traced (compiled)."""
        return self._num_traces

    @staticmethod
    def _signature(params, *arrays):
        leaves, treedef = jax.tree_util.tree_flatten(params)
        return (tuple((tuple(a.shape), str(getattr(a, "dtype", type(a))))
                      for a in arrays), treedef,
                tuple((tuple(p.shape), str(p.dtype)) for p in leaves))

    def _graph_args(self, graph: Graph):
        """Validate + destructure a runtime graph for the dynamic mode.

        Shape mismatches are raised HERE (a bucket-contract violation the
        serving engine must catch), never silently absorbed by a retrace."""
        t = self.plan.g
        if graph.num_vertices != t.num_vertices or \
                graph.src.shape != t.src.shape or \
                graph.in_deg.shape != t.in_deg.shape:
            raise ValueError(
                f"dynamic graph shape {graph.num_vertices}V/"
                f"{graph.src.shape[0]}E does not match the bucket template "
                f"{t.num_vertices}V/{t.src.shape[0]}E -- pad the block "
                "into the bucket before dispatch")
        return (jnp.asarray(graph.src), jnp.asarray(graph.dst),
                jnp.asarray(graph.in_deg))

    def _dedup_args(self, dedup):
        """Validate + destructure runtime dedup arrays (dynamic mode on a
        ``dedup='pairs'`` plan).  ``dedup`` is a ``DedupLayout`` (or the
        4-tuple of its arrays) padded to the template layout's shapes."""
        t = self.plan.dedup_layout
        if hasattr(dedup, "pair_left"):
            dedup = (dedup.pair_left, dedup.pair_right,
                     dedup.src2, dedup.dst2)
        pl, pr, s2, d2 = (jnp.asarray(a) for a in dedup)
        if pl.shape[0] != t.num_pairs or s2.shape[0] != t.num_edges2:
            raise ValueError(
                f"dynamic dedup shapes {pl.shape[0]}P/{s2.shape[0]}E2 do "
                f"not match the bucket template {t.num_pairs}P/"
                f"{t.num_edges2}E2 -- pad via graph.dedup.pad_dedup_arrays")
        return (pl, pr, s2, d2)

    def __call__(self, params, x, graph: Optional[Graph] = None,
                 dedup=None):
        if self.dynamic:
            if graph is None:
                raise ValueError("dynamic compiled plans take (params, x, "
                                 "graph)")
            args = (x,) + self._graph_args(graph)
            if self.plan.dedup == "pairs":
                if dedup is None:
                    raise ValueError(
                        "this dynamic plan was compiled with dedup='pairs'; "
                        "pass the block's padded dedup layout (dedup=)")
                args = args + self._dedup_args(dedup)
            elif dedup is not None:
                raise ValueError("dedup arrays passed to a dedup='none' "
                                 "compiled plan")
        else:
            if graph is not None:
                raise ValueError("this compiled plan is static; build it "
                                 "with plan.compile(dynamic=True) to pass "
                                 "a runtime graph")
            args = (x,)
        sig = self._signature(params, *args)
        before = self._num_traces
        out = self._fn(params, *args)
        if self._num_traces > before and sig in self._seen:
            raise RuntimeError(
                "plan.compile() retraced for an input signature it already "
                "compiled -- something is busting the jit cache (weak "
                "types? fresh arrays with different dtypes?)")
        self._seen.add(sig)
        return out


# ---------------------------------------------------------------------------
# Layer execution core (the ONE place ordering x backend x fusion composes)
# ---------------------------------------------------------------------------


def _fused_agg_op(lp: LayerPlan) -> Optional[str]:
    """Map a layer's aggregation semantics onto fused_gcn_layer's modes."""
    if lp.agg_op == "mean":
        return "mean" if lp.include_self else None
    if lp.agg_op == "sum":
        return "sum_self" if lp.include_self else "sum"
    return None  # max: non-linear, cannot fuse


def _can_fuse(lp: LayerPlan, weights, edge_weight) -> bool:
    if not (lp.fused and lp.blocked is not None and edge_weight is None):
        return False
    if _fused_agg_op(lp) is None:
        return False
    # An inline bias on the fused matmul is exact when it applies after the
    # reduction (aggregate-first) or commutes with it (mean of a constant
    # row is that row); otherwise fall back to the unfused path.
    b0 = weights[0][1]
    return b0 is None or lp.order == AGGREGATE_FIRST or lp.agg_op == "mean"


def _phase(probe, name: str, thunk, *, lp: LayerPlan, **meta):
    """Run one phase, optionally observed by an instrumentation probe.

    ``probe`` is the characterization hook (``repro.profile.instrument``):
    None in production (zero overhead -- the thunk runs directly); when set,
    ``probe.run`` times the phase and records its analytic cost.  Keeping
    the hook HERE means reports always describe the dispatch path that
    actually ran, not a parallel re-implementation.
    """
    if probe is None:
        return thunk()
    return probe.run(name, thunk, lp=lp, **meta)


def _round(h: jnp.ndarray, dtype: str) -> jnp.ndarray:
    """Round a phase output back to the plan dtype's storage precision.
    Identity for f32 and int8-agg (whose phase outputs stay f32)."""
    return h.astype(jnp.bfloat16) if dtype == "bf16" else h


def _reduce_in(h: jnp.ndarray, dtype: str) -> jnp.ndarray:
    """Reduced-precision image of one phase operand: bf16 cast, int8
    per-row fake-quant, or identity for f32."""
    if dtype == "bf16":
        return h.astype(jnp.bfloat16)
    if dtype == "int8-agg":
        return phases.quantize_int8(h)
    return h


def _quant_err(orig: jnp.ndarray, reduced: jnp.ndarray) -> float:
    """Max abs error a precision reduction introduced (probe-time only:
    forces a host sync, so production dispatch never calls it)."""
    return float(jnp.max(jnp.abs(  # analysis: allow(host-in-trace)
        orig.astype(jnp.float32) - reduced.astype(jnp.float32))))


def _dedup_fused_inputs(dedup, xa):
    """Level-1 partials + the (V + P)-row concat for a FUSED dedup layer.

    Mirrors ``phases.aggregate``'s dedup path: cast to f32 first (exact),
    add each matched pair once, stack the partials under the features so
    the fused kernel's gather (over ``dedup.blocked``, the level-2 edge
    list) references them like ordinary rows.
    """
    xf = xa if xa.dtype == jnp.float32 else xa.astype(jnp.float32)
    partials = jnp.take(xf, dedup.pair_left, axis=0) + \
        jnp.take(xf, dedup.pair_right, axis=0)
    return jnp.concatenate([xf, partials], axis=0)


def _execute_layer(g: Graph, lp: LayerPlan, x: jnp.ndarray, weights, *,
                   edge_weight=None, activation: str = "relu",
                   bias_post=None, probe=None,
                   dtype: str = "f32", dedup=None) -> jnp.ndarray:
    """Execute one layer per its plan: fusion > ordering > backend.

    ``dtype`` is the plan's resolved execution precision.  ``"f32"`` takes
    the unmodified path (every cast below is guarded, so the default stays
    bitwise-golden).  ``"bf16"`` casts the operands once at entry and
    rounds each phase output back to bf16 -- reductions and matmuls still
    accumulate f32 (kernel scratch / ``preferred_element_type``).
    ``"int8-agg"`` fake-quantizes ONLY the aggregation operand (per-row
    symmetric scales via ``phases.quantize_int8``), aggregates the
    int8-representable rows in f32, and leaves combination in full f32.

    ``dedup`` is the plan's two-level pair-redundancy layout
    (``graph.dedup.DedupLayout``) or None.  Unfused paths hand it to
    ``phases.aggregate``; the fused path swaps the layer's blocked layout
    for the layout's level-2 blocking and feeds the kernel the
    ``[x ; partials]`` concat.  It only applies where the planner admitted
    it (sum/mean, no edge weights) -- anything else falls back naive.
    """
    entry_err = 0.0
    if dtype == "bf16":
        xr = x.astype(jnp.bfloat16)
        if probe is not None:
            entry_err = _quant_err(x, xr)
        x = xr
        weights = [(w.astype(jnp.bfloat16),
                    None if b is None else b.astype(jnp.bfloat16))
                   for (w, b) in weights]
        if bias_post is not None:
            bias_post = bias_post.astype(jnp.bfloat16)
    mlp_dims = tuple([int(w.shape[0]) for (w, _) in weights] +
                     [int(weights[-1][0].shape[1])])
    if _can_fuse(lp, weights, edge_weight):
        w0, b0 = weights[0]
        fused_dims = (int(w0.shape[0]), int(w0.shape[1]))
        xa, agg_err = x, entry_err
        if dtype == "int8-agg":
            xa = phases.quantize_int8(x)
            if probe is not None:
                agg_err = _quant_err(x, xa)
        # dedup rides the fused path by swapping in the level-2 blocking
        # and the [x ; partials] gather source; the in-tile reduce + GEMM
        # and the self/mean terms (which index the first V rows) are
        # untouched.
        fbg, fx = lp.blocked, xa
        if dedup is not None and dedup.num_pairs > 0 \
                and dedup.blocked is not None:
            fbg, fx = dedup.blocked, _dedup_fused_inputs(dedup, xa)
        if len(weights) == 1:
            # Whole layer fused: aggregate(+)combine never leaves the tile.
            # An inline b0 is exact applied post-aggregation here (that is
            # what _can_fuse admitted), so fold it into the final bias.
            bias = b0 if bias_post is None else (
                bias_post if b0 is None else b0 + bias_post)
            h = _phase(
                probe, "fused_agg_combine",
                lambda: fused_gcn_layer(fbg, fx, w0, bias,
                                        agg_op=_fused_agg_op(lp),
                                        in_deg=g.in_deg, backend=lp.backend),
                lp=lp, dims=fused_dims, quant_error=agg_err)
            return _round(h, dtype)
        # Multi-layer MLP (GIN): fuse aggregation with the FIRST matmul --
        # exact because sum/mean aggregation is linear and the interior
        # nonlinearity only applies after that matmul.
        h = _phase(
            probe, "fused_agg_combine",
            lambda: fused_gcn_layer(fbg, fx, w0, b0,
                                    agg_op=_fused_agg_op(lp),
                                    in_deg=g.in_deg, backend=lp.backend),
            lp=lp, dims=fused_dims, quant_error=agg_err)
        h = _round(phases._act(activation)(h), dtype)
        h = _phase(probe, "combine",
                   lambda hh=h: phases.combine(hh, weights[1:],
                                               activation=activation),
                   lp=lp, dims=mlp_dims[1:])
        h = _round(h, dtype)
    elif lp.order == COMBINE_FIRST:
        h = _phase(probe, "combine",
                   lambda: phases.combine(x, weights, activation=activation),
                   lp=lp, dims=mlp_dims, quant_error=entry_err)
        h = _round(h, dtype)
        ha, agg_err = h, 0.0
        if dtype == "int8-agg":
            ha = phases.quantize_int8(h)
            if probe is not None:
                agg_err = _quant_err(h, ha)
        h = _phase(probe, "aggregate",
                   lambda hh=ha: phases.aggregate(
                       g, hh, op=lp.agg_op, edge_weight=edge_weight,
                       include_self=lp.include_self, backend=lp.backend,
                       layout=lp.agg_layout, dedup=dedup),
                   lp=lp, feature_len=int(h.shape[-1]), quant_error=agg_err)
        h = _round(h, dtype)
    else:
        xa, agg_err = x, entry_err
        if dtype == "int8-agg":
            xa = phases.quantize_int8(x)
            if probe is not None:
                agg_err = _quant_err(x, xa)
        h = _phase(probe, "aggregate",
                   lambda: phases.aggregate(
                       g, xa, op=lp.agg_op, edge_weight=edge_weight,
                       include_self=lp.include_self, backend=lp.backend,
                       layout=lp.agg_layout, dedup=dedup),
                   lp=lp, feature_len=int(x.shape[-1]), quant_error=agg_err)
        h = _round(h, dtype)
        h = _phase(probe, "combine",
                   lambda hh=h: phases.combine(hh, weights,
                                               activation=activation),
                   lp=lp, dims=mlp_dims)
        h = _round(h, dtype)
    if bias_post is not None:
        h = h + bias_post
    return h


# ---------------------------------------------------------------------------
# Plan construction + caching
# ---------------------------------------------------------------------------

_PLAN_CACHE: Dict = {}      # (graph_key, spec_key) -> (src_ref, plan)
_BLOCKED_CACHE: Dict = {}   # (graph_key, tile_m)   -> (src_ref, BlockedGraph)
_CACHE_LIMIT = 64


_REORDER_CACHE: Dict = {}   # graph_key -> (src_ref, reordered Graph, perm)

#: plan-cache accounting (the serving engine's eviction policy reads these):
#: hits/misses count ``_cached_plan`` lookups, evictions count every entry
#: dropped -- FIFO aging in ``_evict_oldest`` AND explicit
#: ``clear_plan_cache(keep=...)`` sweeps.
_PLAN_CACHE_STATS: Dict[str, int] = {"hits": 0, "misses": 0, "evictions": 0}


def plan_cache_stats() -> Dict[str, int]:
    """Observable plan-cache state: ``{size, limit, hits, misses,
    evictions, blocked_size, reorder_size}``.

    ``size`` counts live ``_PLAN_CACHE`` entries; ``hits``/``misses`` count
    cached-plan lookups since the last full ``clear_plan_cache()``;
    ``evictions`` counts entries dropped by FIFO aging or by
    ``clear_plan_cache(keep=...)``.  The serving engine's eviction policy
    (``repro.serve.graph_engine``) polls this to decide when to sweep
    transient per-request plans, and tests assert on it -- previously the
    cache internals were private and untestable.
    """
    return {"size": len(_PLAN_CACHE), "limit": _CACHE_LIMIT,
            "blocked_size": len(_BLOCKED_CACHE),
            "reorder_size": len(_REORDER_CACHE),
            **_PLAN_CACHE_STATS}


def clear_plan_cache(keep=None) -> int:
    """Drop cached plans (and their blocked/reorder cache lines).

    ``keep=None`` wipes everything and resets the hit/miss/eviction
    counters (the test-isolation path).  ``keep=<iterable of
    GraphExecutionPlan>`` is the serving engine's eviction policy: every
    cached plan NOT in ``keep`` is evicted, while the kept plans -- e.g.
    the engine's per-bucket compiled plans -- and the blocked/reorder
    layouts of their graphs survive, so a bounded bucket set keeps a
    bounded cache no matter how many transient per-request graphs were
    planned.  ``evictions`` counts every dropped line -- plan entries AND
    the blocked/reorder layouts swept with them -- and the hit/miss
    counters keep accumulating across the sweep.  Returns the number of
    plan entries dropped.
    """
    if keep is None:
        n = len(_PLAN_CACHE)
        _PLAN_CACHE.clear()
        _BLOCKED_CACHE.clear()
        _REORDER_CACHE.clear()
        _PLAN_CACHE_STATS.update(hits=0, misses=0, evictions=0)
        return n
    keep_plans = {id(p) for p in keep}
    keep_graphs = {_graph_key(p.g) for p in keep}
    drop = [k for k, (_, plan) in _PLAN_CACHE.items()
            if id(plan) not in keep_plans]
    for k in drop:
        del _PLAN_CACHE[k]
    blocked_drop = [k for k in _BLOCKED_CACHE if k[0] not in keep_graphs]
    for k in blocked_drop:
        del _BLOCKED_CACHE[k]          # key = (graph_key, tile_m)
    reorder_drop = [k for k in _REORDER_CACHE if k not in keep_graphs]
    for k in reorder_drop:
        del _REORDER_CACHE[k]          # key = graph_key
    # every dropped line counts -- plan entries AND the blocked/reorder
    # layouts swept with them (the stats docstring's contract); hit/miss
    # counters are untouched, so they survive an eviction cycle
    _PLAN_CACHE_STATS["evictions"] += \
        len(drop) + len(blocked_drop) + len(reorder_drop)
    return len(drop)


def _graph_key(g: Graph):
    if isinstance(g.src, jax.core.Tracer):
        raise ValueError(
            "build_plan needs a concrete Graph; build the plan outside jit "
            "and close over it (plans precompute host-side structures)")
    return (id(g.src), int(g.num_vertices), int(g.src.shape[0]))


def _evict_oldest(cache: Dict) -> None:
    """FIFO eviction: transient graphs (e.g. per-batch sampled blocks) age
    out one at a time instead of wiping hot full-graph entries wholesale."""
    while len(cache) >= _CACHE_LIMIT:
        cache.pop(next(iter(cache)))
        # every dropped line counts, whichever cache aged it out
        _PLAN_CACHE_STATS["evictions"] += 1


def _blocked_for(g: Graph, tile_m: int) -> BlockedGraph:
    """Build (or reuse) the BlockedGraph for (graph, tile_m).

    The regrouping is O(E) host work; plans for the same graph -- across
    rebuilds, convs, and benchmark scenarios -- share one copy.
    """
    key = (_graph_key(g), tile_m)
    hit = _BLOCKED_CACHE.get(key)
    if hit is not None and hit[0] is g.src:
        return hit[1]
    _evict_oldest(_BLOCKED_CACHE)
    bg = block_graph(g, tile_m)
    _BLOCKED_CACHE[key] = (g.src, bg)
    return bg


def _reordered_for(g: Graph):
    """Degree-reordered twin of ``g`` (cached): the O(V log V + E) renumber
    runs once per graph; every plan spec (fused/unfused, any backend) on
    the same graph shares one reordered copy -- and therefore one
    BlockedGraph cache line per tile."""
    key = _graph_key(g)
    hit = _REORDER_CACHE.get(key)
    if hit is not None and hit[0] is g.src:
        return hit[1], hit[2]
    from repro.graph.reorder import degree_reorder
    _evict_oldest(_REORDER_CACHE)
    g2, perm = degree_reorder(g)
    _REORDER_CACHE[key] = (g.src, g2, perm)
    return g2, perm


def _cached_plan(g: Graph, spec_key, builder):
    key = (_graph_key(g), spec_key)
    hit = _PLAN_CACHE.get(key)
    if hit is not None and hit[0] is g.src:
        _PLAN_CACHE_STATS["hits"] += 1
        return hit[1]
    _PLAN_CACHE_STATS["misses"] += 1
    _evict_oldest(_PLAN_CACHE)
    plan = builder()
    _PLAN_CACHE[key] = (g.src, plan)
    return plan


def _plan_layer(g: Graph, index: int, kind: str, dims: Tuple[int, ...], *,
                agg_op: str, ordering: str, backend: str, fused: bool,
                include_self: bool = True, machine=None,
                dtype: str = "f32") -> LayerPlan:
    """Resolve one layer's ordering / backend / fusion decisions.

    ``machine`` (``repro.profile.Machine``, optional) parameterizes the two
    hardware-aware decisions: the ordering cost model prices roofline time
    on it and ``suggest_tile_m`` sizes the fused tile for its memory
    hierarchy.  None keeps the tier's natural preset.

    ``dtype`` is the plan's RESOLVED execution precision (never "auto"):
    the fused tile is sized at the storage width the kernel's gathered
    rows actually occupy, so bf16 plans get the doubled effective
    on-chip budget ``dtype_model`` surfaces as ``tile_rows``.  int8-agg
    sizes at 4 bytes like f32 -- its fake-quantized aggregation operand
    is carried as f32 on device (only the analytic wire model prices the
    1-byte width).
    """
    semantic = AGGREGATE_FIRST if len(dims) > 2 else COMBINE_FIRST
    if ordering in (COMBINE_FIRST, AGGREGATE_FIRST):
        order = ordering if len(dims) <= 2 else AGGREGATE_FIRST  # GIN pinned
    else:
        order = choose_ordering(g, dims[0], dims[-1], agg_op=agg_op,
                                n_mlp_layers=len(dims) - 1,
                                semantic_order=semantic, machine=machine)
    backend = resolve_backend(backend)
    fused = bool(fused) and agg_op in ("sum", "mean")
    tile_m, blocked = 0, None
    align = 32 if backend == PALLAS_GPU else 8
    if fused:
        avg_deg = g.num_edges / max(1, g.num_vertices)
        tile_m = suggest_tile_m(dims[0], dims[1], avg_deg,
                                dtype_bytes=2 if dtype == "bf16" else 4,
                                backend=backend, machine=machine)
        # a tile larger than the graph only pads; clamp to |V| rounded up,
        # keeping the tier's alignment (warp rows on GPU, sublanes on TPU)
        tile_m = max(align, min(tile_m, -(-g.num_vertices // align) * align))
        blocked = _blocked_for(g, tile_m)
    agg_layout = None
    if backend in (PALLAS_TPU, PALLAS_GPU):
        # plan-owned layout for the UNFUSED seg_agg path (also the fusion
        # fallback's), so dispatch never regroups on the host (trace-pure)
        atile = max(align, min(128, -(-g.num_vertices // align) * align))
        agg_layout = _blocked_for(g, atile)
    return LayerPlan(index=index, kind=kind, dims=tuple(int(d) for d in dims),
                     agg_op=agg_op, include_self=include_self, order=order,
                     backend=backend, fused=fused, tile_m=tile_m,
                     blocked=blocked, agg_layout=agg_layout)


def _plan_interpret(interpret, backend: str) -> bool:
    """Plan-level interpret flag: tier-aware for Pallas backends (compiled
    only on the tier's native platform -- ``backend.interpret_for``),
    platform default otherwise, explicit override always wins."""
    if interpret is not None:
        return bool(interpret)
    if backend in (PALLAS_TPU, PALLAS_GPU):
        return interpret_for(backend)
    return resolve_interpret(None)


def _mesh_key(mesh):
    """Cache key for a mesh: identity PLUS shape/axis names, so an address
    reused by a differently-shaped mesh can never alias a cached plan."""
    if mesh is None:
        return None
    return (id(mesh), tuple(getattr(mesh, "axis_names", ())),
            tuple(mesh.devices.shape))


def build_plan(g: Graph, cfg, in_dim: int, num_classes: int, *,
               backend: str = AUTO, fused: Optional[bool] = None,
               ordering: Optional[str] = None, mesh=None,
               num_shards: int = 0, strategy: str = "ring",
               axis: str = "data", interpret: Optional[bool] = None,
               machine=None, reorder: str = "none",
               overlap: str = "none", dtype: str = "f32",
               dedup: str = "none",
               dedup_pad: Optional[tuple] = None) -> GraphExecutionPlan:
    """Plan a full model (``GCNModelConfig``) over one graph.

    Overrides: ``backend`` ("auto" resolves per platform -- see
    ``core.backend.resolve_backend``), ``fused`` / ``ordering`` (default
    from cfg), ``mesh`` (+ optionally ``num_shards``) for the shard
    partition, ``machine`` (a ``repro.profile.Machine`` or registry name:
    parameterizes the hardware-aware decisions -- ordering cost model, fused
    tile sizing, the ``reorder="auto"`` pricing -- and becomes the default
    for ``plan.instrument()``).
    Plans are cached: calling again with the same graph and
    arguments returns the same plan object (and any rebuilt plan on the
    same graph reuses the cached BlockedGraph).

    The ``reorder=`` contract (paper §5.1 guideline 1 as a planned
    decision):

      * ``"none"`` (default): execute in the caller's vertex numbering.
      * ``"degree"``: apply ``graph.reorder.degree_reorder`` ONCE at plan
        build (cached per graph); the plan stores perm/inverse, permutes
        features at ingress and un-permutes logits at egress *inside* the
        (traced) forward -- callers always pass and receive the natural
        vertex order, and ``plan.compile()`` bakes the gathers into the
        compiled executable.
      * ``"auto"``: decide from ``graph.reorder.choose_reorder`` --
        reuse-distance stats of the gather stream priced against the
        plan's ``machine`` (its on-chip row budget at ``in_dim``); picks
        "degree" only when the renumbering materially improves the modeled
        hit ratio.

    ``plan.describe()`` reports the resolved decision per layer.

    The ``overlap=`` contract (the distributed halo SCHEDULE, a planned
    decision like ordering/reorder):

      * ``"none"`` (default): single-buffered ring -- each hop's send waits
        behind its partial combine, collective time fully exposed.
      * ``"pipelined"``: double-buffered ring -- each ``ppermute`` is
        issued first and rides under the resident slab's partial combine;
        bit-for-bit equal outputs (eager and compiled), P-1 sends instead
        of P.  Requires ``strategy="ring"``.
      * ``"auto"``: priced by ``core.distributed.choose_overlap`` against
        the plan's ``machine`` (per-hop link bytes+latency vs. per-hop
        combine work, summed over the layers' exchanged widths); resolves
        to "pipelined" only when the hidden collective time is material.

    Local plans (``mesh=None``) always resolve to ``"none"``; the resolved
    schedule is stored on the plan, surfaced in ``describe()``, priced in
    ``plan.instrument()`` reports (exposed vs. overlapped collective
    time), and part of the plan cache key.

    The ``dtype=`` contract (execution precision as a planned decision):

      * ``"f32"`` (default): full precision -- bitwise-identical to every
        pre-dtype plan, eager and under ``plan.compile()``.
      * ``"bf16"``: aggregate AND combine run on bf16 operands with f32
        accumulators (kernel scratch / ``preferred_element_type``); halo
        exchanges move bf16 payloads -- exactly half the f32 bytes.
      * ``"int8-agg"``: only the AGGREGATION operand is quantized (per-row
        symmetric int8 scales, f32 accumulate, dequantized before
        combination stays f32).  Never auto-chosen -- the quantization
        error is a semantic opt-in.
      * ``"auto"``: resolved by ``profile.machine.choose_dtype`` against
        the plan's ``machine`` -- HBM aggregation traffic, matmul peak per
        precision (``Machine.native_bf16``), and the sharded halo's
        ``hop_time`` on the reduced payload.  Flips between presets:
        bf16 on TPU_V5E/A100, f32 on the paper's V100.

    The resolved dtype is stored on the plan (``plan.dtype``), surfaced in
    ``describe()``, recorded per phase by ``plan.instrument()`` (with the
    measured quantization error), and part of the plan cache key.

    The ``dedup=`` contract (redundancy-eliminated aggregation as a
    planned decision -- GraphACT-style, see ``graph.dedup``):

      * ``"none"`` (default): the naive per-edge fold, unchanged.
      * ``"pairs"``: ``dedup_layout_for_graph`` runs ONCE at plan build --
        greedy leading-pair matching over the dst-sorted edge list -- and
        the plan aggregates two-level: matched pair partials computed once
        (level 1), then a shortened edge list over ``[x ; partials]``
        (level 2).  f32 results stay BITWISE-identical to the naive fold,
        eager and under ``plan.compile()`` (the matching discipline only
        regroups the provably exact prefix of each segment's left fold).
        A graph with zero matchable pairs resolves back to "none".
      * ``"auto"``: priced by ``profile.machine.choose_dedup`` against the
        plan's ``machine`` -- modeled HBM aggregation bytes of the
        two-level layout vs. the naive fold at the widest layer's feature
        length; picks "pairs" only when the modeled saving is material
        (fanout-regular sampled blocks), "none" on sparse full-graph
        layers where few destinations share a leading pair.

    Dedup applies to the sum/mean aggregation paths (XLA, both Pallas
    tiers, and the fused executor); distributed plans and ``max``
    aggregation coerce it to "none".  The resolved mode is stored on the
    plan (``plan.dedup``), surfaced in ``describe()``, recorded by
    ``plan.instrument()`` (``dedup_pairs`` / ``dedup_flops_saved``), and
    part of the plan cache key.

    ``dedup_pad=(num_pairs, num_edges2)`` pads the template layout's
    arrays to those static CAPACITIES with sink no-ops on the last vertex
    row (``graph.dedup.pad_dedup_arrays``) -- the bucket-plan form: a
    ``compile(dynamic=True)`` callable built from the padded template
    accepts any sampled block's runtime dedup arrays padded to the same
    shapes, so ONE compiled train/serve step covers blocks whose matched
    pair counts vary.  ``num_edges2`` is normally the bucket's full edge
    capacity and ``num_pairs`` its ``num_edges // 4`` upper bound (a kept
    pair needs >= 2 matched destinations x 2 edges).  Only meaningful
    with ``dedup != "none"``.

    The ``mesh=`` / ``num_shards=`` contract:

      * ``mesh=None`` (default): a local, single-device plan;
        ``num_shards`` / ``strategy`` / ``axis`` are ignored.
      * 1-D ``mesh`` (one named axis): the 1-D vertex partition.
        ``num_shards`` defaults to the mesh size when 0; ``axis`` names the
        mesh axis to shard over (default "data").
      * 2-D ``mesh`` (two named axes, (node, feature) in order): the 2-D
        node x feature partition (``graph.partition.partition_2d``); shard
        counts come from the mesh shape, ``num_shards``/``axis`` are
        ignored.  ``strategy`` ("ring" | "allgather") picks the node-axis
        halo pattern in both distributed forms.

    Worked example (local planning, CPU container)::

        >>> spec = reduced_graph(CORA, 220, 24)
        >>> g, x = make_synthetic_graph(spec), make_features(spec)
        >>> plan = build_plan(g, PAPER_MODELS["gcn"], spec.feature_len,
        ...                   spec.num_classes)         # backend="auto"
        >>> plan.describe()[0]["backend"]               # xla on CPU
        'xla'
        >>> out = plan.run_model(plan.init(jax.random.PRNGKey(0)), x)

    Worked example (2-D multi-host partition, 8 devices)::

        >>> mesh = jax.make_mesh((4, 2), ("node", "feat"))
        >>> plan = build_plan(g, cfg, spec.feature_len, spec.num_classes,
        ...                   mesh=mesh)                # 4 node x 2 feat
        >>> plan.partition_kind
        '2d'
        >>> with mesh:
        ...     out = plan.run_model(params, x)         # (V, num_classes)
    """
    agg = cfg.aggregator
    use_fused = cfg.fused if fused is None else bool(fused)
    req_order = cfg.ordering if ordering is None else ordering
    if machine is not None:
        from repro.profile.machine import get_machine
        machine = get_machine(machine)
    if reorder not in ("none", "degree", "auto"):
        raise ValueError(f"unknown reorder {reorder!r}; expected "
                         "'none' | 'degree' | 'auto'")
    if overlap not in ("none", "pipelined", "auto"):
        raise ValueError(f"unknown overlap {overlap!r}; expected "
                         "'none' | 'pipelined' | 'auto'")
    if overlap == "pipelined" and mesh is not None and strategy != "ring":
        raise ValueError("overlap='pipelined' requires strategy='ring'; "
                         "the all-gather halo has no per-hop structure "
                         "to pipeline")
    if dtype not in ("f32", "bf16", "int8-agg", "auto"):
        raise ValueError(f"unknown dtype {dtype!r}; expected "
                         "'f32' | 'bf16' | 'int8-agg' | 'auto'")
    if dedup not in ("none", "pairs", "auto"):
        raise ValueError(f"unknown dedup {dedup!r}; expected "
                         "'none' | 'pairs' | 'auto'")
    if dedup_pad is not None:
        if dedup == "none":
            raise ValueError("dedup_pad= is only meaningful with "
                             "dedup='pairs'/'auto'")
        dedup_pad = (int(dedup_pad[0]), int(dedup_pad[1]))
    spec_key = (cfg.name, cfg.conv, agg, tuple(cfg.hidden_dims),
                cfg.num_layers, int(in_dim), int(num_classes), backend,
                use_fused, req_order, _mesh_key(mesh), num_shards, strategy,
                axis, interpret, machine.name if machine else None, reorder,
                overlap, dtype, dedup, dedup_pad)

    def builder():
        # -- locality reorder decision (F4 / §5.1-1), before anything that
        #    depends on the vertex numbering (partition, blocked layouts)
        g_exec, perm, decision = g, None, reorder
        if decision != "none":
            g2, p = _reordered_for(g)
            if decision == "auto":
                from repro.graph.reorder import choose_reorder
                from repro.profile.machine import machine_for_backend
                dec_machine = machine or machine_for_backend(
                    resolve_backend(XLA if mesh is not None else backend))
                decision = choose_reorder(g, g2, p, int(in_dim),
                                          dec_machine)
            if decision == "degree":
                g_exec, perm = g2, p

        axes = ("node", "feat")
        if mesh is not None:
            if cfg.conv == "gin":
                raise ValueError(
                    "distributed plans support single-matmul convs "
                    "(gcn/sage); GIN's interior nonlinearity needs the "
                    "local path")
            axis_names = tuple(getattr(mesh, "axis_names", ()))
            if len(axis_names) == 2:                       # 2-D: node x feat
                from repro.graph.partition import partition_2d
                axes = axis_names
                p_nodes = int(mesh.shape[axis_names[0]])
                q_feats = int(mesh.shape[axis_names[1]])
                partition = partition_2d(g_exec, p_nodes, q_feats)
            else:                                          # 1-D vertex shard
                from repro.graph.partition import partition_1d
                shards = num_shards or int(mesh.devices.size)
                partition = partition_1d(g_exec, shards, edge_balanced=False)
            lay_backend, lay_fused = XLA, False  # shard_map path is XLA
        else:
            partition = None
            lay_backend, lay_fused = backend, use_fused

        hid = cfg.hidden_dims[0]
        dims_list = []
        d = in_dim
        for i in range(cfg.num_layers):
            dout = hid if i < cfg.num_layers - 1 else num_classes
            dims_list.append((d, cfg.hidden_dims[-1], dout)
                             if cfg.conv == "gin" else (d, dout))
            d = dout

        # -- execution precision (a planned decision like ordering):
        #    "auto" is priced HERE, from the layer dims and shard count,
        #    BEFORE the layers are planned -- the fused tile sizing
        #    consumes the resolved dtype's effective on-chip budget
        dt = dtype
        if dt == "auto":
            from repro.profile.machine import choose_dtype, \
                machine_for_backend
            dec_machine = machine or machine_for_backend(
                resolve_backend(lay_backend))
            shards = 1
            if partition is not None:
                shards = getattr(partition, "num_shards", None) or \
                    getattr(partition, "nodes", partition).num_shards
            # price the widest layer: the one whose bytes dominate
            widest = max(dims_list, key=lambda ds: ds[0] * ds[-1])
            dt = choose_dtype(g_exec.num_vertices, g_exec.num_edges,
                              widest[0], widest[-1], machine=dec_machine,
                              num_shards=int(shards))

        layers = [
            _plan_layer(g_exec, i, cfg.conv, dims, agg_op=agg,
                        ordering=req_order, backend=lay_backend,
                        fused=lay_fused, machine=machine, dtype=dt)
            for i, dims in enumerate(dims_list)]

        # -- pair-redundancy elimination (a planned decision like dtype):
        #    the host-side matching runs ONCE here; "auto" prices the
        #    two-level layout's modeled HBM bytes against the naive fold.
        #    Distributed plans and max aggregation coerce to "none" (the
        #    shard halo path folds per shard; max has no shareable adds).
        dd, dlayout = dedup, None
        if partition is not None or agg == "max":
            dd = "none"
        if dd != "none":
            from repro.graph.dedup import attach_blocked, \
                dedup_layout_for_graph
            lay = dedup_layout_for_graph(g_exec)
            if dd == "auto":
                from repro.profile.machine import choose_dedup, \
                    machine_for_backend
                dec_machine = machine or machine_for_backend(
                    resolve_backend(lay_backend))
                widest = max(dims_list, key=lambda ds: ds[0] * ds[-1])
                dd = choose_dedup(g_exec.num_vertices, g_exec.num_edges,
                                  widest[0], num_pairs=lay.num_pairs,
                                  num_edges2=lay.num_edges2,
                                  machine=dec_machine, dtype=dt)
            if dd == "pairs" and lay.num_pairs == 0:
                dd = "none"                 # nothing matchable: no-op plan
            if dd == "pairs" and dedup_pad is not None:
                # bucket form: pad the template layout to the requested
                # static capacities with sink no-ops (last vertex row)
                from repro.graph.dedup import pad_dedup_arrays
                pcap, ecap = dedup_pad
                pl_, pr_, s2_, d2_ = pad_dedup_arrays(
                    lay, pcap, ecap, g_exec.num_vertices - 1)
                lay = lay._replace(
                    pair_left=jnp.asarray(pl_), pair_right=jnp.asarray(pr_),
                    src2=jnp.asarray(s2_), dst2=jnp.asarray(d2_),
                    num_pairs=pcap, num_edges2=ecap)
            if dd == "pairs":
                if any(lp.fused and lp.blocked is not None for lp in layers) \
                        or any(is_pallas(lp.backend) for lp in layers):
                    tiles = [lp.blocked.tile_m for lp in layers
                             if lp.fused and lp.blocked is not None]
                    align = 32 if layers[0].backend == PALLAS_GPU else 8
                    atile = tiles[0] if tiles else max(
                        align, min(128, -(-g_exec.num_vertices // align)
                                   * align))
                    lay = attach_blocked(lay, atile)
                dlayout = lay

        # -- halo overlap schedule (a planned decision like ordering):
        #    resolved HERE so describe()/instrument()/the cache all state
        #    the schedule dispatch will actually run; local plans have no
        #    collective to schedule
        ov = overlap if partition is not None else "none"
        if ov == "auto":
            from repro.core.distributed import choose_overlap
            from repro.graph.partition import Partition2D
            from repro.profile.machine import machine_for_backend
            if isinstance(partition, Partition2D):
                pg_nodes = partition.nodes
                width = partition.feature_block
            else:
                pg_nodes, width = partition, (lambda f: f)
            # one schedule per plan, priced on what each layer's exchange
            # actually moves (dout under combine-first, din otherwise;
            # the F/Q column slice on a 2-D partition)
            lens = [width(lp.din if lp.order == AGGREGATE_FIRST
                          else lp.dout) for lp in layers]
            ov = choose_overlap(pg_nodes, lens,
                                machine or machine_for_backend(XLA),
                                strategy=strategy)

        return GraphExecutionPlan(
            g_exec, layers, interpret=_plan_interpret(interpret,
                                                      layers[0].backend),
            mesh=mesh, partition=partition, strategy=strategy, axis=axis,
            axes=axes, machine=machine, reorder=decision, perm=perm,
            overlap=ov, dtype=dt, dedup=dd, dedup_layout=dlayout)

    return _cached_plan(g, spec_key, builder)


def plan_for_conv(conv, g: Graph, *, machine=None) -> GraphExecutionPlan:
    """Single-layer plan for a standalone conv (GCNConv / SAGEConv / GINConv
    ``apply`` without a model-level plan).

    The conv's own ``ordering`` / ``backend`` / ``fused`` attributes are the
    requested decisions; this resolves them once per (conv spec, graph) and
    caches the plan, so repeated ``conv.apply(params, g, x)`` calls pay no
    planning cost.  ``machine`` (a ``repro.profile.Machine`` or registry
    name) parameterizes the hardware-aware decisions exactly as in
    ``build_plan`` -- ordering cost model and fused tile sizing -- and is
    part of the cache key (previously it was silently dropped and
    standalone convs always planned with preset defaults).

    Worked example::

        >>> conv = GCNConv(din=24, dout=8)      # backend="auto"
        >>> plan = plan_for_conv(conv, g)
        >>> plan.num_layers, plan.layers[0].kind
        (1, 'gcn')
        >>> out = plan.run_layer(conv_params, x)  # == conv.apply(...)
    """
    kind = type(conv).__name__.replace("Conv", "").lower()
    dims = (conv.din, conv.hidden, conv.dout) if kind == "gin" \
        else (conv.din, conv.dout)
    agg_op = "sum" if kind == "gin" else "mean"
    backend = getattr(conv, "backend", AUTO)
    fused = bool(getattr(conv, "fused", False))
    if machine is not None:
        from repro.profile.machine import get_machine
        machine = get_machine(machine)
    spec_key = ("conv", kind, dims, conv.ordering, backend, fused,
                machine.name if machine else None)

    def builder():
        lp = _plan_layer(g, 0, kind, dims, agg_op=agg_op,
                         ordering=conv.ordering, backend=backend,
                         fused=fused, machine=machine)
        return GraphExecutionPlan(g, [lp],
                                  interpret=_plan_interpret(None, lp.backend),
                                  machine=machine)

    return _cached_plan(g, spec_key, builder)


def plan_for_phases(g: Graph, weights, *, order: Optional[str] = None,
                    agg_op: str = "mean", backend: str = AUTO,
                    fused: bool = False, machine=None) -> GraphExecutionPlan:
    """Single-layer plan for a raw weight list (``phase_ordered_layer``).

    ``weights`` is a list of (W, b) tuples; the layer dims are inferred
    from the weight shapes.  ``order=None`` lets the scheduler's cost model
    decide (paper F2): it picks combine-first whenever the projection
    shrinks the feature length the sparse phase must move.  ``machine``
    (a ``repro.profile.Machine`` or registry name) parameterizes the
    hardware-aware decisions as in ``build_plan`` and keys the cache.

    Worked example::

        >>> w = jnp.zeros((24, 8))              # 24 -> 8 shrinks
        >>> plan = plan_for_phases(g, [(w, None)], agg_op="mean")
        >>> plan.layers[0].order
        'combine_first'
        >>> out = plan.run_phases(x, [(w, None)], activation="none")
    """
    dims = tuple([int(w.shape[0]) for (w, _) in weights] +
                 [int(weights[-1][0].shape[1])])
    if machine is not None:
        from repro.profile.machine import get_machine
        machine = get_machine(machine)
    spec_key = ("phase", dims, order, agg_op, backend, fused,
                machine.name if machine else None)

    def builder():
        lp = _plan_layer(g, 0, "phase", dims, agg_op=agg_op,
                         ordering=order or AUTO, backend=backend,
                         fused=fused, machine=machine)
        return GraphExecutionPlan(g, [lp],
                                  interpret=_plan_interpret(None, lp.backend),
                                  machine=machine)

    return _cached_plan(g, spec_key, builder)
