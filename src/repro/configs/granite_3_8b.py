"""granite-3-8b -- dense GQA.  [hf:ibm-granite/granite-3.0-2b-base family]

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
"""

import dataclasses

from repro.config import AttentionConfig, LMConfig, register


def _base() -> LMConfig:
    return LMConfig(
        name="granite-3-8b",
        family="dense",
        num_layers=40,
        d_model=4096,
        d_ff=12800,
        vocab_size=49155,
        attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128),
        mlp_activation="swiglu",
        tie_embeddings=True,
        shape_skips=("long_500k",),
        skip_reason="pure full attention; 500k decode needs sub-quadratic",
        source="hf:ibm-granite/granite-3.0-2b-base",
    )


@register("granite-3-8b")
def config() -> LMConfig:
    return _base()


def reduced() -> LMConfig:
    c = _base()
    return dataclasses.replace(
        c, name=c.name + "-smoke", num_layers=2, d_model=64, d_ff=128,
        vocab_size=256,
        attention=dataclasses.replace(c.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=16))
