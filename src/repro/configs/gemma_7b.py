"""gemma-7b -- GeGLU, head_dim=256.  [arXiv:2403.08295; hf]

28L d_model=3072 16H (GQA kv=16) d_ff=24576 vocab=256000.
(kv=16 == MHA on the 7b; the 2b sibling uses MQA.)
"""

import dataclasses

from repro.config import AttentionConfig, LMConfig, register


def _base() -> LMConfig:
    return LMConfig(
        name="gemma-7b",
        family="dense",
        num_layers=28,
        d_model=3072,
        d_ff=24576,
        vocab_size=256000,
        attention=AttentionConfig(num_heads=16, num_kv_heads=16,
                                  head_dim=256),
        mlp_activation="geglu",
        tie_embeddings=True,
        shape_skips=("long_500k",),
        skip_reason="pure full attention; 500k decode needs sub-quadratic",
        source="arXiv:2403.08295",
    )


@register("gemma-7b")
def config() -> LMConfig:
    return _base()


def reduced() -> LMConfig:
    c = _base()
    return dataclasses.replace(
        c, name=c.name + "-smoke", num_layers=2, d_model=64, d_ff=128,
        vocab_size=256,
        attention=dataclasses.replace(c.attention, num_heads=4,
                                      num_kv_heads=4, head_dim=16))
