"""Encoder-decoder backbone (seamless-m4t-medium).

The audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, S_enc, D) straight into the encoder.
Decoder layers: causal self-attn + cross-attn over encoder memory + FFN.
Both stacks scan over layers like transformer.py.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import LMConfig
from repro.launch.sharding import constrain
from repro.nn.attention import (KVCache, attention_block,
                                cross_attention_block, init_attention)
from repro.nn.layers import (embed, init_embedding, init_mlp, init_rmsnorm,
                             mlp, rmsnorm, unembed)


def init_encdec(cfg: LMConfig, key) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_enc, k_dec, k_final = jax.random.split(key, 4)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_rmsnorm(cfg.d_model),
                "attn": init_attention(k1, cfg.d_model, cfg.attention, dtype),
                "ln2": init_rmsnorm(cfg.d_model),
                "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff,
                                cfg.mlp_activation, dtype)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": init_rmsnorm(cfg.d_model),
                "self_attn": init_attention(k1, cfg.d_model, cfg.attention,
                                            dtype),
                "ln_x": init_rmsnorm(cfg.d_model),
                "cross_attn": init_attention(k2, cfg.d_model, cfg.attention,
                                             dtype),
                "ln2": init_rmsnorm(cfg.d_model),
                "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff,
                                cfg.mlp_activation, dtype)}

    return {
        "embed": init_embedding(k_embed, cfg.padded_vocab, cfg.d_model,
                                dtype),
        "enc": jax.vmap(enc_layer)(jax.random.split(k_enc,
                                                    cfg.encoder_layers)),
        "dec": jax.vmap(dec_layer)(jax.random.split(k_dec, cfg.num_layers)),
        "enc_ln": init_rmsnorm(cfg.d_model),
        "final_ln": init_rmsnorm(cfg.d_model),
    }


def encode(params, cfg: LMConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S_enc, D) precomputed frame embeddings (frontend stub)."""
    ncfg = cfg.attention
    import dataclasses
    ncfg = dataclasses.replace(ncfg, causal=False)

    @jax.checkpoint
    def body(h, lp):
        a, _ = attention_block(lp["attn"], rmsnorm(lp["ln1"], h),
                               ncfg)
        h = h + a
        h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h), cfg.mlp_activation)
        return constrain(h, "batch", "seq", "embed"), None

    h = constrain(frames, "batch", "seq", "embed")
    h, _ = jax.lax.scan(body, h, params["enc"])
    return rmsnorm(params["enc_ln"], h)


def _dec_layer(lp, h, memory, cfg: LMConfig, cache, make_cache, cache_size,
               cache_length):
    inner = None
    if cache is not None:
        inner = KVCache(cache["k"], cache["v"], cache_length)
    a, new_kv = attention_block(lp["self_attn"], rmsnorm(lp["ln1"], h),
                                cfg.attention, cache=inner,
                                make_cache=make_cache, cache_size=cache_size)
    h = h + a
    c = cross_attention_block(lp["cross_attn"], rmsnorm(lp["ln_x"], h),
                              memory, cfg.attention)
    h = h + c
    h = h + mlp(lp["mlp"], rmsnorm(lp["ln2"], h), cfg.mlp_activation)
    out_cache = None
    if new_kv is not None:
        out_cache = {"k": new_kv.k, "v": new_kv.v}
    return constrain(h, "batch", "seq", "embed"), out_cache


def decode_stack(params, cfg: LMConfig, tokens, memory, *, caches=None,
                 cache_length=None, make_cache=False, cache_size=0):
    x = embed(params["embed"], tokens)

    def body(h, xs):
        lp, cache = xs
        h, out_cache = _dec_layer(lp, h, memory, cfg, cache, make_cache,
                                  cache_size, cache_length)
        return h, out_cache

    x, new_caches = jax.lax.scan(body, x, (params["dec"], caches))
    x = rmsnorm(params["final_ln"], x)
    logits = unembed(params["embed"], x)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                             0.0, -1e30).astype(logits.dtype)
        logits = logits + pad_mask
    return constrain(logits, "batch", "seq", "vocab"), new_caches


def encdec_loss(params, cfg: LMConfig, frames, tokens, labels,
                ce_chunk: int = 2048):
    """Chunked CE over decoder tokens (full 256k-vocab f32 logits would
    dominate peak memory -- same trick as transformer.lm_loss)."""
    memory = encode(params, cfg, frames)
    x = embed(params["embed"], tokens)

    @jax.checkpoint
    def body(h, lp):
        h, _ = _dec_layer(lp, h, memory, cfg, None, False, 0, None)
        return h, None

    x, _ = jax.lax.scan(body, x, params["dec"])
    x = rmsnorm(params["final_ln"], x)

    b, s, d = x.shape
    t = b * s
    chunk = min(ce_chunk, t)
    if t % chunk != 0:
        chunk = t
    xf = x.reshape(t, d)
    lf = labels.reshape(t)
    table = params["embed"]["table"]

    @jax.checkpoint
    def chunk_ce(x_c, l_c):
        logits = jnp.einsum("td,vd->tv", x_c, table.astype(x_c.dtype),
                            preferred_element_type=jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            pad = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size,
                            0.0, -1e30).astype(logits.dtype)
            logits = logits + pad
        valid = l_c >= 0
        safe = jnp.where(valid, l_c, 0)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, safe[:, None], axis=-1)[:, 0]
        return (nll * valid).sum(), valid.sum()

    def ce_body(carry, io):
        tot, cnt = carry
        ls, n = chunk_ce(*io)
        return (tot + ls, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(
        ce_body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xf.reshape(t // chunk, chunk, d), lf.reshape(t // chunk, chunk)))
    loss = tot / jnp.maximum(cnt, 1)
    return loss, {"ce": loss}


def encdec_prefill(params, cfg: LMConfig, frames, tokens, cache_size: int):
    memory = encode(params, cfg, frames)
    logits, caches = decode_stack(params, cfg, tokens, memory,
                                  make_cache=True, cache_size=cache_size)
    return logits[:, -1:], caches, memory, jnp.asarray(tokens.shape[1],
                                                       jnp.int32)


def encdec_decode_step(params, cfg: LMConfig, token, caches, memory, length):
    logits, new_caches = decode_stack(params, cfg, token, memory,
                                      caches=caches, cache_length=length)
    return logits, new_caches, length + 1


def init_dec_caches_abstract(cfg: LMConfig, batch: int, cache_size: int):
    a = cfg.attention
    dtype = jnp.dtype(cfg.dtype)
    shp = (cfg.num_layers, batch, a.num_kv_heads, cache_size, a.head_dim)
    return {"k": jax.ShapeDtypeStruct(shp, dtype),
            "v": jax.ShapeDtypeStruct(shp, dtype)}
