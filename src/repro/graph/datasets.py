"""Synthetic graph datasets matched to the paper's Table 2 statistics.

The container has no network access, so Cora/Citeseer/Pubmed/Reddit/LiveJournal
are generated with a power-law (Barabasi-Albert-flavored) degree profile that
matches each dataset's |V|, |E|, and feature length.  The *characterization*
results the paper reports depend on exactly these statistics (feature length,
degree skew, reuse distance), so matched synthetic graphs reproduce the
phenomena: long feature rows, heavy-tailed degrees, shared hot neighbors.

Generation is O(E) numpy, deterministic per (spec, seed).
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.config import GRAPHS, GraphSpec
from repro.graph.structure import Graph, graph_from_coo


def _powerlaw_targets(rng: np.random.Generator, num_edges: int,
                      num_vertices: int, alpha: float = 1.05) -> np.ndarray:
    """Sample edge endpoints with a Zipf-like marginal (heavy-tailed reuse)."""
    # ranks 1..V with prob ∝ rank^-alpha ; vectorized inverse-CDF sampling.
    ranks = np.arange(1, num_vertices + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    u = rng.random(num_edges)
    return np.searchsorted(cdf, u).astype(np.int64)


def make_synthetic_graph(spec: GraphSpec, seed: int | None = None) -> Graph:
    """Generate a graph with |V|, |E| from the spec and power-law degrees."""
    rng = np.random.default_rng(spec.seed if seed is None else seed)
    v, e = spec.num_vertices, spec.num_edges
    # Heavy-tailed sources (hubs shared by many destinations -> reuse), plus a
    # permutation so hub IDs are scattered like real datasets before reorder.
    src = _powerlaw_targets(rng, e, v)
    dst = rng.integers(0, v, size=e)
    # avoid trivial self loops in the raw data (models add their own)
    coll = src == dst
    src[coll] = (src[coll] + 1) % v
    perm = rng.permutation(v)
    return graph_from_coo(perm[src], perm[dst], v)


def make_features(spec: GraphSpec, seed: int | None = None,
                  dtype=jnp.float32) -> jnp.ndarray:
    rng = np.random.default_rng((spec.seed if seed is None else seed) + 1)
    x = rng.standard_normal((spec.num_vertices, spec.feature_len)) / np.sqrt(
        spec.feature_len)
    return jnp.asarray(x, dtype=dtype)


def make_labels(spec: GraphSpec, seed: int | None = None) -> jnp.ndarray:
    rng = np.random.default_rng((spec.seed if seed is None else seed) + 2)
    return jnp.asarray(rng.integers(0, spec.num_classes, spec.num_vertices),
                       dtype=jnp.int32)


def load_dataset(name: str, seed: int | None = None
                 ) -> Tuple[Graph, jnp.ndarray, jnp.ndarray, GraphSpec]:
    """Return (graph, features, labels, spec) for a paper dataset by name."""
    spec = GRAPHS[name]
    g = make_synthetic_graph(spec, seed)
    return g, make_features(spec, seed), make_labels(spec, seed), spec
