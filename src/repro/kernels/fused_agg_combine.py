"""Pallas TPU kernel: fused Aggregation -> Combination (paper F5, §5.1-3).

The paper: "a vertex is able to start the execution in Combination phase after
this vertex completes its aggregation", but GPU frameworks insert a phase
barrier and an HBM round-trip for the aggregated matrix.  Guideline: adaptive
execution granularity.

This kernel IS that guideline on TPU: the execution granularity is a
``tile_m``-row destination block.  Per grid step:

  1. segmented-reduce the block's gathered neighbor rows into a VMEM
     accumulator (one-hot MXU matmul -- see seg_agg.py);
  2. immediately hit the accumulator with the combination weight tile
     (second MXU matmul) while it is still VMEM-resident.

The (tile_m, F_in) aggregate never exists in HBM, and W stays pinned in VMEM
across all destination blocks -- the software realization of the paper's
"degree- & length-aware replacement policy" (the hottest data, W, is made
cache-permanent; DESIGN.md §2).

VMEM per step (tile_m=128, tile_e=512, F_in<=4096, F_out=128, fp32):
rows 8 MiB + W 2 MiB + acc 2 MiB + out 64 KiB -- fits the ~64 MiB half-VMEM
budget used by ops.py's tile picker.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

from repro.core.backend import resolve_interpret


def _fused_kernel(seg_ref, mask_ref, rows_ref, w_ref, out_ref, acc_ref, *,
                  tile_m: int, tile_e: int, acc_dtype=jnp.float32):
    """``acc_dtype`` is the VMEM accumulator precision for BOTH MXU passes
    (segmented reduce and the fused GEMM) -- f32 even for bf16 rows/W (the
    reduced-precision plan contract); one rounding at the output flush."""
    ei = pl.program_id(1)
    n_e = pl.num_programs(1)

    @pl.when(ei == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seg = seg_ref[0, :]
    mask = mask_ref[0, :]
    rows = rows_ref[0]
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (tile_m, tile_e), 0)
    onehot = jnp.where(row_ids == seg[None, :], mask[None, :], 0.0)
    acc_ref[...] += jax.lax.dot(
        onehot.astype(acc_dtype), rows.astype(acc_dtype),
        preferred_element_type=acc_dtype)

    @pl.when(ei == n_e - 1)
    def _combine():
        # Phase fusion point: aggregate tile -> GEMM without leaving VMEM.
        out_ref[0] = jax.lax.dot(
            acc_ref[...], w_ref[...].astype(acc_dtype),
            preferred_element_type=acc_dtype).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_m", "tile_e", "interpret",
                                    "acc_dtype"))
def fused_agg_combine_blocked(rows: jnp.ndarray, seg_local: jnp.ndarray,
                              mask: jnp.ndarray, w: jnp.ndarray, *,
                              tile_m: int, tile_e: int = 512,
                              interpret: Optional[bool] = None,
                              acc_dtype=jnp.float32) -> jnp.ndarray:
    """out[block b] = (sum_seg rows[b]) @ w, fused in VMEM.

    rows: (nblocks, emax, F_in) destination-block-grouped gathered rows.
    seg_local/mask: (nblocks, emax).
    w: (F_in, F_out).
    interpret: None = auto-detect (core.backend.default_interpret).
    acc_dtype: static VMEM accumulator dtype; stays f32 for reduced (bf16)
    rows/W -- storage is reduced, the accumulate is not.
    Returns (nblocks * tile_m, F_out) in w.dtype.
    """
    interpret = resolve_interpret(interpret)
    nblocks, emax, f_in = rows.shape
    f_out = w.shape[1]
    assert w.shape[0] == f_in, (w.shape, f_in)
    assert emax % tile_e == 0, (emax, tile_e)
    grid = (nblocks, emax // tile_e)

    out = pl.pallas_call(
        functools.partial(_fused_kernel, tile_m=tile_m, tile_e=tile_e,
                          acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_e), lambda b, e: (b, e)),
            pl.BlockSpec((1, tile_e), lambda b, e: (b, e)),
            pl.BlockSpec((1, tile_e, f_in), lambda b, e: (b, e, 0)),
            pl.BlockSpec((f_in, f_out), lambda b, e: (0, 0)),  # W: VMEM-pinned
        ],
        out_specs=pl.BlockSpec((1, tile_m, f_out), lambda b, e: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, tile_m, f_out), w.dtype),
        scratch_shapes=[pltpu.VMEM((tile_m, f_in), acc_dtype)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="fused_agg_combine",
    )(seg_local, mask, rows, w)
    return out.reshape(nblocks * tile_m, f_out)
