"""Token-choice top-k Mixture-of-Experts layer (kimi-k2 / arctic / jamba).

The dispatch pipeline is deliberately built as the paper's two phases
(DESIGN.md §4): routing produces an irregular token->expert *gather*
(Aggregation-analogue: sort-by-expert + positioned scatter, collision-free by
construction, exactly like the destination-sorted edge layout), and the
expert FFN is a dense grouped GEMM (Combination-analogue).  The same
characterization machinery prices both phases.

Capacity-based, static shapes: tokens beyond an expert's capacity are
dropped (standard top-k MoE training semantics).  With EP over the `model`
mesh axis GSPMD turns the dispatch scatter into an all-to-all.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import MoEConfig
from repro.launch.sharding import constrain
from repro.nn.layers import init_dense, init_mlp, mlp


def capacity(cfg: MoEConfig, num_tokens: int) -> int:
    c = int(cfg.capacity_factor * num_tokens * cfg.top_k / cfg.num_experts)
    return max(8, -(-c // 8) * 8)


def init_moe(key, d_model: int, cfg: MoEConfig, activation: str,
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 4)
    e, f = cfg.num_experts, cfg.expert_d_ff
    gated = activation in ("swiglu", "geglu")
    p = {
        "router": init_dense(ks[0], d_model, e, jnp.float32),
        "wi": (jax.random.normal(ks[1], (e, d_model, f), jnp.float32)
               * d_model ** -0.5).astype(dtype),
        "wo": (jax.random.normal(ks[2], (e, f, d_model), jnp.float32)
               * f ** -0.5).astype(dtype),
    }
    if gated:
        p["wg"] = (jax.random.normal(ks[3], (e, d_model, f), jnp.float32)
                   * d_model ** -0.5).astype(dtype)
    if cfg.dense_residual:
        p["dense"] = init_mlp(jax.random.fold_in(key, 7), d_model,
                              cfg.dense_residual_d_ff, activation, dtype)
    return p


def moe_ffn(params: Dict, x: jnp.ndarray, cfg: MoEConfig, activation: str,
            dropless: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).  Dispatches to the shard_map EP path
    under an active multi-device sharding context (see _moe_sharded);
    single-device (tests, CPU examples) runs the local path below."""
    from repro.launch.sharding import ctx_mesh_axes
    info = ctx_mesh_axes()
    if info is not None:
        mesh, batch_axes, seq_axes = info
        tp = mesh.shape.get("model", 1)
        dp = 1
        for a in batch_axes:
            dp *= mesh.shape[a]
        sp = 1
        for a in seq_axes:
            sp *= mesh.shape[a]
        if (tp > 1 and cfg.num_experts % tp == 0 and
                x.shape[0] % dp == 0 and x.shape[1] % sp == 0 and
                (x.shape[0] * x.shape[1]) // (dp * sp) >= 1):
            return _moe_sharded(params, x, cfg, activation, dropless, mesh,
                                batch_axes, seq_axes)
    return _moe_local(params, x, cfg, activation, dropless)


def _moe_local(params: Dict, x: jnp.ndarray, cfg: MoEConfig, activation: str,
               dropless: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    Sorted-segment dispatch (Aggregation phase):
      1. top-k routing; flatten (T*K) assignments,
      2. stable argsort by expert id  == destination-sorted edges,
      3. rank-in-segment via searchsorted == collision-free positions,
      4. scatter into the (E, C, D) dispatch buffer.
    Expert GEMMs (Combination phase) run as dense einsums over experts.

    ``dropless=True`` sizes capacity at the worst case (t*k) so no token is
    ever dropped -- used by the single-token decode path where capacity
    drops would corrupt generation; train/prefill keep the standard
    capacity-factor semantics.
    """
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.num_experts
    c = min(t * k, capacity(cfg, t)) if not dropless else max(8, t * k)
    c = -(-c // 8) * 8
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # -- aux load-balance loss (Switch-style) -------------------------------
    me = probs.mean(axis=0)                                   # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        jnp.ones((t * k,), jnp.float32)) / (t * k)
    aux = cfg.aux_loss_weight * e * jnp.sum(me * ce)

    # -- sorted-segment dispatch (the Aggregation analogue) ------------------
    flat_ids = expert_ids.reshape(-1)                         # (T*K,)
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]                              # non-decreasing
    seg_begin = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    pos = jnp.arange(t * k) - seg_begin                       # rank in expert
    keep = pos < c
    tok = order // k                                          # source token
    buf = jnp.zeros((e, c, d), xf.dtype)
    buf = buf.at[sorted_ids, jnp.where(keep, pos, 0)].add(
        xf[tok] * keep[:, None].astype(xf.dtype))

    # -- expert FFN (the Combination analogue) -------------------------------
    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"].astype(buf.dtype),
                   preferred_element_type=jnp.float32).astype(buf.dtype)
    if activation == "swiglu":
        gate_h = jnp.einsum("ecd,edf->ecf", buf,
                            params["wg"].astype(buf.dtype),
                            preferred_element_type=jnp.float32
                            ).astype(buf.dtype)
        h = jax.nn.silu(gate_h) * h
    elif activation == "geglu":
        gate_h = jnp.einsum("ecd,edf->ecf", buf,
                            params["wg"].astype(buf.dtype),
                            preferred_element_type=jnp.float32
                            ).astype(buf.dtype)
        h = jax.nn.gelu(gate_h, approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    y = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(h.dtype),
                   preferred_element_type=jnp.float32).astype(h.dtype)

    # -- combine (scatter back, gate-weighted) -------------------------------
    slot_out = y[sorted_ids, jnp.where(keep, pos, 0)]         # (T*K, D)
    gates_sorted = gate_vals.reshape(-1)[order]
    # cast gates BEFORE the multiply: an f32 gate would upcast the whole
    # residual stream (observed: f32 saved layer carries at kimi-k2)
    w = (gates_sorted * keep).astype(slot_out.dtype)
    slot_out = slot_out * w[:, None]
    out = jnp.zeros((t, d), slot_out.dtype).at[tok].add(slot_out)
    out = out.reshape(b, s, d)

    if cfg.dense_residual:
        out = out + mlp(params["dense"], x, activation)
    return out, aux


def _moe_sharded(params: Dict, x: jnp.ndarray, cfg: MoEConfig,
                 activation: str, dropless: bool, mesh, batch_axes,
                 seq_axes) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert parallelism via shard_map (the production EP flow).

    Per shard: LOCAL routing + sort + dispatch-buffer build (zero comm),
    then one all-to-all over `model` redistributing (E, C_loc) -> experts,
    local grouped GEMMs against the shard's E/tp experts, reverse
    all-to-all, local gate-weighted combine.  GSPMD's scatter-based
    alternative replicates the dispatch buffer (observed 0.5 TiB/device at
    kimi-k2 train_4k); this path wires the canonical a2a instead.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    bp = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    sp = seq_axes if len(seq_axes) > 1 else (
        seq_axes[0] if seq_axes else None)
    all_axes = tuple(mesh.axis_names)
    gated = activation in ("swiglu", "geglu")

    def local_fn(x_loc, router_w, wi, wo, wg, dense):
        out, aux = _moe_local_with_a2a(
            {"router": {"w": router_w}, "wi": wi, "wo": wo,
             **({"wg": wg} if gated else {}),
             **({"dense": dense} if cfg.dense_residual else {})},
            x_loc, cfg, activation, dropless)
        aux = jax.lax.pmean(aux, all_axes)
        return out, aux

    wg = params.get("wg", jnp.zeros((), x.dtype))
    dense = params.get("dense", jnp.zeros((), x.dtype))
    dense_spec = jax.tree.map(lambda _: P(None, None), dense) \
        if cfg.dense_residual else P()
    out, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bp, sp, None),           # x: tokens sharded dp x seq
                  P(None, None),             # router (gathered)
                  P("model", None, None),    # experts EP over model
                  P("model", None, None),
                  P("model", None, None) if gated else P(),
                  dense_spec),
        out_specs=(P(bp, sp, None), P()),
        check_rep=False,
    )(x, params["router"]["w"], params["wi"], params["wo"], wg, dense)
    return out, aux


def _moe_local_with_a2a(params, x, cfg: MoEConfig, activation: str,
                        dropless: bool):
    """Body run per shard inside shard_map: local dispatch + model-axis a2a.

    params["wi"]/["wo"]/["wg"] hold THIS SHARD's E/tp experts; routing is
    over the full expert id space.
    """
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.num_experts
    e_loc = params["wi"].shape[0]
    tp = e // e_loc
    c = max(8, t * k) if dropless else min(t * k, capacity(cfg, t))
    c = -(-c // 8) * 8
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)

    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        jnp.ones((t * k,), jnp.float32)) / (t * k)
    aux = cfg.aux_loss_weight * e * jnp.sum(me * ce)

    flat_ids = expert_ids.reshape(-1)
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    seg_begin = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    pos = jnp.arange(t * k) - seg_begin
    keep = pos < c
    tok = order // k
    buf = jnp.zeros((e, c, d), xf.dtype)
    buf = buf.at[sorted_ids, jnp.where(keep, pos, 0)].add(
        xf[tok] * keep[:, None].astype(xf.dtype))

    # dispatch all-to-all: (E, C, D) -> (E/tp, C*tp, D)
    if tp > 1:
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)

    # expert GEMMs in the model dtype end-to-end: f32 preferred-output here
    # made every backward cotangent f32 (observed: the largest single HBM
    # contributor in the kimi-k2 train profile); TPU MXUs accumulate in f32
    # internally either way.
    h = jnp.einsum("ecd,edf->ecf", buf, params["wi"].astype(buf.dtype))
    if activation in ("swiglu", "geglu"):
        gate_h = jnp.einsum("ecd,edf->ecf", buf,
                            params["wg"].astype(buf.dtype))
        h = (jax.nn.silu(gate_h) if activation == "swiglu"
             else jax.nn.gelu(gate_h, approximate=True)) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    y = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(h.dtype))

    # combine all-to-all back: (E/tp, C*tp, D) -> (E, C, D)
    if tp > 1:
        y = jax.lax.all_to_all(y, "model", split_axis=1, concat_axis=0,
                               tiled=True)

    slot_out = y[sorted_ids, jnp.where(keep, pos, 0)]
    gates_sorted = gate_vals.reshape(-1)[order]
    w = (gates_sorted * keep).astype(slot_out.dtype)
    slot_out = slot_out * w[:, None]
    out = jnp.zeros((t, d), slot_out.dtype).at[tok].add(slot_out)
    out = out.reshape(b, s, d)
    if cfg.dense_residual:
        out = out + mlp(params["dense"], x, activation)
    return out, aux


def moe_flops(cfg: MoEConfig, d_model: int, num_tokens: int,
              activation: str) -> float:
    """Analytic active-FLOPs for one MoE layer (forward)."""
    mats = 3 if activation in ("swiglu", "geglu") else 2
    c = capacity(cfg, num_tokens)
    return 2.0 * cfg.num_experts * c * d_model * cfg.expert_d_ff * mats
