"""Seeded-violation self-test: prove every rule still catches its plant.

``scripts/analyze.py --selftest`` (and ``tests/test_analysis.py``) run
one KNOWN violation per rule through the real detection path --
:func:`~repro.analysis.jaxpr_lint.lint_callable` for traced rules,
:func:`~repro.analysis.ast_lint.lint_source` for source rules -- and
fail if any rule misses.  A linter whose rules silently rot is worse
than no linter: this is the gate that keeps the gate honest.

Each ``plant_*`` function returns the :class:`AnalysisReport` its
seeded violation produced; :func:`run_selftest` maps rule id ->
detected and also checks the suppression pragma path (a planted
violation carrying ``# analysis: allow(...)`` must NOT fire).
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.analysis.ast_lint import lint_source
from repro.analysis.jaxpr_lint import (check_collective_bytes,
                                       check_dedup_fold, check_donation,
                                       check_dynamic_consts, lint_callable)
from repro.analysis.report import AnalysisReport


# -- traced plants ----------------------------------------------------------


def plant_no_callbacks() -> AnalysisReport:
    """A pure_callback smuggled into a traced function."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    def fn(x):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
    return lint_callable(fn, jnp.ones((4,)), where="plant:no-callbacks")


def plant_no_f64() -> AnalysisReport:
    """An f64 upcast traced while x64 is temporarily enabled."""
    import jax
    import jax.numpy as jnp
    jax.config.update("jax_enable_x64", True)
    try:
        return lint_callable(lambda x: x.astype(jnp.float64) + 1.0,
                             jnp.ones((4,), jnp.float32),
                             where="plant:no-f64")
    finally:
        jax.config.update("jax_enable_x64", False)


def plant_bf16_accum() -> AnalysisReport:
    """A bf16 dot WITHOUT the f32 preferred_element_type accumulator."""
    import jax.numpy as jnp
    a = jnp.ones((4, 4), jnp.bfloat16)
    return lint_callable(lambda p, q: jnp.dot(p, q), a, a,
                         where="plant:bf16-f32-accum")


def plant_donation() -> AnalysisReport:
    """A donate=True claim over a lowering that donated nothing."""
    import jax
    import jax.numpy as jnp
    x = jnp.ones((8, 8))
    text = jax.jit(lambda v: v * 2.0).trace(x).lower().as_text()
    report = AnalysisReport()
    check_donation(text, True, "plant:donation", report,
                   alias_possible=True)
    return report


def plant_collective_bytes() -> AnalysisReport:
    """A traced ppermute whose bytes contradict the claimed schedule.

    Runs on ONE device (degenerate 1-ring): the extractor still walks
    the shard_map jaxpr and totals the send, so claiming a 2-send
    schedule must produce a finding.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("x",))

    def body(v):
        return jax.lax.ppermute(v, "x", [(0, 0)])

    fn = shard_map(body, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    x = jnp.ones((4, 8), jnp.float32)
    closed = jax.make_jaxpr(fn)(x)
    report = AnalysisReport()
    one_send = 4 * 8 * 4  # what the trace actually ships
    check_collective_bytes(closed, {"ppermute": 2 * one_send},
                           "plant:collective-bytes", report)
    return report


def plant_dynamic_edge_free() -> AnalysisReport:
    """A 'dynamic' trace that closes over the template graph's edges."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.graph.structure import Graph
    src = np.array([0, 1, 2, 3], np.int32)
    dst = np.array([1, 2, 3, 0], np.int32)
    in_deg = np.ones(4, np.float32)
    g = Graph(src=src, dst=dst, in_deg=in_deg, out_deg=in_deg,
              num_vertices=4)
    baked = jnp.asarray(g.src)  # the violation: template edges as consts

    def fn(x, src_arg, dst_arg):
        return x + jnp.take(x, baked, axis=0).sum()

    closed = jax.make_jaxpr(fn)(jnp.ones((4,)), jnp.asarray(src),
                                jnp.asarray(dst))
    report = AnalysisReport()
    check_dynamic_consts(closed, g, "plant:dynamic-edge-free", report)
    return report


def plant_dedup_accounting() -> AnalysisReport:
    """A dedup='pairs' pricing claim whose trace still runs the NAIVE
    fold: the layout prices the shortened (num_pairs=1, num_edges2=4)
    two-level aggregation, but the traced program segment-sums all 6
    original edges -- the priced FLOP saving is bookkeeping, not work."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.graph.dedup import build_dedup_layout
    src = np.array([3, 4, 4, 3, 2, 3], np.int32)
    dst = np.array([0, 0, 1, 1, 2, 2], np.int32)
    lay = build_dedup_layout(src, dst, 6)   # pair (3,4): dsts 0,1 share it
    assert lay.num_pairs == 1 and lay.num_edges2 == 4
    s, d = jnp.asarray(src), jnp.asarray(dst)

    def fn(x):
        return jax.ops.segment_sum(jnp.take(x, s, axis=0), d,
                                   num_segments=6)

    closed = jax.make_jaxpr(fn)(jnp.ones((6, 8)))
    report = AnalysisReport()
    check_dedup_fold(closed, lay, "plant:dedup-accounting", report)
    return report


# -- source plants ----------------------------------------------------------

_SRC_PLANTS = {
    "host-in-trace": (
        "def f(x):\n"
        "    y = jnp.sum(x)\n"
        "    return float(jnp.max(y))\n"),
    "tracer-branch": (
        "def f(x):\n"
        "    s = jnp.sum(x)\n"
        "    if s > 0:\n"
        "        return s\n"
        "    return -s\n"),
    "broadcast-div": (
        "def f(h, deg):\n"
        "    return h / deg[:, None]\n"),
    "acc-dtype": (
        "def k(tile_m, f_in):\n"
        "    return pl.pallas_call(\n"
        "        kern, scratch_shapes=[pltpu.VMEM((tile_m, f_in),\n"
        "                                         jnp.float32)])\n"),
    "grid-arity": (
        "out = pl.pallas_call(\n"
        "    kern, grid=(4, 4),\n"
        "    in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))])\n"),
}


def _plant_source(rule: str) -> Callable[[], AnalysisReport]:
    def run() -> AnalysisReport:
        return lint_source(_SRC_PLANTS[rule], filename=f"plant:{rule}")
    run.__doc__ = f"Throwaway source seeding one {rule} violation."
    return run


#: rule id -> plant callable; every registered rule must appear here
PLANTS: Dict[str, Callable[[], AnalysisReport]] = {
    "no-callbacks": plant_no_callbacks,
    "no-f64": plant_no_f64,
    "bf16-f32-accum": plant_bf16_accum,
    "donation": plant_donation,
    "collective-bytes": plant_collective_bytes,
    "dynamic-edge-free": plant_dynamic_edge_free,
    "dedup-accounting": plant_dedup_accounting,
    **{rule: _plant_source(rule) for rule in _SRC_PLANTS},
}


def check_suppression() -> bool:
    """The pragma path: an allowed plant must NOT fire."""
    src = ("def f(h, deg):\n"
           "    return h / deg[:, None]  # analysis: allow(broadcast-div)\n")
    return not lint_source(src, filename="plant:suppressed").findings


def run_selftest() -> Tuple[Dict[str, bool], AnalysisReport]:
    """Run every plant; returns (rule -> detected, merged report).

    Detected means the plant produced at least one finding FOR ITS OWN
    rule.  The merged report also carries a synthetic
    ``selftest-suppression`` error if the pragma path stopped working.
    """
    merged = AnalysisReport()
    detected: Dict[str, bool] = {}
    for rule, plant in sorted(PLANTS.items()):
        rep = plant()
        detected[rule] = any(f.rule == rule for f in rep.findings)
        merged.merge(rep)
    if not check_suppression():
        merged.add("selftest-suppression", "error", "plant:suppressed",
                   "suppression pragma no longer suppresses findings")
        detected["selftest-suppression"] = False
    return detected, merged
