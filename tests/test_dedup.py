"""Pair-redundancy elimination (graph/dedup.py) as a planned decision.

Covers the GraphACT-style two-level layout end to end: host-side leading-
pair matching, the f32 bitwise contract across backends x fusion x
ordering (property-tested), the priced ``dedup="auto"`` decision flipping
between fanout-regular sampled blocks and sparse full-graph layers on the
SAME machine, instrument/report accounting, and the bucketed compiled
training loop (steady-state plan reuse, zero retraces, deterministic
checkpoint-resume).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from tolerance import assert_allclose_dtype

from repro.config import GraphSpec
from repro.core.plan import build_plan, plan_cache_stats
from repro.graph.dedup import (build_dedup_layout, dedup_cost,
                               dedup_layout_for_graph, pad_dedup_arrays)
from repro.graph.structure import graph_from_coo
from repro.models.gcn import PAPER_MODELS


def _hub_graph(v=256, num_hubs=8, seed=0):
    """Fanout-regular block: every vertex has EXACTLY two hub in-neighbors
    drawn from ``num_hubs`` hubs -- the GraphACT-favorable shape (many
    destinations share a leading pair)."""
    rng = np.random.default_rng(seed)
    pairs = np.array([(a, b) for a in range(num_hubs)
                      for b in range(a + 1, num_hubs)])
    sel = pairs[rng.integers(0, len(pairs), v)]
    return graph_from_coo(sel.reshape(-1), np.repeat(np.arange(v), 2), v)


def _sparse_graph(v=500, e=750, seed=0):
    rng = np.random.default_rng(seed)
    return graph_from_coo(rng.integers(0, v, e), rng.integers(0, v, e), v)


# ---------------------------------------------------------------------------
# layout construction
# ---------------------------------------------------------------------------


def test_leading_pair_matching_by_hand():
    """Hand-checkable matching: dsts 0,1 share pair (7,8); dst 2's pair is
    unique (frequency 1 -> unmatched); dst 3 is a singleton."""
    src = np.array([7, 8, 8, 7, 5, 6, 9])
    dst = np.array([0, 0, 1, 1, 2, 2, 3])
    lay = build_dedup_layout(src, dst, 10)
    assert lay.num_pairs == 1
    assert (np.asarray(lay.pair_left), np.asarray(lay.pair_right)) == (7, 8)
    assert lay.matched_edges == 4          # 2 dsts x 2 edges
    assert lay.num_edges2 == 5             # 7 - 2 dropped
    # matched dsts' surviving edge references the pair partial row (10 + 0)
    s2, d2 = np.asarray(lay.src2), np.asarray(lay.dst2)
    assert list(s2[d2 == 0]) == [10] and list(s2[d2 == 1]) == [10]
    assert list(s2[d2 == 2]) == [5, 6] and list(s2[d2 == 3]) == [9]
    assert (np.diff(d2) >= 0).all()        # dst-sort preserved
    assert lay.edges_removed == 2
    assert lay.flops_saved(16) == (2 - 1) * 16


def test_layout_no_pairs_and_zero_candidates():
    # all singleton destinations: no candidate at all
    lay = build_dedup_layout(np.arange(4), np.arange(4), 4)
    assert lay.num_pairs == 0 and lay.num_edges2 == 4
    # candidates exist but no pair repeats
    src = np.array([0, 1, 2, 3])
    dst = np.array([0, 0, 1, 1])
    lay = build_dedup_layout(src, dst, 4)
    assert lay.num_pairs == 0 and lay.num_edges2 == 4


def test_pair_count_upper_bound_and_cost_model():
    g = _hub_graph()
    lay = dedup_layout_for_graph(g)
    assert 0 < lay.num_pairs <= g.num_edges // 4
    c = dedup_cost(lay, 32)
    from repro.core.phases import aggregate_cost
    naive = aggregate_cost(g, 32)
    assert c["flops"] < naive["flops"]
    assert c["flops_saved"] == naive["flops"] - c["flops"]
    assert c["pairs"] == lay.num_pairs


def test_pad_dedup_arrays_shapes_and_sink():
    g = _hub_graph(v=64, num_hubs=4)
    lay = dedup_layout_for_graph(g)
    pl, pr, s2, d2 = pad_dedup_arrays(lay, lay.num_pairs + 3,
                                      lay.num_edges2 + 5, sink=63)
    assert len(pl) == len(pr) == lay.num_pairs + 3
    assert len(s2) == len(d2) == lay.num_edges2 + 5
    assert (pl[-3:] == 63).all() and (s2[-5:] == 63).all()
    assert (np.diff(d2) >= 0).all()        # sink edges keep the dst-sort
    with pytest.raises(AssertionError):
        pad_dedup_arrays(lay, lay.num_pairs - 1, lay.num_edges2, sink=63)


# ---------------------------------------------------------------------------
# the f32 bitwise contract across the planner decision space (property)
# ---------------------------------------------------------------------------


@st.composite
def dedup_case(draw):
    return dict(
        seed=draw(st.integers(0, 2 ** 16)),
        v=draw(st.sampled_from([64, 128, 192])),
        hubs=draw(st.sampled_from([4, 6, 8])),
        f=draw(st.sampled_from([8, 24])),
        backend=draw(st.sampled_from(["xla", "pallas-tpu", "pallas-gpu"])),
        ordering=draw(st.sampled_from(["combine_first", "aggregate_first",
                                       None])),
        fused=draw(st.sampled_from([False, True])),
        dtype=draw(st.sampled_from(["f32", "bf16", "int8-agg"])),
    )


@given(dedup_case())
@settings(max_examples=6, deadline=None)
def test_dedup_equivalence_across_planner_axes(case):
    """dedup='pairs' == dedup='none' BITWISE in f32 (eager AND compiled),
    and within the dtype band for reduced precisions, on every
    backend x fusion x ordering combination."""
    g = _hub_graph(case["v"], case["hubs"], case["seed"])
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
    kw = dict(backend=case["backend"], ordering=case["ordering"],
              fused=case["fused"], dtype=case["dtype"])
    p0 = build_plan(g, cfg, case["f"], 7, dedup="none", **kw)
    p1 = build_plan(g, cfg, case["f"], 7, dedup="pairs", **kw)
    assert p1.dedup == "pairs" and p1.dedup_layout.num_pairs > 0
    rng = np.random.default_rng(case["seed"])
    x = jnp.asarray(rng.standard_normal((case["v"], case["f"])), jnp.float32)
    params = p0.init(jax.random.PRNGKey(0))
    ref = p0.run_model(params, x)
    out = p1.run_model(params, x)
    if case["dtype"] == "f32":
        assert_allclose_dtype(out, ref, bitwise=True, err_msg=str(case))
        assert_allclose_dtype(p1.compile()(params, x), ref, bitwise=True,
                              err_msg=f"compiled: {case}")
    else:
        # the pair partials regroup the REDUCED operand's fold; both sides
        # round at the same phase boundaries, so they agree within the
        # dtype band (scale 2: two layers)
        assert_allclose_dtype(out, ref, dtype=case["dtype"], scale=2,
                              err_msg=str(case))
        assert_allclose_dtype(p1.compile()(params, x), out,
                              dtype=case["dtype"], scale=2,
                              err_msg=f"compiled: {case}")


# ---------------------------------------------------------------------------
# the planned decision: pricing, coercion, cache identity
# ---------------------------------------------------------------------------


def test_choose_dedup_flips_between_workloads_on_same_machine():
    """The decision function flips on ONE machine: fanout-regular sampled
    block -> 'pairs', sparse full-graph layer -> 'none'."""
    from repro.profile.machine import TPU_V5E, choose_dedup, dedup_model
    gd = _hub_graph(1024, 16)                   # dense shared-pair block
    ld = dedup_layout_for_graph(gd)
    gs = _sparse_graph()
    ls = dedup_layout_for_graph(gs)
    args_d = dict(num_pairs=ld.num_pairs, num_edges2=ld.num_edges2,
                  machine=TPU_V5E)
    args_s = dict(num_pairs=ls.num_pairs, num_edges2=ls.num_edges2,
                  machine=TPU_V5E)
    assert choose_dedup(gd.num_vertices, gd.num_edges, 128, **args_d) \
        == "pairs"
    assert choose_dedup(gs.num_vertices, gs.num_edges, 128, **args_s) \
        == "none"
    m = dedup_model(gd.num_vertices, gd.num_edges, 128,
                    num_pairs=ld.num_pairs, num_edges2=ld.num_edges2,
                    machine=TPU_V5E)
    assert m["pairs"]["agg_bytes"] < m["none"]["agg_bytes"]
    assert m["pairs"]["saving"] > 0


def test_auto_dedup_resolves_per_workload():
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
    pd = build_plan(_hub_graph(1024, 16), cfg, 128, 7, dedup="auto")
    assert pd.dedup == "pairs"
    ps = build_plan(_sparse_graph(), cfg, 128, 7, dedup="auto")
    assert ps.dedup == "none" and ps.dedup_layout is None


def test_dedup_coercions_and_validation():
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
    g = _hub_graph(64, 4)
    with pytest.raises(ValueError):
        build_plan(g, cfg, 8, 7, dedup="both")
    with pytest.raises(ValueError):
        build_plan(g, cfg, 8, 7, dedup="none",
                   dedup_pad=(4, g.num_edges))
    # zero matchable pairs: explicit "pairs" resolves to "none"
    p = build_plan(_sparse_graph(60, 70, seed=3), cfg, 8, 7, dedup="pairs")
    assert p.dedup in ("none", "pairs")
    if p.dedup == "none":
        assert p.dedup_layout is None
    # max aggregation coerces to "none"
    cfg_max = dataclasses.replace(cfg, aggregator="max",
                                  name="gcn-max-dedup")
    pm = build_plan(g, cfg_max, 8, 7, dedup="pairs")
    assert pm.dedup == "none"


def test_dedup_is_a_cache_axis_and_described():
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
    g = _hub_graph(96, 6)
    p0 = build_plan(g, cfg, 8, 7, dedup="none")
    p1 = build_plan(g, cfg, 8, 7, dedup="pairs")
    assert p0 is not p1
    assert build_plan(g, cfg, 8, 7, dedup="pairs") is p1   # cache hit
    assert p0.describe()[0]["dedup"] == "none"
    assert p1.describe()[0]["dedup"] == "pairs"


def test_dynamic_compiled_dedup_roundtrip():
    """One dedup bucket plan serves same-shape blocks with runtime dedup
    arrays -- bitwise against each block's own naive plan."""
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
    v = 128
    g_a, g_b = _hub_graph(v, 8, seed=1), _hub_graph(v, 8, seed=2)
    plan = build_plan(g_a, cfg, 8, 7, dedup="pairs",
                      dedup_pad=(g_a.num_edges // 4, g_a.num_edges))
    fn = plan.compile(dynamic=True)
    params = plan.init(jax.random.PRNGKey(0))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((v, 8)),
                    jnp.float32)
    for gb in (g_a, g_b):
        lay = dedup_layout_for_graph(gb)
        ded = pad_dedup_arrays(lay, plan.dedup_layout.num_pairs,
                               plan.dedup_layout.num_edges2, sink=v - 1)
        out = fn(params, x, gb, dedup=ded)
        ref = build_plan(gb, cfg, 8, 7, dedup="none").run_model(params, x)
        # pad no-ops dump into the sink row (v-1): in bucketed use that is
        # a dedicated pad slot, but this synthetic graph makes it a real
        # vertex, so exclude it -- every other row must be bitwise
        assert_allclose_dtype(out[:-1], ref[:-1], bitwise=True)
    assert fn.num_traces == 1              # both blocks, one trace
    with pytest.raises(ValueError):        # missing runtime arrays
        fn(params, x, g_b)
    with pytest.raises(ValueError):        # wrong static shapes
        lay_b = dedup_layout_for_graph(g_b)
        fn(params, x, g_b, dedup=(lay_b.pair_left, lay_b.pair_right,
                                  lay_b.src2, lay_b.dst2))


# ---------------------------------------------------------------------------
# instrumentation: records, validation, markdown
# ---------------------------------------------------------------------------


def test_instrument_records_dedup_and_validates():
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
    g = _hub_graph(128, 8)
    p = build_plan(g, cfg, 8, 7, dedup="pairs")
    params = p.init(jax.random.PRNGKey(0))
    x = jnp.zeros((128, 8), jnp.float32)
    rep = p.instrument().run_model(params, x).validate()
    assert not rep.mismatches(p)
    aggs = [r for r in rep.records
            if r.phase in ("aggregate", "fused_agg_combine")]
    assert aggs and all(r.dedup_pairs == p.dedup_layout.num_pairs
                        for r in aggs)
    assert all(r.dedup_flops_saved ==
               p.dedup_layout.flops_saved(r.feature_len) for r in aggs)
    # the dedup record prices the TWO-LEVEL layout, cheaper than naive
    from repro.core.phases import aggregate_cost
    pure_agg = [r for r in aggs if r.phase == "aggregate"]
    for r in pure_agg:
        assert r.flops < aggregate_cost(g, r.feature_len)["flops"]
    assert "Dedup:" in rep.to_markdown()
    # a dedup='pairs' report whose aggregation records lost their pair
    # counts is a schema violation (the dispatch silently skipped dedup)
    d = rep.to_dict()
    for rec in d["phases"]:
        rec["dedup_pairs"] = 0
    from repro.profile.instrument import validate_report_dict
    assert any("dedup" in pr for pr in validate_report_dict(d))
    # ...and a mismatch against describe()
    import dataclasses as dc
    rep.records[:] = [dc.replace(r, dedup_pairs=0, dedup_flops_saved=0.0)
                      for r in rep.records]
    assert any("dedup" in m for m in rep.mismatches(p))


# ---------------------------------------------------------------------------
# the bucketed compiled training loop (satellites 1-2)
# ---------------------------------------------------------------------------


def _training_fixture(seed=0):
    rng = np.random.default_rng(seed)
    v, f, c = 300, 10, 5
    g = _hub_graph(v, 12, seed=seed)
    spec = GraphSpec(name="t", num_vertices=v, feature_len=f,
                     num_edges=g.num_edges, num_classes=c)
    x = rng.standard_normal((v, f)).astype(np.float32)
    y = rng.integers(0, c, v)
    return g, spec, x, y


def test_trainer_steady_state_one_plan_zero_retraces():
    """Satellite 1: ONE cached plan + compiled step across the whole run --
    plan-cache hits grow per step, misses don't, zero retraces."""
    from repro.models.sage_minibatch import PlannedSageTrainer
    g, spec, x, y = _training_fixture()
    tr = PlannedSageTrainer(g, spec, x, y, batch_size=4, fanouts=(2, 2),
                            dedup="pairs", seed=0)
    s0 = plan_cache_stats()
    tr.train(5)
    s1 = plan_cache_stats()
    assert s1["hits"] - s0["hits"] >= 5    # one resolve per step, all hits
    assert s1["misses"] == s0["misses"]    # never rebuilt
    assert tr.retraces == 0
    assert tr._plan() is tr._plan()        # literally the same object
    assert len(tr.losses) == 5 and all(np.isfinite(tr.losses))
    assert tr.last_pairs >= 0


def test_trainer_forward_bitwise_and_training_banded():
    """dedup='pairs' vs 'none': identical compiled FORWARD bits; training
    trajectories agree within the f32 band (the backward scatter regroups,
    so gradients round differently in the last ulp)."""
    from repro.models.sage_minibatch import PlannedSageTrainer
    g, spec, x, y = _training_fixture()
    kw = dict(batch_size=4, fanouts=(2, 2), seed=0)
    tp = PlannedSageTrainer(g, spec, x, y, dedup="pairs", **kw)
    tn = PlannedSageTrainer(g, spec, x, y, dedup="none", **kw)
    assert_allclose_dtype(tp.predict(step=0), tn.predict(step=0),
                          bitwise=True)
    lp, ln = tp.train(4), tn.train(4)
    np.testing.assert_allclose(lp, ln, rtol=1e-4, atol=1e-5)
    jax.tree.map(lambda a, b: assert_allclose_dtype(a, b, scale=10),
                 tp.params, tn.params)


def test_trainer_deterministic_resume(tmp_path):
    """Satellite 2: resume at step k through the Checkpointer reproduces
    the uninterrupted run exactly -- same seed/block stream (batch_at is a
    pure function of (seed, step)), bitwise-identical params and losses."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.models.sage_minibatch import PlannedSageTrainer
    g, spec, x, y = _training_fixture()
    kw = dict(batch_size=4, fanouts=(2, 2), dedup="pairs", seed=0)

    straight = PlannedSageTrainer(g, spec, x, y, **kw)
    straight.train(6)

    ck = Checkpointer(str(tmp_path / "ck"))
    a = PlannedSageTrainer(g, spec, x, y, **kw)
    a.train(3)
    a.save(ck, blocking=True)

    b = PlannedSageTrainer(g, spec, x, y, **kw)
    at = b.restore(ck)
    assert at == 3 and b.pipeline.step == 3
    b.train(3)

    assert b.losses == straight.losses     # float-exact loss stream
    jax.tree.map(lambda p, q: assert_allclose_dtype(p, q, bitwise=True),
                 b.params, straight.params)
    assert b.retraces == 0
