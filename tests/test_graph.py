"""Graph substrate: structures, synthetic datasets, reorder, partition."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import CORA, GRAPHS, GraphSpec, reduced_graph
from repro.graph.datasets import load_dataset, make_synthetic_graph
from repro.graph.partition import edge_balance, partition_1d
from repro.graph.reorder import (atomic_collision_model, degree_reorder,
                                 reuse_distance_stats)
from repro.graph.sampling import two_hop_batch
from repro.graph.structure import (Graph, add_self_loops, graph_from_coo,
                                   to_dense_adj)


def small_graph(v=64, e=256, seed=0):
    spec = GraphSpec("t", v, 8, e, seed=seed)
    return make_synthetic_graph(spec)


def test_graph_from_coo_sorted():
    g = small_graph()
    dst = np.asarray(g.dst)
    assert (np.diff(dst) >= 0).all(), "edges must be destination-sorted"
    assert g.num_edges == 256
    assert int(np.asarray(g.in_deg).sum()) == g.num_edges


def test_dataset_stats_match_spec():
    for name in ("cora", "citeseer", "pubmed"):
        g, x, y, spec = load_dataset(name)
        assert g.num_vertices == spec.num_vertices
        assert g.num_edges == spec.num_edges
        assert x.shape == (spec.num_vertices, spec.feature_len)


def test_degree_distribution_heavy_tailed():
    g = small_graph(v=512, e=4096)
    deg = np.asarray(g.out_deg)
    # power-law sources: max degree should far exceed the mean
    assert deg.max() > 4 * deg.mean()


def test_self_loops():
    g = small_graph()
    g2 = add_self_loops(g)
    assert g2.num_edges == g.num_edges + g.num_vertices


def test_degree_reorder_preserves_structure():
    g = small_graph()
    g2, perm = degree_reorder(g)
    a1 = np.asarray(to_dense_adj(g))
    a2 = np.asarray(to_dense_adj(g2))
    # permuting rows+cols of the adjacency by perm must reproduce a2
    assert np.allclose(a2[np.ix_(perm, perm)], a1[np.ix_(
        np.arange(len(perm)), np.arange(len(perm)))]) or np.allclose(
        a2, a1[np.argsort(perm)][:, np.argsort(perm)])
    # degrees must be non-increasing after reorder
    d = np.asarray(g2.out_deg) + np.asarray(g2.in_deg)
    assert (np.diff(d) <= 0).all()


def test_degree_reorder_improves_reuse():
    """Paper F4: degree-aware scheduling shortens reuse distance."""
    g = small_graph(v=256, e=2048, seed=3)
    g2, _ = degree_reorder(g)
    before = reuse_distance_stats(np.asarray(g.src), budgets=(32,))
    after = reuse_distance_stats(np.asarray(g2.src), budgets=(32,))
    assert after["hit_ratio@32"] >= before["hit_ratio@32"]


def test_reuse_distance_lru_exactness():
    # stream: a b a b -> distances: -1, -1, 1, 1
    s = reuse_distance_stats(np.array([0, 1, 0, 1]), budgets=(1, 2))
    assert s["cold_miss_frac"] == 0.5
    assert s["hit_ratio@2"] == 0.5
    assert s["hit_ratio@1"] == 0.0


def test_atomic_collision_model():
    dst = np.random.default_rng(0).integers(0, 8, 4096)
    pgr = atomic_collision_model(dst, feature_len=1)
    gcn = atomic_collision_model(dst, feature_len=128)
    assert gcn["atomic_txn_per_request"] == 1.0
    assert pgr["atomic_txn_per_request"] > 2.0  # heavy collisions


@given(st.integers(2, 16))
@settings(max_examples=10, deadline=None)
def test_partition_conserves_edges(p):
    g = small_graph(v=128, e=512, seed=1)
    pg = partition_1d(g, p, edge_balanced=False)
    assert int(np.asarray(pg.mask).sum()) == g.num_edges
    assert pg.num_shards == p


def test_partition_edge_balance():
    g = small_graph(v=512, e=8192, seed=2)
    bal_u = edge_balance(partition_1d(g, 8, edge_balanced=False))
    bal_e = edge_balance(partition_1d(g, 8, edge_balanced=True))
    assert bal_e <= bal_u + 1e-6


def test_partition_local_ids_in_range():
    g = small_graph(v=100, e=400)
    pg = partition_1d(g, 4, edge_balanced=False)
    dstl = np.asarray(pg.dst_local)
    mask = np.asarray(pg.mask) > 0
    assert (dstl[mask] >= 0).all()
    assert (dstl[mask] < pg.block_size).all()


def test_two_hop_sampling_static_shapes():
    g = small_graph(v=128, e=1024)
    batch = np.arange(16, dtype=np.int32)
    hop2, hop1 = two_hop_batch(g, batch, fanouts=(4, 4), seed=0)
    assert hop1.graph.num_edges == 16 * 4
    assert len(hop1.seed_ids) == 16
    # every hop1 input vertex is a destination of hop2
    assert len(hop2.seed_ids) == len(hop1.input_ids)
