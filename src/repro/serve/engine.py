"""LM serving engine: continuous-batching decode over a static KV cache.

Serving shape of the assigned cells: ``prefill_*`` lowers ``prefill_step``
(build cache + first logits), ``decode_*`` lowers one ``decode_step`` (one
token for every sequence in the batch against a seq_len cache).

Engine features (the queue/slot/stats loop itself lives in
``repro.serve.core.SlotServeCore``; this class supplies the LM step
bodies):
  * request queue with admission up to ``max_batch`` concurrent sequences,
  * slot-based continuous batching: finished sequences free their slot and
    the next request's prefill fills it (prefill-into-slot),
  * greedy / temperature sampling,
  * per-request max_tokens + EOS stop,
  * static shapes throughout (jit-stable): the cache is allocated once at
    ``cache_size`` and positions advance per step.

The multi-chip layout comes from launch/specs.py (batch over data, cache
sequence over model); on one CPU device the same code runs unsharded.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LMConfig
from repro.models.transformer import (init_caches_abstract, lm_decode_step,
                                      lm_prefill)
from repro.serve.core import SlotServeCore


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    enqueue_t: float = 0.0
    finish_t: float = 0.0


class ServeEngine(SlotServeCore):
    """Continuous-batching LM decode engine on the shared serving core.

    ``submit`` / ``run`` / the slot lifecycle come from ``SlotServeCore``;
    this class implements admission as prefill-into-slot and the step as
    one batched decode over every active slot.
    """

    def __init__(self, cfg: LMConfig, params, *, max_batch: int = 8,
                 cache_size: int = 512, seed: int = 0):
        super().__init__(max_batch)
        self.cfg = cfg
        self.params = params
        self.cache_size = cache_size
        self.rng = np.random.default_rng(seed)
        self._decode = jax.jit(
            lambda p, tok, caches, length: lm_decode_step(p, cfg, tok,
                                                          caches, length))
        self._caches = None
        self._length = None
        self._last_tokens = np.zeros((max_batch, 1), np.int32)

    # ------------------------------------------------------------- internal
    def _admit_into_slot(self, slot: int, req: Request) -> bool:
        """Prefill the request into ``slot``; True if the prefill's first
        sampled token already finished it (EOS / max_tokens=1)."""
        self._prefill_into_slot(slot, req)
        tok = req.output[-1]
        return (req.eos_id is not None and tok == req.eos_id) or \
            len(req.output) >= req.max_tokens

    def _ensure_caches(self):
        if self._caches is None:
            abstract = init_caches_abstract(self.cfg, self.max_batch,
                                            self.cache_size)
            self._caches = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), abstract)
            # per-slot lengths: slots are fully independent sequences
            self._length = jnp.zeros((self.max_batch,), jnp.int32)

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        """Single-sequence prefill written into the batch cache at `slot`.

        Per-slot cache lengths make admission exact at any time: the new
        sequence's rows live at positions [0, L) of ITS slot and its RoPE
        positions restart at 0, independent of every other slot.
        """
        self._ensure_caches()
        prompt = np.asarray(req.prompt, np.int32)[None, :]     # (1, L)
        logits, caches1, _ = lm_prefill(
            self.params, self.cfg, jnp.asarray(prompt),
            cache_size=self.cache_size)

        def write(batch_cache, one_cache):
            return batch_cache.at[:, slot:slot + 1].set(
                one_cache.astype(batch_cache.dtype))

        self._caches = jax.tree.map(write, self._caches, caches1)
        self._length = self._length.at[slot].set(prompt.shape[1])
        tok = self._sample(np.asarray(logits)[:, -1], req)
        req.output.append(int(tok))
        self._last_tokens[slot, 0] = tok

    def _sample(self, logits: np.ndarray, req: Request) -> int:
        logits = np.asarray(logits, np.float64).reshape(-1)
        if req.temperature <= 0:
            return int(logits.argmax())
        p = np.exp(logits / req.temperature - np.max(logits /
                                                     req.temperature))
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def _step(self) -> List[Request]:
        if not self._active:
            return []
        toks = jnp.asarray(self._last_tokens)
        logits, self._caches, self._length = self._decode(
            self.params, toks, self._caches, self._length)
        self._steps += 1
        logits_np = np.asarray(logits)[:, 0]
        finished = []
        for slot, req in list(self._active.items()):
            tok = self._sample(logits_np[slot], req)
            req.output.append(tok)
            self._last_tokens[slot, 0] = tok
            if (req.eos_id is not None and tok == req.eos_id) or \
                    len(req.output) >= req.max_tokens or \
                    int(self._length[slot]) >= self.cache_size - 1:
                finished.append(self._complete(slot))
        return finished

    # ------------------------------------------------------------- metrics
    def stats(self) -> Dict[str, Any]:
        """Core serving stats plus the LM engine's cache view; the legacy
        ``decode_steps`` key aliases the core's step counter."""
        out = super().stats()
        out["decode_steps"] = self._steps
        out["cache_len"] = (np.asarray(self._length).tolist()
                            if self._length is not None else [])
        return out
