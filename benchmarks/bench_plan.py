"""Planner sweep: ONE harness comparing backend x ordering x fusion x
reorder x partition, eager AND compiled.

Every scenario is expressed as a ``build_plan`` override, so this module
exercises exactly the dispatch layer production code uses -- no hand-built
kernel calls.  One row per scenario carries the plan's decisions
(order/RESOLVED backend/tile_m/interpret/reorder) plus measured wall-clock,
and one row per model shows the decisions the planner takes when left on
"auto".  The ``plan/compiled`` spec times ``plan.compile()`` against the
eager dispatch loop and lands an eager-vs-compiled wall-time CSV
(``experiments/bench/bench_plan_compiled*.csv``).

Under dry-run (the ``benchmarks/run.py --dry-run`` path / scripts/smoke.sh)
every scenario additionally runs INSTRUMENTED: the plan executes through
``plan.instrument(machine=...)``, and the resulting ``WorkloadReport`` is
schema-validated (``report.validate()``) and cross-checked against
``plan.describe()`` (``report.mismatches``) -- empty phase records, schema
violations, or planner drift all fail the smoke gate.  Every matrix
scenario ALSO validates the compiled contract: ``plan.compile()`` output
must equal the eager forward bit-for-bit and the second invocation must
not retrace.  ``post_run`` accounts for every scenario in the matrix:
anything skipped is reported with a reason, and a scenario missing
without one raises.

The partition scenarios (1-D and 2-D meshes, including a degree-reordered
variant of each kind) run in a subprocess with 8 fake host devices so the
main process keeps its single real device (the same rule
tests/test_distributed.py follows); the child validates a WorkloadReport
AND the compiled bitwise/retrace contract per partition scenario too.

A backend is only *natively* exercised on its own platform; everywhere else
the Pallas tiers run in interpret mode.  The dry run prints exactly which
tiers were compiled vs interpreted so a GPU-less container can no longer
silently validate nothing but XLA paths.
"""

from __future__ import annotations

import itertools
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np

from repro.core.backend import interpret_for, platform
from repro.core.plan import build_plan
from repro.core.scheduler import AGGREGATE_FIRST, COMBINE_FIRST
from repro.models.gcn import make_paper_model
from repro.profile.bench import BenchSpec, run_specs
from repro.profile.machine import TPU_V5E

BACKENDS = ("xla", "pallas-tpu", "pallas-gpu")
ORDERINGS = (None, COMBINE_FIRST, AGGREGATE_FIRST)  # None = cost model
FUSION = (False, True)

#: local matrix cells: (backend, ordering, fused, reorder) -- the full
#: backend x ordering x fusion product at reorder="none" (the PR 3 matrix)
#: plus every backend x fusion cell under degree reordering and one
#: "auto" reorder cell exercising the choose_reorder pricing path.
MATRIX_POINTS = tuple(
    (b, o, f, "none")
    for b, o, f in itertools.product(BACKENDS, ORDERINGS, FUSION)
) + tuple(
    (b, None, f, "degree")
    for b, f in itertools.product(BACKENDS, FUSION)
) + (("xla", None, False, "auto"),)

#: eager-vs-compiled timing cells: (backend, fused, reorder)
COMPILED_POINTS = (
    ("xla", False, "none"),
    ("xla", True, "none"),
    ("xla", False, "degree"),
)

#: (kind, mesh shape, mesh axis names, halo strategy, reorder) --
#: subprocess matrix (one degree-reordered variant per partition kind)
PARTITIONS = (
    ("1d", (8,), ("data",), "ring", "none"),
    ("1d", (8,), ("data",), "allgather", "none"),
    ("2d", (4, 2), ("node", "feat"), "ring", "none"),
    ("2d", (4, 2), ("node", "feat"), "allgather", "none"),
    ("2d", (2, 4), ("node", "feat"), "ring", "none"),
    ("1d", (8,), ("data",), "ring", "degree"),
    ("2d", (4, 2), ("node", "feat"), "ring", "degree"),
)


def _scenario_name(backend, ordering, fused, reorder="none"):
    base = (f"plan/gcn/{backend}/{ordering or 'auto'}/"
            f"{'fused' if fused else 'unfused'}")
    return base if reorder == "none" else f"{base}/reorder-{reorder}"


def _partition_name(kind, shape, strategy, reorder="none"):
    base = (f"plan/gcn/partition-{kind}/{'x'.join(map(str, shape))}/"
            f"{strategy}")
    return base if reorder == "none" else f"{base}/reorder-{reorder}"


def _compiled_name(backend, fused, reorder):
    return (f"plan/compiled/gcn/{backend}/"
            f"{'fused' if fused else 'unfused'}/{reorder}")


def expected_matrix():
    """Every scenario name the dry run must account for."""
    names = [_scenario_name(*pt) for pt in MATRIX_POINTS]
    names += [_partition_name(k, s, st, r) for k, s, _, st, r in PARTITIONS]
    names += [_compiled_name(*pt) for pt in COMPILED_POINTS]
    return names


def _check_compiled_contract(name, plan, params, x, eager_out):
    """The plan.compile() acceptance contract, enforced per dry scenario:
    bit-for-bit equality with the eager forward and no retrace on the
    second invocation."""
    fn = plan.compile()
    out_c = fn(params, x)
    fn(params, x)
    if not np.array_equal(np.asarray(out_c), np.asarray(eager_out)):
        err = float(np.abs(np.asarray(out_c) -
                           np.asarray(eager_out)).max())
        raise RuntimeError(
            f"{name}: plan.compile() output differs from eager dispatch "
            f"(max |diff|={err:.3e}); the compiled contract is bitwise")
    if fn.num_traces != 1:
        raise RuntimeError(f"{name}: plan.compile() traced "
                           f"{fn.num_traces}x for one signature")


def _setup(ctx):
    m = make_paper_model("gcn", ctx.spec)
    return m, m.init(jax.random.PRNGKey(0))


def _scenario(ctx, point):
    """One (backend, ordering, fusion, reorder) cell of the local matrix."""
    backend, ordering, fused, reorder = point
    spec, g, x = ctx.spec, ctx.g, ctx.x
    m, params = ctx.state
    plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                      backend=backend, ordering=ordering, fused=fused,
                      reorder=reorder)
    d0 = plan.describe()[0]
    derived = dict(order=d0["order"], backend=d0["backend"],
                   fused=d0["fused"], tile_m=d0["tile_m"],
                   interpret=d0["interpret"], reorder=d0["reorder"],
                   agg_bytes=d0["agg_bytes"])
    name = _scenario_name(backend, ordering, fused, reorder)
    if ctx.dry:
        # instrumented validation: run through the plan's real dispatch,
        # schema-check the WorkloadReport, and fail on planner drift
        report = plan.instrument(machine=ctx.machine).run_model(params, x)
        report.validate()
        drift = report.mismatches(plan)
        if drift:
            raise RuntimeError(
                f"{name}: describe() disagrees with dispatch: {drift}")
        assert report.output.shape == (spec.num_vertices, spec.num_classes)
        _check_compiled_contract(name, plan, params, x, report.output)
        ctx.emit(name, 0.0, report_phases=len(report.records), **derived)
    elif backend != "xla":
        # interpret-mode wall-clock is meaningless; describe only
        ctx.emit(name, 0.0, **derived)
    else:
        fn = plan.compile()
        ctx.emit(name, ctx.time(fn, params, x), **derived)


def _compiled(ctx, point):
    """Eager-vs-compiled wall time for one (backend, fused, reorder) cell.

    Timing mode: median wall time of the eager dispatch loop vs the
    ``plan.compile()`` executable.  Dry-run: the instrumented compiled run
    (``InstrumentedPlan.run_model(compiled=True)``) -- schema + drift +
    compiled-contract validation, with the measured (tiny-graph) times
    still emitted so the CSV artifact always carries a real speedup
    column.
    """
    backend, fused, reorder = point
    spec, g, x = ctx.spec, ctx.g, ctx.x
    m, params = ctx.state
    plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                      backend=backend, fused=fused, reorder=reorder)
    name = _compiled_name(backend, fused, reorder)
    d0 = plan.describe()[0]
    derived = dict(backend=d0["backend"], fused=d0["fused"],
                   reorder=d0["reorder"])
    if ctx.dry:
        report = plan.instrument(machine=ctx.machine).run_model(
            params, x, compiled=True)
        report.validate()
        drift = report.mismatches(plan)
        if drift:
            raise RuntimeError(
                f"{name}: describe() disagrees with dispatch: {drift}")
        _check_compiled_contract(name, plan, params, x, report.output)
        eager_us = report.totals()["wall_time_s"] * 1e6
        compiled_us = report.compiled_times["model_s"] * 1e6
        ctx.emit(name, compiled_us, eager_us=round(eager_us, 2),
                 compiled_us=round(compiled_us, 2),
                 speedup=round(report.compiled_speedup()["model"], 3),
                 **derived)
    else:
        eager_us = ctx.time(plan.run_model, params, x)
        fn = plan.compile()
        compiled_us = ctx.time(fn, params, x)
        ctx.emit(name, compiled_us, eager_us=round(eager_us, 2),
                 compiled_us=round(compiled_us, 2),
                 speedup=round(eager_us / max(compiled_us, 1e-9), 3),
                 **derived)


def _auto_decisions(ctx, model_name):
    """What does the planner decide unaided, per paper model?"""
    spec, g = ctx.spec, ctx.g
    mm = make_paper_model(model_name, spec)
    plan = build_plan(g, mm.cfg, spec.feature_len, spec.num_classes)
    for d in plan.describe():
        ctx.emit(f"plan/auto/{model_name}/layer{d['layer']}", 0.0,
                 order=d["order"], backend=d["backend"], fused=d["fused"],
                 din=d["din"], dout=d["dout"], agg_bytes=d["agg_bytes"])


_PARTITION_CHILD_FLAG = "--partition-child"


def _partition_child(csv_out: str):
    """Subprocess body: validate every partition scenario on fake devices,
    each through an instrumented (WorkloadReport-validated) run PLUS the
    compiled contract (bitwise eager equality, no retrace).  Rows are
    written to ``csv_out`` so the parent re-emits them through its own
    harness context (they land in the parent's CSV artifact, no stdout
    re-parsing)."""
    from repro.profile.bench import BenchContext, bench_graph, write_csv
    from repro.graph.datasets import make_features, make_synthetic_graph

    spec = bench_graph("reddit", max_vertices=256, max_feature=64)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    m = make_paper_model("gcn", spec)
    params = m.init(jax.random.PRNGKey(0))
    ref = build_plan(g, m.cfg, spec.feature_len,
                     spec.num_classes).run_model(params, x)
    ctx = BenchContext(bench=None, machine=TPU_V5E, dry=True)
    for kind, shape, names, strategy, reorder in PARTITIONS:
        mesh = jax.make_mesh(shape, names)
        plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                          mesh=mesh, strategy=strategy, reorder=reorder)
        assert plan.partition_kind == kind, (plan.partition_kind, kind)
        name = _partition_name(kind, shape, strategy, reorder)
        with mesh:
            report = plan.instrument(machine=TPU_V5E).run_model(params, x)
            report.validate()
            drift = report.mismatches(plan)
            assert not drift, (kind, shape, strategy, reorder, drift)
            _check_compiled_contract(name, plan, params, x, report.output)
        err = float(np.abs(np.asarray(report.output - ref)).max())
        assert err < 1e-3, (kind, shape, strategy, reorder, err)
        d0 = plan.describe()[0]
        ctx.emit(name, 0.0,
                 order=d0["order"], backend=d0["backend"],
                 partition=d0["partition"], reorder=d0["reorder"],
                 report_phases=len(report.records),
                 collective_bytes=int(sum(r.collective_bytes
                                          for r in report.records)),
                 max_err=f"{err:.2e}")
    write_csv(ctx.rows, csv_out)
    print("PARTITION-CHILD-OK")


def _partitions(ctx, _):
    """Spawn the partition matrix in a subprocess with 8 fake devices and
    re-emit its rows here, so they join the parent's CSV artifact and the
    matrix accounting.  Dry-run only: partition *timing* needs a real
    multi-device mesh (post_run logs that skip reason)."""
    if not ctx.dry:
        return
    import csv as _csv
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        out = Path(td) / "partition_child.csv"
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.pathsep.join(
            [str(Path(__file__).resolve().parents[1] / "src"),
             str(Path(__file__).resolve().parents[1])])
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_plan",
             _PARTITION_CHILD_FLAG, str(out)],
            capture_output=True, text=True, env=env, timeout=900)
        if res.returncode != 0 or "PARTITION-CHILD-OK" not in res.stdout:
            sys.stdout.write(res.stdout)
            raise RuntimeError(
                f"partition dry-run subprocess failed:\n{res.stderr[-3000:]}")
        with out.open(newline="") as f:
            child_rows = list(_csv.DictReader(f))
    for row in child_rows:
        name = row.pop("name")
        us = float(row.pop("us_per_call"))
        ctx.emit(name, us, **row)


SPECS = [
    BenchSpec(name="plan/matrix", graph="reddit", max_vertices=2048,
              max_feature=128, dry_max_vertices=256, machine=TPU_V5E,
              sweep=MATRIX_POINTS,
              setup=_setup, measure=_scenario, dry="run"),
    BenchSpec(name="plan/compiled", graph="reddit", max_vertices=2048,
              max_feature=128, dry_max_vertices=256, machine=TPU_V5E,
              sweep=COMPILED_POINTS, setup=_setup, measure=_compiled,
              dry="run"),
    BenchSpec(name="plan/auto", graph="reddit", max_vertices=2048,
              max_feature=128, dry_max_vertices=256,
              sweep=("gcn", "sage", "gin"), measure=_auto_decisions,
              dry="run"),
    BenchSpec(name="plan/partitions", measure=_partitions, dry="run"),
]


def post_run(rows, dry: bool = False):
    """Matrix accounting + backend coverage report (fails loudly on gaps),
    plus the eager-vs-compiled CSV artifact (``plan/compiled`` rows land
    in ``experiments/bench/bench_plan_compiled*.csv`` with eager_us /
    compiled_us / speedup columns).

    Only names in ``expected_matrix()`` count as validated scenarios (the
    ``plan/auto`` introspection rows are reported but not matrix cells).
    """
    from repro.profile.bench import BENCH_ARTIFACT_DIR, write_csv

    comp_rows = [r for r in rows if r["name"].startswith("plan/compiled/")]
    if comp_rows:
        p = write_csv(comp_rows, BENCH_ARTIFACT_DIR /
                      f"bench_plan_compiled{'.dry' if dry else ''}.csv")
        print(f"# eager-vs-compiled csv artifact: {p}")

    matrix = set(expected_matrix())
    validated = [r["name"] for r in rows if r["name"] in matrix]
    skipped = {}
    if not dry:
        for name in (_partition_name(k, s, st, r)
                     for k, s, _, st, r in PARTITIONS):
            skipped[name] = "partition timing needs a real multi-device mesh"

    plat = platform()
    compiled = [b for b in BACKENDS
                if b == "xla" or not interpret_for(b)]
    interp = [b for b in BACKENDS if b not in compiled]
    print(f"# backend coverage on platform={plat}: compiled natively: "
          f"{','.join(compiled)}; interpret-mode only (numerics validated, "
          f"perf NOT exercised): {','.join(interp) or 'none'}")
    for name, why in skipped.items():
        print(f"# skipped: {name} ({why})")
    missing = [n for n in expected_matrix()
               if n not in validated and n not in skipped]
    if missing:
        raise RuntimeError(
            "dry-run matrix scenarios silently skipped: " + ", ".join(missing))
    print(f"# matrix: {len(validated)} scenario(s) validated, "
          f"{len(skipped)} skipped with reasons, 0 silent")


def run(dry: bool = False):
    """Direct-invocation entry (``python -m benchmarks.bench_plan
    [--dry-run]``); writes the same CSV artifact benchmarks/run.py does."""
    from repro.profile.bench import BENCH_ARTIFACT_DIR
    rows = run_specs(
        SPECS, dry=dry,
        csv=BENCH_ARTIFACT_DIR / f"bench_plan{'.dry' if dry else ''}.csv")
    post_run(rows, dry=dry)


if __name__ == "__main__":
    if _PARTITION_CHILD_FLAG in sys.argv:
        _partition_child(sys.argv[sys.argv.index(_PARTITION_CHILD_FLAG) + 1])
    else:
        run(dry="--dry-run" in sys.argv)
