"""PageRank -- the paper's classic-graph-processing baseline (Gunrock, PGR).

Feature length is 1 (one scalar rank per vertex): the contrast case for every
aggregation-phase observation (F3 spatial locality, F4 reuse distance, the
atomic-collision model).  Implemented as power iteration over the same
destination-sorted edge list the GCN aggregation uses, so every comparison is
apples-to-apples on the identical graph structure.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.phases import aggregate_cost
from repro.graph.structure import Graph


def pagerank(g: Graph, damping: float = 0.85, iters: int = 20,
             tol: float = 0.0) -> jnp.ndarray:
    """Standard power iteration: r = (1-d)/V + d * A^T (r / outdeg)."""
    v = g.num_vertices
    out_deg = jnp.maximum(g.out_deg.astype(jnp.float32), 1.0)

    def step(r, _):
        contrib = r / out_deg
        gathered = jnp.take(contrib, g.src)            # feature_len == 1
        summed = jax.ops.segment_sum(gathered, g.dst, num_segments=v)
        # dangling mass redistributed uniformly
        dangling = jnp.where(g.out_deg == 0, r, 0.0).sum()
        r_new = (1.0 - damping) / v + damping * (summed + dangling / v)
        return r_new, jnp.abs(r_new - r).sum()

    r0 = jnp.full((v,), 1.0 / v, jnp.float32)
    r, deltas = jax.lax.scan(step, r0, None, length=iters)
    return r


def pagerank_cost(g: Graph, iters: int = 1) -> dict:
    """Per-iteration byte/flop accounting -- the PGR column of Fig. 2/Table 3."""
    c = aggregate_cost(g, feature_len=1, include_self=False)
    return {k: (v * iters if isinstance(v, (int, float)) else v)
            for k, v in c.items()}


def pagerank_reference(g: Graph, damping: float = 0.85, iters: int = 20
                       ) -> jnp.ndarray:
    """Dense-matrix oracle for tests (O(V^2); small graphs only)."""
    import numpy as np
    v = g.num_vertices
    a = np.zeros((v, v), np.float64)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    np.add.at(a, (dst, src), 1.0)
    out_deg = np.maximum(np.asarray(g.out_deg, np.float64), 1.0)
    r = np.full(v, 1.0 / v)
    for _ in range(iters):
        contrib = r / out_deg
        dangling = r[np.asarray(g.out_deg) == 0].sum()
        r = (1 - damping) / v + damping * (a @ contrib + dangling / v)
    return jnp.asarray(r, jnp.float32)
