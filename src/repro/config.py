"""Configuration system for the repro framework.

Dataclass-based, immutable configs with a global registry so every model is
selectable via ``--arch <id>`` from launchers, benchmarks and tests.

Two families live here:
  * ``GCNModelConfig``   -- the paper's models (GCN / GIN / GraphSAGE) + baselines.
  * ``LMConfig``         -- the assigned LM architectures (dense / MoE / hybrid /
                            SSM / VLM / audio backbones).

Shape specs (``train_4k`` etc.) are shared by all LM archs; each arch declares
which shapes apply (e.g. pure full-attention archs skip ``long_500k``).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Shape specs (assigned input shapes; see system brief)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    """One (seq_len, global_batch) workload cell.

    ``kind`` selects which step gets lowered in the dry-run:
      * ``train``   -> train_step (fwd+bwd+opt update)
      * ``prefill`` -> serve_prefill (forward, builds KV cache)
      * ``decode``  -> serve_decode (one new token against a seq_len KV cache)
    """

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode"), self.kind


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME: Dict[str, ShapeSpec] = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# LM architecture configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # Arctic-style dense residual branch running in parallel with the experts.
    dense_residual: bool = False
    dense_residual_d_ff: int = 0
    # Which layers are MoE. "all" or "every_2" (Jamba: alternate dense/MoE).
    layer_pattern: str = "all"
    # Aux load-balancing loss weight.
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block hyper-parameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256
    # dtype of the intra-chunk score/decay tensors (the (B,H,Q,Q) traffic);
    # inter-chunk state recurrence always runs in f32.
    compute_dtype: str = "float32"

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    # gemma2: alternate sliding-window ("local") and full ("global") layers.
    sliding_window: int = 0  # 0 = full attention everywhere
    local_global_alternate: bool = False
    logit_softcap: float = 0.0  # gemma2 uses 50.0
    attn_logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    causal: bool = True

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


@dataclass(frozen=True)
class LMConfig:
    """A decoder-style (or enc-dec) transformer / SSM / hybrid backbone."""

    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba): one attention layer per `attn_every` layers, rest SSM.
    attn_every: int = 0  # 0 = all layers attention (or all SSM if attention None)
    # enc-dec (seamless): encoder layer count (decoder = num_layers).
    encoder_layers: int = 0
    # activation: "swiglu" (3-matrix) | "geglu" | "gelu" (2-matrix)
    mlp_activation: str = "swiglu"
    tie_embeddings: bool = False
    final_logit_softcap: float = 0.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # VLM / audio: the modality frontend is a stub; inputs are precomputed
    # patch/frame embeddings occupying the first `frontend_tokens` positions.
    frontend_stub: bool = False
    # Which assigned shapes run for this arch (long_500k skipped for pure
    # full-attention archs -- see DESIGN.md §4).
    shape_skips: Tuple[str, ...] = ()
    skip_reason: str = ""
    source: str = ""

    # -- derived ------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding-table allocation size: vocab padded to a multiple of 256
        so the vocab dim shards over the 16-way model axis (odd published
        vocab sizes like 151655 are otherwise unshardable).  Logits beyond
        ``vocab_size`` are masked to -inf; semantics are unchanged."""
        return -(-self.vocab_size // 256) * 256

    def layer_is_attention(self, i: int) -> bool:
        if self.ssm is None:
            return True
        if self.attention is None:
            return False
        if self.attn_every <= 0:
            return True
        # Jamba-style: one attention layer in every `attn_every` block,
        # placed in the middle of the block (matches released Jamba).
        return i % self.attn_every == self.attn_every // 2

    def layer_is_moe(self, i: int) -> bool:
        if self.moe is None:
            return False
        if self.moe.layer_pattern == "all":
            return True
        if self.moe.layer_pattern == "every_2":
            return i % 2 == 1
        raise ValueError(self.moe.layer_pattern)

    def layer_is_local(self, i: int) -> bool:
        a = self.attention
        if a is None or not a.local_global_alternate:
            return False
        return i % 2 == 0  # even layers sliding-window (gemma2 convention)

    def shapes(self) -> List[ShapeSpec]:
        return [s for s in ALL_SHAPES if s.name not in self.shape_skips]

    def param_count(self) -> int:
        """Analytic total parameter count (embedding + layers)."""
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        return _count_params(self, active_only=True)


def _mlp_params(d_model: int, d_ff: int, activation: str) -> int:
    mats = 3 if activation in ("swiglu", "geglu") else 2
    return mats * d_model * d_ff


def _attn_params(d_model: int, a: AttentionConfig) -> int:
    return d_model * a.q_dim * 2 + d_model * a.kv_dim * 2


def _ssm_params(d_model: int, s: SSMConfig) -> int:
    d_in = s.d_inner(d_model)
    nh = s.n_heads(d_model)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    in_proj = d_model * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
    return in_proj + d_in * d_model + conv_dim * s.d_conv + 2 * nh + d_in


def _count_params(cfg: LMConfig, active_only: bool) -> int:
    total = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_layers = cfg.num_layers + cfg.encoder_layers
    for i in range(n_layers):
        is_enc = i >= cfg.num_layers  # encoder layers appended conceptually
        li = i if not is_enc else i - cfg.num_layers
        if cfg.layer_is_attention(li) and cfg.attention is not None:
            total += _attn_params(cfg.d_model, cfg.attention)
            if is_enc is False and cfg.encoder_layers > 0:
                # decoder cross-attention block
                total += _attn_params(cfg.d_model, cfg.attention)
        elif cfg.ssm is not None:
            total += _ssm_params(cfg.d_model, cfg.ssm)
        if cfg.layer_is_moe(li):
            m = cfg.moe
            per_expert = _mlp_params(cfg.d_model, m.expert_d_ff, cfg.mlp_activation)
            n_active = m.top_k if active_only else m.num_experts
            total += n_active * per_expert + cfg.d_model * m.num_experts
            if m.dense_residual:
                total += _mlp_params(cfg.d_model, m.dense_residual_d_ff or cfg.d_ff,
                                     cfg.mlp_activation)
        elif cfg.d_ff > 0:
            total += _mlp_params(cfg.d_model, cfg.d_ff, cfg.mlp_activation)
    return total


# ---------------------------------------------------------------------------
# GCN configs (the paper's own workloads)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GCNModelConfig:
    """Paper Table 1 layer configs."""

    name: str
    conv: str  # "gcn" | "gin" | "sage"
    aggregator: str  # "mean" | "sum"
    hidden_dims: Tuple[int, ...]  # MLP dims after the input feature length
    # Paper's F2: which phase runs first. "combine" | "aggregate" | "auto".
    ordering: str = "auto"
    fused: bool = False  # use the fused Pallas dataflow kernel (F5)
    num_layers: int = 2
    dropout: float = 0.0


@dataclass(frozen=True)
class GraphSpec:
    """Synthetic dataset spec matched to paper Table 2 statistics."""

    name: str
    num_vertices: int
    feature_len: int
    num_edges: int
    num_classes: int = 16
    seed: int = 0


# Paper Table 2. (LiveJournal feature_len=1 -- classic graph processing.)
CORA = GraphSpec("cora", 2708, 1433, 5429, num_classes=7)
CITESEER = GraphSpec("citeseer", 3327, 3703, 4732, num_classes=6)
PUBMED = GraphSpec("pubmed", 19717, 500, 44338, num_classes=3)
REDDIT = GraphSpec("reddit", 232965, 602, 11606919, num_classes=41)
LIVEJOURNAL = GraphSpec("livejournal", 4847571, 1, 68993773, num_classes=2)

GRAPHS: Dict[str, GraphSpec] = {
    g.name: g for g in (CORA, CITESEER, PUBMED, REDDIT, LIVEJOURNAL)
}


def reduced_graph(spec: GraphSpec, max_vertices: int = 512,
                  max_feature: int = 64) -> GraphSpec:
    """Scale a graph spec down for CPU tests, preserving density."""
    scale = min(1.0, max_vertices / spec.num_vertices)
    nv = max(8, int(spec.num_vertices * scale))
    ne = max(nv, int(spec.num_edges * scale))
    return dataclasses.replace(
        spec, name=spec.name + "_small", num_vertices=nv, num_edges=ne,
        feature_len=min(spec.feature_len, max_feature))


# ---------------------------------------------------------------------------
# Parallelism / run configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    # axis sizes are fixed by make_production_mesh; these name the roles.
    fsdp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    pod_axis: str = "pod"


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # bf16 moments keep trillion-param optimizer state inside HBM (see
    # EXPERIMENTS.md §Dry-run memory notes).
    moment_dtype: str = "float32"
    # gradient-accumulation buffer dtype (microbatched training)
    accum_dtype: str = "float32"
    # int8 error-feedback gradient compression on the data axis.
    grad_compression: str = "none"  # "none" | "int8_ef"


@dataclass(frozen=True)
class TrainConfig:
    model: str  # registry key
    shape: str = "train_4k"
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    seed: int = 0
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    remat: str = "none"  # "none" | "full" | "selective"
    microbatch: int = 0  # 0 = no gradient accumulation


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], Any]] = {}


def register(name: str):
    def deco(fn: Callable[[], Any]):
        if name in _REGISTRY:
            raise ValueError(f"duplicate arch {name!r}")
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str):
    """Resolve ``--arch <name>`` to a config object (LMConfig or GCNModelConfig)."""
    # Import populates the registry on first use.
    from repro import configs as _configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    from repro import configs as _configs  # noqa: F401
    return sorted(_REGISTRY)


def override(cfg, **kw):
    """dataclasses.replace that works through nested dotted keys."""
    direct = {k: v for k, v in kw.items() if "." not in k}
    nested: Dict[str, Dict[str, Any]] = {}
    for k, v in kw.items():
        if "." in k:
            head, rest = k.split(".", 1)
            nested.setdefault(head, {})[rest] = v
    for head, sub in nested.items():
        direct[head] = override(getattr(cfg, head), **sub)
    return dataclasses.replace(cfg, **direct)
