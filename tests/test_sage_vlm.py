"""SAGE mini-batch training (paper §2 setting) + VLM composition helpers."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CORA, reduced_graph
from repro.configs import internvl2_1b
from repro.graph.datasets import (make_features, make_labels,
                                  make_synthetic_graph)
from repro.graph.sampling import two_hop_batch
from repro.models.sage_minibatch import (SageMiniBatchModel,
                                         train_minibatch_sage)
from repro.models import vlm
from repro.models.transformer import init_lm


@pytest.fixture(scope="module")
def data():
    spec = reduced_graph(CORA, 256, 32)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    y = make_labels(spec)
    # plant signal so the loss can actually go down
    x = x.at[:, :spec.num_classes].add(
        3.0 * jax.nn.one_hot(y, spec.num_classes))
    return spec, g, x, y


def test_minibatch_shapes_and_orderings(data):
    spec, g, x, y = data
    seeds = np.arange(16, dtype=np.int32)
    hop2, hop1 = two_hop_batch(g, seeds, (4, 4), seed=0)
    m = SageMiniBatchModel(spec.feature_len, 128, spec.num_classes)
    p = m.init(jax.random.PRNGKey(0))
    logits = m.apply(p, hop2, hop1, jnp.asarray(np.asarray(x)[
        hop2.input_ids]))
    assert logits.shape == (16, spec.num_classes)
    o1, o2 = m.orderings(hop2, hop1)
    # layer1 expands 32->128: aggregate_first; layer2 shrinks 128->7:
    # combine_first -- the scheduler re-decides per block (Table 4 logic)
    assert o1 == "aggregate_first"
    assert o2 == "combine_first"


def test_minibatch_training_reduces_loss(data):
    spec, g, x, y = data
    _, losses, _ = train_minibatch_sage(g, spec, x, y, steps=25,
                                        batch_size=48, lr=0.15)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first, (first, last)


def test_vlm_composition():
    cfg = dataclasses.replace(internvl2_1b.reduced(), dtype="float32")
    params = init_lm(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    pe = vlm.stub_patch_embeds(key, 2, cfg, n_patches=8)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    logits, _ = vlm.vlm_forward(params, cfg, pe, toks)
    assert logits.shape == (2, 24, cfg.padded_vocab)
    loss, _ = vlm.vlm_loss(params, cfg, pe, toks, toks)
    assert np.isfinite(float(loss))
    lg, caches, length = vlm.vlm_prefill(params, cfg, pe, toks,
                                         cache_size=32)
    assert int(length) == 24  # patches + tokens both cached
