"""mamba2-2.7b -- SSD (state-space duality), attention-free.

64L d_model=2560 d_ff=0 vocab=50280, ssm_state=128.  [arXiv:2405.21060]

d_inner = 2*2560 = 5120, head_dim=64 -> 80 heads, 1 group, conv4, chunk 256.
Attention-free: the paper's sparse-aggregation technique is inapplicable
(DESIGN.md §4); long_500k RUNS (O(1) recurrent state).
"""

import dataclasses

from repro.config import LMConfig, SSMConfig, register


def _base() -> LMConfig:
    return LMConfig(
        name="mamba2-2.7b",
        family="ssm",
        num_layers=64,
        d_model=2560,
        d_ff=0,
        vocab_size=50280,
        attention=None,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=256,
                      compute_dtype="bfloat16"),
        mlp_activation="gelu",
        tie_embeddings=True,
        source="arXiv:2405.21060; unverified",
    )


@register("mamba2-2.7b")
def config() -> LMConfig:
    return _base()


def reduced() -> LMConfig:
    c = _base()
    return dataclasses.replace(
        c, name=c.name + "-smoke", num_layers=2, d_model=64, vocab_size=256,
        ssm=dataclasses.replace(c.ssm, d_state=16, head_dim=8,
                                chunk_size=16,
                                compute_dtype="float32"))
