"""Phase-ordering scheduler (paper F2 / Table 4) -- the analytic cost model.

The paper's headline systems result: executing Combination before Aggregation
reduces the Aggregation phase's data accesses by the in/out feature-length
ratio (RD: 602->128 => 4.75x bytes, 4.72x ops, 4.76x time).  This module turns
that observation into a *decision procedure*:

  * ``ordering_cost(graph, in_len, out_len)`` -- closed-form bytes/flops for
    both orderings (matching paper Table 4's accounting).
  * ``choose_ordering`` -- picks the cheaper LEGAL ordering.  Reordering is
    legal only when aggregation is linear (sum/mean) and the combination
    applied across the swap is linear (single matmul; GIN's 2-layer MLP with
    an interior ReLU pins it to aggregate_first).

At cluster scale the same model also prices the *collective* term: with
1-D vertex partitioning the halo exchange moves one feature row per remote
edge, so combine-first shrinks collective bytes by the same ratio.  This is
the paper's insight restated for multi-chip execution (DESIGN.md §8.5).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.phases import aggregate_cost, combine_cost
from repro.graph.structure import Graph
from repro.profile.machine import Machine

COMBINE_FIRST = "combine_first"
AGGREGATE_FIRST = "aggregate_first"


@dataclass(frozen=True)
class OrderingCost:
    order: str
    agg_bytes: int
    agg_flops: int
    comb_bytes: int
    comb_flops: int
    halo_bytes_per_remote_edge: int

    @property
    def total_bytes(self) -> int:
        return self.agg_bytes + self.comb_bytes

    @property
    def total_flops(self) -> int:
        return self.agg_flops + self.comb_flops


def ordering_cost(g: Graph, in_len: int, out_len: int, order: str,
                  dtype_bytes: int = 4) -> OrderingCost:
    """Cost of one layer under a given phase ordering (paper Table 4 math)."""
    v = g.num_vertices
    if order == COMBINE_FIRST:
        agg_len = out_len          # aggregation moves already-projected rows
    else:
        agg_len = in_len           # aggregation moves raw input rows
    agg = aggregate_cost(g, agg_len, dtype_bytes)
    comb = combine_cost(v, (in_len, out_len), dtype_bytes)
    return OrderingCost(
        order=order,
        agg_bytes=agg["bytes"], agg_flops=agg["flops"],
        comb_bytes=comb["bytes"], comb_flops=comb["flops"],
        halo_bytes_per_remote_edge=agg_len * dtype_bytes)


def ordering_time(oc: OrderingCost, machine: Machine) -> float:
    """Roofline-modeled seconds for one layer under ``machine``.

    Each phase is ``max(compute, memory)`` time against the machine's peaks
    and the phases serialize (no inter-phase overlap -- exactly the missed
    dataflow the paper's F5 fuses away), so this is the cost the planner
    minimizes when a ``Machine`` is supplied: on a balance-240 TPU the same
    byte counts price differently than on the paper's balance-17 V100, but
    the *ordering* decision is driven by the aggregation term either way.
    """
    agg = max(oc.agg_flops / machine.peak_flops,
              oc.agg_bytes / machine.hbm_bw)
    comb = max(oc.comb_flops / machine.peak_flops,
               oc.comb_bytes / machine.hbm_bw)
    return agg + comb


def reduction_ratios(g: Graph, in_len: int, out_len: int) -> dict:
    """Paper Table 4's three reduction ratios, analytically."""
    cf = ordering_cost(g, in_len, out_len, COMBINE_FIRST)
    af = ordering_cost(g, in_len, out_len, AGGREGATE_FIRST)
    return {
        "data_access_reduction": af.agg_bytes / max(1, cf.agg_bytes),
        "computation_reduction": af.agg_flops / max(1, cf.agg_flops),
        "combine_first": cf, "aggregate_first": af,
    }


def swap_is_legal(agg_op: str, n_mlp_layers: int) -> bool:
    """Ordering may be swapped iff both phases commute.

    sum/mean aggregation is linear; a single affine layer commutes with it
    (A(XW) = (AX)W, and mean-normalization is a diagonal scale absorbed on
    either side).  max aggregation or a multi-layer MLP (interior
    nonlinearity) breaks commutation -> ordering is fixed by semantics.
    """
    return agg_op in ("sum", "mean") and n_mlp_layers <= 1


def choose_ordering(g: Graph, in_len: int, out_len: int, agg_op: str = "mean",
                    n_mlp_layers: int = 1,
                    semantic_order: Optional[str] = None,
                    machine: Optional[Machine] = None) -> str:
    """Pick the cheaper legal ordering for one layer.

    ``semantic_order`` is the order the model *definition* implies (GIN:
    aggregate_first).  If swapping is illegal we honor it; otherwise we pick
    by modeled cost: with a ``machine`` (``repro.profile.Machine``) the
    roofline-priced ``ordering_time``, without one the total byte count --
    i.e. combine_first iff out_len < in_len.  Both criteria agree whenever
    the aggregation phase is memory-bound (it always is, Table 3), so the
    machine only changes the *margin*, never flips a legal decision.
    """
    base = semantic_order or COMBINE_FIRST
    if not swap_is_legal(agg_op, n_mlp_layers):
        return base
    cf = ordering_cost(g, in_len, out_len, COMBINE_FIRST)
    af = ordering_cost(g, in_len, out_len, AGGREGATE_FIRST)
    if machine is not None:
        return COMBINE_FIRST if ordering_time(cf, machine) <= \
            ordering_time(af, machine) else AGGREGATE_FIRST
    return COMBINE_FIRST if cf.total_bytes <= af.total_bytes else AGGREGATE_FIRST
