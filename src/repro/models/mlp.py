"""MLP-MNIST baseline (paper Table 1: 784–128, batch 1000).

The paper contrasts GCN Combination against a plain fully-connected layer
classifying single samples: parameters are NOT shared across a neighborhood,
and batch parallelism is the only parallelism.  Synthetic MNIST-shaped data
(no network access) -- the characterization depends only on shapes.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.phases import combine_cost

MNIST_IN, MNIST_OUT, MNIST_BATCH = 784, 128, 1000


def init_mlp(key, din: int = MNIST_IN, dout: int = MNIST_OUT) -> Dict:
    return {"w": jax.random.normal(key, (din, dout)) * (2.0 / din) ** 0.5,
            "b": jnp.zeros((dout,))}


def apply_mlp(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.relu(x @ params["w"] + params["b"])


def mlp_cost(batch: int = MNIST_BATCH, din: int = MNIST_IN,
             dout: int = MNIST_OUT) -> dict:
    """Cost + parameter-reuse factor (paper §4.3): reuse = rows per weight."""
    c = combine_cost(batch, (din, dout))
    c["param_reuse"] = batch  # each weight used once per row
    return c


def synthetic_mnist(key, batch: int = MNIST_BATCH) -> Tuple[jnp.ndarray,
                                                            jnp.ndarray]:
    kx, ky = jax.random.split(key)
    x = jax.random.uniform(kx, (batch, MNIST_IN))
    y = jax.random.randint(ky, (batch,), 0, 10)
    return x, y
