"""Quickstart: the paper's workload end-to-end in ~a minute on CPU.

Builds synthetic Cora, trains a 2-layer GCN with the phase-ordering
scheduler in `auto` mode, prints the per-phase characterization (paper
Table 3/4 views) -- including the one-call instrumented WorkloadReport
(`plan.instrument(machine=...)`, docs/characterization.md) -- and
evaluates accuracy.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CORA, reduced_graph
from repro.core.plan import build_plan
from repro.core.scheduler import reduction_ratios
from repro.graph.datasets import make_features, make_labels, \
    make_synthetic_graph
from repro.models.gcn import make_paper_model
from repro.profile import V100


def main():
    spec = reduced_graph(CORA, max_vertices=1024, max_feature=256)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    y = make_labels(spec)
    # plant a learnable signal (synthetic labels are otherwise random)
    x = x.at[:, :spec.num_classes].add(
        4.0 * jax.nn.one_hot(y, spec.num_classes))

    model = make_paper_model("gcn", spec)
    params = model.init(jax.random.PRNGKey(0))

    print("== phase characterization (first conv layer) ==")
    costs = model.layer_costs(g)
    print(f" chosen ordering : {costs['order']}")
    print(f" aggregation     : {costs['aggregation']['bytes']:,} bytes, "
          f"AI={costs['aggregation']['arithmetic_intensity']:.3f}")
    print(f" combination     : {costs['combination']['bytes']:,} bytes, "
          f"AI={costs['combination']['arithmetic_intensity']:.1f}")
    r = reduction_ratios(g, spec.feature_len, 128)
    print(f" ordering wins   : {r['data_access_reduction']:.2f}x fewer "
          f"aggregation bytes (paper Table 4: 4.75x on Reddit)")

    print("\n== instrumented workload report (paper's V100) ==")
    plan = build_plan(g, model.cfg, spec.feature_len, spec.num_classes)
    report = plan.instrument(machine=V100).run_model(params, x,
                                                     compiled=True)
    print(report.to_markdown())

    # the production path: ONE jitted callable, bit-for-bit == eager
    fwd = plan.compile()
    assert bool(jnp.array_equal(fwd(params, x), report.output))

    print("\n== training ==")
    loss_grad = jax.jit(jax.value_and_grad(
        lambda p: model.loss_fn(p, g, x, y)))
    lr = 0.2
    for step in range(120):
        loss, grads = loss_grad(params)
        params = jax.tree.map(lambda a, b: a - lr * b, params, grads)
        if step % 20 == 0:
            print(f" step {step:3d}  loss {float(loss):.4f}")

    logits = model.apply(params, g, x)
    acc = float((jnp.argmax(logits, -1) == y).mean())
    print(f"\nfinal accuracy: {acc:.3f} "
          f"(chance {1 / spec.num_classes:.3f})")


if __name__ == "__main__":
    main()
