"""Mamba2 SSD and MoE layer correctness + properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import MoEConfig, SSMConfig
from repro.models.mamba2 import (SSMCache, _ssd_chunked, init_mamba2,
                                 mamba2_block, ssd_reference)
from repro.models.moe import capacity, init_moe, moe_ffn

RNG = np.random.default_rng(11)


def _ssd_inputs(B=2, S=64, H=4, P=8, G=2, N=16):
    x = jnp.asarray(RNG.standard_normal((B, S, H, P)), jnp.float32)
    bm = jnp.asarray(RNG.standard_normal((B, S, G, N)) * 0.3, jnp.float32)
    cm = jnp.asarray(RNG.standard_normal((B, S, G, N)) * 0.3, jnp.float32)
    dt = jnp.asarray(RNG.random((B, S, H)) * 0.5 + 0.01, jnp.float32)
    a = -jnp.asarray(RNG.random(H) + 0.2, jnp.float32)
    return x, bm, cm, dt, a


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_ssd_chunked_vs_sequential(chunk):
    cfg = SSMConfig(d_state=16, n_groups=2, head_dim=8, chunk_size=chunk)
    x, bm, cm, dt, a = _ssd_inputs()
    y1, st1 = _ssd_chunked(x, bm, cm, dt, a, cfg)
    y2, st2 = ssd_reference(x, bm, cm, dt, a)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), rtol=1e-4,
                               atol=1e-5)


def test_ssd_chunk_invariance():
    """Chunk size is an execution detail, not semantics (paper F5 analogue)."""
    x, bm, cm, dt, a = _ssd_inputs()
    outs = []
    for chunk in (8, 32):
        cfg = SSMConfig(d_state=16, n_groups=2, head_dim=8, chunk_size=chunk)
        y, _ = _ssd_chunked(x, bm, cm, dt, a, cfg)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)


def test_mamba_block_prefill_decode_consistency():
    d_model = 32
    cfg = SSMConfig(d_state=16, n_groups=1, head_dim=8, chunk_size=16)
    params = init_mamba2(jax.random.PRNGKey(0), d_model, cfg)
    x = jnp.asarray(RNG.standard_normal((2, 33, d_model)) * 0.5, jnp.float32)
    full, _ = mamba2_block(params, x, cfg)
    pre, cache = mamba2_block(params, x[:, :32], cfg, make_cache=True)
    np.testing.assert_allclose(np.asarray(full[:, :32]), np.asarray(pre),
                               rtol=1e-4, atol=1e-5)
    dec, cache2 = mamba2_block(params, x[:, 32:33], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(full[:, 32:33]), np.asarray(dec),
                               rtol=1e-4, atol=2e-5)
    assert int(cache2.length) == 33


def test_mamba_decay_stability():
    """State magnitude must stay bounded (A<0 => contraction)."""
    cfg = SSMConfig(d_state=16, n_groups=1, head_dim=8, chunk_size=16)
    params = init_mamba2(jax.random.PRNGKey(0), 32, cfg)
    x = jnp.ones((1, 256, 32)) * 0.5
    out, cache = mamba2_block(params, x, cfg, make_cache=True)
    assert np.isfinite(np.asarray(out)).all()
    assert np.abs(np.asarray(cache.state)).max() < 1e3


# ------------------------------------------------------------------- MoE
def test_moe_matches_per_token_loop():
    cfg = MoEConfig(num_experts=8, top_k=2, expert_d_ff=32,
                    capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(1), 16, cfg, "swiglu")
    x = jnp.asarray(RNG.standard_normal((2, 8, 16)), jnp.float32)
    out, aux = moe_ffn(p, x, cfg, "swiglu")
    xf = np.asarray(x, np.float64).reshape(-1, 16)
    logits = xf @ np.asarray(p["router"]["w"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    ref = np.zeros_like(xf)
    for t in range(len(xf)):
        top = np.argsort(-probs[t])[:2]
        gv = probs[t, top] / probs[t, top].sum()
        for gate, e in zip(gv, top):
            h = xf[t] @ np.asarray(p["wi"][e], np.float64)
            g = xf[t] @ np.asarray(p["wg"][e], np.float64)
            h = g / (1 + np.exp(-g)) * h
            ref[t] += gate * (h @ np.asarray(p["wo"][e], np.float64))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 16), ref,
                               rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    cfg = MoEConfig(num_experts=4, top_k=1, expert_d_ff=16,
                    capacity_factor=0.25)
    p = init_moe(jax.random.PRNGKey(2), 8, cfg, "gelu")
    x = jnp.asarray(RNG.standard_normal((1, 64, 8)), jnp.float32)
    out, _ = moe_ffn(p, x, cfg, "gelu")
    dropped = np.asarray((jnp.abs(out[0]).sum(-1) == 0.0))
    assert dropped.any(), "low capacity must drop some tokens"
    out2, _ = moe_ffn(p, x, cfg, "gelu", dropless=True)
    assert not np.asarray((jnp.abs(out2[0]).sum(-1) == 0.0)).any()


def test_moe_dense_residual():
    cfg = MoEConfig(num_experts=4, top_k=2, expert_d_ff=16,
                    dense_residual=True, dense_residual_d_ff=16,
                    capacity_factor=4.0)
    p = init_moe(jax.random.PRNGKey(3), 8, cfg, "swiglu")
    assert "dense" in p
    x = jnp.asarray(RNG.standard_normal((1, 8, 8)), jnp.float32)
    out, _ = moe_ffn(p, x, cfg, "swiglu")
    assert out.shape == x.shape


@given(st.integers(2, 6), st.integers(1, 3), st.integers(4, 32))
@settings(max_examples=10, deadline=None)
def test_moe_capacity_formula(e, k, t):
    cfg = MoEConfig(num_experts=e, top_k=min(k, e), expert_d_ff=8)
    c = capacity(cfg, t)
    assert c >= 8 and c % 8 == 0


def test_moe_permutation_equivariance():
    """Token order must not change per-token outputs (dropless)."""
    cfg = MoEConfig(num_experts=4, top_k=2, expert_d_ff=16,
                    capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(4), 8, cfg, "gelu")
    x = jnp.asarray(RNG.standard_normal((1, 16, 8)), jnp.float32)
    out1, _ = moe_ffn(p, x, cfg, "gelu")
    perm = RNG.permutation(16)
    out2, _ = moe_ffn(p, x[:, perm], cfg, "gelu")
    np.testing.assert_allclose(np.asarray(out1[0, perm]),
                               np.asarray(out2[0]), rtol=1e-4, atol=1e-5)
