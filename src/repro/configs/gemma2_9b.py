"""gemma2-9b -- local+global alternating attention, logit softcaps.

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.  [arXiv:2408.00118]

head_dim=256, sliding window 4096 on even (local) layers, attn softcap 50,
final softcap 30, GeGLU, tied embeddings, sandwich norms.
Runs long_500k: local layers are windowed; global-layer decode is O(S)/token
(DESIGN.md §4).
"""

import dataclasses

from repro.config import AttentionConfig, LMConfig, register


def _base() -> LMConfig:
    return LMConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        d_ff=14336,
        vocab_size=256000,
        attention=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=256,
                                  sliding_window=4096,
                                  local_global_alternate=True,
                                  attn_logit_softcap=50.0),
        mlp_activation="geglu",
        tie_embeddings=True,
        final_logit_softcap=30.0,
        source="arXiv:2408.00118",
    )


@register("gemma2-9b")
def config() -> LMConfig:
    return _base()


def reduced() -> LMConfig:
    c = _base()
    return dataclasses.replace(
        c, name=c.name + "-smoke", num_layers=4, d_model=64, d_ff=128,
        vocab_size=256,
        attention=dataclasses.replace(c.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=16,
                                      sliding_window=16))
