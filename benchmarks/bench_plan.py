"""Planner sweep: ONE harness comparing backend x ordering x fusion.

Every scenario is expressed as a ``build_plan`` override, so this module
exercises exactly the dispatch layer production code uses -- no hand-built
kernel calls.  Emits one row per scenario with the plan's decisions
(order/backend/tile_m/interpret) plus measured wall-clock, and one row per
model with the decisions the planner takes when left on "auto".

``run(dry=True)`` (the ``benchmarks/run.py --dry-run`` path) builds and
validates every plan and emits the decisions without timing -- the CI smoke
check (scripts/smoke.sh).
"""

from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp

from benchmarks.common import bench_graph, emit, timeit
from repro.core.plan import build_plan
from repro.core.scheduler import AGGREGATE_FIRST, COMBINE_FIRST
from repro.graph.datasets import make_features, make_synthetic_graph
from repro.models.gcn import PAPER_MODELS, make_paper_model

BACKENDS = ("xla", "pallas")
ORDERINGS = (None, COMBINE_FIRST, AGGREGATE_FIRST)  # None = cost model
FUSION = (False, True)


def _scenario_name(backend, ordering, fused):
    return (f"plan/gcn/{backend}/{ordering or 'auto'}/"
            f"{'fused' if fused else 'unfused'}")


def run(dry: bool = False):
    spec = bench_graph("reddit", max_vertices=256 if dry else 2048,
                       max_feature=128)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    m = make_paper_model("gcn", spec)
    params = m.init(jax.random.PRNGKey(0))

    for backend, ordering, fused in itertools.product(BACKENDS, ORDERINGS,
                                                      FUSION):
        plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                          backend=backend, ordering=ordering, fused=fused)
        d0 = plan.describe()[0]
        derived = dict(order=d0["order"], backend=d0["backend"],
                       fused=d0["fused"], tile_m=d0["tile_m"],
                       interpret=d0["interpret"], agg_bytes=d0["agg_bytes"])
        if dry or backend == "pallas":
            # interpret-mode wall-clock is meaningless; validate + describe
            out = plan.run_model(params, x) if dry else None
            if out is not None:
                assert out.shape == (spec.num_vertices, spec.num_classes)
            emit(_scenario_name(backend, ordering, fused), 0.0, **derived)
        else:
            fn = jax.jit(lambda xx, p=plan: p.run_model(params, xx))
            emit(_scenario_name(backend, ordering, fused), timeit(fn, x),
                 **derived)

    # what does the planner decide unaided, per paper model?
    for name in ("gcn", "sage", "gin"):
        mm = make_paper_model(name, spec)
        plan = build_plan(g, mm.cfg, spec.feature_len, spec.num_classes)
        for d in plan.describe():
            emit(f"plan/auto/{name}/layer{d['layer']}", 0.0,
                 order=d["order"], backend=d["backend"], fused=d["fused"],
                 din=d["din"], dout=d["dout"], agg_bytes=d["agg_bytes"])


def dry_run():
    run(dry=True)


if __name__ == "__main__":
    run()
