# Pallas TPU kernels for the paper's compute hot-spots:
#   seg_agg            -- collision-free segmented row aggregation (F3)
#   fused_agg_combine  -- inter-phase dataflow fusion in VMEM (F5)
#   flash_attention    -- blockwise attention substrate for the LM archs
# Each kernel has a pure-jnp oracle in ref.py; ops.py holds jit'd wrappers.
