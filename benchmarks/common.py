"""Shared benchmark utilities: timing, CSV emission, scaled datasets.

CPU wall-times here are CORRECTNESS-SHAPED, not TPU predictions: they verify
relative effects the paper reports (breakdown shares, ordering speedups,
linear scaling).  TPU-roofline numbers come from the dry-run artifacts
(benchmarks/roofline.py), never from CPU timing.

Datasets are scaled-down replicas (same degree distribution, same
feature-length RATIOS) sized so the full suite runs in minutes on CPU; the
analytic tables additionally report the paper's full-size numbers.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np

from repro.config import GRAPHS, GraphSpec, reduced_graph

ROWS: List[Dict] = []


def emit(name: str, us_per_call: float, **derived):
    row = {"name": name, "us_per_call": round(us_per_call, 2)}
    row.update(derived)
    ROWS.append(row)
    extras = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{row['us_per_call']},{extras}")


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of jitted fn; blocks on result leaves."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def bench_graph(name: str, max_vertices: int = 8192,
                max_feature: int = 100000) -> GraphSpec:
    """Scaled dataset preserving |E|/|V| and feature length (unless capped)."""
    return reduced_graph(GRAPHS[name], max_vertices, max_feature)
