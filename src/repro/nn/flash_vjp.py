"""Flash attention with a custom VJP: O(chunk^2) memory in forward AND backward.

Plain autodiff through blockwise attention saves every chunk's probability
matrix for the backward pass, resurrecting the O(S^2) memory the forward
carefully avoided (observed directly in the internvl train_4k dry-run: a
168 GiB/device saved-probabilities buffer).  The standard fix -- and the one
every production system ships -- is recomputation: save only (q, k, v, out,
row-logsumexp) and rebuild each (q_chunk x kv_chunk) score tile on the fly in
the backward sweep.

Math (per tile, with optional logit softcap c and masks M):
  Z = scale Q K^T ; S = c tanh(Z/c) ; P = exp(S - L_row)  (L = logsumexp)
  dV += P^T dO
  dP  = dO V^T ;  D = rowsum(dO * O)
  dS  = P * (dP - D)
  dZ  = dS * (1 - (S/c)^2)            (tanh softcap jacobian; dZ=dS if c=0)
  dQ += scale dZ K ; dK += scale dZ^T Q

GQA: K/V gradients sum over the query-head group dim.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qpos, kpos, causal: bool, window: int):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def _fwd_scan(q, k, v, q_start, *, causal, window, cap, q_chunk, kv_chunk):
    """Returns (out, lse) with out (B,Hkv,G,Sq,D), lse (B,Hkv,G,Sq)."""
    b, hkv, g, sq, d = q.shape
    sk = k.shape[2]
    nq, nk = sq // q_chunk, sk // kv_chunk
    q_off = q_start.astype(jnp.int32)
    qs = q.reshape(b, hkv, g, nq, q_chunk, d)

    def per_q(qi):
        qc = qs[:, :, :, qi].astype(jnp.float32)
        qpos = q_off + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m_run, l_run, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk,
                                              axis=2).astype(jnp.float32)
            vc = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk,
                                              axis=2).astype(jnp.float32)
            z = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc)
            if cap > 0:
                z = cap * jnp.tanh(z / cap)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            msk = _mask(qpos, kpos, causal, window)
            z = jnp.where(msk[None, None, None], z, NEG_INF)
            m_cur = jnp.max(z, axis=-1, keepdims=True)
            m_new = jnp.maximum(m_run, m_cur)
            m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
            p = jnp.where(msk[None, None, None],
                          jnp.exp(z - m_safe), 0.0)
            alpha = jnp.exp(jnp.where(m_run <= NEG_INF / 2, NEG_INF,
                                      m_run - m_safe))
            l_new = l_run * alpha + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vc)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, q_chunk, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk))
        l_safe = jnp.where(l_f == 0.0, 1.0, l_f)
        out = acc / l_safe
        lse = (m_f + jnp.log(l_safe))[..., 0]
        return out.astype(q.dtype), lse

    outs = jax.lax.map(per_q, jnp.arange(nq))      # (nq,b,hkv,g,qc,*)
    out = outs[0].transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, sq, d)
    lse = outs[1].transpose(1, 2, 3, 0, 4).reshape(b, hkv, g, sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def flash_mha(q, k, v, q_start, causal: bool = True, window: int = 0,
              cap: float = 0.0, q_chunk: int = 2048, kv_chunk: int = 1024):
    """q: (B,Hkv,G,Sq,D) pre-scaled; k/v: (B,Hkv,Sk,D).  Out like q.

    ``q_start``: f32 scalar -- absolute position of q row 0 (context-parallel
    shards pass sk - sq_global + axis_index * local_sq).
    """
    out, _ = _fwd_scan(q, k, v, q_start, causal=causal, window=window,
                       cap=cap, q_chunk=q_chunk, kv_chunk=kv_chunk)
    return out


def _flash_fwd(q, k, v, q_start, causal, window, cap, q_chunk, kv_chunk):
    out, lse = _fwd_scan(q, k, v, q_start, causal=causal, window=window,
                         cap=cap, q_chunk=q_chunk, kv_chunk=kv_chunk)
    return out, (q, k, v, q_start, out, lse)


def _tile_grads(qc, doc, lsec, dc, kc, vc, qpos, kpos, causal, window, cap,
                tile_dtype=jnp.float32):
    """Recompute one (q_chunk x kv_chunk) tile; return (ds, p).

    The recomputed score/probability tiles are emitted in the MODEL's
    compute dtype (``tile_dtype`` = q's dtype): they are pure recompute
    traffic feeding MXU dots (f32-accumulated), and at 32k sequences the
    f32 versions dominated backward HBM bytes.  f32-input tests keep full
    precision.
    """
    z = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc)
    s = cap * jnp.tanh(z / cap) if cap > 0 else z
    msk = _mask(qpos, kpos, causal, window)[None, None, None]
    p = jnp.where(msk, jnp.exp(s - lsec[..., None]), 0.0)
    dp = jnp.einsum("bhgqd,bhkd->bhgqk", doc, vc)
    ds = p * (dp - dc[..., None])
    if cap > 0:
        ds = ds * (1.0 - jnp.square(s / cap))
    return ds.astype(tile_dtype), p.astype(tile_dtype)


def _flash_bwd(causal, window, cap, q_chunk, kv_chunk, res, dout):
    """Two-pass flash backward.

    Pass A (dq): scan q chunks, accumulate over kv chunks, EMIT dq chunks.
    Pass B (dk/dv): scan kv chunks, accumulate over q chunks, EMIT chunks.
    Carries and ys stay chunk-sized -- no full-size zero-init carries or
    dynamic_update_slice, which GSPMD otherwise reshards by gathering the
    whole batch (observed: 3.8 GB/step all-gathers in the internvl cell).
    """
    q, k, v, q_start, out, lse = res
    b, hkv, g, sq, d = q.shape
    sk = k.shape[2]
    nq, nk = sq // q_chunk, sk // kv_chunk
    q_off = q_start.astype(jnp.int32)

    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                   # (b,hkv,g,sq)

    def slc(x, i, chunk, axis):
        return jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=axis)

    # ---- pass A: dq ----
    def qi_step(_, qi):
        qc = slc(q, qi, q_chunk, 3).astype(jnp.float32)
        doc = slc(dout, qi, q_chunk, 3).astype(jnp.float32)
        lsec = slc(lse, qi, q_chunk, 3)
        dc = slc(delta, qi, q_chunk, 3)
        qpos = q_off + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(dq_acc, ki):
            kc = slc(k, ki, kv_chunk, 2).astype(jnp.float32)
            vc = slc(v, ki, kv_chunk, 2).astype(jnp.float32)
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            ds, _ = _tile_grads(qc, doc, lsec, dc, kc, vc, qpos, kpos,
                                causal, window, cap, tile_dtype=q.dtype)
            return dq_acc + jnp.einsum(
                "bhgqk,bhkd->bhgqd", ds, kc.astype(ds.dtype),
                preferred_element_type=jnp.float32), None

        dq0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
        dq_c, _ = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
        return 0, dq_c

    _, dqs = jax.lax.scan(qi_step, 0, jnp.arange(nq))          # (nq,b,h,g,qc,d)
    dq = dqs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, sq, d)

    # ---- pass B: dk, dv ----
    def ki_step(_, ki):
        kc = slc(k, ki, kv_chunk, 2).astype(jnp.float32)
        vc = slc(v, ki, kv_chunk, 2).astype(jnp.float32)
        kpos = ki * kv_chunk + jnp.arange(kv_chunk)

        def q_step(carry, qi):
            dk_acc, dv_acc = carry
            qc = slc(q, qi, q_chunk, 3).astype(jnp.float32)
            doc = slc(dout, qi, q_chunk, 3).astype(jnp.float32)
            lsec = slc(lse, qi, q_chunk, 3)
            dc = slc(delta, qi, q_chunk, 3)
            qpos = q_off + qi * q_chunk + jnp.arange(q_chunk)
            ds, p = _tile_grads(qc, doc, lsec, dc, kc, vc, qpos, kpos,
                                causal, window, cap, tile_dtype=q.dtype)
            dk_acc = dk_acc + jnp.einsum(
                "bhgqk,bhgqd->bhkd", ds, qc.astype(ds.dtype),
                preferred_element_type=jnp.float32)
            dv_acc = dv_acc + jnp.einsum(
                "bhgqk,bhgqd->bhkd", p, doc.astype(p.dtype),
                preferred_element_type=jnp.float32)
            return (dk_acc, dv_acc), None

        z = jnp.zeros((b, hkv, kv_chunk, d), jnp.float32)
        (dk_c, dv_c), _ = jax.lax.scan(q_step, (z, z), jnp.arange(nq))
        return 0, (dk_c, dv_c)

    _, (dks, dvs) = jax.lax.scan(ki_step, 0, jnp.arange(nk))   # (nk,b,h,kc,d)
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(b, hkv, sk, d)
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(b, hkv, sk, d)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            jnp.zeros_like(q_start))


flash_mha.defvjp(_flash_fwd, _flash_bwd)
