#!/usr/bin/env python
"""Static contract verification gate (scripts/smoke.sh leg 4).

Runs ``repro.analysis`` over the FULL static plan matrix -- backend x
fusion x partition x dtype x overlap, local plans plus 1-D and 2-D
shard_map plans on 8 fake CPU devices -- and the AST lint over
``src/repro/``, without executing a single plan.  Rule catalog:
``docs/analysis.md``.

  python scripts/analyze.py --strict     # exit 1 on any error finding
  python scripts/analyze.py --selftest   # every rule must catch its plant
  python scripts/analyze.py --json       # machine-readable report
  python scripts/analyze.py --markdown   # rendered report

``--strict`` is the CI gate: zero error-severity findings on the
shipped tree.  ``--selftest`` seeds one known violation per rule
(``repro.analysis.selftest``) and fails if ANY rule misses its plant --
the gate that keeps the gate honest.
"""

from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

# 8 fake devices BEFORE jax import: the distributed matrix cells trace
# shard_map programs over a (8,) / (4, 2) mesh
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

LOCAL_BACKENDS = ("xla", "pallas-tpu", "pallas-gpu")
DTYPES = ("f32", "bf16", "int8-agg")
OVERLAPS = ("none", "pipelined")


def _build_matrix():
    """Yield (label, plan, lint kwargs) for every static matrix cell."""
    import dataclasses

    import jax

    from repro.config import CORA, reduced_graph
    from repro.core.plan import build_plan
    from repro.graph.datasets import make_synthetic_graph
    from repro.models.gcn import PAPER_MODELS

    spec = reduced_graph(CORA, 64, 16)
    g = make_synthetic_graph(spec)
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(8,))

    # -- local: backend x fusion x dtype; the xla/unfused/f32 cell also
    #    proves the dynamic bucket path edge-content-free
    for backend in LOCAL_BACKENDS:
        for fused in (False, True):
            for dtype in DTYPES:
                plan = build_plan(g, cfg, spec.feature_len,
                                  spec.num_classes, backend=backend,
                                  fused=fused, dtype=dtype)
                dyn = backend == "xla" and not fused and dtype == "f32"
                yield plan, {"dynamic": dyn}

    # -- donation: a cell whose output CAN alias the donated features
    #    (feature_len == num_classes), so the marker must appear
    spec_d = dataclasses.replace(spec, feature_len=spec.num_classes)
    g_d = make_synthetic_graph(spec_d)
    plan = build_plan(g_d, cfg, spec_d.feature_len, spec_d.num_classes)
    yield plan, {"donate": True}

    # -- reorder cell: the permuted ingress/egress must stay trace-pure
    plan = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                      reorder="degree")
    yield plan, {}

    # -- dedup cells: a fanout-regular block (every dst draws two hub
    #    in-neighbors -> guaranteed matched pairs) where the XLA cell's
    #    trace must show the SHORTENED two-level fold (dedup-accounting)
    #    and the Pallas cell must still pass the general rules
    import numpy as np

    from repro.graph.structure import graph_from_coo
    rng = np.random.default_rng(0)
    hub_pairs = np.array([(a, b) for a in range(4) for b in range(a + 1, 4)])
    sel = hub_pairs[rng.integers(0, len(hub_pairs), spec.num_vertices)]
    g_dd = graph_from_coo(sel.reshape(-1),
                          np.repeat(np.arange(spec.num_vertices), 2),
                          spec.num_vertices)
    for backend in ("xla", "pallas-tpu"):
        plan = build_plan(g_dd, cfg, spec.feature_len, spec.num_classes,
                          backend=backend, dedup="pairs")
        yield plan, {}

    # -- 1-D halo: strategy x overlap x dtype on an (8,) mesh
    mesh = jax.make_mesh((8,), ("data",))
    for overlap in OVERLAPS:
        for dtype in DTYPES:
            plan = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                              mesh=mesh, overlap=overlap, dtype=dtype)
            yield plan, {}
    plan = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                      mesh=mesh, strategy="allgather")
    yield plan, {}

    # -- 2-D node x feature partition on a (4, 2) mesh
    mesh2 = jax.make_mesh((4, 2), ("node", "feat"))
    for overlap in OVERLAPS:
        for dtype in DTYPES:
            plan = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                              mesh=mesh2, overlap=overlap, dtype=dtype)
            yield plan, {}


def run_matrix(verbose: bool = False):
    """Lint every matrix cell + the shipped source tree; returns the
    merged AnalysisReport and the number of plan cells."""
    from repro.analysis.ast_lint import lint_tree
    from repro.analysis.jaxpr_lint import lint_plan, plan_label
    from repro.analysis.report import AnalysisReport

    report = AnalysisReport()
    cells = 0
    for plan, kwargs in _build_matrix():
        cells += 1
        if verbose:
            print(f"  lint {plan_label(plan)} {kwargs or ''}")
        report.merge(lint_plan(plan, **kwargs))
    lint_tree(ROOT / "src" / "repro", report)
    return report, cells


def run_selftest() -> int:
    from repro.analysis.selftest import run_selftest as _selftest
    detected, _ = _selftest()
    missed = sorted(r for r, ok in detected.items() if not ok)
    for rule in sorted(detected):
        print(f"  {rule:20s} {'DETECTED' if detected[rule] else 'MISSED'}")
    if missed:
        print(f"analyze --selftest: FAILED ({len(missed)} rule(s) missed "
              f"their plant: {', '.join(missed)})")
        return 1
    print(f"analyze --selftest: OK ({len(detected)} rules caught their "
          "plants; suppression pragma honored)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any error-severity finding")
    ap.add_argument("--selftest", action="store_true",
                    help="seed one violation per rule; fail on any miss")
    ap.add_argument("--json", action="store_true", help="JSON report")
    ap.add_argument("--markdown", action="store_true",
                    help="markdown report")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.selftest:
        return run_selftest()

    report, cells = run_matrix(verbose=args.verbose)
    if args.json:
        print(report.to_json())
    elif args.markdown:
        print(report.to_markdown())
    elif report.findings:
        print(report.render())
    counts = report.counts()
    ok = report.ok(strict=True)
    status = "OK" if ok else "FAILED"
    print(f"analyze: {status} ({cells} plan cells, {counts['error']} "
          f"error(s), {counts['warning']} warning(s), "
          f"{counts['info']} info)")
    if args.strict and not ok:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
