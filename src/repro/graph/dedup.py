"""GraphACT-style pair-redundancy elimination for sampled minibatch blocks.

The paper's guideline: aggregation is the memory-bound phase, so exploit
data reuse inside it.  GraphACT (PAPERS.md, arXiv:2001.02498) observes the
sharpest form of that reuse in fanout-regular sampled blocks: many
destination vertices share the same *pair* of in-neighbors, so the sum
``x[a] + x[b]`` is recomputed once per sharing destination.  This module
detects those shared pairs on the host (the same host/accelerator split
GraphACT uses between CPU matching and FPGA aggregation) and emits a
**two-level aggregation layout**:

  * **Level 1** computes each matched pair's partial sum ONCE:
    ``partials = x[pair_left] + x[pair_right]``           (P rows).
  * **Level 2** aggregates a *shortened* edge list over the virtual
    concatenation ``[x ; partials]`` (V + P rows): every matched
    destination's two pair edges are replaced by ONE edge referencing the
    pair partial, singleton edges pass through unchanged.

Matching discipline — why f32 stays bitwise-golden
--------------------------------------------------
Candidate pairs are **leading pairs only**: for each destination with
in-degree >= 2, the candidate is its FIRST TWO edges in dst-sorted order,
and a pair is kept only when at least ``min_frequency`` destinations share
it.  XLA's ``segment_sum`` reduces each destination segment as an in-order
left fold, so the naive fold ``((0 + e1) + e2) + rest`` and the dedup fold
``(0 + (e1 + e2)) + rest`` are IEEE-identical (``0 + x == x`` exactly, and
float addition is commutative, so the canonical ``(min, max)`` pair key is
safe).  Restricting to the leading pair keeps every eliminated addition
inside that provably exact prefix — which is what lets ``plan.compile()``
hold its bitwise f32 contract with dedup enabled (tests/test_dedup.py).

The layout is plan-owned and trace-pure: ``build_dedup_layout`` runs once
at plan-build time (O(E) numpy), the arrays it emits are consumed by the
XLA path and both Pallas tiers (``attach_blocked`` pre-blocks the level-2
edge list for ``kernels.ops.seg_agg_planned``), and the padding helper
(``pad_dedup_arrays``) extends a block's layout to a bucket's static
shapes with sink no-ops so ONE compiled callable serves every
fanout-regular block (models/sage_minibatch.py training loop).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

DEDUP_MODES = ("none", "pairs", "auto")


class DedupLayout(NamedTuple):
    """Two-level aggregation layout over a destination-sorted edge list.

    Level 1: ``partials = x[pair_left] + x[pair_right]`` (one row per
    matched pair).  Level 2: segment-sum ``src2``/``dst2`` over the
    virtual concatenation ``[x ; partials]`` — ``src2`` values in
    ``[0, num_vertices)`` reference original feature rows, values in
    ``[num_vertices, num_vertices + num_pairs)`` reference pair partials.
    ``dst2`` stays non-decreasing (dst-sorted), and within each matched
    destination the pair edge comes FIRST — the prefix position that makes
    the f32 left fold bitwise-equal to the naive fold.

    Static python ints (``num_pairs``/``num_edges2``/``matched_edges``/
    ``naive_edges``/``num_vertices``) are compile-time shape facts;
    ``blocked`` is the optional plan-time level-2 ``BlockedGraph`` for the
    Pallas tiers (``attach_blocked``).
    """

    pair_left: jnp.ndarray      # (P,) int32 first member of each pair
    pair_right: jnp.ndarray     # (P,) int32 second member (left <= right)
    src2: jnp.ndarray           # (E2,) int32 into [x ; partials]
    dst2: jnp.ndarray           # (E2,) int32 destination, non-decreasing
    num_pairs: int
    num_edges2: int
    matched_edges: int          # original edges covered by matched pairs
    naive_edges: int            # original |E|
    num_vertices: int
    blocked: Optional[object] = None   # core.dataflow.BlockedGraph

    @property
    def edges_removed(self) -> int:
        """Edges the level-2 list no longer carries (= matched dsts)."""
        return self.naive_edges - self.num_edges2

    def flops_saved(self, feature_len: int) -> float:
        """Adds eliminated per feature column: removed edge-adds minus the
        P pair-partial adds level 1 spends computing them."""
        return float((self.edges_removed - self.num_pairs) * feature_len)


def build_dedup_layout(src, dst, num_vertices: int, *,
                       min_frequency: int = 2) -> DedupLayout:
    """Greedy leading-pair matching over a dst-sorted edge list (host side).

    For every destination with >= 2 in-edges the candidate pair is its
    first two sources in dst-sorted order (canonicalized ``(min, max)`` —
    float add is commutative so the partial is order-independent).  Pairs
    shared by at least ``min_frequency`` destinations are kept; each
    matched destination's two leading edges collapse into one edge whose
    source is ``num_vertices + pair_id``.  O(E) numpy, no Python loop over
    edges.  A block with no shareable pairs yields ``num_pairs == 0`` —
    callers treat that as "dedup resolves to none".
    """
    s = np.asarray(src, np.int64)
    d = np.asarray(dst, np.int64)
    assert s.shape == d.shape and s.ndim == 1
    e = len(s)
    deg = np.bincount(d, minlength=num_vertices)
    assert (np.diff(d) >= 0).all() if e else True, "edge list must be dst-sorted"
    starts = np.zeros(num_vertices, np.int64)
    np.cumsum(deg[:-1], out=starts[1:])

    cand = np.where(deg >= 2)[0]                 # dsts owning a leading pair
    if len(cand) == 0:
        return DedupLayout(
            pair_left=jnp.zeros(0, jnp.int32), pair_right=jnp.zeros(0, jnp.int32),
            src2=jnp.asarray(s, jnp.int32), dst2=jnp.asarray(d, jnp.int32),
            num_pairs=0, num_edges2=e, matched_edges=0, naive_edges=e,
            num_vertices=int(num_vertices))
    a = s[starts[cand]]
    b = s[starts[cand] + 1]
    keys = np.stack([np.minimum(a, b), np.maximum(a, b)], axis=1)
    uniq, inv, counts = np.unique(keys, axis=0, return_inverse=True,
                                  return_counts=True)
    kept = counts >= min_frequency
    num_pairs = int(kept.sum())
    pid_of_uniq = np.full(len(uniq), -1, np.int64)
    pid_of_uniq[kept] = np.arange(num_pairs)
    pid = pid_of_uniq[inv]                       # per candidate dst; -1 = unmatched
    matched = pid >= 0
    matched_dsts = cand[matched]

    # Collapse in place: the first edge of each matched dst becomes the pair
    # edge (src = V + pair_id, the PREFIX slot that keeps the left fold
    # exact), the second edge is dropped.  Global dst-sort is preserved.
    s2 = s.copy()
    s2[starts[matched_dsts]] = num_vertices + pid[matched]
    drop = np.zeros(e, bool)
    drop[starts[matched_dsts] + 1] = True
    src2 = s2[~drop].astype(np.int32)
    dst2 = d[~drop].astype(np.int32)
    return DedupLayout(
        pair_left=jnp.asarray(uniq[kept, 0], jnp.int32),
        pair_right=jnp.asarray(uniq[kept, 1], jnp.int32),
        src2=jnp.asarray(src2), dst2=jnp.asarray(dst2),
        num_pairs=num_pairs, num_edges2=int(len(src2)),
        matched_edges=int(2 * len(matched_dsts)), naive_edges=e,
        num_vertices=int(num_vertices), blocked=None)


def dedup_layout_for_graph(g, *, min_frequency: int = 2) -> DedupLayout:
    """``build_dedup_layout`` over a ``Graph``'s dst-sorted edge arrays."""
    return build_dedup_layout(np.asarray(g.src), np.asarray(g.dst),
                              g.num_vertices, min_frequency=min_frequency)


def attach_blocked(layout: DedupLayout, tile_m: int) -> DedupLayout:
    """Pre-block the level-2 edge list for the Pallas tiers (plan time).

    The blocked layout's gather sources index the (V + P)-row virtual
    concatenation, so it must be built by ``core.dataflow
    .block_graph_arrays`` (plain ``block_graph`` would reject src >= V);
    the output row space stays the original V destinations.
    """
    from repro.core.dataflow import block_graph_arrays
    bg = block_graph_arrays(np.asarray(layout.src2), np.asarray(layout.dst2),
                            layout.num_vertices, tile_m)
    return layout._replace(blocked=bg)


def dedup_cost(layout: DedupLayout, feature_len: int, dtype_bytes: int = 4,
               include_self: bool = True) -> dict:
    """Analytic cost of the two-level aggregation (``aggregate_cost`` twin).

    flops: P pair adds + E2 level-2 adds (+ V self adds); bytes: gather one
    row per level-2 edge and per pair member, write P partials + V outputs,
    plus index traffic for both levels.  Compare with the naive
    ``phases.aggregate_cost`` of the same graph to get the modeled saving.
    """
    p, e2, v = layout.num_pairs, layout.num_edges2, layout.num_vertices
    v_self = v if include_self else 0
    flops = (p + e2 + v_self) * feature_len
    reads = (e2 + 2 * p + v_self) * feature_len * dtype_bytes
    writes = (v + p) * feature_len * dtype_bytes
    index_reads = e2 * 8 + 2 * p * 4
    byt = reads + writes + index_reads
    return {"bytes": byt, "flops": flops, "gathered_rows": e2 + 2 * p,
            "pairs": p, "flops_saved": layout.flops_saved(feature_len),
            "arithmetic_intensity": flops / max(1, byt)}


def pad_dedup_arrays(layout: DedupLayout, num_pairs: int, num_edges2: int,
                     sink: int) -> Tuple[np.ndarray, np.ndarray,
                                         np.ndarray, np.ndarray]:
    """Pad a block's dedup arrays to a bucket's static shapes (host side).

    Exactness contract (mirrors ``GraphServeEngine._pad_into``): pad pairs
    are ``(sink, sink)`` — the sink row is all-zero, so their partials are
    exact zeros — and pad level-2 edges are sink self-loops appended AFTER
    the real (sorted) edges, so every real destination sees exactly the
    real fold in the real order.  Returns numpy
    ``(pair_left, pair_right, src2, dst2)`` sized ``(num_pairs,)`` /
    ``(num_edges2,)`` ready to feed one compiled callable per bucket.
    """
    assert layout.num_pairs <= num_pairs, "bucket too small for pairs"
    assert layout.num_edges2 <= num_edges2, "bucket too small for edges"
    pad_p = num_pairs - layout.num_pairs
    pad_e = num_edges2 - layout.num_edges2
    pl = np.concatenate([np.asarray(layout.pair_left, np.int32),
                         np.full(pad_p, sink, np.int32)])
    pr = np.concatenate([np.asarray(layout.pair_right, np.int32),
                         np.full(pad_p, sink, np.int32)])
    s2 = np.concatenate([np.asarray(layout.src2, np.int32),
                         np.full(pad_e, sink, np.int32)])
    d2 = np.concatenate([np.asarray(layout.dst2, np.int32),
                         np.full(pad_e, sink, np.int32)])
    return pl, pr, s2, d2
