"""Backend & runtime detection shared by the kernels and the planner.

One place answers three questions every execution path used to answer
ad-hoc (and sometimes wrongly, e.g. a hardcoded ``interpret=True``):

  * which platform are we on (``platform`` / ``on_tpu``)?
  * should Pallas kernels run compiled or interpreted
    (``default_interpret``: interpret off-TPU so the whole suite runs on
    CPU containers, compiled on real TPUs; overridable via
    ``REPRO_PALLAS_INTERPRET``)?
  * which aggregation backend should a plan use when asked for "auto"
    (``resolve_backend``: the Pallas kernels only pay off where an MXU
    exists, so auto means pallas-on-TPU / XLA ``segment_sum`` elsewhere)?

The execution planner (core/plan.py) consults this module once at plan-build
time; kernels consult it only when a caller passes ``interpret=None``.
"""

from __future__ import annotations

import os

import jax

XLA = "xla"
PALLAS = "pallas"
AUTO = "auto"
BACKENDS = (XLA, PALLAS)


def platform() -> str:
    """The JAX default backend platform: "cpu" | "gpu" | "tpu"."""
    return jax.default_backend()


def on_tpu() -> bool:
    return platform() == "tpu"


def default_interpret() -> bool:
    """Pallas interpret mode default: compiled on TPU, interpreted elsewhere.

    ``REPRO_PALLAS_INTERPRET=0``/``1`` overrides the detection (e.g. to force
    interpret mode on a TPU while debugging a kernel).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env != "0"
    return not on_tpu()


def resolve_interpret(interpret=None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def resolve_backend(requested: str = AUTO) -> str:
    """Map a requested backend ("auto" allowed) to a concrete one."""
    if requested in BACKENDS:
        return requested
    if requested != AUTO:
        raise ValueError(
            f"unknown backend {requested!r}; expected one of "
            f"{BACKENDS + (AUTO,)}")
    return PALLAS if on_tpu() else XLA
