"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

Real-cluster entry point: builds the production mesh from the available
devices (or any smaller mesh on dev boxes), shards state per launch/specs,
and drives train/trainer.Trainer (checkpoint-resume, failure recovery,
straggler watchdog).  On this CPU container run it with a reduced config:

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
      --reduced --steps 20 --batch 4 --seq 64

On a TPU slice the same command with real flags uses the full config and
the (data, model) production mesh.
"""

from __future__ import annotations

import argparse
import dataclasses
import importlib
import logging

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (OptimizerConfig, ShapeSpec, TrainConfig,
                          get_config)
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_test_mesh
from repro.launch.sharding import rules_for, sharding_rules
from repro.launch.specs import (arch_attn_tp, input_pspecs, state_pspecs)
from repro.launch.steps import make_train_step
from repro.models import encdec as encdec_lib
from repro.models.transformer import init_lm
from repro.optim.optimizer import make_train_state
from repro.train.trainer import Trainer

MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2", "arctic-480b": "arctic_480b",
    "deepseek-67b": "deepseek_67b", "gemma2-9b": "gemma2_9b",
    "gemma-7b": "gemma_7b", "granite-3-8b": "granite_3_8b",
    "jamba-1.5-large-398b": "jamba_1_5_large", "internvl2-1b": "internvl2_1b",
    "seamless-m4t-medium": "seamless_m4t_medium", "mamba2-2.7b": "mamba2_2_7b",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-sized family config (CPU dev)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    if args.reduced:
        mod = importlib.import_module(f"repro.configs.{MODULES[args.arch]}")
        cfg = dataclasses.replace(mod.reduced(), dtype="float32")
    else:
        cfg = get_config(args.arch)
    if cfg.family == "audio":
        raise SystemExit("use the encdec example path for audio archs")

    shape = ShapeSpec("train_cli", args.seq, args.batch, "train")
    opt = OptimizerConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                          total_steps=args.steps)
    tc = TrainConfig(model=cfg.name, steps=args.steps, optimizer=opt,
                     checkpoint_dir=args.ckpt_dir, checkpoint_every=25,
                     log_every=5)

    mesh = make_test_mesh()
    with mesh, sharding_rules(mesh, rules_for(cfg, mesh)):
        attn_tp = arch_attn_tp(cfg, mesh)
        step_fn0 = make_train_step(cfg, opt, remat=args.remat,
                                   microbatch=args.microbatch)
        abstract = jax.eval_shape(
            lambda: make_train_state(init_lm(cfg, jax.random.PRNGKey(0)),
                                     opt))
        st_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                             state_pspecs(abstract, mesh, attn_tp),
                             is_leaf=lambda x: isinstance(x, P))
        bt_sh = {k: NamedSharding(mesh, v) for k, v in
                 input_pspecs(cfg, shape, mesh).items()
                 if k in ("tokens", "labels", "embeds")}
        step_fn = jax.jit(step_fn0, in_shardings=(st_sh, bt_sh),
                          donate_argnums=(0,))
        pipeline = TokenPipeline(cfg, shape, seed=0)

        def make_state():
            return jax.jit(
                lambda: make_train_state(
                    init_lm(cfg, jax.random.PRNGKey(0)), opt),
                out_shardings=st_sh)()

        trainer = Trainer(tc, make_state=make_state, step_fn=step_fn,
                          pipeline=pipeline, state_shardings=st_sh,
                          batch_shardings=bt_sh)
        result = trainer.run()
    h = result["history"]
    print(f"done: loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}; "
          f"recoveries={result['recoveries']}")


if __name__ == "__main__":
    main()
