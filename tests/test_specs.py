"""Dry-run specs: cell enumeration, abstract inputs, param partition specs.

These run WITHOUT the 512-device env (pure metadata) -- mesh construction
for spec checks uses an AbstractMesh so no devices are touched."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.config import ALL_SHAPES, SHAPES_BY_NAME, get_config
from repro.configs import ASSIGNED_ARCHS
from repro.launch.specs import (abstract_params, arch_attn_tp, input_specs,
                                param_pspecs)


def _mesh(multi=False):
    # jax >= 0.5 takes (axis_sizes, axis_names); 0.4.x takes one tuple of
    # (name, size) pairs -- build whichever this install accepts.
    sizes, names = ((2, 16, 16), ("pod", "data", "model")) if multi \
        else ((16, 16), ("data", "model"))
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


def test_cell_enumeration_is_40():
    cells = [(a, s.name) for a in ASSIGNED_ARCHS for s in ALL_SHAPES]
    assert len(cells) == 40
    runnable = [(a, s.name) for a in ASSIGNED_ARCHS
                for s in get_config(a).shapes()]
    skipped = 40 - len(runnable)
    assert skipped == 7  # 7 archs skip long_500k


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_input_specs_all_cells(arch):
    cfg = get_config(arch)
    for shape in cfg.shapes():
        specs = input_specs(cfg, shape)
        assert specs, (arch, shape.name)
        if shape.kind == "train":
            assert specs["tokens"].shape[0] == shape.global_batch
            total = specs["tokens"].shape[1] + \
                (specs["embeds"].shape[1] if "embeds" in specs else 0)
            assert total == shape.seq_len
        elif shape.kind == "decode":
            assert specs["token"].shape == (shape.global_batch, 1)
            kv_leaves = jax.tree.leaves(specs["caches"])
            if cfg.attention is not None:  # SSM caches have no seq dim
                assert any(shape.seq_len in l.shape for l in kv_leaves)
        # no real allocation happened
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


@pytest.mark.parametrize("arch", ["granite-3-8b", "kimi-k2-1t-a32b",
                                  "mamba2-2.7b", "internvl2-1b"])
def test_param_pspecs_divisibility(arch):
    """Every sharded dim must divide by its mesh-axes product."""
    cfg = get_config(arch)
    mesh = _mesh()
    params = abstract_params(cfg)
    specs = param_pspecs(params, mesh, arch_attn_tp(cfg, mesh))

    def check(leaf, spec):
        for dim, part in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (leaf.shape, spec)
    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def test_moe_experts_sharded():
    cfg = get_config("kimi-k2-1t-a32b")
    mesh = _mesh()
    specs = param_pspecs(abstract_params(cfg), mesh, True)
    wi_spec = specs["blocks"]["pos0"]["moe"]["wi"]
    assert wi_spec[1] == "model"  # experts dim (after stack dim) EP-sharded


def test_ctx_profile_for_indivisible_heads():
    mesh = _mesh()
    assert not arch_attn_tp(get_config("internvl2-1b"), mesh)  # 14 heads
    assert not arch_attn_tp(get_config("arctic-480b"), mesh)   # 56 heads
    assert arch_attn_tp(get_config("deepseek-67b"), mesh)      # 64 heads


def test_padded_vocab_shards():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab - cfg.vocab_size < 256


def test_sharded_params_fit_hbm_serve():
    """bf16 serving params per chip must fit 16G HBM on the single pod."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        per_chip = cfg.param_count() * 2 / 256
        assert per_chip < 16 * 2 ** 30, f"{arch}: {per_chip/2**30:.1f} GiB"
