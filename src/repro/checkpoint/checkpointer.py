"""Fault-tolerant checkpointing: async, atomic, elastic-restorable.

Design points (the 1000-node posture, DESIGN.md §6):

  * **Atomicity** -- writes go to ``step_N.tmp/`` and are renamed to
    ``step_N/`` only after every array + metadata file is fsync'd.  A crash
    mid-save can never corrupt the latest-good checkpoint.
  * **Async** -- ``save()`` snapshots the (device) state to host and hands
    the serialization to a background thread; the train loop continues.  A
    failed async save marks the checkpointer dirty and surfaces on the next
    ``wait()``/``save()``.
  * **Retention** -- keeps the newest ``keep`` checkpoints (never deletes
    the one being written).
  * **Elastic restore** -- arrays are stored UNSHARDED (host-gathered
    numpy), so a restore may target a different mesh/topology than the
    writer; restore takes abstract shardings and re-shards on load.  This is
    the restart-on-fewer-nodes path.
  * **Pipeline state** -- the data-pipeline position and RNG are part of the
    checkpoint payload, so restarts are bitwise-resumable.

Format: one ``.npy`` per leaf (path-encoded filename) + ``meta.json``
(tree structure, step, extra state).  No external deps.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_elem(p) for p in path)
        out[key] = leaf
    return out


def _path_elem(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        """Snapshot state to host, then serialize in the background."""
        self.wait()  # surface previous failure / avoid overlapping saves

        def to_host(a):
            # checkpointing IS host materialization -- never traced
            arr = np.asarray(jax.device_get(a))  # analysis: allow(host-in-trace)
            # numpy can't serialize ml_dtypes (bf16/f8); store as f32 --
            # bf16 embeds exactly in f32, restore casts back via the
            # abstract dtype.
            if arr.dtype.name in ("bfloat16", "float8_e4m3fn",
                                  "float8_e5m2"):
                arr = arr.astype(np.float32)
            return arr
        host_state = jax.tree.map(to_host, state)
        treedef = jax.tree_util.tree_structure(state)
        payload = _flatten_with_paths(host_state)
        meta = {"step": int(step), "extra": extra or {},
                "treedef": str(treedef), "keys": sorted(payload.keys()),
                "time": time.time()}

        def work():
            tmp = self.dir / f"step_{step:012d}.tmp"
            final = self.dir / f"step_{step:012d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for key, arr in payload.items():
                fname = tmp / (key.replace("/", "__") + ".npy")
                with open(fname, "wb") as f:
                    np.save(f, arr)
                    f.flush()
                    os.fsync(f.fileno())
            with open(tmp / "meta.json", "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)  # atomic publish
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=self._guard(work),
                                            daemon=True)
            self._thread.start()

    def _guard(self, fn):
        def wrapped():
            try:
                fn()
            except BaseException as e:  # noqa: BLE001
                self._error = e
        return wrapped

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {err}") \
                from err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:012d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and \
                    not p.name.endswith(".tmp") and (p / "meta.json").exists():
                out.append(int(p.name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, abstract_state: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``abstract_state``.

        ``shardings``: optional matching tree of Shardings -- arrays are
        placed (and re-sharded) accordingly; THIS is what makes restore
        elastic across mesh changes.
        Returns (state, step, extra).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:012d}"
        meta = json.loads((d / "meta.json").read_text())

        paths_to_leaves = {}
        for key in meta["keys"]:
            arr = np.load(d / (key.replace("/", "__") + ".npy"))
            paths_to_leaves[key] = arr

        flat_abs = jax.tree_util.tree_flatten_with_path(abstract_state)
        leaves_abs, treedef = flat_abs
        shard_flat = None
        if shardings is not None:
            shard_flat = jax.tree.leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec") or x is None)
        out_leaves = []
        for i, (path, leaf) in enumerate(leaves_abs):
            key = "/".join(_path_elem(p) for p in path)
            if key not in paths_to_leaves:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = paths_to_leaves[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"abstract {leaf.shape}")
            arr = arr.astype(leaf.dtype)
            if shard_flat is not None and shard_flat[i] is not None:
                out_leaves.append(jax.device_put(arr, shard_flat[i]))
            else:
                out_leaves.append(jax.device_put(arr))
        state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(abstract_state), out_leaves)
        return state, step, meta["extra"]
