"""Paper Table 4 as a runnable demo: phase ordering on (scaled) Reddit,
driven end-to-end by the GraphExecutionPlan.

Shows the four views of the paper's headline result:
  1. analytic bytes/ops for both orderings (the paper's accounting),
  2. the planner's own decision for this (graph, layer) -- F2 as code,
  3. measured wall-clock Com->Agg vs Agg->Com (both as planner scenarios),
  4. the fused inter-phase dataflow (guideline 5.1-3) on top.

  PYTHONPATH=src python examples/gcn_phase_ordering.py
"""

import time

import jax
import jax.numpy as jnp

from repro.config import REDDIT, reduced_graph
from repro.core.plan import plan_for_phases
from repro.core.scheduler import reduction_ratios
from repro.graph.datasets import make_features, make_synthetic_graph

IN_LEN, OUT_LEN = 602, 128


def bench(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e3


def main():
    spec = reduced_graph(REDDIT, max_vertices=8192, max_feature=IN_LEN)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    w = jax.random.normal(jax.random.PRNGKey(0), (IN_LEN, OUT_LEN)) * 0.05
    weights = [(w, None)]

    print(f"graph: |V|={g.num_vertices:,} |E|={g.num_edges:,} "
          f"features {IN_LEN} -> {OUT_LEN}")

    r = reduction_ratios(g, IN_LEN, OUT_LEN)
    print(f"\n1. analytic (paper Table 4 accounting)")
    print(f"   aggregation bytes  Agg->Com: {r['aggregate_first'].agg_bytes:,}")
    print(f"   aggregation bytes  Com->Agg: {r['combine_first'].agg_bytes:,}")
    print(f"   reduction: {r['data_access_reduction']:.2f}x data, "
          f"{r['computation_reduction']:.2f}x ops "
          f"(paper: 4.75x, 4.72x)")

    auto = plan_for_phases(g, weights, order=None, agg_op="mean")
    d = auto.describe()[0]
    print(f"\n2. planner decision: order={d['order']} backend={d['backend']} "
          f"interpret={d['interpret']}")

    plans = {o: plan_for_phases(g, weights, order=o, agg_op="mean")
             for o in ("combine_first", "aggregate_first")}
    cf = jax.jit(lambda xx: plans["combine_first"].run_phases(
        xx, weights, activation="none"))
    af = jax.jit(lambda xx: plans["aggregate_first"].run_phases(
        xx, weights, activation="none"))
    t_cf, t_af = bench(cf, x), bench(af, x)
    print(f"\n3. measured: Com->Agg {t_cf:.1f} ms | Agg->Com {t_af:.1f} ms"
          f" | speedup {t_af / t_cf:.2f}x (paper: 4.76x)")

    fused_plan = plan_for_phases(g, weights, order="combine_first",
                                 agg_op="mean", fused=True)
    fused = jax.jit(lambda xx: fused_plan.run_phases(
        xx, weights, activation="none"))
    t_fused = bench(fused, x)
    err = float(jnp.abs(fused(x) - cf(x)).max())
    print(f"\n4. fused inter-phase dataflow "
          f"(tile_m={fused_plan.layers[0].tile_m}): "
          f"{t_fused:.1f} ms (err vs unfused {err:.1e})")


if __name__ == "__main__":
    main()
