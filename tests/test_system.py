"""End-to-end behaviour tests: the paper's workloads through the full stack."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (CORA, OptimizerConfig, ShapeSpec, TrainConfig,
                          override, reduced_graph)
from repro.configs import granite_3_8b
from repro.data.pipeline import GraphPipeline, TokenPipeline
from repro.graph.datasets import (load_dataset, make_features, make_labels,
                                  make_synthetic_graph)
from repro.models.gcn import make_paper_model


def test_gcn_node_classification_end_to_end():
    """Train 2-layer GCN on synthetic cora; accuracy must beat chance."""
    spec = reduced_graph(CORA, 256, 48)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    y = make_labels(spec)
    # make labels learnable: inject class signal into features.  The signal
    # must survive neighborhood-mean smoothing, so make it dominant.
    x = x.at[:, :spec.num_classes].add(
        5.0 * jax.nn.one_hot(y, spec.num_classes))
    m = make_paper_model("gcn", spec)
    p = m.init(jax.random.PRNGKey(0))
    lr = 0.2
    loss_grad = jax.jit(jax.value_and_grad(lambda pp: m.loss_fn(pp, g, x, y)))
    for _ in range(120):
        loss, gr = loss_grad(p)
        p = jax.tree.map(lambda a, b: a - lr * b, p, gr)
    logits = m.apply(p, g, x)
    acc = float((jnp.argmax(logits, -1) == y).mean())
    assert acc > 2.0 / spec.num_classes, f"accuracy {acc}"


def test_gin_and_sage_end_to_end():
    spec = reduced_graph(CORA, 128, 32)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    y = make_labels(spec)
    for name in ("gin", "sage"):
        m = make_paper_model(name, spec)
        p = m.init(jax.random.PRNGKey(1))
        l0 = float(m.loss_fn(p, g, x, y))
        grad = jax.jit(jax.grad(lambda pp: m.loss_fn(pp, g, x, y)))
        for _ in range(25):
            p = jax.tree.map(lambda a, b: a - 0.2 * b, p, grad(p))
        l1 = float(m.loss_fn(p, g, x, y))
        assert l1 < l0, name


def test_lm_overfits_tiny_batch():
    """Substrate sanity: a small LM must overfit one repeated batch."""
    cfg = dataclasses.replace(granite_3_8b.reduced(), dtype="float32")
    from repro.launch.steps import make_train_step
    from repro.models.transformer import init_lm
    from repro.optim.optimizer import make_train_state
    opt = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=100,
                          weight_decay=0.0)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    state = make_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    first = None
    for i in range(40):
        state, metrics = step(state, batch)
        if first is None:
            first = float(np.asarray(metrics["ce"]))
    last = float(np.asarray(metrics["ce"]))
    assert last < first * 0.5, (first, last)


def test_pipeline_determinism_and_resume():
    cfg = granite_3_8b.reduced()
    shape = ShapeSpec("t", 16, 4, "train")
    p1 = TokenPipeline(cfg, shape, seed=7)
    p2 = TokenPipeline(cfg, shape, seed=7)
    b1 = p1.batch_at(13)
    b2 = p2.batch_at(13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # zipf marginal: token 0 must be the most common
    toks = p1.batch_at(0)["tokens"].ravel()
    counts = np.bincount(toks, minlength=cfg.vocab_size)
    assert counts[0] == counts.max()
    # resume state round-trips
    p1.step = 5
    st = p1.state_dict()
    p3 = TokenPipeline(cfg, shape, seed=0)
    p3.load_state_dict(st)
    assert p3.step == 5 and p3.seed == 7


def test_graph_pipeline():
    spec = reduced_graph(CORA, 128, 16)
    g = make_synthetic_graph(spec)
    gp = GraphPipeline(g, spec, batch_size=8, fanouts=(3, 3), seed=0)
    b = gp.batch_at(0)
    assert len(b["seeds"]) == 8
    assert b["hop1"].graph.num_edges == 8 * 3
    b2 = GraphPipeline(g, spec, batch_size=8, fanouts=(3, 3),
                       seed=0).batch_at(0)
    np.testing.assert_array_equal(b["seeds"], b2["seeds"])


def test_config_override_nested():
    cfg = granite_3_8b.reduced()
    c2 = override(cfg, num_layers=4, **{"attention.num_heads": 8})
    assert c2.num_layers == 4 and c2.attention.num_heads == 8
    assert cfg.attention.num_heads == 4  # original untouched
