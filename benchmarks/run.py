"""Benchmark harness entry: one module per paper table/figure.

Every module is a list of declarative ``BenchSpec``s (``mod.SPECS``)
executed by the ONE shared harness (``repro.profile.bench.run_specs``),
which owns warmup/timing, the ``name,us_per_call,derived...`` stdout echo,
and a per-module CSV artifact under ``experiments/bench/`` (header row,
stable column order -- what ``experiments/make_tables.py::bench_tables``
reads instead of re-parsing stdout).

  bench_breakdown       Fig. 1  execution-time breakdown
  bench_agg_vs_pgr      Fig. 2  Aggregation vs PageRank + reorder guideline
  bench_phase_metrics   Fig. 2(f,g)/Table 3  hybrid patterns x Machines
  bench_ordering        Table 4 phase-ordering impact (+distributed halo)
  bench_feature_length  Fig. 5  input/output length sweeps
  bench_kernels         beyond-paper: Pallas kernels + fused dataflow
  bench_plan            planner sweep: backend x ordering x fusion scenarios
  bench_overlap         overlap x strategy x partition halo-pipelining matrix
  bench_serve           serving: GraphServeEngine offered-load latency sweep
  bench_dtype           dtype x feature_len precision matrix + choose_dtype flip
  bench_dedup           pair-redundancy elimination: dedup savings + choose_dedup flip
  roofline              deliverable (g): dry-run roofline table

Usage: PYTHONPATH=src python -m benchmarks.run [--dry-run] [module ...]

``--dry-run`` routes through the execution planner only: every scenario
plan is built, run INSTRUMENTED (a schema-validated ``WorkloadReport`` per
scenario -- empty phase records or describe()-vs-dispatch drift fail), and
validated on tiny graphs with no timing -- the pre-merge smoke check
(scripts/smoke.sh).  A selected module whose specs declare no dry-run
scenarios is a HARD failure: a scenario silently skipped here would merge
unvalidated.
"""

import sys
import traceback


def _run_module(name: str, mod, dry: bool) -> None:
    """Run one module's specs through the shared harness + its post hook."""
    from repro.profile.bench import BENCH_ARTIFACT_DIR, run_specs

    specs = getattr(mod, "SPECS", None)
    if not specs:
        raise RuntimeError(f"{name} declares no SPECS; its scenarios would "
                           "be silently skipped -- declare BenchSpecs")
    if dry and not any(s.dry == "run" for s in specs):
        raise RuntimeError(
            f"{name} has no dry-run-capable specs; its scenarios would be "
            "silently skipped -- mark specs dry='run' or drop it from the "
            "dry-run selection")
    rows = run_specs(
        specs, dry=dry,
        csv=BENCH_ARTIFACT_DIR / f"{name}{'.dry' if dry else ''}.csv")
    post = getattr(mod, "post_run", None)
    if post is not None:
        post(rows, dry=dry)


def main() -> None:
    argv = sys.argv[1:]
    dry = "--dry-run" in argv
    argv = [a for a in argv if a != "--dry-run"]

    from benchmarks import (bench_agg_vs_pgr, bench_breakdown, bench_dedup,
                            bench_dtype, bench_feature_length,
                            bench_kernels, bench_ordering, bench_overlap,
                            bench_phase_metrics, bench_plan, bench_serve,
                            roofline)
    modules = {
        "bench_breakdown": bench_breakdown,
        "bench_agg_vs_pgr": bench_agg_vs_pgr,
        "bench_phase_metrics": bench_phase_metrics,
        "bench_ordering": bench_ordering,
        "bench_feature_length": bench_feature_length,
        "bench_kernels": bench_kernels,
        "bench_plan": bench_plan,
        "bench_overlap": bench_overlap,
        "bench_serve": bench_serve,
        "bench_dtype": bench_dtype,
        "bench_dedup": bench_dedup,
        "roofline": roofline,
    }
    if dry:
        # bench_serve's dry sweep is the serving acceptance gate (bucket
        # misses, retraces, padded-vs-eager drift, empty serving stats),
        # bench_overlap's is the halo-pipelining gate (bitwise
        # pipelined==none, compiled contract, modeled-time ordering), and
        # bench_dtype's is the precision gate (f32 bitwise under compile,
        # reduced dtypes banded, choose_dtype preset flip, bf16 halo
        # halving), and bench_dedup's is the redundancy-elimination gate
        # (zero matched pairs on a fanout-regular block, an analytic
        # aggregation-FLOP reduction under the floor, f32 drift from the
        # naive plan, or a missing choose_dedup workload flip hard-fail)
        # -- all hard-fail the smoke check alongside the planner matrix.
        selected = argv or ["bench_plan", "bench_overlap", "bench_serve",
                            "bench_dtype", "bench_dedup"]
    else:
        selected = argv or list(modules)

    failures = 0
    for name in selected:
        print(f"# === {name}{' (dry)' if dry else ''} ===")
        try:
            _run_module(name, modules[name], dry)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if failures:
        raise SystemExit(
            f"{failures} benchmark module(s) failed"
            + (" (dry-run)" if dry else ""))


if __name__ == '__main__':
    main()
