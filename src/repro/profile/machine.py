"""Machine: one dataclass describing the hardware a characterization targets.

The paper characterizes GCNs on a V100 and derives guidelines from that
machine's balance point; PRs 2-3 added a TPU tier and a GPU tier but left the
hardware numbers as module-level constants in ``core/characterize.py`` (TPU
v5e) plus a bag of ``GPU_*`` occupancy constants.  This module replaces both:
every roofline term, bound classification, tile picker, and ordering cost
model takes a ``Machine`` value instead of importing globals, so the same
analysis runs against any accelerator by passing a different preset.

Presets::

    TPU_V5E   197 TFLOP/s bf16, 819 GB/s HBM, 4x50 GB/s ICI, 128 MiB VMEM
    TPU_V5P   459 TFLOP/s bf16, 2765 GB/s HBM2e, 6x100 GB/s ICI (3-D
              torus), 128 MiB VMEM -- the multi-host scale-out target the
              distributed overlap model prices
    A100      312 TFLOP/s bf16, 1555 GB/s HBM, 12x25 GB/s NVLink,
              192 KiB SMEM/L1 carveout per SM (the GPU occupancy model)
    H100      989 TFLOP/s bf16, 3350 GB/s HBM3, 18x25 GB/s NVLink 4,
              228 KiB SMEM/L1 carveout per SM (the serving-tier GPU)
    V100      15.7 TFLOP/s fp32, 900 GB/s HBM -- the PAPER's machine; its
              balance point (~17.4 F/B) is the classification threshold
              behind Table 3's "Execution Bound" row.

The interconnect is described per hop -- ``interconnect_bw`` (one link's
bandwidth) plus ``link_latency_s`` (per-message launch latency) -- because
the ring halo schedules (``core.distributed``) saturate ONE link per
direction per hop; ``interconnect_total`` remains the aggregate all-links
number for bisection-style accounting.  ``hop_time(nbytes)`` is the
overlap model's per-hop wire term.

``machine_for_backend`` maps a resolved backend tier (``core.backend``) to
its natural preset so plan-level code can stay machine-implicit until a
caller overrides it.

``choose_dtype``/``dtype_model`` price the execution dtype the same way
``choose_overlap``/``overlap_model`` price halo pipelining: per-phase byte
and FLOP terms against THIS machine's HBM bandwidth, matmul peak at the
candidate precision (``native_bf16`` gates whether bf16 doubles or halves
the matmul rate), and -- when a partition is in play -- ``hop_time`` on the
reduced halo payload.  The resolved value feeds ``build_plan(dtype="auto")``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class Machine:
    """Hardware description consumed by the characterization subsystem.

    Attributes:
      name: registry key ("tpu-v5e" | "a100" | "v100" | ...).
      kind: accelerator family, "tpu" | "gpu" (selects the occupancy model
        ``suggest_tile_m`` applies).
      peak_flops: peak matmul FLOP/s at the native precision the repo
        models (bf16 tensor/MXU for v5e/A100, fp32 CUDA cores for the
        paper's V100 numbers).
      hbm_bw: HBM bandwidth, bytes/s.
      interconnect_bw: per-link chip interconnect bandwidth, bytes/s
        (ICI link on TPU, NVLink lane on GPU) -- the PER-HOP bandwidth a
        ring collective sees (one link per direction per hop).
      interconnect_links: number of such links per chip.
      link_latency_s: per-message launch latency of one interconnect hop,
        seconds (the fixed term of ``hop_time``; ~1 us ICI, ~2 us NVLink
        with software overheads).
      on_chip_bytes: the fast scratch a fused tile must fit -- whole VMEM
        on TPU, the unified SMEM/L1 carveout per SM on GPU.
      regfile_bytes: register file per SM (GPU occupancy input; 0 on TPU).
      target_ctas: resident CTAs per SM needed to hide HBM latency (GPU
        occupancy input; 0 on TPU, where one sequential grid walks blocks).
      row_align: natural row granularity of a tile (8 sublanes on TPU,
        32 warp threads on GPU).
      matrix_tile: systolic/tensor tile edge for pad-waste accounting
        (128 MXU lanes on TPU).
      native_bf16: whether the matmul units run bf16 at ``peak_flops``
        (MXU / tensor cores: v5e, v5p, A100, H100).  False on the paper's
        V100, whose ``peak_flops`` is the fp32 CUDA-core rate -- there
        bf16 matmuls emulate through fp32 and gain nothing, which is what
        lets ``choose_dtype`` flip between presets on the same workload.
    """

    name: str
    kind: str
    peak_flops: float
    hbm_bw: float
    interconnect_bw: float
    interconnect_links: int
    on_chip_bytes: int
    link_latency_s: float = 1e-6
    regfile_bytes: int = 0
    target_ctas: int = 0
    row_align: int = 8
    matrix_tile: int = 128
    native_bf16: bool = True

    def __post_init__(self):
        assert self.kind in ("tpu", "gpu"), self.kind

    @property
    def balance(self) -> float:
        """Machine balance: FLOPs per HBM byte at which compute and memory
        time are equal.  AI below this is memory-bound (paper Table 3)."""
        return self.peak_flops / self.hbm_bw

    @property
    def interconnect_total(self) -> float:
        """Aggregate interconnect bandwidth (all links), bytes/s."""
        return self.interconnect_bw * self.interconnect_links

    def hop_time(self, nbytes: float) -> float:
        """Seconds for ONE interconnect hop moving ``nbytes`` over a single
        link: ``link_latency_s + nbytes / interconnect_bw``.  The per-hop
        wire term of the distributed overlap model
        (``core.distributed.overlap_model``) -- a ring collective's hop
        sees one link's bandwidth, never ``interconnect_total``."""
        return self.link_latency_s + nbytes / self.interconnect_bw

    def tile_budget(self) -> int:
        """On-chip bytes one fused tile may claim: half of VMEM on TPU
        (the other half double-buffers), an SM-carveout share per resident
        CTA on GPU (latency hiding comes from CTA count, not tile size)."""
        if self.kind == "gpu":
            return self.on_chip_bytes // max(1, self.target_ctas)
        return self.on_chip_bytes // 2

    def classify(self, arithmetic_intensity: float) -> str:
        """"memory" | "compute" bound classification against this balance."""
        return "memory" if arithmetic_intensity < self.balance else "compute"

    def matmul_peak(self, dtype: str = "f32") -> float:
        """Effective matmul FLOP/s at ``dtype`` on this machine.

        ``peak_flops`` is quoted at the native precision: bf16 for
        MXU/tensor-core parts (``native_bf16=True``), fp32 for the paper's
        V100.  bf16 on a non-native part emulates through the fp32 units
        (no gain); f32 on a native-bf16 part runs the matrix units at half
        rate.  ``int8-agg`` keeps combination in f32, so it prices as f32.
        """
        if dtype == "bf16":
            return self.peak_flops if self.native_bf16 \
                else self.peak_flops / 2
        return self.peak_flops / 2 if self.native_bf16 else self.peak_flops


#: TPU v5e, per chip (the repo's default modeling target since PR 1).
TPU_V5E = Machine(
    name="tpu-v5e", kind="tpu",
    peak_flops=197e12, hbm_bw=819e9,
    interconnect_bw=50e9, interconnect_links=4,     # 2-D torus: +-x, +-y
    on_chip_bytes=128 * 1024 * 1024,                # VMEM
    link_latency_s=1e-6,
    row_align=8, matrix_tile=128)

#: TPU v5p, per chip: the scale-out pod part (3-D torus, 6 ICI links at
#: ~100 GB/s each).  The Machine the distributed overlap model prices
#: multi-host halo pipelining against -- fatter links than v5e move the
#: choose_overlap break-even point.
TPU_V5P = Machine(
    name="tpu-v5p", kind="tpu",
    peak_flops=459e12, hbm_bw=2765e9,
    interconnect_bw=100e9, interconnect_links=6,    # 3-D torus: +-x,y,z
    on_chip_bytes=128 * 1024 * 1024,                # VMEM
    link_latency_s=1e-6,
    row_align=8, matrix_tile=128)

#: A100-SXM4 (bf16 tensor cores).  The occupancy fields are what the GPU
#: tile picker consumes: per-SM SMEM/L1 carveout shared by ``target_ctas``
#: resident blocks, warp-aligned rows.
A100 = Machine(
    name="a100", kind="gpu",
    peak_flops=312e12, hbm_bw=1555e9,
    interconnect_bw=25e9, interconnect_links=12,    # NVLink 3
    link_latency_s=2e-6,
    on_chip_bytes=192 * 1024,                       # unified SMEM/L1 per SM
    regfile_bytes=256 * 1024, target_ctas=4,
    row_align=32, matrix_tile=16)

#: H100-SXM5 (bf16 tensor cores, dense).  Same occupancy model as A100 with
#: Hopper's larger SMEM/L1 carveout and HBM3; its steeper balance point
#: (~295 F/B) pushes even more GCN phases memory-bound -- the machine the
#: serving benchmarks (``bench_serve``) price latency against.
H100 = Machine(
    name="h100", kind="gpu",
    peak_flops=989e12, hbm_bw=3350e9,
    interconnect_bw=25e9, interconnect_links=18,    # NVLink 4
    link_latency_s=2e-6,
    on_chip_bytes=228 * 1024,                       # unified SMEM/L1 per SM
    regfile_bytes=256 * 1024, target_ctas=4,
    row_align=32, matrix_tile=16)

#: V100 with the PAPER's numbers (fp32 CUDA-core peak / 900 GB/s HBM2):
#: balance ~17.4 F/B, the threshold behind Table 3's bound classification.
V100 = Machine(
    name="v100", kind="gpu",
    peak_flops=15.7e12, hbm_bw=900e9,
    interconnect_bw=25e9, interconnect_links=6,     # NVLink 2
    link_latency_s=2e-6,
    on_chip_bytes=128 * 1024,                       # unified SMEM/L1 per SM
    regfile_bytes=256 * 1024, target_ctas=4,
    row_align=32, matrix_tile=16,
    native_bf16=False)                              # fp32 CUDA-core peak

MACHINES: Dict[str, Machine] = {m.name: m
                                for m in (TPU_V5E, TPU_V5P, A100, H100, V100)}


def get_machine(name_or_machine) -> Machine:
    """Resolve a registry name (or pass a Machine through) to a Machine."""
    if isinstance(name_or_machine, Machine):
        return name_or_machine
    try:
        return MACHINES[name_or_machine]
    except KeyError:
        raise ValueError(f"unknown machine {name_or_machine!r}; "
                         f"known: {sorted(MACHINES)}") from None


def machine_for_backend(backend: Optional[str]) -> Machine:
    """Natural Machine preset for a resolved backend tier.

    ``pallas-gpu`` -> A100 (GPU occupancy math must never mix TPU balance
    points -- the bug this replaces); everything else -> TPU_V5E, the repo's
    default modeling target.  Callers wanting the paper's machine pass
    ``V100`` explicitly.
    """
    return A100 if backend == "pallas-gpu" else TPU_V5E


# --------------------------------------------------------------------------
# Execution dtype as a priced decision (build_plan(dtype="auto"))
# --------------------------------------------------------------------------

#: storage bytes per element at each plan dtype.  ``int8-agg`` is the wire
#: and gather width of the AGGREGATION operand only -- combination stays
#: f32, which is why it never wins the auto decision and stays opt-in.
DTYPE_BYTES: Dict[str, int] = {"f32": 4, "bf16": 2, "int8-agg": 1}

#: minimum modeled fractional saving before ``choose_dtype`` leaves f32.
#: Mirrors ``core.distributed.OVERLAP_SAVING_THRESHOLD``: a sub-5% modeled
#: win is inside the model's noise and not worth the precision loss.
DTYPE_SAVING_THRESHOLD = 0.05


def dtype_model(num_vertices: int, num_edges: int, feature_len: int,
                out_len: Optional[int] = None, *,
                machine: Machine = None, num_shards: int = 1,
                dtypes=("f32", "bf16")) -> Dict[str, Dict[str, float]]:
    """Model per-layer time at each candidate execution dtype.

    Per dtype ``dt`` with element width ``B = DTYPE_BYTES[dt]`` (the
    aggregation operand width; combination activations use ``B`` except
    under ``int8-agg`` where combine stays f32):

    * aggregation (memory-bound, paper Table 3): gather ``E`` neighbor rows
      + read/write ``V`` rows at ``feature_len * B`` bytes each, plus the
      dtype-independent 8-byte edge indices -- all over ``hbm_bw``;
    * combination: ``2 * V * feature_len * out_len`` FLOPs at
      ``matmul_peak(dt)`` vs. its HBM traffic, whichever dominates;
    * halo (only when ``num_shards > 1``): ``num_shards - 1`` ring hops of
      one resident block (``ceil(V / num_shards)`` rows) at the reduced
      payload width, each priced by ``hop_time`` -- the wire is where
      bf16's exact 2x byte cut pays most;
    * ``tile_rows``: rows of width ``feature_len`` one ``tile_budget()``
      holds at this dtype -- the "reduced precision doubles the effective
      tile budget" term surfaced for ``bench_dtype``.

    Returns ``{dtype: {"agg_s", "combine_s", "halo_s", "total_s",
    "tile_rows"}}``.
    """
    machine = TPU_V5E if machine is None else get_machine(machine)
    out_len = feature_len if out_len is None else out_len
    v, e, f = float(num_vertices), float(num_edges), float(feature_len)
    out = {}
    for dt in dtypes:
        b = float(DTYPE_BYTES[dt])
        comb_b = 4.0 if dt == "int8-agg" else b
        agg_bytes = (e + 2.0 * v) * f * b + e * 8.0
        agg_s = agg_bytes / machine.hbm_bw
        flops = 2.0 * v * f * out_len
        comb_bytes = v * (f + out_len) * comb_b + f * out_len * comb_b
        comb_s = max(flops / machine.matmul_peak(dt),
                     comb_bytes / machine.hbm_bw)
        halo_s = 0.0
        if num_shards > 1:
            block = -(-num_vertices // num_shards)  # ceil
            halo_s = (num_shards - 1) * machine.hop_time(block * f * b)
        out[dt] = {
            "agg_s": agg_s, "combine_s": comb_s, "halo_s": halo_s,
            "total_s": agg_s + comb_s + halo_s,
            "tile_rows": float(machine.tile_budget() //
                               max(1, int(f * b))),
        }
    return out


def choose_dtype(num_vertices: int, num_edges: int, feature_len: int,
                 out_len: Optional[int] = None, *,
                 machine: Machine = None, num_shards: int = 1) -> str:
    """Resolve ``build_plan(dtype="auto")`` to ``"f32"`` or ``"bf16"``.

    Prices one layer via ``dtype_model`` -- HBM aggregation traffic,
    matmul peak at each precision (``Machine.native_bf16``), and, when
    sharded, ``Machine.hop_time`` on the halved halo payload -- and picks
    bf16 only when its modeled total beats f32 by at least
    ``DTYPE_SAVING_THRESHOLD``.  ``int8-agg`` is never auto-chosen: its
    quantization error is a semantic decision the caller must opt into.

    The decision provably flips across presets on one workload: a 256-node
    / ~1k-edge graph at 128->128 features is bf16 on ``TPU_V5E``/``A100``
    (native bf16 matmul, halved HBM bytes) but f32 on the paper's ``V100``
    (fp32 CUDA-core peak: bf16 would halve the matmul rate and the layer
    is combination-limited there).

    >>> choose_dtype(256, 1024, 128, machine=V100)
    'f32'
    >>> choose_dtype(256, 1024, 128, machine=TPU_V5E)
    'bf16'
    """
    model = dtype_model(num_vertices, num_edges, feature_len, out_len,
                        machine=machine, num_shards=num_shards,
                        dtypes=("f32", "bf16"))
    f32_s, bf16_s = model["f32"]["total_s"], model["bf16"]["total_s"]
    if f32_s <= 0:
        return "f32"
    return "bf16" if (f32_s - bf16_s) / f32_s >= DTYPE_SAVING_THRESHOLD \
        else "f32"


# --------------------------------------------------------------------------
# Pair-redundancy elimination as a priced decision (build_plan(dedup="auto"))
# --------------------------------------------------------------------------

#: minimum modeled fractional aggregation-time saving before
#: ``choose_dedup`` leaves the naive layout.  Mirrors
#: ``DTYPE_SAVING_THRESHOLD``: below this the two-level layout's extra
#: indirection is inside the model's noise.
DEDUP_SAVING_THRESHOLD = 0.05


def dedup_model(num_vertices: int, num_edges: int, feature_len: int, *,
                num_pairs: int, num_edges2: int,
                machine: Machine = None,
                dtype: str = "f32") -> Dict[str, Dict[str, float]]:
    """Model the aggregation phase naive vs. two-level dedup (graph/dedup.py).

    Aggregation is memory-bound on every preset (paper Table 3), so both
    layouts are priced as HBM slab traffic over ``machine.hbm_bw`` at the
    plan dtype's element width:

    * ``"none"``: gather ``E`` neighbor rows + read/write ``V`` rows
      (``feature_len * B`` bytes each) + ``E`` 8-byte edge indices — the
      same slab term ``dtype_model`` charges the phase.
    * ``"pairs"``: gather ``E2`` shortened-list rows + read ``2 * P`` pair
      members + write ``P`` partials (level 1) + the same ``V`` self
      read/write, plus the shortened index traffic and the pair-id
      indirection — the extra gather/indirection cost the eliminated edges
      must beat.

    ``num_pairs``/``num_edges2`` come from a concrete
    ``build_dedup_layout`` run on the block (matching is host-side and
    cheap, so ``"auto"`` prices the REAL layout, not an estimate).
    Returns ``{"none": {...}, "pairs": {...}}`` with ``agg_bytes``,
    ``agg_s``, ``flops`` and ``saving`` (fraction of naive time saved).
    """
    machine = TPU_V5E if machine is None else get_machine(machine)
    b = float(DTYPE_BYTES.get(dtype, 4))
    v, e, f = float(num_vertices), float(num_edges), float(feature_len)
    p, e2 = float(num_pairs), float(num_edges2)
    naive_bytes = (e + 2.0 * v) * f * b + e * 8.0
    dedup_bytes = (e2 + 3.0 * p + 2.0 * v) * f * b + e2 * 8.0 + 2.0 * p * 4.0
    naive_s = naive_bytes / machine.hbm_bw
    dedup_s = dedup_bytes / machine.hbm_bw
    saving = (naive_s - dedup_s) / naive_s if naive_s > 0 else 0.0
    return {
        "none": {"agg_bytes": naive_bytes, "agg_s": naive_s,
                 "flops": (e + v) * f, "saving": 0.0},
        "pairs": {"agg_bytes": dedup_bytes, "agg_s": dedup_s,
                  "flops": (p + e2 + v) * f, "saving": saving},
    }


def choose_dedup(num_vertices: int, num_edges: int, feature_len: int, *,
                 num_pairs: int, num_edges2: int,
                 machine: Machine = None, dtype: str = "f32") -> str:
    """Resolve ``build_plan(dedup="auto")`` to ``"none"`` or ``"pairs"``.

    Prices the block's REAL matching result (``dedup_model``) against this
    ``Machine``'s HBM bandwidth and picks ``"pairs"`` only when the modeled
    aggregation-time saving clears ``DEDUP_SAVING_THRESHOLD``.  The
    decision provably flips between workloads on one machine: a
    fanout-regular sampled block (hub-heavy — many destinations share
    their leading neighbor pair, so matching removes a large edge
    fraction) picks ``"pairs"``, while a sparse full-graph layer (pairs
    scarce — the shortened list barely shrinks but still pays the pair
    gather + partial write) stays ``"none"``.

    >>> choose_dedup(96, 128, 128, num_pairs=8, num_edges2=80,
    ...              machine=TPU_V5E)
    'pairs'
    >>> choose_dedup(96, 128, 128, num_pairs=2, num_edges2=126,
    ...              machine=TPU_V5E)
    'none'
    """
    if num_pairs <= 0:
        return "none"
    model = dedup_model(num_vertices, num_edges, feature_len,
                        num_pairs=num_pairs, num_edges2=num_edges2,
                        machine=machine, dtype=dtype)
    return "pairs" if model["pairs"]["saving"] >= DEDUP_SAVING_THRESHOLD \
        else "none"
