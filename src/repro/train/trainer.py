"""Training loop with fault tolerance, elastic restart, and step watchdog.

Cluster posture (DESIGN.md §6), with every mechanism testable on CPU:

  * **Checkpoint/restart** -- async atomic checkpoints every
    ``checkpoint_every`` steps including data-pipeline + RNG state; startup
    auto-resumes from the newest valid checkpoint (``run()`` is re-entrant:
    kill the process at any step and re-invoke).
  * **Node-failure handling** -- simulated failures (``FailureInjector``)
    raise mid-step; the supervisor catches, rebuilds the mesh from surviving
    devices, re-shards the restored state (elastic restore -- checkpoints
    are topology-free), and continues.  On a real cluster the same path is
    driven by the coordinator's device-health callbacks.
  * **Straggler mitigation** -- a wall-clock watchdog tracks per-step
    latency EWMA; steps slower than ``straggler_factor`` x EWMA are logged
    and counted.  On TPU pods the actionable response is checkpoint +
    evict + elastic restart, which is exactly the path above; the watchdog
    triggers it after ``max_straggler_steps`` consecutive slow steps.
  * **Gradient compression** -- optional int8 error-feedback DP reduction
    (optim/compression.py) for the explicitly-shard_mapped GCN path.
"""

from __future__ import annotations

import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.config import TrainConfig
from repro.optim.optimizer import TrainState

log = logging.getLogger("repro.trainer")


class FailureInjector:
    """Deterministic fault injection for tests: fail at given steps."""

    def __init__(self, fail_at=(), exc=RuntimeError):
        self.fail_at = set(fail_at)
        self.exc = exc
        self.history = []

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.history.append(step)
            raise self.exc(f"injected node failure at step {step}")


class StepWatchdog:
    def __init__(self, factor: float = 3.0, max_straggler_steps: int = 5):
        self.ewma: Optional[float] = None
        self.factor = factor
        self.max_straggler_steps = max_straggler_steps
        self.consecutive = 0
        self.straggler_steps = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True when the straggler threshold demands a restart."""
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.factor * self.ewma
        self.ewma = 0.9 * self.ewma + 0.1 * dt
        if slow:
            self.straggler_steps.append(step)
            self.consecutive += 1
            log.warning("straggler step %d: %.3fs (ewma %.3fs)", step, dt,
                        self.ewma)
        else:
            self.consecutive = 0
        return self.consecutive >= self.max_straggler_steps


class Trainer:
    """Supervised train loop: builds step fn, owns recovery."""

    def __init__(self, cfg: TrainConfig, *, make_state: Callable[[], Any],
                 step_fn: Callable, pipeline, state_shardings=None,
                 batch_shardings=None,
                 failure_injector: Optional[FailureInjector] = None):
        self.cfg = cfg
        self.make_state = make_state
        self.step_fn = step_fn
        self.pipeline = pipeline
        self.state_shardings = state_shardings
        self.batch_shardings = batch_shardings
        self.ckpt = Checkpointer(cfg.checkpoint_dir,
                                 keep=cfg.keep_checkpoints)
        self.failure_injector = failure_injector
        self.watchdog = StepWatchdog()
        self.metrics_history: list = []
        self.recoveries = 0

    # ------------------------------------------------------------------ io
    def _try_restore(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return None
        abstract = jax.eval_shape(self.make_state)
        state, step, extra = self.ckpt.restore(
            abstract, shardings=self.state_shardings)
        self.pipeline.load_state_dict(extra["pipeline"])
        log.info("restored checkpoint step=%d", step)
        return state, step

    def _save(self, step: int, state, blocking=False):
        self.ckpt.save(step, state,
                       extra={"pipeline": self.pipeline.state_dict()},
                       blocking=blocking)

    # ---------------------------------------------------------------- loop
    def run(self, steps: Optional[int] = None) -> Dict[str, Any]:
        steps = steps or self.cfg.steps
        attempt = 0
        while True:
            try:
                return self._run_once(steps)
            except RuntimeError as e:
                attempt += 1
                self.recoveries += 1
                log.warning("step failure (%s); recovery #%d", e, attempt)
                if attempt > 10:
                    raise
                # elastic path: on a real cluster we would rebuild the mesh
                # from jax.devices() here; state is re-created from the last
                # checkpoint either way.
                continue

    def _run_once(self, steps: int) -> Dict[str, Any]:
        restored = self._try_restore()
        if restored is None:
            state = self.make_state()
            start = 0
        else:
            state, start = restored
            start += 1

        it = iter(self.pipeline)
        self.pipeline.step = start  # regenerate from the exact position
        last_metrics: Dict[str, Any] = {}
        for step in range(start, steps):
            batch = self.pipeline.batch_at(step)
            self.pipeline.step = step + 1
            if self.batch_shardings is not None:
                batch = {k: jax.device_put(v, self.batch_shardings[k])
                         if k in self.batch_shardings else v
                         for k, v in batch.items()}
            t0 = time.time()
            if self.failure_injector is not None:
                self.failure_injector.check(step)
            state, metrics = self.step_fn(state, batch)
            if hasattr(jax.tree.leaves(metrics)[0], "block_until_ready"):
                jax.tree.leaves(metrics)[0].block_until_ready()
            dt = time.time() - t0
            need_restart = self.watchdog.observe(step, dt)
            if step % self.cfg.log_every == 0 or step == steps - 1:
                host = {k: float(np.asarray(v)) for k, v in metrics.items()}
                host["step"] = step
                host["dt"] = dt
                self.metrics_history.append(host)
                log.info("step %d: %s", step, host)
            last_metrics = metrics
            if (step + 1) % self.cfg.checkpoint_every == 0:
                self._save(step, state)
            if need_restart:
                self._save(step, state, blocking=True)
                raise RuntimeError("straggler threshold exceeded")
        self.ckpt.wait()
        self._save(steps - 1, state, blocking=True)
        return {"state": state, "metrics": last_metrics,
                "history": self.metrics_history,
                "recoveries": self.recoveries}
