"""repro.profile: Machine presets, InstrumentedPlan/WorkloadReport, the
BenchSpec harness, and the describe()-vs-dispatch consistency guard."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CORA, reduced_graph
from repro.core import characterize
from repro.core.backend import default_machine
from repro.core.dataflow import suggest_tile_m
from repro.core.hlo_cost import analyze_hlo
from repro.core.plan import build_plan, plan_for_phases
from repro.core.scheduler import (AGGREGATE_FIRST, COMBINE_FIRST,
                                  choose_ordering, ordering_cost,
                                  ordering_time)
from repro.graph.datasets import make_features, make_synthetic_graph
from repro.models.gcn import make_paper_model
from repro.profile import (A100, H100, MACHINES, TPU_V5E, TPU_V5P, V100,
                           BenchSpec, Machine, WorkloadReportError,
                           get_machine, machine_for_backend, run_specs)
from repro.profile.bench import csv_columns, write_csv

GOLDEN = Path(__file__).parent / "golden" / "workload_report.schema.json"


@pytest.fixture(scope="module")
def data():
    spec = reduced_graph(CORA, 220, 24)
    g = make_synthetic_graph(spec)
    return spec, g, make_features(spec)


def _gcn(spec, g, x, **plan_kw):
    m = make_paper_model("gcn", spec)
    p = m.init(jax.random.PRNGKey(0))
    plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                      **plan_kw)
    return m, p, plan


# ---------------------------------------------------------------------------
# Machine presets
# ---------------------------------------------------------------------------


def test_machine_presets_and_registry():
    assert set(MACHINES) == {"tpu-v5e", "tpu-v5p", "a100", "h100", "v100"}
    # the paper's classification threshold: V100 fp32 balance ~17.4 F/B
    assert V100.balance == pytest.approx(15.7e12 / 900e9)
    assert TPU_V5E.balance == pytest.approx(197e12 / 819e9)
    # v5p: fatter chip, but HBM grows faster than peak -> lower balance
    assert TPU_V5P.balance < TPU_V5E.balance
    assert get_machine("tpu-v5p") is TPU_V5P
    assert V100.classify(5.0) == "memory"
    assert V100.classify(50.0) == "compute"
    # the same AI=50 GEMM is memory-bound on v5e: the hardware-adaptation
    # finding the repo reports alongside the paper numbers
    assert TPU_V5E.classify(50.0) == "memory"
    assert get_machine("a100") is A100
    assert get_machine(A100) is A100
    assert get_machine("h100") is H100
    # H100 is still memory-hungrier than its FLOP growth: balance rises
    assert H100.balance > A100.balance
    with pytest.raises(ValueError):
        get_machine("h200")


def test_machine_for_backend_mapping():
    assert machine_for_backend("pallas-gpu") is A100
    assert machine_for_backend("pallas-tpu") is TPU_V5E
    assert machine_for_backend("xla") is TPU_V5E
    # default_machine resolves the tier first (CPU container: auto -> xla)
    assert default_machine("auto") in (TPU_V5E, A100)
    assert default_machine("pallas-gpu") is A100


def test_deprecated_characterize_shims_removed():
    """The PR 4 'one release' constant shims are gone: Machine presets are
    the only copy of the hardware numbers."""
    for name in ("VMEM_BYTES", "MACHINE_BALANCE", "GPU_SMEM_PER_SM",
                 "GPU_TARGET_CTAS_PER_SM", "GPU_WARP_ROWS", "V100_BALANCE",
                 "PEAK_FLOPS_BF16", "HBM_BW", "MXU_DIM"):
        assert not hasattr(characterize, name), name


def test_suggest_tile_m_is_machine_parameterized():
    """Satellite: GPU occupancy math comes from the A100 Machine, not from
    TPU constants; a smaller-SMEM machine (V100) can only shrink the tile."""
    default_gpu = suggest_tile_m(128, 128, 8.0, backend="pallas-gpu")
    a100_gpu = suggest_tile_m(128, 128, 8.0, backend="pallas-gpu",
                              machine=A100)
    v100_gpu = suggest_tile_m(128, 128, 8.0, backend="pallas-gpu",
                              machine=V100)
    assert default_gpu == a100_gpu          # A100 is the GPU-tier default
    assert v100_gpu <= a100_gpu             # 128K carveout vs 192K
    assert v100_gpu % V100.row_align == 0
    # the occupancy model follows machine.kind, not the backend string: a
    # GPU machine with a non-GPU backend must use the GPU per-CTA model
    # (never "GPU budget minus the whole W" -- the reverse mixing bug)
    assert suggest_tile_m(602, 128, 50.0, backend="xla",
                          machine=A100) == \
        suggest_tile_m(602, 128, 50.0, backend="pallas-gpu", machine=A100)
    # TPU path budget follows the machine's VMEM, not a hardcoded constant
    big = Machine(name="tpu-big", kind="tpu", peak_flops=197e12,
                  hbm_bw=819e9, interconnect_bw=50e9, interconnect_links=4,
                  on_chip_bytes=4 * TPU_V5E.on_chip_bytes)
    assert suggest_tile_m(602, 512, 50.0, machine=big) >= \
        suggest_tile_m(602, 512, 50.0, machine=TPU_V5E)


def test_choose_ordering_machine_agrees_with_bytes(data):
    """A Machine only re-prices the margin; the legal decision (driven by
    the memory-bound aggregation term) is identical across presets."""
    _, g, _ = data
    for in_len, out_len in ((602, 128), (128, 602), (64, 64)):
        base = choose_ordering(g, in_len, out_len)
        for m in (TPU_V5E, A100, V100):
            assert choose_ordering(g, in_len, out_len, machine=m) == base
    # ordering_time itself is finite, positive, and orders correctly
    cf = ordering_cost(g, 602, 128, COMBINE_FIRST)
    af = ordering_cost(g, 602, 128, AGGREGATE_FIRST)
    assert 0 < ordering_time(cf, V100) < ordering_time(af, V100)


# ---------------------------------------------------------------------------
# The one-call characterization path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("machine", [A100, TPU_V5E], ids=lambda m: m.name)
def test_one_call_report(data, machine):
    """build_plan(...).instrument(machine=...).run_model(...) yields a
    validated WorkloadReport whose markdown reproduces a paper-style
    per-phase breakdown -- on >= 2 Machine presets (acceptance)."""
    spec, g, x = data
    m, p, plan = _gcn(spec, g, x)
    report = plan.instrument(machine=machine).run_model(p, x).validate()
    # the forward result rides along and matches the uninstrumented plan
    ref = plan.run_model(p, x)
    np.testing.assert_allclose(np.asarray(report.output), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
    # per-phase records: one aggregate + one combine per layer (unfused)
    assert len(report.records) == 2 * plan.num_layers
    for r in report.records:
        assert r.wall_time_s > 0
        assert r.bound == machine.classify(r.arithmetic_intensity)
    md = report.to_markdown()
    assert machine.name in md
    assert "| layer | phase | order | backend |" in md
    assert "aggregate" in md and "combine" in md
    assert md.count("\n| ") >= 2 * plan.num_layers + 1  # rows + totals
    assert f"balance {machine.balance:.1f}" in md


def test_report_json_schema_golden(data):
    """Golden-file schema: key sets of every report section are pinned."""
    spec, g, x = data
    _, p, plan = _gcn(spec, g, x)
    d = json.loads(plan.instrument(machine=V100).run_model(p, x).to_json())
    golden = json.loads(GOLDEN.read_text())
    assert d["schema"] == golden["schema"]
    assert d["version"] == golden["version"]
    assert sorted(d) == golden["top"]
    assert sorted(d["machine"]) == golden["machine"]
    assert sorted(d["plan"]) == golden["plan"]
    assert sorted(d["totals"]) == golden["totals"]
    for rec in d["phases"]:
        assert sorted(rec) == golden["phase_record"]
    for lay in d["plan"]["layers"]:
        assert sorted(lay) == golden["layer"]


def test_report_validate_catches_violations(data):
    spec, g, x = data
    _, p, plan = _gcn(spec, g, x)
    report = plan.instrument().run_model(p, x)
    report.validate()  # clean passes
    empty = type(report)(machine=report.machine,
                         plan_summary=report.plan_summary, records=[])
    with pytest.raises(WorkloadReportError, match="empty phase records"):
        empty.validate()
    bad = type(report)(machine=report.machine,
                       plan_summary=report.plan_summary,
                       records=[report.records[0].__class__(
                           layer=0, phase="warp", order="combine_first",
                           backend="xla", fused=False, feature_len=8,
                           flops=1.0, bytes=1.0, collective_bytes=0.0,
                           wall_time_s=0.0, bound="memory")])
    with pytest.raises(WorkloadReportError, match="unknown phase"):
        bad.validate()
    # deserialized artifacts are validated in dict form, where the
    # totals-vs-phases cross-check is meaningful (files can be edited)
    from repro.profile import validate_report_dict
    d = json.loads(report.to_json())
    assert validate_report_dict(d) == []
    d["totals"]["flops"] += 1e6
    assert any("totals.flops" in p for p in validate_report_dict(d))


def test_report_phase_costs_match_hlo(data):
    """Invariant: the report's combine-phase FLOPs sum EXACTLY to the dot
    FLOPs hlo_cost extracts from the compiled model, and analytic totals
    never exceed the compiled program's (the analytic model is a lower
    bound; XLA's CPU scatter lowering adds platform noise on top)."""
    spec, g, x = data
    _, p, plan = _gcn(spec, g, x, backend="xla", fused=False)
    report = plan.instrument(machine=TPU_V5E).run_model(p, x)
    hc = analyze_hlo(jax.jit(
        lambda pp, xx: plan.run_model(pp, xx)).lower(p, x).compile()
        .as_text())
    comb_flops = sum(r.flops for r in report.records
                     if r.phase == "combine")
    assert comb_flops == pytest.approx(hc.dot_flops, rel=1e-6)
    tot = report.totals()
    assert 0 < tot["flops"] <= hc.flops
    assert 0 < tot["bytes"] <= hc.bytes_accessed


# ---------------------------------------------------------------------------
# describe() vs dispatch consistency (regression guard)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["gcn", "sage", "gin"])
def test_describe_matches_dispatch(data, model):
    """plan.describe() must agree with the decisions actually dispatched
    (ordering / backend / fusion per layer) across the planner matrix."""
    spec, g, x = data
    m = make_paper_model(model, spec)
    p = m.init(jax.random.PRNGKey(1))
    orderings = (None,) if model == "gin" else (None, COMBINE_FIRST,
                                                AGGREGATE_FIRST)
    for backend in ("xla", "pallas-tpu", "pallas-gpu"):
        for fused in (False, True):
            for order in orderings:
                plan = build_plan(g, m.cfg, spec.feature_len,
                                  spec.num_classes, backend=backend,
                                  fused=fused, ordering=order)
                report = plan.instrument().run_model(p, x).validate()
                assert report.mismatches(plan) == [], \
                    (model, backend, fused, order)


def test_runtime_fusion_fallback_is_reported(data):
    """The drift guard is not vacuous: run_phases with an inline bias that
    fusion cannot absorb (sum + combine_first) legitimately falls back at
    call time, and mismatches() reports exactly that."""
    spec, g, x = data
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((x.shape[1], 8)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((8,)), jnp.float32)
    plan = plan_for_phases(g, [(w, b)], order=COMBINE_FIRST, agg_op="sum",
                           fused=True)
    assert plan.layers[0].fused  # planned fused...
    report = plan.instrument().run_phases(x, [(w, b)], activation="none")
    drift = report.mismatches(plan)
    assert drift and "fused" in drift[0]  # ...but dispatch fell back


def test_unresolved_backend_alias_is_reported(data):
    """The backend drift check observes call-time resolution: a plan that
    regressed to storing the legacy 'pallas' alias (instead of a resolved
    tier) must be flagged -- proves the guard is not vacuous."""
    from dataclasses import replace

    from repro.core.plan import GraphExecutionPlan
    spec, g, x = data
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.standard_normal((x.shape[1], 8)) * 0.3, jnp.float32)
    good = plan_for_phases(g, [(w, None)], order=COMBINE_FIRST,
                           agg_op="mean", backend="pallas-tpu")
    bad_lp = replace(good.layers[0], backend="pallas")  # unresolved alias
    bad = GraphExecutionPlan(g, [bad_lp], interpret=True)
    report = bad.instrument(machine=TPU_V5E).run_phases(
        x, [(w, None)], activation="none")
    drift = report.mismatches(bad)
    assert drift and "backend" in drift[0]


def test_distributed_record_carries_collective_bytes(data):
    """The probe prices distributed layers with the halo model's collective
    bytes (the full multi-device matrix runs in bench_plan's dry-run
    subprocess; here the cost hookup is checked without a mesh)."""
    import types

    from repro.core.distributed import halo_bytes
    from repro.graph.partition import partition_1d
    from repro.profile.instrument import _Probe
    spec, g, x = data
    pg = partition_1d(g, 4, edge_balanced=False)
    hb = halo_bytes(pg, 8)["min_halo_bytes"]
    assert hb > 0  # the fixture graph has cut edges
    fake_plan = types.SimpleNamespace(g=g, partition_kind="1d", partition=pg)
    probe = _Probe(fake_plan, TPU_V5E)
    assert probe._halo_bytes(8) == float(hb)
    lp = types.SimpleNamespace(index=0, order=COMBINE_FIRST, backend="xla",
                               include_self=True, dims=(24, 8))
    probe.run("distributed", lambda: jnp.zeros(()), lp=lp, feature_len=8)
    (rec,) = probe.records
    assert rec.phase == "distributed" and rec.collective_bytes == float(hb)


# ---------------------------------------------------------------------------
# Machine plumbing through build_plan
# ---------------------------------------------------------------------------


def test_build_plan_machine_in_cache_key(data):
    spec, g, x = data
    m = make_paper_model("gcn", spec)
    p0 = build_plan(g, m.cfg, spec.feature_len, spec.num_classes)
    pa = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                    machine=A100)
    pa2 = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                     machine="a100")
    assert pa is not p0
    assert pa2 is pa            # name resolves to the same preset -> cached
    assert pa.machine is A100
    # instrument() defaults to the plan's machine
    assert pa.instrument().machine is A100


# ---------------------------------------------------------------------------
# BenchSpec harness
# ---------------------------------------------------------------------------


def test_bench_harness_csv_and_dry(tmp_path):
    calls = []

    def measure(ctx, point):
        t = ctx.time(lambda: jnp.ones(4))
        calls.append((point, ctx.dry, t))
        row = {"sweep": point} if point == "a" else {"other": point}
        ctx.emit(f"t/{point}", t, **row)

    spec = BenchSpec(name="t", sweep=("a", "b"), measure=measure, dry="run")
    csv_path = tmp_path / "t.csv"
    rows = run_specs([spec], dry=True, csv=csv_path)
    assert [c[0] for c in calls] == ["a", "b"]
    assert all(dry and t == 0.0 for _, dry, t in calls)  # timing disabled
    assert len(rows) == 2
    # CSV artifact: header row, stable column order, empty cells for holes
    lines = csv_path.read_text().strip().splitlines()
    assert lines[0] == "name,us_per_call,other,sweep"
    assert lines[1] == "t/a,0.0,,a"
    assert lines[2] == "t/b,0.0,b,"
    assert csv_columns(rows) == ["name", "us_per_call", "other", "sweep"]
    # dry="skip" specs are skipped under dry-run, run otherwise
    skip_spec = BenchSpec(name="s", measure=measure, dry="skip")
    n_before = len(calls)
    run_specs([skip_spec], dry=True)
    assert len(calls) == n_before


def test_bench_write_csv_empty(tmp_path):
    assert write_csv([], tmp_path / "none.csv") is None
    assert not (tmp_path / "none.csv").exists()
