"""Generate EXPERIMENTS.md markdown tables from artifacts.

Two artifact families:
  * ``experiments/dryrun/*.json``  (repro.launch.dryrun): roofline tables.
  * ``experiments/bench/*.csv``    (the BenchSpec harness,
    ``repro.profile.bench.write_csv``): per-module benchmark tables,
    rendered from the CSV artifact -- no stdout re-parsing.
"""

import csv
import json
import sys
from pathlib import Path

DIR = Path(__file__).parent / "dryrun"
ORDER = ["kimi-k2-1t-a32b", "arctic-480b", "deepseek-67b", "gemma2-9b",
         "gemma-7b", "granite-3-8b", "jamba-1.5-large-398b", "internvl2-1b",
         "seamless-m4t-medium", "mamba2-2.7b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x >= 0.1:
        return f"{x:.2f}s"
    if x >= 1e-4:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def load(tag="baseline"):
    recs = {}
    for p in DIR.glob("*.json"):
        r = json.loads(p.read_text())
        if r.get("tag", "baseline") != tag:
            continue
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def roofline_table(mesh="single", tag="baseline"):
    recs = load(tag)
    print(f"\n### Roofline — {mesh}-pod ({tag})\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "HLO flops/dev | model flops/dev | useful | roofline frac | "
          "peak GiB | fits 16G |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for arch in ORDER:
        for shape in SHAPES:
            r = recs.get((arch, shape, mesh))
            if r is None:
                print(f"| {arch} | {shape} | — | — | — | SKIP | | | | | | |")
                continue
            if r["status"] != "ok":
                print(f"| {arch} | {shape} | ERROR: "
                      f"{r.get('error','')[:60]} ||||||||||")
                continue
            rl = r["roofline"]
            print(f"| {arch} | {shape} | {fmt_s(rl['compute_s'])} | "
                  f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
                  f"**{rl['dominant']}** | {rl['flops']:.2e} | "
                  f"{rl['model_flops']:.2e} | {rl['useful_ratio']:.2f} | "
                  f"{rl['roofline_fraction']:.4f} | "
                  f"{r['peak_bytes_per_device']/2**30:.1f} | "
                  f"{'Y' if r['fits_16g'] else 'N'} |")


def dryrun_table(tag="baseline"):
    recs = load(tag)
    print("\n### Dry-run matrix (compile status, both meshes)\n")
    print("| arch | shape | single-pod | multi-pod | bytes/dev (multi) | "
          "collective bytes/dev (multi) | dominant collective |")
    print("|---|---|---|---|---|---|---|")
    for arch in ORDER:
        for shape in SHAPES:
            s = recs.get((arch, shape, "single"))
            m = recs.get((arch, shape, "multi"))
            if s is None and m is None:
                print(f"| {arch} | {shape} | SKIP | SKIP | | | |")
                continue

            def st(r):
                if r is None:
                    return "—"
                return "ok" if r["status"] == "ok" else "ERR"
            extra = ["", "", ""]
            if m and m["status"] == "ok":
                coll = m["collective"]
                dom = max((k for k in coll if k != "total"),
                          key=lambda k: coll[k])
                extra = [f"{m['peak_bytes_per_device']/2**30:.1f} GiB",
                         f"{coll['total']:.2e}",
                         f"{dom} ({coll[dom]:.1e})"]
            print(f"| {arch} | {shape} | {st(s)} | {st(m)} | {extra[0]} | "
                  f"{extra[1]} | {extra[2]} |")


def bench_tables(bench_dir=None):
    """Render every BenchSpec CSV artifact as a markdown table.

    Consumes the files ``benchmarks/run.py`` writes via
    ``repro.profile.bench.write_csv`` (header row, stable column order);
    empty cells pass through as empty table cells.  ``*.dry.csv``
    validation artifacts (all-zero timings from the smoke gate) are
    excluded -- only measured runs become tables.
    """
    if bench_dir is None:
        sys.path.insert(0, str(Path(__file__).parents[1] / "src"))
        from repro.profile.bench import BENCH_ARTIFACT_DIR
        bench_dir = BENCH_ARTIFACT_DIR
    paths = [p for p in sorted(Path(bench_dir).glob("*.csv"))
             if not p.name.endswith(".dry.csv")] \
        if Path(bench_dir).exists() else []
    if not paths:
        print("\n(no bench CSV artifacts; run `python -m benchmarks.run`)")
        return
    for p in paths:
        with p.open(newline="") as f:
            rows = list(csv.reader(f))
        if not rows:
            continue
        header, body = rows[0], rows[1:]
        print(f"\n### Benchmarks — {p.stem}\n")
        print("| " + " | ".join(header) + " |")
        print("|" + "---|" * len(header))
        for r in body:
            print("| " + " | ".join(r) + " |")


if __name__ == "__main__":
    tag = sys.argv[1] if len(sys.argv) > 1 else "baseline"
    dryrun_table(tag)
    roofline_table("single", tag)
    roofline_table("multi", tag)
    bench_tables()
