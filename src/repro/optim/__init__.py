from repro.optim.optimizer import (adamw_init, adamw_update, cosine_lr,
                                   global_norm, TrainState, make_train_state)
