"""Slot-based continuous-batching serving core shared by every engine.

The seed's LM ``ServeEngine`` and the GCN ``GraphServeEngine`` are the same
loop with different step bodies: an admission queue feeds a fixed set of
``max_batch`` *slots*; a finished request frees its slot and the next queued
request is admitted into it immediately (continuous batching -- no
wave barriers); per-request enqueue/finish walltimes accumulate into
latency percentiles and throughput.  This module owns that loop ONCE --
``SlotServeCore`` -- so LM decode and graph inference are two
instantiations of one serving core rather than parallel implementations.

Request protocol (duck-typed -- engines keep their own dataclasses): a
request must carry mutable ``done`` / ``enqueue_t`` / ``finish_t``
attributes; everything else (prompt, seeds, outputs) is engine-specific.

Subclass contract:

  * ``_admit_into_slot(slot, req) -> bool``: admit one queued request into
    a free slot (LM: prefill-into-slot; graph: sample + pad + bucket).
    Return True iff the request finished AT admission (e.g. the prefill's
    first token hit EOS) -- the core then records it without occupying the
    slot.
  * ``_step() -> list``: advance every active slot by one engine step (LM:
    one batched decode; graph: drain each slot through its bucket's
    compiled callable), calling ``_complete(slot)`` for each request that
    finished.  Runs only while slots are active.

``stats()`` reports the core's view -- steps, served, active, queued,
latency percentiles (p50/p95/p99 ms), throughput -- and engines extend it
with their own counters.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List

from repro.profile.bench import latency_percentiles


class SlotServeCore:
    """The shared admission-queue + slot-lifecycle + stats serving loop.

    Engines subclass it with ``_admit_into_slot`` / ``_step`` (see the
    module docstring for the contract); ``submit`` / ``run`` / ``stats``
    are the public serving surface every engine shares.
    """

    def __init__(self, max_batch: int):
        self.max_batch = int(max_batch)
        self._queue: List[Any] = []
        self._active: Dict[int, Any] = {}   # slot -> request
        self._steps = 0
        self._served = 0
        self._latencies_s: List[float] = []
        self._slot_assignments = 0          # admissions into slots
        self._t_first_enqueue = None
        self._t_last_finish = None

    # --------------------------------------------------------------- public

    def submit(self, req) -> None:
        """Enqueue one request (stamps ``enqueue_t``); FIFO admission."""
        req.enqueue_t = time.time()
        if self._t_first_enqueue is None:
            self._t_first_enqueue = req.enqueue_t
        self._queue.append(req)

    def run(self, max_steps: int = 10_000) -> List[Any]:
        """Drive the loop until queue + active slots drain; returns the
        finished requests in completion order.  ``max_steps`` bounds the
        number of ``_step`` rounds (runaway guard)."""
        finished: List[Any] = []
        while (self._queue or self._active) and self._steps < max_steps:
            finished.extend(self.tick())
        return finished

    def tick(self) -> List[Any]:
        """ONE admission + step round; returns requests finished this
        round.  ``run`` is tick-until-drained (the closed loop); open-loop
        drivers instead interleave ticks with timed ``submit`` calls so
        arrivals keep landing while earlier requests are in flight --
        measured latency then includes queueing delay, not just service
        time.  A tick with nothing queued or active is a no-op."""
        if not (self._queue or self._active):
            return []
        finished = list(self._admit())
        finished.extend(self._step())
        return finished

    @property
    def outstanding(self) -> int:
        """Requests submitted but not yet finished (queued + active)."""
        return len(self._queue) + len(self._active)

    def stats(self) -> Dict[str, Any]:
        """Core serving stats: steps/served/active/queued, per-request
        latency percentiles (ms), and end-to-end throughput (requests/s
        from first enqueue to last finish)."""
        out: Dict[str, Any] = {
            "steps": self._steps,
            "served": self._served,
            "active": len(self._active),
            "queued": len(self._queue),
            "slot_assignments": self._slot_assignments,
        }
        out.update(latency_percentiles(self._latencies_s))
        dt = None
        if self._t_first_enqueue is not None and \
                self._t_last_finish is not None:
            dt = max(self._t_last_finish - self._t_first_enqueue, 1e-9)
        out["throughput_rps"] = (self._served / dt) if dt else 0.0
        return out

    @property
    def latencies_s(self) -> List[float]:
        """Per-request end-to-end latencies (seconds), completion order."""
        return list(self._latencies_s)

    # ------------------------------------------------------------- lifecycle

    def _admit(self) -> List[Any]:
        """Fill free slots from the queue; returns requests that finished
        at admission (the continuous-batching half of the loop)."""
        done_at_admit: List[Any] = []
        free = [s for s in range(self.max_batch) if s not in self._active]
        while free and self._queue:
            slot = free[0]
            req = self._queue.pop(0)
            self._slot_assignments += 1
            if self._admit_into_slot(slot, req):
                self._record_finish(req)
                done_at_admit.append(req)
                continue                    # slot stays free for the next
            free.pop(0)
            self._active[slot] = req
        return done_at_admit

    def _complete(self, slot: int):
        """Finish the request in ``slot`` and free the slot (engines call
        this from ``_step`` for every request that finished)."""
        req = self._active.pop(slot)
        self._record_finish(req)
        return req

    def _record_finish(self, req) -> None:
        req.done = True
        req.finish_t = time.time()
        self._t_last_finish = req.finish_t
        self._latencies_s.append(req.finish_t - req.enqueue_t)
        self._served += 1

    # ------------------------------------------------------------ subclasses

    def _admit_into_slot(self, slot: int, req) -> bool:
        raise NotImplementedError

    def _step(self) -> List[Any]:
        raise NotImplementedError
