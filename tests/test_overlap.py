"""Overlapped halo pipelining: pricing, plan threading, and the bitwise
schedule-equivalence regression (multi-device parts run in SUBPROCESSES
with 8 fake CPU devices, same rule as tests/test_distributed.py)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.config import CORA, reduced_graph
from repro.core.distributed import (OVERLAP_SAVING_THRESHOLD, choose_overlap,
                                    overlap_model)
from repro.core.plan import build_plan
from repro.graph.datasets import make_features, make_synthetic_graph
from repro.graph.partition import partition_1d
from repro.models.gcn import PAPER_MODELS
from repro.profile.machine import TPU_V5E, TPU_V5P

from test_distributed import run_sub


@pytest.fixture(scope="module")
def pg249():
    """8-way 1-D partition of a V=249 graph -- 249 % 8 != 0, so every
    shard's last rows are padding."""
    spec = reduced_graph(CORA, 249, 32)
    g = make_synthetic_graph(spec)
    return spec, g, partition_1d(g, 8, edge_balanced=False)


# ---------------------------------------------------------------------------
# pricing: overlap_model / choose_overlap
# ---------------------------------------------------------------------------


def test_overlap_model_per_hop_terms(pg249):
    """The model prices ONE link per hop: wire time is hop_time(per-hop
    slab bytes), exposure is hops * wire single-buffered and
    hops * max(0, wire - comp) pipelined."""
    _, _, pg = pg249
    m = overlap_model(pg, 64, TPU_V5E)
    assert m["strategy"] == "ring" and m["hops"] == 7
    assert m["bytes_per_hop"] == pg.block_size * 64 * 4
    assert m["t_wire_hop_s"] == pytest.approx(
        TPU_V5E.hop_time(m["bytes_per_hop"]))
    assert m["exposed_none_s"] == pytest.approx(7 * m["t_wire_hop_s"])
    hidden = min(m["t_wire_hop_s"], m["t_comp_hop_s"])
    assert m["overlapped_pipelined_s"] == pytest.approx(7 * hidden)
    assert m["exposed_pipelined_s"] == pytest.approx(
        m["exposed_none_s"] - m["overlapped_pipelined_s"])
    assert m["t_none_s"] == pytest.approx(
        7 * m["t_comp_hop_s"] + m["exposed_none_s"])
    # the all-gather strategy is one fused collective: nothing to pipeline
    ag = overlap_model(pg, 64, TPU_V5E, strategy="allgather")
    assert ag["overlapped_pipelined_s"] == 0.0


def test_choose_overlap_flips_with_interconnect_speed(pg249):
    """Satellite: the pricing decision is a genuine function of the
    Machine's link speed -- slower links expose more wire time per hop, so
    hiding it behind the hop's combine work clears the saving threshold;
    fast-enough links make pipelining pointless."""
    _, _, pg = pg249
    lens = [64, 16]
    assert choose_overlap(pg, lens, TPU_V5E) == "pipelined"
    # v5p's 2x-fatter ICI links shrink the wire term below the threshold:
    # the SAME workload flips to single-buffered on the faster machine
    assert choose_overlap(pg, lens, TPU_V5P) == "none"
    fast = dataclasses.replace(TPU_V5E, interconnect_bw=1e18,
                               link_latency_s=0.0)
    assert choose_overlap(pg, lens, fast) == "none"
    # threshold semantics: the v5e saving actually clears the 2% bar
    tot_none = sum(overlap_model(pg, f, TPU_V5E)["t_none_s"] for f in lens)
    tot_hidden = sum(overlap_model(pg, f, TPU_V5E)["overlapped_pipelined_s"]
                     for f in lens)
    assert tot_hidden >= OVERLAP_SAVING_THRESHOLD * tot_none
    # no per-hop structure / nothing moving => never pipeline
    assert choose_overlap(pg, lens, TPU_V5E, strategy="allgather") == "none"
    pg1 = partition_1d(pg249[1], 1, edge_balanced=False)
    assert choose_overlap(pg1, lens, TPU_V5E) == "none"
    # int shorthand == one-element sequence
    assert choose_overlap(pg, 64, TPU_V5E) == \
        choose_overlap(pg, [64], TPU_V5E)


# ---------------------------------------------------------------------------
# plan threading: validation, describe(), cache key
# ---------------------------------------------------------------------------


def test_build_plan_overlap_validation(pg249):
    spec, g, _ = pg249
    cfg = PAPER_MODELS["gcn"]
    with pytest.raises(ValueError, match="overlap"):
        build_plan(g, cfg, spec.feature_len, spec.num_classes,
                   overlap="sometimes")
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="requires strategy='ring'"):
        build_plan(g, cfg, spec.feature_len, spec.num_classes, mesh=mesh,
                   strategy="allgather", overlap="pipelined")
    # a LOCAL plan has no collective to overlap: the knob resolves to none
    local = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                       overlap="pipelined")
    assert local.overlap == "none"


def test_overlap_in_describe_and_cache_key(pg249):
    spec, g, _ = pg249
    cfg = PAPER_MODELS["gcn"]
    mesh = jax.make_mesh((1,), ("data",))
    kw = dict(mesh=mesh, num_shards=1, strategy="ring")
    p_none = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                        overlap="none", **kw)
    p_pipe = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                        overlap="pipelined", **kw)
    assert p_none is not p_pipe              # overlap is in the cache key
    assert p_none is build_plan(g, cfg, spec.feature_len, spec.num_classes,
                                overlap="none", **kw)   # cache hit
    assert p_pipe.overlap == "pipelined"
    for d in p_pipe.describe():
        assert d["overlap"] == "pipelined"
    for d in p_none.describe():
        assert d["overlap"] == "none"
    # "auto" stores the RESOLVED schedule, never the literal request
    p_auto = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                        overlap="auto", **kw)
    assert p_auto.overlap in ("none", "pipelined")


# ---------------------------------------------------------------------------
# the bitwise regression: V % shards != 0, eager AND compiled, 1-D and 2-D
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_overlapped_halo_bitwise_with_ragged_padding():
    """Satellite regression: with V=249 on 8 shards every device block
    ends in padding rows; the pipelined schedule must produce the SAME
    BITS as the single-buffered one (pad rows never enter a hop's partial
    combine -- their mask zeroes them in _hop_partial), eager and
    compiled, 1-D and 2-D, and the instrumented report must carry the
    matching exposed/overlapped split."""
    out = run_sub("""
        import dataclasses
        from repro.config import CORA, reduced_graph
        from repro.graph.datasets import make_synthetic_graph, make_features
        from repro.core.plan import build_plan
        from repro.models.gcn import PAPER_MODELS
        from repro.profile.machine import TPU_V5E
        spec = reduced_graph(CORA, 249, 32)       # 249 % 8 == 1
        g = make_synthetic_graph(spec); x = make_features(spec)
        cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
        local = build_plan(g, cfg, spec.feature_len, spec.num_classes)
        params = local.init(jax.random.PRNGKey(0))
        ref = np.asarray(local.run_model(params, x))
        meshes = {"1d": jax.make_mesh((8,), ("data",)),
                  "2d": jax.make_mesh((4, 2), ("node", "feat"))}
        for kind, mesh in meshes.items():
            outs = {}
            for ov in ("none", "pipelined"):
                plan = build_plan(g, cfg, spec.feature_len,
                                  spec.num_classes, mesh=mesh,
                                  strategy="ring", overlap=ov)
                assert plan.overlap == ov
                with mesh:
                    rep = plan.instrument(machine=TPU_V5E).run_model(
                        params, x)
                    rep.validate()
                    assert not rep.mismatches(plan), (kind, ov)
                    fn = plan.compile()
                    comp = np.asarray(fn(params, x))
                    fn(params, x)
                    assert fn.num_traces == 1, (kind, ov)
                eager = np.asarray(rep.output)
                assert np.array_equal(comp, eager), (kind, ov)
                outs[ov] = eager
                exp = sum(r.exposed_collective_time for r in rep.records)
                hid = sum(r.overlapped_collective_time
                          for r in rep.records)
                assert exp > 0, (kind, ov)
                assert (hid > 0) == (ov == "pipelined"), (kind, ov)
                # correctness vs the unsharded reference: pad rows never
                # contaminate real rows (float tolerance: different
                # reduction grouping than the local plan is expected)
                err = np.abs(outs[ov] - ref).max()
                assert err < 1e-3, (kind, ov, err)
            assert np.array_equal(outs["none"], outs["pipelined"]), kind
        print("OK")
    """)
    assert "OK" in out
