"""GCN serving driver: node-prediction traffic through bucketed plans.

Builds a GraphServeEngine over a reduced synthetic graph, warms up the
bucket ladder (every bucket's single ``plan.compile(dynamic=True)``
callable traces exactly once), submits a wave of node-prediction requests
with mixed seed-batch sizes, drains them with continuous batching, and
prints the serving report: latency percentiles, throughput, per-bucket
hit counts, and the zero-retrace check.  See docs/serving.md.

  PYTHONPATH=src python examples/serve_gcn.py --requests 50 --max-batch 8
"""

import argparse

import jax
import numpy as np

from repro.config import GRAPHS, reduced_graph
from repro.graph.datasets import make_features, make_synthetic_graph
from repro.models.gcn import PAPER_MODELS
from repro.serve import GraphRequest, GraphServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--vertices", type=int, default=512)
    ap.add_argument("--max-seeds", type=int, default=16)
    ap.add_argument("--report", action="store_true",
                    help="print the full WorkloadReport markdown")
    args = ap.parse_args()

    spec = reduced_graph(GRAPHS["reddit"], args.vertices, 64)
    g = make_synthetic_graph(spec)
    x = make_features(spec)

    engine = GraphServeEngine(g, PAPER_MODELS["gcn"], None, x,
                              spec.num_classes, fanouts=(5, 5),
                              max_batch=args.max_batch)
    engine.params = engine.init_params(jax.random.PRNGKey(0))
    traces = engine.warmup()
    print(f"warmup: {len(engine.buckets)} bucket(s) compiled: {traces}")

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        seeds = rng.choice(g.num_vertices,
                           size=int(rng.integers(1, args.max_seeds + 1)),
                           replace=False)
        engine.submit(GraphRequest(rid=i, seeds=seeds))
    done = engine.run()

    s = engine.stats()
    print(f"served {s['served']} requests in {s['steps']} step(s) — "
          f"{s['throughput_rps']:.1f} req/s, p50 {s['p50_ms']:.1f} ms, "
          f"p95 {s['p95_ms']:.1f} ms, p99 {s['p99_ms']:.1f} ms")
    print(f"buckets: hits={s['bucket_hits']} misses={s['bucket_misses']} "
          f"retraces={s['retraces']} plan_cache={s['plan_cache']['size']}")
    for b in s["buckets"]:
        print(f"  bucket s{b['num_seeds']}/v{b['num_inputs']}/"
              f"e{b['num_edges']}: {b['hits']} hit(s)")
    for r in done[:5]:
        lat = (r.finish_t - r.enqueue_t) * 1e3
        print(f"  req {r.rid}: {len(r.seeds):2d} seeds -> frontier "
              f"{r.frontier_size:3d}/{r.edge_count:3d} edges, "
              f"bucket s{r.bucket.num_seeds if r.bucket else '-'}, "
              f"latency {lat:.1f} ms")
    if args.report:
        print()
        print(engine.workload_report().to_markdown())


if __name__ == "__main__":
    main()
