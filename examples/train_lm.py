"""End-to-end training driver: train a ~100M-param LM with the full runtime
(pipeline, AdamW+cosine, async checkpointing, fault-tolerant trainer).

Default invocation trains a granite-family ~100M model for a few hundred
steps on synthetic Zipf tokens:

  PYTHONPATH=src python examples/train_lm.py --steps 300

CPU throughput note: ~100M params at batch 8 x seq 256 is ~2-6 s/step on a
laptop-class CPU; use --preset tiny for a smoke run.  Any assigned arch is
selectable: ``--arch gemma2-9b --preset smoke`` trains that family's
reduced config.

Resumability: re-running the same command continues from the newest
checkpoint (kill it mid-run and restart to see).
"""

import argparse
import dataclasses
import importlib
import logging

import jax

from repro.config import (AttentionConfig, LMConfig, OptimizerConfig,
                          ShapeSpec, TrainConfig)
from repro.data.pipeline import TokenPipeline
from repro.launch.steps import make_train_step
from repro.models.transformer import init_lm
from repro.optim.optimizer import make_train_state
from repro.train.trainer import Trainer

MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2", "arctic-480b": "arctic_480b",
    "deepseek-67b": "deepseek_67b", "gemma2-9b": "gemma2_9b",
    "gemma-7b": "gemma_7b", "granite-3-8b": "granite_3_8b",
    "jamba-1.5-large-398b": "jamba_1_5_large", "internvl2-1b": "internvl2_1b",
    "mamba2-2.7b": "mamba2_2_7b",
}


def model_100m() -> LMConfig:
    """granite-family ~100M: 12L d=640 10H kv=2 ffn 1792 vocab 32768."""
    return LMConfig(
        name="granite-100m", family="dense", num_layers=12, d_model=640,
        d_ff=1792, vocab_size=32768,
        attention=AttentionConfig(num_heads=10, num_kv_heads=2, head_dim=64),
        mlp_activation="swiglu", tie_embeddings=True, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-100m",
                    help="granite-100m | any assigned arch id (reduced)")
    ap.add_argument("--preset", default="full", choices=["full", "tiny",
                                                         "smoke"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    if args.arch == "granite-100m":
        cfg = model_100m()
    else:
        mod = importlib.import_module(f"repro.configs.{MODULES[args.arch]}")
        cfg = dataclasses.replace(mod.reduced(), dtype="float32")
    if args.preset == "tiny":
        cfg = dataclasses.replace(cfg, num_layers=4, d_model=256, d_ff=704,
                                  vocab_size=8192)
    elif args.preset == "smoke":
        args.steps, args.batch, args.seq = min(args.steps, 5), 2, 32

    n = cfg.param_count()
    print(f"arch={cfg.name}  params={n/1e6:.1f}M  steps={args.steps}  "
          f"batch={args.batch}x{args.seq}")

    shape = ShapeSpec("train_cli", args.seq, args.batch, "train")
    opt = OptimizerConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                          total_steps=args.steps)
    tc = TrainConfig(model=cfg.name, steps=args.steps, optimizer=opt,
                     checkpoint_dir=args.ckpt_dir, checkpoint_every=50,
                     log_every=10)
    pipeline = TokenPipeline(cfg, shape, seed=0)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    make_state = lambda: make_train_state(  # noqa: E731
        init_lm(cfg, jax.random.PRNGKey(0)), opt)

    trainer = Trainer(tc, make_state=make_state, step_fn=step_fn,
                      pipeline=pipeline)
    result = trainer.run()
    hist = result["history"]
    print(f"\ndone: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {args.steps} steps; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
