"""Mamba-2 block: SSD (state-space duality) with chunked execution.

[arXiv:2405.21060]  h_t = exp(dt_t * A_h) h_{t-1} + dt_t B_t x_t ; y_t = C_t h_t.

The chunked SSD algorithm is itself an instance of the paper's
phase-fusion insight: a memory-bound sequential recurrence (inter-chunk scan,
the Aggregation-like irregular phase) interleaved with dense intra-chunk
block GEMMs (Combination-like), executed at chunk granularity so the state
never round-trips HBM per token.  We note this correspondence in DESIGN.md §4.

Layout: heads H = d_inner / head_dim; B/C shared across heads in G groups.
Train/prefill use the chunked scan (lax.scan over S/chunk steps); decode is
the O(1)-state recurrence step.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import SSMConfig
from repro.launch.sharding import constrain
from repro.nn.layers import gated_rmsnorm, init_dense, init_rmsnorm


class SSMCache(NamedTuple):
    state: jnp.ndarray       # (B, H, N, P) SSM state
    conv: jnp.ndarray        # (B, conv_dim, d_conv-1) conv tail
    length: jnp.ndarray      # () int32


def init_mamba2(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32) -> Dict:
    d_in = cfg.d_inner(d_model)
    h = cfg.n_heads(d_model)
    gn = cfg.n_groups * cfg.d_state
    conv_dim = d_in + 2 * gn
    ks = jax.random.split(key, 6)
    return {
        # input projections, SPLIT at the [z | xBC | dt] boundaries so each
        # output's TP shard boundaries align with its consumer layout
        # (a fused projection shards at arbitrary 1/16 offsets and forces
        # per-layer resharding of z/xBC/dt -- observed in the mamba2
        # train_4k profile as unsharded f32[B,S,5376] copies).
        "z_proj": init_dense(ks[0], d_model, d_in, dtype),
        "xbc_proj": init_dense(ks[5], d_model, conv_dim, dtype),
        "dt_proj": init_dense(ks[2], d_model, h, dtype),
        "out_proj": init_dense(ks[1], d_in, d_model, dtype,
                               scale=d_in ** -0.5),
        "conv_w": (jax.random.normal(ks[2], (conv_dim, cfg.d_conv),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)),      # A = -exp(A_log)
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 1e-2))),  # softplus^-1
        "norm": init_rmsnorm(d_in),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv1d.  xbc: (B, S, C); w: (C, K); tail: (B,C,K-1)."""
    bsz, s, c = xbc.shape
    k = w.shape[1]
    xt = xbc.transpose(0, 2, 1)                              # (B, C, S)
    if tail is None:
        xt = jnp.pad(xt, ((0, 0), (0, 0), (k - 1, 0)))
    else:
        xt = jnp.concatenate([tail.astype(xt.dtype), xt], axis=2)
    out = jax.lax.conv_general_dilated(
        xt[:, :, None, :], w.astype(xt.dtype)[:, None, None, :],
        window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=c)[:, :, 0, :]
    out = out + b.astype(out.dtype)[None, :, None]
    return jax.nn.silu(out).transpose(0, 2, 1)               # (B, S, C)


def _ssd_chunked(x, b_mat, c_mat, dt, a, cfg: SSMConfig,
                 init_state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    x: (B, S, H, P); b_mat/c_mat: (B, S, G, N); dt: (B, S, H); a: (H,) (<0).
    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    hg = h // g
    q = min(cfg.chunk_size, s)
    assert s % q == 0, (s, q)
    nc = s // q

    xg = x.reshape(bsz, nc, q, g, hg, p)
    bg = b_mat.reshape(bsz, nc, q, g, n)
    cg = c_mat.reshape(bsz, nc, q, g, n)
    dtc = dt.reshape(bsz, nc, q, h)
    da = dtc * a[None, None, None, :]                        # (B,nc,Q,H) <0

    if init_state is None:
        init_state = jnp.zeros((bsz, h, n, p), jnp.float32)

    cdt = jnp.dtype(cfg.compute_dtype)

    def chunk_step(state, inp):
        xc, bc, cc, dac, dtcc = inp                          # per-chunk slices
        cum = jnp.cumsum(dac, axis=1)                        # (B,Q,H)
        cum_g = cum.reshape(bsz, q, g, hg)
        # off-diagonal: y_off[i] = exp(cum_i) * C_i . state
        st_g = state.reshape(bsz, g, hg, n, p)
        y_off = jnp.einsum("bqgn,bghnp->bqghp", cc, st_g)
        y_off = y_off * jnp.exp(cum_g)[..., None]
        # intra-chunk (the (B,H,Q,Q) tensors: compute_dtype traffic)
        scores = jnp.einsum("bign,bjgn->bgij", cc.astype(cdt),
                            bc.astype(cdt))                  # (B,G,Q,Q)
        diff = cum_g.transpose(0, 2, 3, 1)                   # (B,G,Hg,Q)
        m = jnp.exp(diff[..., :, None] - diff[..., None, :])  # (B,G,Hg,Q,Q)
        tri = jnp.tril(jnp.ones((q, q), bool))
        m = jnp.where(tri, m.astype(cdt), jnp.zeros((), cdt))
        dtx = (xc.reshape(bsz, q, g, hg, p) *
               dtcc.reshape(bsz, q, g, hg)[..., None]).astype(cdt)
        t_mat = scores[:, :, None] * m                       # (B,G,Hg,Q,Q)
        y_diag = jnp.einsum("bghij,bjghp->bighp", t_mat, dtx,
                            preferred_element_type=jnp.float32)
        y = (y_off.astype(jnp.float32) + y_diag).reshape(bsz, q, h, p)
        # state update (f32 recurrence)
        cum_last = cum[:, -1:, :]                            # (B,1,H)
        w = jnp.exp(cum_last - cum)                          # (B,Q,H)
        wg = w.reshape(bsz, q, g, hg)
        s_c = jnp.einsum("bjgn,bjghp->bghnp", bc.astype(jnp.float32),
                         dtx.astype(jnp.float32) * wg[..., None])
        new_state = state * jnp.exp(cum_last[:, 0])[..., None, None] \
            .reshape(bsz, h, 1, 1) + s_c.reshape(bsz, h, n, p)
        return new_state, y

    xs = (xg.transpose(1, 0, 2, 3, 4, 5).reshape(nc, bsz, q, g, hg, p)
          .reshape(nc, bsz, q, h, p),
          bg.transpose(1, 0, 2, 3, 4),
          cg.transpose(1, 0, 2, 3, 4),
          da.transpose(1, 0, 2, 3),
          dtc.transpose(1, 0, 2, 3))
    final_state, ys = jax.lax.scan(chunk_step, init_state, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    return y, final_state


def ssd_reference(x, b_mat, c_mat, dt, a):
    """Sequential per-token oracle (tests)."""
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    hg = h // g
    state = jnp.zeros((bsz, h, n, p), jnp.float32)
    ys = []
    for t in range(s):
        da = jnp.exp(dt[:, t] * a[None, :])                  # (B,H)
        bt = jnp.repeat(b_mat[:, t], hg, axis=1)             # (B,H,N)
        ct = jnp.repeat(c_mat[:, t], hg, axis=1)
        state = state * da[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", bt, x[:, t] * dt[:, t][..., None])
        ys.append(jnp.einsum("bhn,bhnp->bhp", ct, state))
    return jnp.stack(ys, axis=1), state


def mamba2_block(params: Dict, x: jnp.ndarray, cfg: SSMConfig, *,
                 cache: Optional[SSMCache] = None, make_cache: bool = False,
                 ) -> Tuple[jnp.ndarray, Optional[SSMCache]]:
    """x: (B, S, D) -> (out (B,S,D), cache).  Decode when cache is not None."""
    bsz, s, d_model = x.shape
    d_in = cfg.d_inner(d_model)
    h = cfg.n_heads(d_model)
    gn = cfg.n_groups * cfg.d_state
    conv_dim = d_in + 2 * gn

    z = jnp.einsum("bsd,df->bsf", x, params["z_proj"]["w"].astype(x.dtype))
    xbc = jnp.einsum("bsd,df->bsf", x,
                     params["xbc_proj"]["w"].astype(x.dtype))
    dt_raw = jnp.einsum("bsd,df->bsf", x,
                        params["dt_proj"]["w"].astype(x.dtype))
    z = constrain(z, "batch", None, "mlp")
    xbc = constrain(xbc, "batch", None, "mlp")
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"][None, None, :])
    dt = constrain(dt, "batch", None, "heads")
    a = -jnp.exp(params["A_log"])

    if cache is not None:  # ---------- decode: single token ----------
        assert s == 1
        conv_in = jnp.concatenate(
            [cache.conv, xbc.transpose(0, 2, 1).astype(cache.conv.dtype)],
            axis=2)                                          # (B,C,K)
        conv_out = (conv_in * params["conv_w"][None].astype(conv_in.dtype)
                    ).sum(-1) + params["conv_b"][None]
        xbc_act = jax.nn.silu(conv_out)                      # (B, conv_dim)
        new_conv = conv_in[:, :, 1:]
        xs = xbc_act[:, :d_in].reshape(bsz, h, -1)           # (B,H,P)
        b_t = xbc_act[:, d_in:d_in + gn].reshape(bsz, cfg.n_groups, -1)
        c_t = xbc_act[:, d_in + gn:].reshape(bsz, cfg.n_groups, -1)
        hg = h // cfg.n_groups
        bt = jnp.repeat(b_t, hg, axis=1)
        ct = jnp.repeat(c_t, hg, axis=1)
        dt1 = dt[:, 0]                                       # (B,H)
        da = jnp.exp(dt1 * a[None, :])
        state = cache.state * da[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", bt, xs.astype(jnp.float32) * dt1[..., None])
        y = jnp.einsum("bhn,bhnp->bhp", ct, state)
        y = y + params["D"][None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(bsz, 1, d_in).astype(x.dtype)
        new_cache = SSMCache(state, new_conv, cache.length + 1)
    else:  # ---------- train / prefill: chunked scan ----------
        xbc_raw = xbc  # unpadded; conv tail for the cache comes from here
        s_pad = -(-s // cfg.chunk_size) * cfg.chunk_size if s > cfg.chunk_size \
            else s
        if s_pad != s:
            xbc = jnp.pad(xbc, ((0, 0), (0, s_pad - s), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, s_pad - s), (0, 0)))
        xbc_act = _causal_conv(xbc, params["conv_w"], params["conv_b"])
        xbc_act = constrain(xbc_act, "batch", None, "mlp")
        xs = xbc_act[..., :d_in].reshape(bsz, s_pad, h, -1)
        # TP over SSD heads: the intra-chunk decay/score tensors are
        # (B, H, Q, Q)-shaped -- unsharded they dominate activation memory
        # (observed 566 GiB/device at jamba train_4k).
        xs = constrain(xs, "batch", None, "heads", None)
        b_mat = xbc_act[..., d_in:d_in + gn].reshape(bsz, s_pad,
                                                     cfg.n_groups, -1)
        c_mat = xbc_act[..., d_in + gn:].reshape(bsz, s_pad,
                                                 cfg.n_groups, -1)
        # B/C are per-group (tiny) and consumed by every head: replicate
        b_mat = constrain(b_mat, "batch", None, None, None)
        c_mat = constrain(c_mat, "batch", None, None, None)
        cdt = jnp.dtype(cfg.compute_dtype)
        y, state = _ssd_chunked(xs.astype(cdt), b_mat.astype(cdt),
                                c_mat.astype(cdt), dt, a, cfg)
        y = constrain(y, "batch", None, "heads", None)
        y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
        y = y[:, :s].reshape(bsz, s, d_in).astype(x.dtype)
        new_cache = None
        if make_cache:
            tail = xbc_raw.transpose(0, 2, 1)[:, :, s - (cfg.d_conv - 1):]
            new_cache = SSMCache(state, tail.astype(jnp.float32),
                                 jnp.asarray(s, jnp.int32))

    y = gated_rmsnorm(params["norm"], y, z)
    return jnp.einsum("bsf,fd->bsd", y,
                      params["out_proj"]["w"].astype(y.dtype)), new_cache
