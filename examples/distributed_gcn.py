import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Distributed GCN training demo: a mesh-built GraphExecutionPlan
(shard_map vertex partitioning) + int8 error-feedback gradient compression
(DESIGN.md §6).

8 placeholder devices on CPU (the same code drives a real (data,) mesh):
  * ``build_plan(..., mesh=mesh, num_shards=8)`` owns the 1-D partition,
    the per-layer phase ordering (cost model prices the halo: combine-first
    moves 16-wide projected rows, not 64-wide inputs -- the Table 4
    collective saving), and the ring-halo aggregation strategy,
  * per-shard gradients reduced with int8 error feedback (4x wire bytes
    reduction vs fp32; unbiased over time).

  PYTHONPATH=src python examples/distributed_gcn.py
"""

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.config import CORA, reduced_graph  # noqa: E402
from repro.core.distributed import halo_bytes, halo_bytes_2d  # noqa: E402
from repro.core.plan import build_plan  # noqa: E402
from repro.graph.datasets import (make_features, make_labels,  # noqa: E402
                                  make_synthetic_graph)
from repro.models.gcn import PAPER_MODELS  # noqa: E402
from repro.optim.compression import (compression_wire_bytes,  # noqa: E402
                                     init_residuals,
                                     make_compressed_allreduce)


def main():
    spec = reduced_graph(CORA, max_vertices=512, max_feature=64)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    y = make_labels(spec)
    x = x.at[:, :spec.num_classes].add(
        4.0 * jax.nn.one_hot(y, spec.num_classes))

    mesh = jax.make_mesh((8,), ("data",))
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
    plan = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                      mesh=mesh, num_shards=8, strategy="ring")
    pg = plan.partition
    hb_in = halo_bytes(pg, spec.feature_len)["min_halo_bytes"]
    hb_out = halo_bytes(pg, 16)["min_halo_bytes"]
    print(f"partition: 8 shards x {pg.block_size} vertices, "
          f"halo {hb_in:,} B (agg-first) vs {hb_out:,} B (combine-first) "
          f"-> {hb_in / hb_out:.1f}x collective saving")
    for d in plan.describe():
        print(f"  layer{d['layer']}: {d['din']}->{d['dout']} "
              f"order={d['order']} (planned)")

    params = plan.init(jax.random.PRNGKey(0))

    def loss_fn(p):
        logits = plan.run_model(p, x)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, y[:, None], axis=-1)[:, 0]
        return nll.mean()

    allreduce = make_compressed_allreduce(mesh, "data")
    residuals = init_residuals(params)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    wire = compression_wire_bytes(
        sum(int(np.prod(v.shape)) for v in jax.tree.leaves(params)), dp=8)
    print(f"grad wire bytes/step: fp32 {wire['fp32_bytes']:,.0f} -> "
          f"int8+EF {wire['int8_ef_bytes']:,.0f} "
          f"({wire['reduction_vs_fp32']:.0f}x)")

    lr = 0.25
    with mesh:
        for step in range(30):
            loss, grads = grad_fn(params)
            grads, residuals = allreduce(grads, residuals)  # int8 EF wire
            params = jax.tree.map(lambda p_, g_: p_ - lr * g_, params,
                                  grads)
            if step % 5 == 0:
                print(f" step {step:2d}  loss {float(loss):.4f}")

        logits = plan.run_model(params, x)
    acc = float((jnp.argmax(logits, -1) == y).mean())
    print(f"final accuracy {acc:.3f} (chance {1 / spec.num_classes:.3f})")

    # --- the same model on a 2-D (node x feature) mesh -------------------
    # The multi-host shape: node axis across hosts (halo bytes / Q), the
    # feature axis across intra-host links (the combine reduce-scatter stays
    # local).
    mesh2 = jax.make_mesh((4, 2), ("node", "feat"))
    plan2 = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                       mesh=mesh2, strategy="ring")
    hb1 = halo_bytes(plan.partition, 16)["min_halo_bytes"]
    hb2 = halo_bytes_2d(plan2.partition, 16)["min_halo_bytes"]
    print(f"2-D partition {plan2.partition_kind}: 4 node x 2 feat shards, "
          f"per-device halo {hb2:,} B vs {hb1:,} B 1-D "
          f"(columns ride {plan2.partition.feature_block(16)} wide)")
    with mesh2:
        logits2 = plan2.run_model(params, x)
    drift = float(jnp.abs(logits2 - logits).max())
    print(f"2-D forward matches 1-D-trained logits (max |diff| {drift:.2e})")


if __name__ == "__main__":
    main()
