"""Machine: one dataclass describing the hardware a characterization targets.

The paper characterizes GCNs on a V100 and derives guidelines from that
machine's balance point; PRs 2-3 added a TPU tier and a GPU tier but left the
hardware numbers as module-level constants in ``core/characterize.py`` (TPU
v5e) plus a bag of ``GPU_*`` occupancy constants.  This module replaces both:
every roofline term, bound classification, tile picker, and ordering cost
model takes a ``Machine`` value instead of importing globals, so the same
analysis runs against any accelerator by passing a different preset.

Presets::

    TPU_V5E   197 TFLOP/s bf16, 819 GB/s HBM, 4x50 GB/s ICI, 128 MiB VMEM
    TPU_V5P   459 TFLOP/s bf16, 2765 GB/s HBM2e, 6x100 GB/s ICI (3-D
              torus), 128 MiB VMEM -- the multi-host scale-out target the
              distributed overlap model prices
    A100      312 TFLOP/s bf16, 1555 GB/s HBM, 12x25 GB/s NVLink,
              192 KiB SMEM/L1 carveout per SM (the GPU occupancy model)
    H100      989 TFLOP/s bf16, 3350 GB/s HBM3, 18x25 GB/s NVLink 4,
              228 KiB SMEM/L1 carveout per SM (the serving-tier GPU)
    V100      15.7 TFLOP/s fp32, 900 GB/s HBM -- the PAPER's machine; its
              balance point (~17.4 F/B) is the classification threshold
              behind Table 3's "Execution Bound" row.

The interconnect is described per hop -- ``interconnect_bw`` (one link's
bandwidth) plus ``link_latency_s`` (per-message launch latency) -- because
the ring halo schedules (``core.distributed``) saturate ONE link per
direction per hop; ``interconnect_total`` remains the aggregate all-links
number for bisection-style accounting.  ``hop_time(nbytes)`` is the
overlap model's per-hop wire term.

``machine_for_backend`` maps a resolved backend tier (``core.backend``) to
its natural preset so plan-level code can stay machine-implicit until a
caller overrides it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class Machine:
    """Hardware description consumed by the characterization subsystem.

    Attributes:
      name: registry key ("tpu-v5e" | "a100" | "v100" | ...).
      kind: accelerator family, "tpu" | "gpu" (selects the occupancy model
        ``suggest_tile_m`` applies).
      peak_flops: peak matmul FLOP/s at the native precision the repo
        models (bf16 tensor/MXU for v5e/A100, fp32 CUDA cores for the
        paper's V100 numbers).
      hbm_bw: HBM bandwidth, bytes/s.
      interconnect_bw: per-link chip interconnect bandwidth, bytes/s
        (ICI link on TPU, NVLink lane on GPU) -- the PER-HOP bandwidth a
        ring collective sees (one link per direction per hop).
      interconnect_links: number of such links per chip.
      link_latency_s: per-message launch latency of one interconnect hop,
        seconds (the fixed term of ``hop_time``; ~1 us ICI, ~2 us NVLink
        with software overheads).
      on_chip_bytes: the fast scratch a fused tile must fit -- whole VMEM
        on TPU, the unified SMEM/L1 carveout per SM on GPU.
      regfile_bytes: register file per SM (GPU occupancy input; 0 on TPU).
      target_ctas: resident CTAs per SM needed to hide HBM latency (GPU
        occupancy input; 0 on TPU, where one sequential grid walks blocks).
      row_align: natural row granularity of a tile (8 sublanes on TPU,
        32 warp threads on GPU).
      matrix_tile: systolic/tensor tile edge for pad-waste accounting
        (128 MXU lanes on TPU).
    """

    name: str
    kind: str
    peak_flops: float
    hbm_bw: float
    interconnect_bw: float
    interconnect_links: int
    on_chip_bytes: int
    link_latency_s: float = 1e-6
    regfile_bytes: int = 0
    target_ctas: int = 0
    row_align: int = 8
    matrix_tile: int = 128

    def __post_init__(self):
        assert self.kind in ("tpu", "gpu"), self.kind

    @property
    def balance(self) -> float:
        """Machine balance: FLOPs per HBM byte at which compute and memory
        time are equal.  AI below this is memory-bound (paper Table 3)."""
        return self.peak_flops / self.hbm_bw

    @property
    def interconnect_total(self) -> float:
        """Aggregate interconnect bandwidth (all links), bytes/s."""
        return self.interconnect_bw * self.interconnect_links

    def hop_time(self, nbytes: float) -> float:
        """Seconds for ONE interconnect hop moving ``nbytes`` over a single
        link: ``link_latency_s + nbytes / interconnect_bw``.  The per-hop
        wire term of the distributed overlap model
        (``core.distributed.overlap_model``) -- a ring collective's hop
        sees one link's bandwidth, never ``interconnect_total``."""
        return self.link_latency_s + nbytes / self.interconnect_bw

    def tile_budget(self) -> int:
        """On-chip bytes one fused tile may claim: half of VMEM on TPU
        (the other half double-buffers), an SM-carveout share per resident
        CTA on GPU (latency hiding comes from CTA count, not tile size)."""
        if self.kind == "gpu":
            return self.on_chip_bytes // max(1, self.target_ctas)
        return self.on_chip_bytes // 2

    def classify(self, arithmetic_intensity: float) -> str:
        """"memory" | "compute" bound classification against this balance."""
        return "memory" if arithmetic_intensity < self.balance else "compute"


#: TPU v5e, per chip (the repo's default modeling target since PR 1).
TPU_V5E = Machine(
    name="tpu-v5e", kind="tpu",
    peak_flops=197e12, hbm_bw=819e9,
    interconnect_bw=50e9, interconnect_links=4,     # 2-D torus: +-x, +-y
    on_chip_bytes=128 * 1024 * 1024,                # VMEM
    link_latency_s=1e-6,
    row_align=8, matrix_tile=128)

#: TPU v5p, per chip: the scale-out pod part (3-D torus, 6 ICI links at
#: ~100 GB/s each).  The Machine the distributed overlap model prices
#: multi-host halo pipelining against -- fatter links than v5e move the
#: choose_overlap break-even point.
TPU_V5P = Machine(
    name="tpu-v5p", kind="tpu",
    peak_flops=459e12, hbm_bw=2765e9,
    interconnect_bw=100e9, interconnect_links=6,    # 3-D torus: +-x,y,z
    on_chip_bytes=128 * 1024 * 1024,                # VMEM
    link_latency_s=1e-6,
    row_align=8, matrix_tile=128)

#: A100-SXM4 (bf16 tensor cores).  The occupancy fields are what the GPU
#: tile picker consumes: per-SM SMEM/L1 carveout shared by ``target_ctas``
#: resident blocks, warp-aligned rows.
A100 = Machine(
    name="a100", kind="gpu",
    peak_flops=312e12, hbm_bw=1555e9,
    interconnect_bw=25e9, interconnect_links=12,    # NVLink 3
    link_latency_s=2e-6,
    on_chip_bytes=192 * 1024,                       # unified SMEM/L1 per SM
    regfile_bytes=256 * 1024, target_ctas=4,
    row_align=32, matrix_tile=16)

#: H100-SXM5 (bf16 tensor cores, dense).  Same occupancy model as A100 with
#: Hopper's larger SMEM/L1 carveout and HBM3; its steeper balance point
#: (~295 F/B) pushes even more GCN phases memory-bound -- the machine the
#: serving benchmarks (``bench_serve``) price latency against.
H100 = Machine(
    name="h100", kind="gpu",
    peak_flops=989e12, hbm_bw=3350e9,
    interconnect_bw=25e9, interconnect_links=18,    # NVLink 4
    link_latency_s=2e-6,
    on_chip_bytes=228 * 1024,                       # unified SMEM/L1 per SM
    regfile_bytes=256 * 1024, target_ctas=4,
    row_align=32, matrix_tile=16)

#: V100 with the PAPER's numbers (fp32 CUDA-core peak / 900 GB/s HBM2):
#: balance ~17.4 F/B, the threshold behind Table 3's bound classification.
V100 = Machine(
    name="v100", kind="gpu",
    peak_flops=15.7e12, hbm_bw=900e9,
    interconnect_bw=25e9, interconnect_links=6,     # NVLink 2
    link_latency_s=2e-6,
    on_chip_bytes=128 * 1024,                       # unified SMEM/L1 per SM
    regfile_bytes=256 * 1024, target_ctas=4,
    row_align=32, matrix_tile=16)

MACHINES: Dict[str, Machine] = {m.name: m
                                for m in (TPU_V5E, TPU_V5P, A100, H100, V100)}


def get_machine(name_or_machine) -> Machine:
    """Resolve a registry name (or pass a Machine through) to a Machine."""
    if isinstance(name_or_machine, Machine):
        return name_or_machine
    try:
        return MACHINES[name_or_machine]
    except KeyError:
        raise ValueError(f"unknown machine {name_or_machine!r}; "
                         f"known: {sorted(MACHINES)}") from None


def machine_for_backend(backend: Optional[str]) -> Machine:
    """Natural Machine preset for a resolved backend tier.

    ``pallas-gpu`` -> A100 (GPU occupancy math must never mix TPU balance
    points -- the bug this replaces); everything else -> TPU_V5E, the repo's
    default modeling target.  Callers wanting the paper's machine pass
    ``V100`` explicitly.
    """
    return A100 if backend == "pallas-gpu" else TPU_V5E
