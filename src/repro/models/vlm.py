"""VLM composition helpers (internvl2-1b): frontend stub + backbone glue.

Per the assignment, the vision frontend is a STUB: ``input_specs`` provides
precomputed patch embeddings occupying the first ``NUM_PATCH_TOKENS``
positions.  The backbone is models/transformer.py; this module holds the
composition conventions so launchers/tests share one definition.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import LMConfig
from repro.configs.internvl2_1b import NUM_PATCH_TOKENS
from repro.models.transformer import lm_forward, lm_loss, lm_prefill


def stub_patch_embeds(key, batch: int, cfg: LMConfig,
                      n_patches: int = NUM_PATCH_TOKENS) -> jnp.ndarray:
    """Stand-in for InternViT+pixel-shuffle output: (B, P, d_model)."""
    return jax.random.normal(key, (batch, n_patches, cfg.d_model)) * 0.02


def vlm_forward(params, cfg: LMConfig, patch_embeds, tokens, **kw):
    """logits over [patch positions ++ token positions]."""
    return lm_forward(params, cfg, tokens, embeds=patch_embeds, **kw)


def vlm_loss(params, cfg: LMConfig, patch_embeds, tokens, labels, **kw):
    """CE over the text positions only (patch positions carry no labels)."""
    return lm_loss(params, cfg, tokens, labels, embeds=patch_embeds, **kw)


def vlm_prefill(params, cfg: LMConfig, patch_embeds, tokens,
                cache_size: int):
    """Image+prompt prefill; the cache includes the patch positions."""
    assert cache_size >= patch_embeds.shape[1] + tokens.shape[1]
    return lm_prefill(params, cfg, tokens, cache_size, embeds=patch_embeds)
