"""Paper Fig. 5: execution time vs input/output feature length (SAG, Reddit).

(a) sweep input length at fixed out=128: Combination time ~ linear in
    in_len, Aggregation time CONSTANT (combine-first: independent of in_len);
(b) sweep output length at fixed in=602: both phases ~ linear in out_len.

Sweet spots: the paper sees power-of-2 dips on V100; the TPU analogue is
128-multiple MXU tile alignment, reported as pad waste (out_len/128 ceil).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_graph, emit, timeit
from repro.core.phases import aggregate, aggregate_cost, combine_cost
from repro.graph.datasets import make_synthetic_graph


def _combine_time(g, x, w):
    f = jax.jit(lambda xx: xx @ w)
    return timeit(f, x)


def _aggregate_time(g, h):
    f = jax.jit(lambda hh: aggregate(g, hh, op="mean"))
    return timeit(f, h)


def run():
    spec = bench_graph("reddit", max_vertices=4096)
    g = make_synthetic_graph(spec)
    key = jax.random.PRNGKey(0)

    # (a) input length sweep, out fixed at 128 (combine first)
    for in_len in (64, 128, 250, 256, 512, 602, 1024):
        x = jax.random.normal(key, (g.num_vertices, in_len))
        w = jax.random.normal(key, (in_len, 128)) * 0.05
        t_comb = _combine_time(g, x, w)
        t_agg = _aggregate_time(g, x @ w)
        emit(f"fig5a/in_{in_len}", t_comb + t_agg,
             comb_us=round(t_comb, 1), agg_us=round(t_agg, 1),
             agg_analytic_bytes=aggregate_cost(g, 128)["bytes"],
             mxu_pad_waste=round(128 * -(-in_len // 128) / in_len - 1, 3))

    # (b) output length sweep, in fixed at 602
    x = jax.random.normal(key, (g.num_vertices, 602))
    for out_len in (16, 64, 100, 128, 256, 512):
        w = jax.random.normal(key, (602, out_len)) * 0.05
        t_comb = _combine_time(g, x, w)
        t_agg = _aggregate_time(g, x @ w)
        emit(f"fig5b/out_{out_len}", t_comb + t_agg,
             comb_us=round(t_comb, 1), agg_us=round(t_agg, 1),
             agg_analytic_bytes=aggregate_cost(g, out_len)["bytes"],
             comb_analytic_flops=combine_cost(g.num_vertices,
                                              (602, out_len))["flops"],
             mxu_pad_waste=round(128 * -(-out_len // 128) / out_len - 1, 3))


if __name__ == "__main__":
    run()
