"""Source/AST lint for retrace and bitwise hazards in ``src/repro/``.

Static companion to the jaxpr rules: some contracts (the PR 5 bitwise
reciprocal-multiply fix, the PR 8 ``acc_dtype`` threading) are idioms in
the SOURCE, invisible once traced.  :func:`lint_tree` walks every
``.py`` under a root; :func:`lint_source` lints one string (the
self-test plants use it).

Rules (ids match ``docs/analysis.md``):

  * ``host-in-trace``  -- host materialization (``.item()`` /
    ``.tolist()`` / ``float(jnp...)`` / ``jax.device_get``) in a
    function that also does device compute: breaks under jit and forces
    a device sync when eager.
  * ``tracer-branch``  -- ``if``/``while`` on a value produced by a
    ``jnp.``/``jax.`` call in the same function: a retrace/ConcretizationError
    hazard (warning severity -- data flow is approximated).
  * ``broadcast-div``  -- dividing by a ``[..., None]``-shaped operand
    instead of multiplying by a precomputed ``(V, 1)`` reciprocal; the
    PR 5 bitwise-equality rule, now enforced.
  * ``acc-dtype``      -- a Pallas ``pltpu.VMEM``/``SMEM`` scratch whose
    dtype is a literal instead of the threaded ``acc_dtype`` name: the
    kernel would silently pin its accumulator precision.
  * ``grid-arity``     -- a literal ``grid=`` tuple whose length differs
    from a ``BlockSpec`` index_map lambda's arity in the same
    ``pallas_call``: statically incompatible block/grid specs.

Suppression pragmas (per-rule, see ``docs/analysis.md``):
``# analysis: allow(rule-id)`` on the offending line or the line above;
``# analysis: allow-file(rule-id)`` anywhere in the file.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Set

from repro.analysis.report import AnalysisReport

_ALLOW_LINE = re.compile(r"#\s*analysis:\s*allow\(([a-z0-9\-,\s]+)\)")
_ALLOW_FILE = re.compile(r"#\s*analysis:\s*allow-file\(([a-z0-9\-,\s]+)\)")

#: host materialization calls (dotted suffixes / names)
_HOST_ATTRS = (".item", ".tolist")
_HOST_CALLS = ("jax.device_get",)

#: rough signature of device compute: calls under these prefixes
_DEVICE_PREFIXES = ("jnp.", "jax.lax.", "jax.nn.", "jax.ops.", "lax.",
                    "pl.", "pltpu.")


def _remediation() -> str:
    """The host-in-trace fix, verbatim from the runtime error users hit
    (``repro.kernels.ops.SEG_AGG_REMEDIATION``) -- satellite contract:
    lint finding and ValueError must agree on the remediation text."""
    try:
        from repro.kernels.ops import SEG_AGG_REMEDIATION
        return SEG_AGG_REMEDIATION
    except Exception:  # keep the linter usable without jax installed
        return "dispatch the trace-pure seg_agg_planned instead"


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of a call target ('' when not a name)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _parse_pragmas(src: str):
    """(file-level allowed rules, line -> allowed rules) from pragmas."""
    file_rules: Set[str] = set()
    line_rules: Dict[int, Set[str]] = {}
    for i, line in enumerate(src.splitlines(), start=1):
        m = _ALLOW_FILE.search(line)
        if m:
            file_rules |= {r.strip() for r in m.group(1).split(",")}
        m = _ALLOW_LINE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",")}
            line_rules.setdefault(i, set()).update(rules)
            line_rules.setdefault(i + 1, set()).update(rules)
    return file_rules, line_rules


class _FileLint:
    """One file's AST pass; collects findings through the pragma filter."""

    def __init__(self, src: str, filename: str, report: AnalysisReport):
        self.src = src
        self.filename = filename
        self.report = report
        self.file_allow, self.line_allow = _parse_pragmas(src)

    def add(self, rule: str, severity: str, line: int, message: str,
            detail: str = "") -> None:
        if rule in self.file_allow or rule in self.line_allow.get(line, ()):
            return
        self.report.add(rule, severity, f"{self.filename}:{line}", message,
                        detail)

    # -- per-function rules -------------------------------------------------

    def _segment(self, node: ast.AST) -> str:
        return ast.get_source_segment(self.src, node) or ""

    def check_function(self, fn: ast.FunctionDef) -> None:
        device_compute = False
        host_sites: List = []  # (line, label, needs_device_compute)
        jnp_names: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                name = _dotted(node.func)
                if name.startswith(_DEVICE_PREFIXES) or \
                        "segment_sum" in name or "pallas_call" in name:
                    device_compute = True
                if name in _HOST_CALLS:
                    host_sites.append((node.lineno, name, False))
                elif name in ("float", "int") and node.args:
                    seg = self._segment(node.args[0])
                    if "jnp." in seg or "jax." in seg:
                        host_sites.append(
                            (node.lineno, f"{name}({seg[:40]}...)", False))
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr in ("item", "tolist"):
                    host_sites.append(
                        (node.lineno, f".{node.func.attr}()", True))
                elif name in ("np.asarray", "numpy.asarray") and node.args:
                    seg = self._segment(node.args[0])
                    if "device_get" in seg:
                        host_sites.append((node.lineno, name, False))
            elif isinstance(node, ast.Assign) and \
                    len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name) and \
                    isinstance(node.value, ast.Call):
                vname = _dotted(node.value.func)
                if vname.startswith(("jnp.", "jax.")):
                    jnp_names.add(node.targets[0].id)
        for line, label, needs_dc in host_sites:
            if needs_dc and not device_compute:
                continue
            self.add("host-in-trace", "error", line,
                     f"host materialization {label} in a traced/compute "
                     "scope",
                     f"in function {fn.name!r}; {_remediation()}")
        self._check_tracer_branch(fn, jnp_names)

    def _check_tracer_branch(self, fn: ast.FunctionDef,
                             jnp_names: Set[str]) -> None:
        def suspect(test: ast.AST) -> Optional[str]:
            if isinstance(test, ast.Name) and test.id in jnp_names:
                return test.id
            if isinstance(test, ast.Compare):
                if any(isinstance(op, (ast.Is, ast.IsNot))
                       for op in test.ops):
                    return None
                if isinstance(test.left, ast.Name) and \
                        test.left.id in jnp_names:
                    return test.left.id
            if isinstance(test, ast.UnaryOp) and \
                    isinstance(test.op, ast.Not):
                return suspect(test.operand)
            if isinstance(test, ast.BoolOp):
                for v in test.values:
                    s = suspect(v)
                    if s:
                        return s
            return None

        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                name = suspect(node.test)
                if name:
                    self.add("tracer-branch", "warning", node.lineno,
                             f"Python branch on {name!r}, a value produced "
                             "by a jnp/jax call",
                             "retrace / ConcretizationTypeError hazard "
                             f"in {fn.name!r}")

    # -- whole-tree rules ---------------------------------------------------

    def check_broadcast_div(self, tree: ast.AST) -> None:
        def is_expand(node: ast.AST) -> bool:
            # matches  expr[..., None]  /  expr[:, None]
            if not isinstance(node, ast.Subscript):
                return False
            sl = node.slice
            if isinstance(sl, ast.Tuple):
                return any(isinstance(e, ast.Constant) and e.value is None
                           for e in sl.elts)
            return False

        for node in ast.walk(tree):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div) \
                    and is_expand(node.right) \
                    and not isinstance(node.left, ast.Constant):
                self.add("broadcast-div", "error", node.lineno,
                         "broadcast division by a [..., None] operand",
                         "precompute the (V, 1) reciprocal and multiply "
                         "(the PR 5 bitwise rule)")

    def check_pallas(self, tree: ast.AST) -> None:
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func)
            if name.endswith(("VMEM", "SMEM")) and len(node.args) >= 2:
                dt = node.args[1]
                if not (isinstance(dt, ast.Name) and dt.id == "acc_dtype"):
                    self.add("acc-dtype", "error", node.lineno,
                             f"Pallas scratch dtype is a literal "
                             f"({self._segment(dt)[:40]}), not the "
                             "threaded acc_dtype",
                             "reduced-dtype plans would silently keep "
                             "this accumulator pinned")
            if name.endswith("pallas_call"):
                self._check_grid_arity(node)

    def _check_grid_arity(self, call: ast.Call) -> None:
        grid_len = None
        for kw in call.keywords:
            if kw.arg == "grid" and isinstance(kw.value, ast.Tuple):
                grid_len = len(kw.value.elts)
        if grid_len is None:
            return  # grid is dynamic/expr -- not statically provable
        for node in ast.walk(call):
            if isinstance(node, ast.Call) and \
                    _dotted(node.func).endswith("BlockSpec"):
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    if isinstance(arg, ast.Lambda):
                        arity = len(arg.args.args)
                        if arity != grid_len:
                            self.add("grid-arity", "error", node.lineno,
                                     f"BlockSpec index_map takes {arity} "
                                     f"arg(s) but grid has {grid_len} "
                                     "dimension(s)",
                                     "block/grid specs statically "
                                     "incompatible")

    def run(self) -> None:
        tree = ast.parse(self.src, filename=self.filename)
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.check_function(node)
        self.check_broadcast_div(tree)
        self.check_pallas(tree)


def lint_source(src: str, filename: str = "<string>",
                report: Optional[AnalysisReport] = None) -> AnalysisReport:
    """Run every AST rule over one source string; returns the report.

    Suppression pragma comments are honored: ``# analysis: allow(rule)``
    covers its own line and the next, ``# analysis: allow-file(rule)``
    the whole file.  Used directly by the self-test plants so a seeded
    violation travels the same detection path as shipped source.
    """
    report = report if report is not None else AnalysisReport()
    _FileLint(src, filename, report).run()
    return report


def lint_file(path, report: Optional[AnalysisReport] = None
              ) -> AnalysisReport:
    """Lint one ``.py`` file from disk (path shown in findings)."""
    p = Path(path)
    return lint_source(p.read_text(), str(p), report)


def lint_tree(root, report: Optional[AnalysisReport] = None
              ) -> AnalysisReport:
    """Lint every ``*.py`` under ``root`` (the shipped-tree gate:
    ``scripts/analyze.py`` points this at ``src/repro/``)."""
    report = report if report is not None else AnalysisReport()
    for p in sorted(Path(root).rglob("*.py")):
        lint_file(p, report)
    return report
