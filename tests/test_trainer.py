"""Trainer fault tolerance: checkpoint-resume, failure injection, watchdog."""

import dataclasses
import shutil
import tempfile

import jax
import numpy as np
import pytest

from repro.config import OptimizerConfig, ShapeSpec, TrainConfig
from repro.configs import granite_3_8b
from repro.data.pipeline import TokenPipeline
from repro.launch.steps import make_train_step
from repro.models.transformer import init_lm
from repro.optim.optimizer import make_train_state
from repro.train.trainer import FailureInjector, StepWatchdog, Trainer


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(granite_3_8b.reduced(), dtype="float32")
    shape = ShapeSpec("tiny", 16, 4, "train")
    opt = OptimizerConfig(warmup_steps=2, total_steps=20)
    step_fn = jax.jit(make_train_step(cfg, opt))
    make_state = lambda: make_train_state(  # noqa: E731
        init_lm(cfg, jax.random.PRNGKey(0)), opt)
    return cfg, shape, opt, step_fn, make_state


def _trainer(setup, tdir, steps=10, fail_at=(), ckpt_every=3):
    cfg, shape, opt, step_fn, make_state = setup
    tc = TrainConfig(model=cfg.name, steps=steps, checkpoint_every=ckpt_every,
                     log_every=100, checkpoint_dir=tdir, optimizer=opt)
    return Trainer(tc, make_state=make_state, step_fn=step_fn,
                   pipeline=TokenPipeline(cfg, shape, seed=1),
                   failure_injector=FailureInjector(fail_at=fail_at))


def test_recovery_bitwise_equals_clean_run(setup):
    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        res_f = _trainer(setup, d1, fail_at=(5,)).run()
        res_c = _trainer(setup, d2).run()
        assert res_f["recoveries"] == 1
        l1 = float(np.asarray(res_f["metrics"]["loss"]))
        l2 = float(np.asarray(res_c["metrics"]["loss"]))
        assert l1 == l2, "recovered run must be bitwise-resumable"
    finally:
        shutil.rmtree(d1)
        shutil.rmtree(d2)


def test_multiple_failures(setup):
    d = tempfile.mkdtemp()
    try:
        res = _trainer(setup, d, fail_at=(2, 7)).run()
        assert res["recoveries"] == 2
    finally:
        shutil.rmtree(d)


def test_resume_from_kill(setup):
    """Simulate a process kill: run 6 steps, then a fresh Trainer resumes."""
    d = tempfile.mkdtemp()
    try:
        t1 = _trainer(setup, d, steps=6, ckpt_every=2)
        t1.run()
        t2 = _trainer(setup, d, steps=10, ckpt_every=2)
        res = t2.run()
        # fresh full run for comparison
        d2 = tempfile.mkdtemp()
        res_c = _trainer(setup, d2, steps=10, ckpt_every=2).run()
        assert float(np.asarray(res["metrics"]["loss"])) == \
            float(np.asarray(res_c["metrics"]["loss"]))
        shutil.rmtree(d2)
    finally:
        shutil.rmtree(d)


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(factor=2.0, max_straggler_steps=3)
    restart = False
    for i in range(10):
        restart = wd.observe(i, 0.1)
    assert not restart and wd.straggler_steps == []
    for i in range(10, 13):
        restart = wd.observe(i, 1.0)  # 10x slower
    assert restart
    assert len(wd.straggler_steps) == 3
