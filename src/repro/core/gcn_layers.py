"""Graph-convolution layers built on the phase primitives (paper Table 1).

  * GCNConv  -- mean({N(v)} ∪ {v}) ∘ Linear(|h|->d)      [combine-first legal]
  * SAGEConv -- same propagation rule as GCN (paper §2)   [combine-first legal]
  * GINConv  -- MLP(sum({N(v)} ∪ {v})), MLP = |h|->d->d   [aggregate-first only]

Parameters are plain pytrees (dicts) -- the framework is functional.
Each layer exposes ``apply(params, graph, x)`` plus ``init`` and a static
``cost(graph, in_len)`` used by the scheduler and benchmarks.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import phases
from repro.core.dataflow import BlockedGraph, fused_gcn_layer
from repro.core.scheduler import (AGGREGATE_FIRST, COMBINE_FIRST,
                                  choose_ordering)
from repro.graph.structure import Graph


def _dense_init(key, din, dout, scale=None):
    scale = scale if scale is not None else (2.0 / din) ** 0.5
    return {"w": jax.random.normal(key, (din, dout), jnp.float32) * scale,
            "b": jnp.zeros((dout,), jnp.float32)}


class GCNConv:
    """Paper Eq. 1 with mean aggregation over {N(v)} ∪ {v}."""

    def __init__(self, din: int, dout: int, ordering: str = "auto",
                 impl: str = "xla"):
        self.din, self.dout = din, dout
        self.ordering = ordering
        self.impl = impl

    def init(self, key) -> Dict:
        return {"lin": _dense_init(key, self.din, self.dout)}

    def resolve_order(self, g: Graph) -> str:
        if self.ordering in (COMBINE_FIRST, AGGREGATE_FIRST):
            return self.ordering
        return choose_ordering(g, self.din, self.dout, agg_op="mean",
                               n_mlp_layers=1, semantic_order=COMBINE_FIRST)

    def apply(self, params, g: Graph, x, *, order: Optional[str] = None,
              blocked: Optional[BlockedGraph] = None):
        order = order or self.resolve_order(g)
        w, b = params["lin"]["w"], params["lin"]["b"]
        if blocked is not None:  # fused dataflow path (F5)
            return fused_gcn_layer(blocked, x, w, b, agg_op="mean",
                                   in_deg=g.in_deg, impl=self.impl)
        if order == COMBINE_FIRST:
            h = x @ w
            h = phases.aggregate(g, h, op="mean", impl=self.impl)
        else:
            h = phases.aggregate(g, x, op="mean", impl=self.impl)
            h = h @ w
        return h + b


class SAGEConv(GCNConv):
    """GraphSAGE-mean: identical per-layer rule (paper §2); differs upstream
    by mini-batch 2-hop sampling (graph/sampling.py)."""


class GINConv:
    """GIN-0 (paper Eq. 2): MLP(sum over {N(v)} ∪ {v}); MLP has an interior
    ReLU so the ordering is pinned to aggregate_first (scheduler enforces)."""

    def __init__(self, din: int, dout: int, hidden: Optional[int] = None,
                 impl: str = "xla"):
        self.din, self.dout = din, dout
        self.hidden = hidden or dout
        self.impl = impl
        self.ordering = AGGREGATE_FIRST

    def init(self, key) -> Dict:
        k1, k2 = jax.random.split(key)
        return {"mlp1": _dense_init(k1, self.din, self.hidden),
                "mlp2": _dense_init(k2, self.hidden, self.dout)}

    def resolve_order(self, g: Graph) -> str:
        return AGGREGATE_FIRST

    def apply(self, params, g: Graph, x, *, order: Optional[str] = None,
              blocked=None):
        h = phases.aggregate(g, x, op="sum", include_self=True, impl=self.impl)
        h = h @ params["mlp1"]["w"] + params["mlp1"]["b"]
        h = jax.nn.relu(h)
        return h @ params["mlp2"]["w"] + params["mlp2"]["b"]


CONVS = {"gcn": GCNConv, "sage": SAGEConv, "gin": GINConv}
