"""arctic-480b -- 128 experts top-2 + dense residual.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
[hf:Snowflake/snowflake-arctic-base; hf]

Arctic's dense-MoE hybrid: a dense FFN residual branch runs in parallel with
the routed experts on every layer.
"""

import dataclasses

from repro.config import AttentionConfig, LMConfig, MoEConfig, register


def _base() -> LMConfig:
    return LMConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        d_ff=4864,
        vocab_size=32000,
        attention=AttentionConfig(num_heads=56, num_kv_heads=8, head_dim=128),
        moe=MoEConfig(num_experts=128, top_k=2, expert_d_ff=4864,
                      dense_residual=True, dense_residual_d_ff=4864,
                      capacity_factor=1.25),
        mlp_activation="swiglu",
        shape_skips=("long_500k",),
        skip_reason="pure full attention; 500k decode needs sub-quadratic",
        source="hf:Snowflake/snowflake-arctic-base",
    )


@register("arctic-480b")
def config() -> LMConfig:
    return _base()


def reduced() -> LMConfig:
    c = _base()
    return dataclasses.replace(
        c, name=c.name + "-smoke", num_layers=2, d_model=64, d_ff=48,
        vocab_size=256,
        attention=dataclasses.replace(c.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=16),
        moe=dataclasses.replace(c.moe, num_experts=4, top_k=2,
                                expert_d_ff=48, dense_residual_d_ff=48))
