"""Distributed GCN execution: vertex-partitioned aggregation via shard_map.

The paper profiles a single GPU; this module is the cluster-scale story its
Table 4 implies (DESIGN.md §8.5): with a 1-D destination partition the
Aggregation phase's remote traffic is one feature row per cut edge, so
running Combination first shrinks the COLLECTIVE term by in_len/out_len --
the multi-chip restatement of the paper's 4.7x.

Two interchangeable aggregation strategies (both exact):

  * ``allgather``  -- one all-gather of the full feature matrix per layer,
    then purely local gather+segment-reduce.  Simple; wire bytes V*F.
  * ``ring``       -- P-1 ``collective_permute`` steps around the data-axis
    ring; at each step every device reduces the contributions of the block
    it currently holds while the next block is in flight.  Same total wire
    bytes, but O(V/P * F) resident and compute/comm OVERLAPPED -- the
    distributed-optimization trick the brief asks for, expressed in
    jax-native collectives.

Both run under shard_map on the ``data`` axis; per-shard edge lists come
from graph.partition (edge-balanced, padded static shapes).

**2-D (node x feature) partitioning** (``distributed_gcn_layer_2d``)
generalizes the same halo patterns to a multi-host mesh: device (p, q) owns
node block p restricted to feature columns q, the ring/all-gather halo runs
along the *node* axis on rows that are only F/Q wide (per-device halo bytes
/ Q), and the Combination GEMM is a feature-parallel partial matmul closed
with one reduce-scatter (``psum_scatter``) over the *feature* axis.  The
intended placement is node
axis across hosts (the expensive, DCN-crossing halo shrinks by Q) and
feature axis across the fast intra-host links (the reduce-scatter stays
local).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.graph.partition import Partition2D, PartitionedGraph


def pad_features(x: jnp.ndarray, block: int, num_shards: int) -> jnp.ndarray:
    """Pad vertex features to num_shards*block rows (partition layout)."""
    total = block * num_shards
    v = x.shape[0]
    return jnp.pad(x, ((0, total - v), (0, 0)))


def _require_uniform(pg: PartitionedGraph) -> None:
    """The shard_map strategies lay out rows as p*block + local; that needs
    the UNIFORM partition (partition_1d(..., edge_balanced=False)).  The
    edge-balanced variant feeds the analytic load model instead."""
    starts = np.asarray(pg.vtx_start)
    expect = np.arange(pg.num_shards) * pg.block_size
    expect = np.minimum(expect, pg.num_vertices)
    if not np.array_equal(starts, expect):
        raise ValueError(
            "distributed aggregation requires a uniform partition; build "
            "with partition_1d(g, P, edge_balanced=False)")


def _local_agg(x_full, src, dst_local, mask, block):
    rows = jnp.take(x_full, src, axis=0) * mask[:, None]
    return jax.ops.segment_sum(rows, dst_local, num_segments=block)


def _allgather_local(x_loc, srcl, dstl, mskl, block, nsh, axis):
    """Per-device all-gather halo body (inside shard_map, over ``axis``)."""
    del nsh
    x_full = jax.lax.all_gather(x_loc, axis, tiled=True)
    return _local_agg(x_full, srcl, dstl, mskl, block)


def _ring_local(x_loc, srcl, dstl, mskl, block, nsh, axis):
    """Per-device ring halo body: nsh hops of collective_permute over
    ``axis``, reducing the currently-held block's contributions each hop.

    Device p holds block b_k = (p - k) mod P at hop k; the permute of hop
    k+1 can overlap the reduce of hop k on real hardware (async start).
    Shared by the 1-D path (axis = the single data axis) and the 2-D path
    (axis = the node axis of the mesh; feature columns ride along).
    """
    p = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % nsh) for i in range(nsh)]  # ring

    def hop(carry, k):
        buf, acc = carry
        # ring sends i -> i+1, so after k hops we hold block (p - k)
        owner = jnp.mod(p - k, nsh)               # whose block we hold
        sel = (srcl // block) == owner
        local_src = srcl - owner * block
        rows = jnp.take(buf, jnp.clip(local_src, 0, block - 1), axis=0)
        rows = rows * (mskl * sel)[:, None]
        acc = acc + jax.ops.segment_sum(rows, dstl, num_segments=block)
        buf = jax.lax.ppermute(buf, axis, perm)   # pass block onward
        return (buf, acc), None

    acc0 = jnp.zeros((block, x_loc.shape[-1]), x_loc.dtype)
    (_, acc), _ = jax.lax.scan(hop, (x_loc, acc0), jnp.arange(nsh))
    return acc


_STRATEGIES = {"ring": _ring_local, "allgather": _allgather_local}


def aggregate_allgather(pg: PartitionedGraph, x: jnp.ndarray, mesh: Mesh,
                        axis: str = "data") -> jnp.ndarray:
    """x: (P*block, F) sharded over `axis` -> aggregated (P*block, F)."""
    _require_uniform(pg)
    block = pg.block_size

    def fn(x_local, src, dst_local, mask, starts):
        out = _allgather_local(x_local[0], src[0], dst_local[0], mask[0],
                               block, pg.num_shards, axis)
        return out[None]

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P(axis, None),
                  P(axis)),
        out_specs=P(axis, None), check_rep=False,
    )(x.reshape(pg.num_shards, -1, x.shape[-1]), pg.src, pg.dst_local,
      pg.mask, pg.vtx_start).reshape(x.shape[0], x.shape[-1])


def aggregate_ring(pg: PartitionedGraph, x: jnp.ndarray, mesh: Mesh,
                   axis: str = "data") -> jnp.ndarray:
    """Ring halo exchange: P-1 collective_permutes, partial reduce per hop
    (see ``_ring_local``)."""
    _require_uniform(pg)
    block = pg.block_size
    nsh = pg.num_shards

    def fn(x_local, src, dst_local, mask):
        out = _ring_local(x_local[0], src[0], dst_local[0], mask[0],
                          block, nsh, axis)
        return out[None]

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P(axis, None)),
        out_specs=P(axis, None), check_rep=False,
    )(x.reshape(nsh, -1, x.shape[-1]), pg.src, pg.dst_local,
      pg.mask).reshape(x.shape[0], x.shape[-1])


def halo_bytes(pg: PartitionedGraph, feature_len: int,
               dtype_bytes: int = 4) -> dict:
    """Analytic collective cost of one distributed Aggregation (both strats).

    Reported by bench_ordering to show the combine-first collective saving.
    """
    v_padded = pg.block_size * pg.num_shards
    per_device = v_padded * feature_len * dtype_bytes * \
        (pg.num_shards - 1) / pg.num_shards
    # cut edges: sources not owned by the destination shard
    src = np.asarray(pg.src)
    starts = np.asarray(pg.vtx_start)
    owners = np.clip(np.searchsorted(starts, src, side="right") - 1, 0,
                     pg.num_shards - 1)
    mine = owners == np.arange(pg.num_shards)[:, None]
    cut_edges = int((np.asarray(pg.mask) * ~mine).sum())
    return {
        "allgather_bytes_per_device": per_device,
        "ring_bytes_per_device": per_device,  # same total, overlapped
        "cut_edges": cut_edges,
        "min_halo_bytes": cut_edges * feature_len * dtype_bytes,
    }


def _local_graph_view(pg: PartitionedGraph):
    """Minimal |V|/|E| stats view for the scheduler's analytic cost model."""
    import types
    return types.SimpleNamespace(
        num_vertices=pg.num_vertices,
        num_edges=int(np.asarray(pg.mask).sum()))


def distributed_gcn_layer(pg: PartitionedGraph, x, w, bias, in_deg,
                          mesh: Mesh, *, order: Optional[str] = None,
                          strategy: str = "ring", axis: str = "data"):
    """One distributed GCN layer with explicit phase ordering (Table 4).

    combine_first: project locally (embarrassingly parallel GEMM), then
    aggregate projected rows -- halo moves out_len-wide rows.
    aggregate_first: aggregate raw features (halo moves in_len-wide rows),
    then project.  ``order=None`` asks the scheduler's cost model (which at
    cluster scale also prices the collective term -- same in/out ratio).

    This is the shard_map primitive; model-level code reaches it through a
    ``GraphExecutionPlan`` built with ``mesh=``/``num_shards=`` (core/plan.py)
    rather than calling it with hand-threaded flags.
    """
    if order is None:
        from repro.core.scheduler import choose_ordering
        order = choose_ordering(
            _local_graph_view(pg), int(w.shape[0]), int(w.shape[1]),
            agg_op="mean", n_mlp_layers=1)
    agg = aggregate_ring if strategy == "ring" else aggregate_allgather
    deg = jnp.maximum(in_deg.astype(x.dtype) + 1.0, 1.0)[:, None]
    deg = pad_features(deg, pg.block_size, pg.num_shards)
    # reciprocal-multiply normalization (not broadcast division) so the
    # jitted plan.compile() path stays bit-for-bit equal to eager dispatch
    rdeg = 1.0 / jnp.where(deg == 0, 1.0, deg)
    if order == "combine_first":
        h = x @ w
        out = (agg(pg, h, mesh, axis) + h) * rdeg
    else:
        out = ((agg(pg, x, mesh, axis) + x) * rdeg) @ w
    return out + bias


# ---------------------------------------------------------------------------
# 2-D (node x feature) partitioned execution
# ---------------------------------------------------------------------------


def pad_features_2d(x: jnp.ndarray, p2: Partition2D) -> jnp.ndarray:
    """Pad (V, F) features to the (P*block, Q*fblock) partition layout."""
    fb = p2.feature_block(x.shape[1])
    rows = p2.block_size * p2.node_shards - x.shape[0]
    cols = fb * p2.feat_shards - x.shape[1]
    return jnp.pad(x, ((0, rows), (0, cols)))


def distributed_gcn_layer_2d(p2: Partition2D, x, w, bias, in_deg,
                             mesh: Mesh, *, order: Optional[str] = None,
                             strategy: str = "ring",
                             axes=("node", "feat")):
    """One GCN layer on a 2-D (node x feature) device mesh (exact).

    Device (p, q) owns node block p's rows restricted to feature block q.
    Per ordering:

    combine_first: partial GEMM with the device's W row-block, closed by a
    reduce-scatter over the feature axis (fast intra-host links, each device
    receiving its own output column block), then the ring/all-gather halo along the node axis moves
    rows only ``F_out/Q`` wide -- the per-device halo bytes of the 1-D
    partition divided by Q *on top of* Table 4's in/out ratio saving.

    aggregate_first: halo first on the raw ``F_in/Q``-wide column slice
    (purely feature-parallel -- each feature shard's halo is independent),
    then the same partial-GEMM + reduce-scatter.

    Args mirror :func:`distributed_gcn_layer`; ``x`` must be in the padded
    ``(P*block, Q*fblock_in)`` layout (see :func:`pad_features_2d`) and the
    result is ``(P*block, Q*fblock_out)`` -- pad columns are exact zeros.
    ``axes`` names the (node, feature) mesh axes; ``order=None`` asks the
    scheduler's cost model.  Model-level code reaches this through a
    ``GraphExecutionPlan`` built with a 2-D ``mesh=`` (core/plan.py).
    """
    pg = p2.nodes
    _require_uniform(pg)
    node_ax, feat_ax = axes
    nsh, q_sh = pg.num_shards, p2.feat_shards
    block = pg.block_size
    f_in, f_out = int(w.shape[0]), int(w.shape[1])
    fb_in, fb_out = p2.feature_block(f_in), p2.feature_block(f_out)
    if order is None:
        from repro.core.scheduler import choose_ordering
        order = choose_ordering(_local_graph_view(pg), f_in, f_out,
                                agg_op="mean", n_mlp_layers=1)
    local = _STRATEGIES[strategy]

    # zero-pad W/bias onto the (Q*fb_in, Q*fb_out) grid: pad x columns hit
    # zero W rows, pad W columns produce zero outputs -- exactness is free
    wp = jnp.zeros((q_sh * fb_in, q_sh * fb_out), w.dtype)
    wp = wp.at[:f_in, :f_out].set(w)
    bp = jnp.zeros((q_sh * fb_out,), w.dtype).at[:f_out].set(bias)

    deg = jnp.maximum(in_deg.astype(x.dtype) + 1.0, 1.0)[:, None]
    deg = pad_features(deg, block, nsh)
    # reciprocal of the (rows, 1) degree column: multiplied, never divided
    # (bitwise eager/compiled equality -- see distributed_gcn_layer)
    rdeg = 1.0 / jnp.where(deg == 0, 1.0, deg)

    expect = (nsh * block, q_sh * fb_in)
    if x.shape != expect:
        raise ValueError(f"x must be in the padded 2-D layout {expect}, "
                         f"got {tuple(x.shape)} (see pad_features_2d)")

    def fn(x_blk, src, dstl, msk, rdeg_blk, wp_, bp_):
        x_loc = x_blk.reshape(block, fb_in)
        srcl, dl, ml = src[0], dstl[0], msk[0]
        rdg = rdeg_blk[0]
        qi = jax.lax.axis_index(feat_ax)

        def w_block(fb):
            return jax.lax.dynamic_slice(wp_, (qi * fb, 0),
                                         (fb, q_sh * fb_out))

        def combine(h):
            # partial GEMM closed with a reduce-scatter over the feature
            # axis: each device receives only its own (block, fb_out)
            # column slice -- 1/Q the wire bytes of psum + local slice
            return jax.lax.psum_scatter(h @ w_block(fb_in), feat_ax,
                                        scatter_dimension=1, tiled=True)

        if order == "combine_first":
            hq = combine(x_loc)                          # (block, fb_out)
            out = (local(hq, srcl, dl, ml, block, nsh, node_ax) + hq) * rdg
        else:
            agg = local(x_loc, srcl, dl, ml, block, nsh, node_ax)
            out = combine((agg + x_loc) * rdg)
        out = out + jax.lax.dynamic_slice(bp_, (qi * fb_out,), (fb_out,))
        return out.reshape(1, block, 1, fb_out)

    out = shard_map(
        fn, mesh=mesh,
        in_specs=(P(node_ax, None, feat_ax, None), P(node_ax, None),
                  P(node_ax, None), P(node_ax, None), P(node_ax, None, None),
                  P(None, None), P(None)),
        out_specs=P(node_ax, None, feat_ax, None), check_rep=False,
    )(x.reshape(nsh, block, q_sh, fb_in), pg.src, pg.dst_local, pg.mask,
      rdeg.reshape(nsh, block, 1), wp, bp)
    return out.reshape(nsh * block, q_sh * fb_out)


def halo_bytes_2d(p2: Partition2D, feature_len: int,
                  dtype_bytes: int = 4) -> dict:
    """Analytic per-device halo cost of the 2-D partition: the 1-D numbers
    evaluated at the F/Q column slice each device actually exchanges."""
    out = halo_bytes(p2.nodes, p2.feature_block(feature_len), dtype_bytes)
    out["feat_shards"] = p2.feat_shards
    return out
