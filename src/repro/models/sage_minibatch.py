"""GraphSAGE mini-batch training (paper §2: "GraphSAGE only updates a batch
of vertexes along with their 2-hop neighbors in an iteration").

Two training paths:

  * ``SageMiniBatchModel`` / ``train_minibatch_sage`` -- the per-block demo:
    each sampled block gets its own ``GraphExecutionPlan`` (built/cached per
    block graph by core/plan.py), showing the planner re-deciding the
    ordering per block (the Table-4 decision depends on |E|/|V|, which
    sampling changes).
  * ``PlannedSageTrainer`` / ``train_minibatch_planned`` -- the production
    loop: ONE worst-case shape bucket, ONE cached bucket plan, ONE jitted
    train step.  Every ``data.pipeline.GraphPipeline`` block is padded into
    the bucket (sink no-ops, exactness contract of
    ``serve.graph_engine._pad_into``) and dispatched with the graph -- and,
    on ``dedup="pairs"`` plans, the block's two-level pair layout
    (graph/dedup.py) -- as RUNTIME arrays: zero retraces after step 1, and
    checkpoint-resume is exact because the pipeline state IS the step
    counter (every batch is a pure function of (seed, step)).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import GCNModelConfig, GraphSpec
from repro.core.gcn_layers import SAGEConv
from repro.core.plan import plan_for_conv
from repro.graph.sampling import SampledBlock
from repro.graph.structure import Graph, graph_from_coo


class SageMiniBatchModel:
    def __init__(self, in_dim: int, hidden: int, num_classes: int):
        self.layer1 = SAGEConv(in_dim, hidden, ordering="auto")
        self.layer2 = SAGEConv(hidden, num_classes, ordering="auto")

    def init(self, key) -> Dict:
        k1, k2 = jax.random.split(key)
        return {"l1": self.layer1.init(k1), "l2": self.layer2.init(k2)}

    def apply(self, params, hop2: SampledBlock, hop1: SampledBlock,
              x_inputs: jnp.ndarray) -> jnp.ndarray:
        """x_inputs: features of hop2.input_ids (the full required frontier).

        Returns logits for hop1.seed_ids (the mini-batch seeds).
        """
        p1 = plan_for_conv(self.layer1, hop2.graph)
        p2 = plan_for_conv(self.layer2, hop1.graph)
        h = self.layer1.apply(params["l1"], hop2.graph, x_inputs, plan=p1)
        h = jax.nn.relu(h)
        # hop1's input vertices are a prefix-compatible subset: map rows
        h1_inputs = h[_index_of(hop2.input_ids, hop1.input_ids)]
        out = self.layer2.apply(params["l2"], hop1.graph, h1_inputs, plan=p2)
        return out[: len(hop1.seed_ids)]

    def loss(self, params, hop2, hop1, x_inputs, labels) -> jnp.ndarray:
        logits = self.apply(params, hop2, hop1, x_inputs)
        ll = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(ll, labels[:, None], axis=-1).mean()

    def orderings(self, hop2: SampledBlock, hop1: SampledBlock
                  ) -> Tuple[str, str]:
        return (self.layer1.resolve_order(hop2.graph),
                self.layer2.resolve_order(hop1.graph))


def _index_of(haystack: np.ndarray, needles: np.ndarray) -> np.ndarray:
    """Positions of `needles` inside sorted unique `haystack`."""
    haystack = np.asarray(haystack)
    needles = np.asarray(needles)
    pos = np.searchsorted(haystack, needles)
    assert (haystack[pos] == needles).all(), "frontier must cover hop-1"
    return pos


def train_minibatch_sage(graph, spec: GraphSpec, features, labels, *,
                         steps: int = 20, batch_size: int = 32,
                         fanouts=(5, 5), lr: float = 0.1, seed: int = 0):
    """Host-side mini-batch loop (sampling is pipeline work, not jit)."""
    from repro.graph.sampling import two_hop_batch
    rng = np.random.default_rng(seed)
    model = SageMiniBatchModel(spec.feature_len, 128, spec.num_classes)
    params = model.init(jax.random.PRNGKey(seed))
    feats = np.asarray(features)
    labs = np.asarray(labels)
    losses = []
    for step in range(steps):
        seeds = rng.choice(spec.num_vertices, size=batch_size,
                           replace=False).astype(np.int32)
        hop2, hop1 = two_hop_batch(graph, seeds, fanouts,
                                   seed=seed * 1000 + step)
        x_in = jnp.asarray(feats[hop2.input_ids])
        y = jnp.asarray(labs[hop1.seed_ids])
        loss, grads = jax.value_and_grad(model.loss)(params, hop2, hop1,
                                                     x_in, y)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        losses.append(float(loss))
    return params, losses, model


# ---------------------------------------------------------------------------
# Bucketed, compiled, dedup-aware mini-batch training (the production loop)
# ---------------------------------------------------------------------------


def _bucket_template_graph(n: int, e: int, paired: bool) -> Graph:
    """Deterministic template with a bucket's static shapes.

    Edge CONTENT is replaced per call by the dynamic compiled plan; only
    the shapes (and the plan cost model's |V|, |E|) matter.  ``paired``
    plants one guaranteed matched leading pair (destinations 0 and 1 both
    drawing from sources {0, 1}) so ``build_plan(dedup="pairs")`` does not
    coerce to "none" on the template -- the pair CAPACITY the compiled
    callable actually carries comes from ``dedup_pad``, not from the
    template's own matches.  Filler edges are per-destination self-loops
    (unique ``(d, d)`` candidate keys, frequency 1 -> never matched).
    """
    if not paired:
        idx = np.arange(e, dtype=np.int32) % n
        return graph_from_coo(idx, idx, n)
    assert n >= 4 and e >= 4, "bucket too small for a paired template"
    fill = np.arange(e - 4, dtype=np.int32) % (n - 2) + 2
    src = np.concatenate([np.array([0, 1, 0, 1], np.int32), fill])
    dst = np.concatenate([np.array([0, 0, 1, 1], np.int32), fill])
    return graph_from_coo(src, dst, n)


class PlannedSageTrainer:
    """Steady-state mini-batch training through ONE bucketed compiled plan.

    Setup (once): size the worst-case bucket for (batch_size, fanouts)
    (``serve.graph_engine.default_buckets`` closed form), resolve the
    ``dedup`` decision -- ``"auto"`` prices the step-0 block's measured
    pair stats at the bucket's shapes via ``profile.machine.choose_dedup``
    -- and build the bucket plan (``build_plan(..., dedup=, dedup_pad=)``)
    plus its compiled forward (``plan.compile(dynamic=True, donate=)``)
    and ONE jitted SGD train step that differentiates through the plan's
    trace-pure dispatch.

    Per step (hot loop, no planning): ``GraphPipeline.batch_at(step)``
    samples the block (pure function of (seed, step) -- deterministic
    resume for free), the union block is padded into the bucket with sink
    no-ops, the block's two-level dedup layout is matched on the host and
    padded to the plan's static capacities (``pad_dedup_arrays``), and
    everything dispatches through the SAME compiled step.  The plan is
    re-fetched through ``build_plan`` each step -- a plan-cache HIT
    (``plan_cache_stats()``), never a rebuild -- and ``retraces`` stays 0
    after the first step.

    Exactness: the FORWARD (``predict``, and the loss each step computes)
    is bitwise-identical between ``dedup="pairs"`` and ``dedup="none"`` in
    f32 -- the leading-pair discipline of graph/dedup.py.  The BACKWARD
    pass regroups the aggregation adjoint's scatter the same way the
    forward regroups the fold, so gradients are mathematically equal but
    round differently in the last ulp; training trajectories across dedup
    modes therefore agree to f32 tolerance, not bit-for-bit
    (tests/test_dedup.py bands this with tests/tolerance.py).
    """

    def __init__(self, graph: Graph, spec: GraphSpec, features, labels, *,
                 hidden: int = 64, batch_size: int = 8,
                 fanouts: Tuple[int, int] = (3, 3), lr: float = 0.1,
                 seed: int = 0, dedup: str = "auto", donate: bool = False,
                 machine=None):
        from repro.data.pipeline import GraphPipeline
        from repro.serve.graph_engine import default_buckets

        self.graph, self.spec = graph, spec
        self.features = np.asarray(features, np.float32)
        self.labels = np.asarray(labels, np.int32)
        self.in_dim = int(self.features.shape[1])
        self.num_classes = int(spec.num_classes)
        self.lr = float(lr)
        self.pipeline = GraphPipeline(graph, spec, batch_size,
                                      fanouts=tuple(fanouts), seed=seed)
        self.bucket = default_buckets(
            tuple(fanouts), seed_levels=(batch_size,),
            max_inputs=graph.num_vertices)[0]
        self.cfg = GCNModelConfig(
            name=f"sage-mb-h{hidden}", conv="sage", aggregator="mean",
            hidden_dims=(int(hidden),), ordering="auto", num_layers=2)
        self.pair_cap = self.bucket.num_edges // 4  # >= any block's pairs
        self.dedup_requested = dedup
        if dedup == "auto":
            # price the decision on a REAL block's measured pair stats at
            # the bucket's static shapes (the template graph is synthetic,
            # so pricing it would characterize the wrong workload)
            from repro.profile.machine import choose_dedup, get_machine, \
                machine_for_backend
            lay0 = self._block_layout(
                self._prepare(self.pipeline.batch_at(0)))
            m = get_machine(machine) if machine is not None \
                else machine_for_backend("xla")
            dedup = choose_dedup(
                self.bucket.num_inputs, self.bucket.num_edges, self.in_dim,
                num_pairs=lay0.num_pairs, num_edges2=lay0.num_edges2,
                machine=m)
        self.dedup = dedup
        self._template = _bucket_template_graph(
            self.bucket.num_inputs, self.bucket.num_edges,
            paired=dedup == "pairs")
        self._plan_kwargs = dict(backend="xla", fused=False, machine=machine,
                                 dedup=dedup)
        if dedup == "pairs":
            self._plan_kwargs["dedup_pad"] = (self.pair_cap,
                                              self.bucket.num_edges)
        plan = self._plan()
        self.params = plan.init(jax.random.PRNGKey(seed))
        #: compiled inference forward over the same bucket (predict path)
        self.fwd = plan.compile(dynamic=True, donate=donate)
        self._traces = 0
        self._step_fn = jax.jit(self._make_step(plan))
        self.losses: list = []
        self.last_pairs = 0   # matched pairs of the most recent block

    # ------------------------------------------------------------- planning

    def _plan(self):
        """The bucket plan, through the global plan cache (steady-state
        steps re-resolve it here -- a cache HIT, never a rebuild)."""
        from repro.core.plan import build_plan
        return build_plan(self._template, self.cfg, self.in_dim,
                          self.num_classes, **self._plan_kwargs)

    def _make_step(self, plan):
        lr = self.lr

        def step_fn(params, x, src, dst, in_deg, seed_pos, y, *ded):
            self._traces += 1   # runs at TRACE time only

            def loss_fn(p):
                g2 = plan.g._replace(src=src, dst=dst, in_deg=in_deg,
                                     row_ptr=None)
                lay = None
                if ded:
                    pl, pr, s2, d2 = ded
                    lay = plan.dedup_layout._replace(
                        pair_left=pl, pair_right=pr, src2=s2, dst2=d2,
                        blocked=None)
                logits = plan.run_model(p, x, graph=g2, dedup_layout=lay)
                logits = jnp.take(logits, seed_pos, axis=0)
                ll = jax.nn.log_softmax(logits, axis=-1)
                return -jnp.take_along_axis(ll, y[:, None], axis=-1).mean()

            loss, grads = jax.value_and_grad(loss_fn)(params)
            new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return new, loss

        return step_fn

    @property
    def retraces(self) -> int:
        """Train-step traces beyond the expected first (0 = steady state)."""
        return max(0, self._traces - 1)

    # ---------------------------------------------------------- block prep

    def _prepare(self, batch) -> Dict[str, np.ndarray]:
        """Union the sampled hops and pad into the bucket's static shapes
        (sink no-ops: zero feature rows, sink self-loop edges, zero
        in-degrees -- the ``serve.graph_engine`` exactness contract)."""
        from repro.serve.graph_engine import union_two_hop
        frontier, ug, seed_pos = union_two_hop(batch["hop2"], batch["hop1"],
                                               batch["seeds"])
        b = self.bucket
        n, e = len(frontier), ug.num_edges
        assert b.fits(len(batch["seeds"]), n, e), \
            "sampled block exceeds its worst-case bucket"
        sink = b.num_inputs - 1
        pad_e = b.num_edges - e
        src = np.concatenate([np.asarray(ug.src, np.int32),
                              np.full(pad_e, sink, np.int32)])
        dst = np.concatenate([np.asarray(ug.dst, np.int32),
                              np.full(pad_e, sink, np.int32)])
        in_deg = np.zeros(b.num_inputs, np.int32)
        in_deg[:n] = np.asarray(ug.in_deg, np.int32)
        x = np.zeros((b.num_inputs, self.in_dim), np.float32)
        x[:n] = self.features[frontier]
        return {"x": x, "src": src, "dst": dst, "in_deg": in_deg,
                "seed_pos": np.asarray(seed_pos, np.int32),
                "y": self.labels[np.asarray(batch["seeds"])]}

    def _block_layout(self, prep):
        """Host-side pair matching over the PADDED block (so the virtual
        partial-row offsets agree with the bucket's vertex count)."""
        from repro.graph.dedup import build_dedup_layout
        return build_dedup_layout(prep["src"], prep["dst"],
                                  self.bucket.num_inputs)

    def _dedup_args(self, prep) -> tuple:
        if self.dedup != "pairs":
            return ()
        from repro.graph.dedup import pad_dedup_arrays
        lay = self._block_layout(prep)
        self.last_pairs = lay.num_pairs
        return tuple(jnp.asarray(a) for a in pad_dedup_arrays(
            lay, self.pair_cap, self.bucket.num_edges,
            self.bucket.num_inputs - 1))

    # ------------------------------------------------------------- training

    def step(self) -> float:
        """One SGD step on the pipeline's next block (hot loop)."""
        batch = self.pipeline.batch_at(self.pipeline.step)
        self.pipeline.step += 1
        prep = self._prepare(batch)
        self._plan()   # steady-state: plan-cache hit, the decision replays
        args = tuple(jnp.asarray(prep[k]) for k in
                     ("x", "src", "dst", "in_deg", "seed_pos", "y"))
        self.params, loss = self._step_fn(self.params, *args,
                                          *self._dedup_args(prep))
        self.losses.append(float(loss))
        return float(loss)

    def train(self, steps: int, *, checkpointer=None,
              checkpoint_every: int = 0) -> list:
        """Run ``steps`` more minibatch steps; returns the full loss list.

        With ``checkpointer`` and ``checkpoint_every=k``, saves every k
        pipeline steps (the deterministic-resume protocol: restoring any
        of those checkpoints and continuing reproduces this run's
        remaining loss stream and final params bitwise)."""
        for _ in range(int(steps)):
            self.step()
            if checkpointer is not None and checkpoint_every and \
                    self.pipeline.step % checkpoint_every == 0:
                self.save(checkpointer)
        return self.losses

    def predict(self, step: Optional[int] = None) -> np.ndarray:
        """Seed logits for the pipeline block at ``step`` (default: the
        next one) through the bucket's COMPILED forward
        (``plan.compile(dynamic=True, donate=)``)."""
        batch = self.pipeline.batch_at(
            self.pipeline.step if step is None else int(step))
        prep = self._prepare(batch)
        g2 = Graph(src=jnp.asarray(prep["src"]), dst=jnp.asarray(prep["dst"]),
                   in_deg=jnp.asarray(prep["in_deg"]),
                   out_deg=jnp.asarray(prep["in_deg"]),
                   num_vertices=self.bucket.num_inputs)
        ded = self._dedup_args(prep) or None
        out = self.fwd(self.params, jnp.asarray(prep["x"]), g2, dedup=ded)
        return np.asarray(out)[prep["seed_pos"]]

    # ---------------------------------------------------- checkpoint/resume

    def save(self, checkpointer, *, blocking: bool = True) -> None:
        """Snapshot (params, pipeline step, loss history) at the CURRENT
        pipeline step -- the step counter is the whole pipeline state."""
        checkpointer.save(self.pipeline.step, {"params": self.params},
                          extra={"pipeline": self.pipeline.state_dict(),
                                 "losses": list(self.losses)},
                          blocking=blocking)

    def restore(self, checkpointer, step: Optional[int] = None) -> int:
        """Resume from a checkpoint: restored params + pipeline counter
        regenerate the exact block stream a never-interrupted run sees
        (``batch_at`` is a pure function of (seed, step))."""
        state, at, extra = checkpointer.restore({"params": self.params},
                                                step=step)
        self.params = state["params"]
        self.pipeline.load_state_dict(extra["pipeline"])
        self.losses = list(extra.get("losses", []))
        return at


def train_minibatch_planned(graph, spec: GraphSpec, features, labels, *,
                            steps: int = 20, **kw):
    """Bucketed compiled mini-batch training; returns (params, losses,
    trainer).  See ``PlannedSageTrainer`` for the steady-state contract."""
    trainer = PlannedSageTrainer(graph, spec, features, labels, **kw)
    trainer.train(steps)
    return trainer.params, trainer.losses, trainer
