# The paper's primary contribution: phase-split execution of GCNs
# (Aggregation vs Combination), the phase-ordering scheduler (Table 4),
# tiled inter-phase dataflow (F5), and the characterization machinery.
from repro.core import characterize, dataflow, gcn_layers, phases, scheduler
