"""Paper Fig. 2 (a-e): Aggregation vs classic graph processing (PageRank).

Same graph, two workloads.  Architecture-neutral restatements:
  (a/b) spatial locality: contiguous bytes moved per gathered element
        (602-float rows vs 1 scalar) -> vector-width utilization;
  (c)   memory throttle: outstanding-request pressure ~ gathers per byte;
  (d/e) parallelism: independent work items per vertex (intra-vertex lanes);
  plus wall-clock of both on the same scaled graph.

Also exercises the degree-aware reorder guideline (paper §5.1-1): reuse
distance before/after degree_reorder.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core.phases import aggregate
from repro.graph.reorder import degree_reorder, reuse_distance_stats
from repro.models.pagerank import pagerank
from repro.profile.bench import BenchSpec, run_specs


def _locality(ctx, _):
    g, x = ctx.g, ctx.x
    t_agg = ctx.time(jax.jit(lambda xx: aggregate(g, xx, op="mean")), x)
    t_pgr = ctx.time(jax.jit(lambda: pagerank(g, iters=1)))

    ctx.emit("fig2/locality", 0.0,
             agg_contig_bytes_per_access=602 * 4,
             pgr_contig_bytes_per_access=4,
             vector_width_utilization_agg=1.0,
             vector_width_utilization_pgr=round(1 / 128, 4))
    ctx.emit("fig2/parallelism", 0.0,
             agg_work_items_per_edge=602,   # intra-vertex lanes
             pgr_work_items_per_edge=1,
             agg_us=round(t_agg, 1), pgr_iter_us=round(t_pgr, 1))
    ctx.emit("fig2/memory_pressure", 0.0,
             agg_gathers_per_kbyte=round(1024 / (602 * 4), 2),
             pgr_gathers_per_kbyte=round(1024 / 4, 2),
             paper_reference="memory throttle 0.225% vs 39.27%")


def _reorder(ctx, _):
    """Degree-aware reorder effect (guideline 5.1-1)."""
    g = ctx.g
    stream = np.asarray(g.src)[:150_000]
    g2, _ = degree_reorder(g)
    stream2 = np.asarray(g2.src)[:150_000]
    budget = 2048
    before = reuse_distance_stats(stream, budgets=(budget,))
    after = reuse_distance_stats(stream2, budgets=(budget,))
    ctx.emit("guideline/degree_reorder", 0.0,
             hit_ratio_before=round(before[f"hit_ratio@{budget}"], 3),
             hit_ratio_after=round(after[f"hit_ratio@{budget}"], 3),
             mean_dist_before=round(before["mean_reuse_distance"], 1),
             mean_dist_after=round(after["mean_reuse_distance"], 1))


SPECS = [
    BenchSpec(name="fig2/agg_vs_pgr", graph="reddit", max_vertices=8192,
              max_feature=602, measure=_locality),
    BenchSpec(name="fig2/reorder", graph="reddit", max_vertices=8192,
              max_feature=602, measure=_reorder),
]


def run():
    from repro.profile.bench import BENCH_ARTIFACT_DIR
    run_specs(SPECS, csv=BENCH_ARTIFACT_DIR / "bench_agg_vs_pgr.csv")


if __name__ == "__main__":
    run()
