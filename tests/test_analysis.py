"""repro.analysis: static contract verification of plans and source.

Covers the PR 9 tentpole: every rule catches its seeded plant (the
self-test contract), the shipped tree and plan matrix are clean under
``--strict``, suppression pragmas work, and -- in a subprocess on 8
fake devices -- the jaxpr-extracted collective bytes equal BOTH the
analytic ``schedule_wire_bytes`` accounting and the
``WorkloadReport.wire_collective_bytes`` column exactly (f32 and bf16,
1-D and 2-D).
"""

import dataclasses
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

from repro.analysis.ast_lint import lint_source, lint_tree
from repro.analysis.jaxpr_lint import lint_plan
from repro.analysis.report import AnalysisReport, Finding
from repro.analysis.selftest import PLANTS, check_suppression

ROOT = Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")
TESTS = str(Path(__file__).resolve().parent)

ALL_RULES = sorted(PLANTS)


# ---------------------------------------------------------------------------
# Report core
# ---------------------------------------------------------------------------


def test_report_core_roundtrip():
    r = AnalysisReport()
    r.add("no-f64", "error", "plan[x]", "boom", "evidence")
    r.add("tracer-branch", "warning", "f.py:3", "maybe")
    assert not r.ok(strict=True)
    assert r.counts() == {"error": 1, "warning": 1, "info": 0}
    assert "no-f64" in r.to_json() and "boom" in r.to_markdown()
    # strict gate ignores warnings, non-strict does not
    r2 = AnalysisReport([Finding("tracer-branch", "warning", "f.py:3", "m")])
    assert r2.ok(strict=True) and not r2.ok(strict=False)
    with pytest.raises(ValueError):
        r.add("x", "fatal", "y", "z")


# ---------------------------------------------------------------------------
# Self-test: every rule must catch its plant (the gate that keeps the
# gate honest) -- one planted-positive test per rule
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", ALL_RULES)
def test_rule_detects_its_plant(rule):
    report = PLANTS[rule]()
    assert any(f.rule == rule for f in report.findings), \
        f"rule {rule} missed its seeded violation:\n{report.render()}"


def test_rule_registry_covers_both_front_ends():
    """>= 8 rules total, spanning jaxpr and AST front ends."""
    assert len(ALL_RULES) >= 8
    assert {"no-callbacks", "no-f64", "bf16-f32-accum", "donation",
            "collective-bytes", "dynamic-edge-free"} <= set(ALL_RULES)
    assert {"host-in-trace", "tracer-branch", "broadcast-div",
            "acc-dtype", "grid-arity"} <= set(ALL_RULES)


def test_suppression_pragmas():
    assert check_suppression()
    # file-level pragma form
    src = ("# analysis: allow-file(broadcast-div)\n"
           "def f(h, deg):\n"
           "    return h / deg[:, None]\n")
    assert not lint_source(src).findings
    # an unrelated rule id does NOT suppress
    src = ("def f(h, deg):\n"
           "    return h / deg[:, None]  # analysis: allow(acc-dtype)\n")
    assert lint_source(src).findings


# ---------------------------------------------------------------------------
# The shipped tree and local plan matrix are clean
# ---------------------------------------------------------------------------


def test_shipped_tree_is_clean():
    report = lint_tree(ROOT / "src" / "repro")
    assert report.ok(strict=True), report.render()


@pytest.fixture(scope="module")
def small_setup():
    from repro.config import CORA, reduced_graph
    from repro.graph.datasets import make_synthetic_graph
    from repro.models.gcn import PAPER_MODELS
    spec = reduced_graph(CORA, 64, 16)
    g = make_synthetic_graph(spec)
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(8,))
    return spec, g, cfg


@pytest.mark.parametrize("backend,fused,dtype", [
    ("xla", False, "f32"), ("xla", False, "bf16"),
    ("pallas-tpu", True, "bf16"), ("pallas-gpu", True, "int8-agg"),
])
def test_lint_plan_local_cells_clean(small_setup, backend, fused, dtype):
    from repro.core.plan import build_plan
    spec, g, cfg = small_setup
    plan = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                      backend=backend, fused=fused, dtype=dtype)
    report = lint_plan(plan, dynamic=(backend == "xla" and not fused
                                      and dtype == "f32"))
    assert report.ok(strict=True), report.render()


def test_lint_plan_donation_positive(small_setup):
    """A plan whose logits CAN alias the donated features must show the
    donation marker in lowered HLO (zero findings); the no-alias shape
    yields an info finding, never an error."""
    from repro.core.plan import build_plan
    from repro.graph.datasets import make_synthetic_graph
    spec, g, cfg = small_setup
    spec_d = dataclasses.replace(spec, feature_len=spec.num_classes)
    g_d = make_synthetic_graph(spec_d)
    plan = build_plan(g_d, cfg, spec_d.feature_len, spec_d.num_classes)
    assert lint_plan(plan, donate=True).ok(strict=True)
    # mismatched shapes: donation silently unusable -> info, not error
    plan2 = build_plan(g, cfg, spec.feature_len, spec.num_classes)
    rep = lint_plan(plan2, donate=True)
    assert rep.ok(strict=True)
    assert any(f.rule == "donation" and f.severity == "info"
               for f in rep.findings)


def test_dynamic_edge_free_catches_baked_plan(small_setup):
    """A plan that bakes edge content (pallas blocked layout) cannot even
    reach dynamic compile; the jaxpr-level rule proves the qualifying
    plan's trace has no template-edge consts."""
    from repro.core.plan import build_plan
    spec, g, cfg = small_setup
    plan = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                      backend="pallas-tpu")
    with pytest.raises(ValueError, match="edge-content-free"):
        plan._check_dynamic_ok()


def test_seg_agg_remediation_shared_with_ast_rule():
    """Satellite 6: the error a user hits when tracing ``seg_agg`` and
    the host-in-trace finding a reviewer reads agree VERBATIM on the fix
    (seg_agg_planned via the plan entry points)."""
    import jax.numpy as jnp

    from repro.kernels.ops import SEG_AGG_REMEDIATION, seg_agg

    assert "seg_agg_planned" in SEG_AGG_REMEDIATION
    for entry in ("build_plan", "plan_for_conv", "plan_for_phases"):
        assert entry in SEG_AGG_REMEDIATION
    with pytest.raises(ValueError) as ei:
        jax.jit(lambda r, s: seg_agg(r, s, 4))(
            jnp.ones((6, 2)), jnp.zeros((6,), jnp.int32))
    assert SEG_AGG_REMEDIATION in str(ei.value)
    # the AST rule's remediation text is the SAME constant
    src = ("def f(x):\n"
           "    y = jnp.sum(x)\n"
           "    return float(jnp.max(y))\n")
    hits = [f for f in lint_source(src).findings
            if f.rule == "host-in-trace"]
    assert hits and SEG_AGG_REMEDIATION in hits[0].detail


# ---------------------------------------------------------------------------
# Acceptance: analyzer-extracted collective bytes == analytic accounting
# == WorkloadReport.wire_collective_bytes, exactly, on 8 fake devices
# ---------------------------------------------------------------------------


def run_sub(body: str):
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True,
                         env={"PYTHONPATH": f"{SRC}:{TESTS}",
                              "PATH": "/usr/bin:/bin", "HOME": "/root"},
                         timeout=600)
    assert res.returncode == 0, f"subprocess failed:\n{res.stderr[-3000:]}"
    return res.stdout


@pytest.mark.slow
def test_collective_bytes_match_workload_report_8dev():
    out = run_sub("""
        import dataclasses
        from repro.config import CORA, reduced_graph
        from repro.graph.datasets import make_synthetic_graph, make_features
        from repro.core.plan import build_plan
        from repro.models.gcn import PAPER_MODELS
        from repro.analysis.jaxpr_lint import (collective_bytes, lint_plan,
                                               plan_expected_collectives)
        spec = reduced_graph(CORA, 64, 16)
        g = make_synthetic_graph(spec); x = make_features(spec)
        cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(8,))
        meshes = {"1d": jax.make_mesh((8,), ("data",)),
                  "2d": jax.make_mesh((4, 2), ("node", "feat"))}
        for kind, mesh in meshes.items():
            for dtype in ("f32", "bf16"):
                for overlap in ("none", "pipelined"):
                    plan = build_plan(g, cfg, spec.feature_len,
                                      spec.num_classes, mesh=mesh,
                                      overlap=overlap, dtype=dtype)
                    params = plan.init(jax.random.PRNGKey(0))
                    jx = jax.make_jaxpr(
                        lambda p, xx: plan.run_model(p, xx))(params, x)
                    got = collective_bytes(jx)
                    exp = plan_expected_collectives(plan)
                    assert got == exp, (kind, dtype, overlap, got, exp)
                    # the full rule registry agrees
                    assert lint_plan(plan).ok(strict=True)
                    # WorkloadReport carries the SAME schedule-exact
                    # accounting, summed over distributed records
                    rep = plan.instrument().run_model(params, x)
                    wire = sum(r.wire_collective_bytes
                               for r in rep.records
                               if r.phase == "distributed")
                    assert wire == float(sum(got.values())), \\
                        (kind, dtype, overlap, wire, got)
                    print("MATCH", kind, dtype, overlap, sum(got.values()))
        print("OK")
    """)
    assert "OK" in out
    assert out.count("MATCH") == 8
