"""Full GCN / GIN / GraphSAGE models (paper Table 1 configurations).

Two-layer node-classification networks over the phase primitives, with
per-layer phase-ordering control, the fused-dataflow option, and the analytic
per-phase cost breakdown used by the benchmark harness.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.config import GCNModelConfig, GraphSpec
from repro.core import phases
from repro.core.dataflow import BlockedGraph, block_graph, suggest_tile_m
from repro.core.gcn_layers import CONVS
from repro.core.scheduler import ordering_cost
from repro.graph.structure import Graph

# Paper Table 1 model configs: |h|->128 single layer (GCN/SAG);
# |h|->128->128 MLP (GIN).  num_layers=2 gives the usual 2-conv network;
# the paper profiles the FIRST conv layer, which bench code isolates.
PAPER_MODELS: Dict[str, GCNModelConfig] = {
    "gcn": GCNModelConfig("gcn", conv="gcn", aggregator="mean",
                          hidden_dims=(128,), ordering="auto"),
    "sage": GCNModelConfig("sage", conv="sage", aggregator="mean",
                           hidden_dims=(128,), ordering="auto"),
    "gin": GCNModelConfig("gin", conv="gin", aggregator="sum",
                          hidden_dims=(128, 128), ordering="aggregate_first"),
}


class GCNModel:
    """num_layers stacked convolutions + classifier head."""

    def __init__(self, cfg: GCNModelConfig, in_dim: int, num_classes: int,
                 impl: str = "xla"):
        self.cfg = cfg
        self.in_dim = in_dim
        self.num_classes = num_classes
        hid = cfg.hidden_dims[0]
        conv_cls = CONVS[cfg.conv]
        self.convs = []
        d = in_dim
        for i in range(cfg.num_layers):
            dout = hid if i < cfg.num_layers - 1 else num_classes
            if cfg.conv == "gin":
                self.convs.append(conv_cls(d, dout, hidden=cfg.hidden_dims[-1],
                                           impl=impl))
            else:
                self.convs.append(conv_cls(d, dout, ordering=cfg.ordering,
                                           impl=impl))
            d = dout

    def init(self, key) -> Dict:
        keys = jax.random.split(key, len(self.convs))
        return {f"conv{i}": c.init(k) for i, (c, k) in
                enumerate(zip(self.convs, keys))}

    def apply(self, params, g: Graph, x,
              blocked: Optional[BlockedGraph] = None) -> jnp.ndarray:
        h = x
        for i, conv in enumerate(self.convs):
            h = conv.apply(params[f"conv{i}"], g, h,
                           blocked=blocked if self.cfg.fused else None)
            if i < len(self.convs) - 1:
                h = jax.nn.relu(h)
        return h

    def loss_fn(self, params, g: Graph, x, labels,
                mask: Optional[jnp.ndarray] = None):
        logits = self.apply(params, g, x)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, labels[:, None], axis=-1)[:, 0]
        if mask is not None:
            return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return nll.mean()

    def make_blocked(self, g: Graph) -> BlockedGraph:
        avg_deg = g.num_edges / max(1, g.num_vertices)
        tile = suggest_tile_m(self.in_dim, self.cfg.hidden_dims[0], avg_deg)
        return block_graph(g, tile)

    # -- analytic per-phase costs (drives benchmarks + Table 3/4) ----------
    def layer_costs(self, g: Graph, layer: int = 0) -> Dict:
        conv = self.convs[layer]
        din = conv.din
        dims: List[int] = [din] + ([conv.hidden, conv.dout]
                                   if self.cfg.conv == "gin" else [conv.dout])
        order = conv.resolve_order(g)
        agg_len = dims[0] if order == "aggregate_first" else dims[-1]
        return {
            "order": order,
            "aggregation": phases.aggregate_cost(g, agg_len),
            "combination": phases.combine_cost(g.num_vertices, dims),
            "ordering_cost": ordering_cost(g, dims[0], dims[-1], order),
        }


def make_paper_model(name: str, spec: GraphSpec, impl: str = "xla",
                     **overrides) -> GCNModel:
    import dataclasses
    cfg = PAPER_MODELS[name]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return GCNModel(cfg, in_dim=spec.feature_len,
                    num_classes=spec.num_classes, impl=impl)
