"""GraphServeEngine: GCN node-prediction serving through bucketed compiled plans.

The paper characterizes GCN *inference* as the GPU workload that matters;
this engine is the repo's millions-of-users path for it.  It instantiates
the shared serving core (``repro.serve.core.SlotServeCore``) for graph
traffic the same way ``ServeEngine`` instantiates it for LM decode:

  * **Admission** (host-side, the data-pipeline half): each node-prediction
    request samples its 2-hop frontier (``graph.sampling.two_hop_batch``,
    the paper's SAG setting) from one long-lived RNG, merges both hops into
    one destination-sorted union block, and picks the smallest *shape
    bucket* that fits.
  * **Dispatch** (device-side, the planned half): every bucket
    ``(num_seeds, num_inputs, num_edges)`` owns exactly ONE
    ``plan.compile(dynamic=True, donate=True)`` callable (each call pads a
    fresh feature buffer, so donating it lets the device recycle the
    bucket's input allocation under sustained load) -- the vLLM/aphrodite
    ``_BATCH_SIZES_TO_CAPTURE`` idiom applied to graphs: the sampled block
    is padded into the bucket's static shapes (zero feature rows, sink
    self-edges, zero in-degrees) and executed with the edge arrays as
    runtime data, so ANY block that fits the bucket replays the same
    compiled executable with zero retraces.  Padding is exact: pad edges
    only touch the sink row, so real rows are bit-identical to an eager
    forward on the unpadded block.
  * **Lifecycle / stats**: slots bound in-flight requests and are reused on
    completion; per-request latency percentiles (p50/p95/p99) and
    throughput report through the ``WorkloadReport`` machinery
    (``workload_report()``).

Requests too large for every bucket are *bucket misses*: served through a
per-request eager plan (correct but slow) and counted -- the smoke gate
hard-fails on any miss.  Per-request plans are what the plan-cache
eviction policy exists for: ``warmup()`` pins the bucket plans and the
engine sweeps transient plans via ``core.plan.clear_plan_cache(keep=...)``
whenever the cache crosses ``plan_cache_watermark``.

Worked example (docs/serving.md walks the full lifecycle)::

    engine = GraphServeEngine(g, PAPER_MODELS["gcn"], params, features,
                              num_classes=7, fanouts=(5, 5))
    engine.warmup()                      # compile every bucket up front
    engine.submit(GraphRequest(rid=0, seeds=np.array([3, 17, 401])))
    done = engine.run()
    done[0].logits                       # (3, 7) seed logits
    print(engine.workload_report().to_markdown())
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.plan import build_plan, clear_plan_cache, plan_cache_stats
from repro.graph.sampling import SampledBlock, two_hop_batch
from repro.graph.structure import Graph, graph_from_coo
from repro.serve.core import SlotServeCore


class Bucket(NamedTuple):
    """One serving shape bucket; every field is a static compiled dim.

    ``num_seeds`` bounds the request batch (seed vertices per request),
    ``num_inputs`` the padded frontier rows, ``num_edges`` the padded
    union edge list.  A sampled block *fits* iff seeds/edges fit and the
    frontier leaves a sink row for pad edges when padding is needed
    (``fits``).
    """

    num_seeds: int
    num_inputs: int
    num_edges: int

    def fits(self, seeds: int, inputs: int, edges: int) -> bool:
        """True iff a block of these REAL sizes can pad into this bucket.

        Pad edges are sink self-loops on the last row, so when any edge
        padding is needed (``edges < num_edges``) the frontier must leave
        at least one pad row free to serve as the sink."""
        if seeds > self.num_seeds or edges > self.num_edges:
            return False
        limit = self.num_inputs if edges == self.num_edges \
            else self.num_inputs - 1
        return inputs <= limit


def default_buckets(fanouts: Tuple[int, int],
                    seed_levels: Sequence[int] = (4, 16, 64),
                    max_inputs: Optional[int] = None) -> Tuple[Bucket, ...]:
    """Worst-case bucket ladder for ``two_hop_batch`` sampling.

    One bucket per seed level: ``sample_neighbors`` emits exactly
    ``n * fanout`` edges per hop and at most ``n * (1 + fanout)`` frontier
    vertices, so the worst case is closed-form -- hop-1 inputs
    ``s*(1+f1)``, union frontier ``s*(1+f1)*(1+f2)``, union edges
    ``s*f1 + s*(1+f1)*f2`` -- plus one reserved sink row for pad edges.
    ``max_inputs`` (e.g. ``g.num_vertices``) caps the frontier dim.
    """
    f1, f2 = int(fanouts[0]), int(fanouts[1])
    out = []
    for s in sorted(int(v) for v in seed_levels):
        n1 = s * (1 + f1)
        frontier = n1 * (1 + f2)
        if max_inputs is not None:
            frontier = min(frontier, int(max_inputs))
        out.append(Bucket(num_seeds=s, num_inputs=frontier + 1,
                          num_edges=s * f1 + n1 * f2))
    return tuple(out)


@dataclasses.dataclass
class GraphRequest:
    """One node-prediction request: logits for a batch of seed vertices."""

    rid: int
    seeds: np.ndarray                     # (s,) global vertex ids
    # filled by the engine
    logits: Optional[np.ndarray] = None   # (s, num_classes)
    bucket: Optional[Bucket] = None       # None => served as a bucket miss
    frontier_size: int = 0                # real union-frontier rows
    edge_count: int = 0                   # real union edges
    done: bool = False
    enqueue_t: float = 0.0
    finish_t: float = 0.0
    prep: Any = dataclasses.field(default=None, repr=False)


@dataclasses.dataclass
class PreparedBlock:
    """Host-side admission product: the sampled union block, bucketed."""

    frontier: np.ndarray                  # (n,) global frontier vertex ids
    graph: Graph                          # unpadded dst-sorted union graph
    seed_pos: np.ndarray                  # (s,) seed rows within frontier
    bucket: Optional[Bucket]              # None = no bucket fits (miss)


def _index_of(haystack: np.ndarray, needles: np.ndarray) -> np.ndarray:
    """Positions of ``needles`` inside sorted unique ``haystack``."""
    pos = np.searchsorted(haystack, needles)
    assert (np.asarray(haystack)[pos] == np.asarray(needles)).all(), \
        "frontier must cover the needles"
    return pos.astype(np.int32)


def union_two_hop(hop2: SampledBlock, hop1: SampledBlock,
                  seeds: np.ndarray) -> Tuple[np.ndarray, Graph, np.ndarray]:
    """Merge a (hop2, hop1) sampled pair into ONE union block.

    Both hops' edges are renumbered into the hop-2 input frontier (a
    superset of hop-1 inputs and seeds) and concatenated into a single
    destination-sorted multigraph over ``len(frontier)`` vertices -- the
    sampled-subgraph inference form, where a 2-layer planned forward over
    the union graph yields seed logits at ``seed_pos``.  One graph per
    request is what lets one ``plan.compile(dynamic=True)`` callable per
    bucket serve the whole model.
    """
    frontier = np.asarray(hop2.input_ids)
    pos_h1 = _index_of(frontier, hop1.input_ids)
    seed_pos = _index_of(frontier, seeds)
    # hop2 edges: src already frontier-local, dst indexes hop1.input_ids
    src = np.concatenate([np.asarray(hop2.graph.src),
                          pos_h1[np.asarray(hop1.graph.src)]])
    dst = np.concatenate([pos_h1[np.asarray(hop2.graph.dst)],
                          seed_pos[np.asarray(hop1.graph.dst)]])
    g = graph_from_coo(src, dst, len(frontier))
    return frontier, g, seed_pos


class GraphServeEngine(SlotServeCore):
    """Continuous-batching GCN inference on the shared serving core.

    Two instantiations of one loop: where the LM ``ServeEngine``'s
    admission is prefill-into-slot and its step is one batched decode,
    this engine's admission is sample+bucket (host pipeline work) and its
    step drains every active slot through its bucket's single compiled
    callable.  See the module docstring for the serving contract and
    ``docs/serving.md`` for the full lifecycle.
    """

    def __init__(self, g: Graph, cfg, params, features, num_classes: int, *,
                 buckets: Optional[Sequence[Tuple[int, int, int]]] = None,
                 fanouts: Tuple[int, int] = (5, 5), max_batch: int = 8,
                 seed: int = 0, machine=None, ordering: Optional[str] = None,
                 plan_cache_watermark: int = 32, donate: bool = True):
        super().__init__(max_batch)
        self.g = g
        self.cfg = cfg
        self.params = params
        self.features = np.asarray(features, np.float32)
        self.in_dim = int(self.features.shape[1])
        self.num_classes = int(num_classes)
        self.fanouts = (int(fanouts[0]), int(fanouts[1]))
        self.machine = machine
        self.ordering = ordering
        self.plan_cache_watermark = int(plan_cache_watermark)
        # donate the padded feature buffer to each bucket call: every call
        # builds a fresh padded x, so under sustained load the device
        # reuses the bucket's feature allocation instead of holding two.
        # (On CPU XLA ignores donation with a one-time warning; harmless.)
        self.donate = bool(donate)
        self.rng = np.random.default_rng(seed)
        if buckets is None:
            buckets = default_buckets(self.fanouts,
                                      max_inputs=g.num_vertices)
        # selection order: smallest padded frontier, then edges, then seeds
        self.buckets: Tuple[Bucket, ...] = tuple(sorted(
            (Bucket(*b) for b in buckets),
            key=lambda b: (b.num_inputs, b.num_edges, b.num_seeds)))
        self._plans: Dict[Bucket, Any] = {}      # bucket -> plan
        self._fns: Dict[Bucket, Any] = {}        # bucket -> CompiledPlan
        self._bucket_hits: Dict[Bucket, int] = {b: 0 for b in self.buckets}
        self._bucket_misses = 0
        self._cache_sweeps = 0
        self._warmed = False

    # ----------------------------------------------------------- bucket mgmt

    def _template_graph(self, bucket: Bucket) -> Graph:
        """Deterministic template with the bucket's static shapes (edge
        CONTENT is irrelevant -- it is replaced per call by the dynamic
        compiled plan; only shapes and the plan's cost-model inputs
        |V|, |E| matter)."""
        n, e = bucket.num_inputs, bucket.num_edges
        idx = np.arange(e, dtype=np.int32) % n
        return graph_from_coo(idx, idx, n)

    def _bucket_plan(self, bucket: Bucket):
        plan = self._plans.get(bucket)
        if plan is None:
            plan = build_plan(self._template_graph(bucket), self.cfg,
                              self.in_dim, self.num_classes, backend="xla",
                              fused=False, ordering=self.ordering,
                              machine=self.machine)
            self._plans[bucket] = plan
            self._fns[bucket] = plan.compile(dynamic=True,
                                             donate=self.donate)
        return plan, self._fns[bucket]

    def select_bucket(self, num_seeds: int, num_inputs: int,
                      num_edges: int) -> Optional[Bucket]:
        """Smallest fitting bucket (selection order: padded frontier rows,
        then edges, then seeds); None when every bucket is too small --
        a bucket MISS, served eagerly and counted in ``stats()``."""
        for b in self.buckets:
            if b.fits(num_seeds, num_inputs, num_edges):
                return b
        return None

    def warmup(self) -> Dict[str, int]:
        """Compile every bucket BEFORE admission and pin the bucket plans.

        Traces each bucket's single dynamic callable once on its template
        shapes (so first-request latency is honest -- no hidden compile),
        then sweeps the plan cache down to exactly the bucket plans
        (``clear_plan_cache(keep=...)``).  Idempotent; returns
        ``{bucket-name: num_traces}`` -- every value is 1 after a fresh
        warm-up and STAYS 1 through serving (the zero-retrace contract).
        """
        for b in self.buckets:
            plan, fn = self._bucket_plan(b)
            if fn.num_traces == 0:
                x = jnp.zeros((b.num_inputs, self.in_dim), jnp.float32)
                fn(self.params, x, plan.g)
        clear_plan_cache(keep=list(self._plans.values()))
        self._cache_sweeps += 1
        self._warmed = True
        return {self._bucket_name(b): self._fns[b].num_traces
                for b in self.buckets}

    @staticmethod
    def _bucket_name(b: Bucket) -> str:
        return f"s{b.num_seeds}/v{b.num_inputs}/e{b.num_edges}"

    def init_params(self, key):
        """Params pytree for the engine's model (any bucket plan's
        ``init`` -- the shapes depend only on (cfg, in_dim, classes))."""
        plan, _ = self._bucket_plan(self.buckets[0])
        return plan.init(key)

    # ----------------------------------------------------------- preparation

    def prepare(self, seeds: np.ndarray) -> PreparedBlock:
        """Host-side admission work for one request: sample the 2-hop
        frontier (fresh draws from the engine's long-lived RNG), merge
        into the union block, select the bucket."""
        seeds = np.asarray(seeds, np.int32)
        hop2, hop1 = two_hop_batch(self.g, seeds, self.fanouts, rng=self.rng)
        frontier, ug, seed_pos = union_two_hop(hop2, hop1, seeds)
        bucket = self.select_bucket(len(seeds), len(frontier), ug.num_edges)
        return PreparedBlock(frontier=frontier, graph=ug, seed_pos=seed_pos,
                             bucket=bucket)

    def _pad_into(self, prep: PreparedBlock, bucket: Bucket
                  ) -> Tuple[jnp.ndarray, Graph]:
        """Pad the union block into the bucket's static shapes.

        Exactness contract: pad feature rows are zero, pad edges are
        sink self-loops on the LAST row (preserving the dst-sort), pad
        in-degrees are zero -- so every real row sees exactly the real
        edge set in the real (sorted) order, and the padded compiled
        result is bit-identical to the unpadded eager forward.
        """
        n, e = len(prep.frontier), prep.graph.num_edges
        pad_e = bucket.num_edges - e
        sink = bucket.num_inputs - 1
        src = np.concatenate([np.asarray(prep.graph.src, np.int32),
                              np.full(pad_e, sink, np.int32)])
        dst = np.concatenate([np.asarray(prep.graph.dst, np.int32),
                              np.full(pad_e, sink, np.int32)])
        in_deg = np.zeros(bucket.num_inputs, np.int32)
        in_deg[:n] = np.asarray(prep.graph.in_deg, np.int32)
        x = np.zeros((bucket.num_inputs, self.in_dim), np.float32)
        x[:n] = self.features[prep.frontier]
        g = Graph(src=jnp.asarray(src), dst=jnp.asarray(dst),
                  in_deg=jnp.asarray(in_deg), out_deg=jnp.asarray(in_deg),
                  num_vertices=bucket.num_inputs)
        return jnp.asarray(x), g

    # ------------------------------------------------------------- execution

    def run_prepared(self, prep: PreparedBlock) -> np.ndarray:
        """Serve one prepared block through its bucket's compiled callable
        (the production path); falls back to ``run_eager`` on a miss."""
        if prep.bucket is None:
            return self.run_eager(prep)
        plan, fn = self._bucket_plan(prep.bucket)
        x, g = self._pad_into(prep, prep.bucket)
        out = fn(self.params, x, g)
        return np.asarray(out)[prep.seed_pos]

    def run_eager(self, prep: PreparedBlock) -> np.ndarray:
        """Unpadded eager reference for a prepared block.

        With a bucket: the SAME bucket plan replays its planned decisions
        eagerly on the unpadded union graph (``run_model(graph=...)``) --
        the oracle the padded compiled path must match bit-for-bit.
        Without one (a miss): a per-request plan is built for the union
        graph -- correct, but host planning work per request; these
        transient plans are what the cache eviction policy sweeps.
        """
        x = jnp.asarray(self.features[prep.frontier])
        if prep.bucket is not None:
            plan, _ = self._bucket_plan(prep.bucket)
            out = plan.run_model(self.params, x, graph=prep.graph)
        else:
            plan = build_plan(prep.graph, self.cfg, self.in_dim,
                              self.num_classes, backend="xla", fused=False,
                              ordering=self.ordering, machine=self.machine)
            out = plan.run_model(self.params, x)
        return np.asarray(out)[prep.seed_pos]

    # ------------------------------------------------------------ core hooks

    def _admit_into_slot(self, slot: int, req: GraphRequest) -> bool:
        req.prep = self.prepare(req.seeds)
        req.bucket = req.prep.bucket
        req.frontier_size = len(req.prep.frontier)
        req.edge_count = req.prep.graph.num_edges
        if req.bucket is None:
            self._bucket_misses += 1
        return False                       # always needs a dispatch step

    def _step(self) -> List[GraphRequest]:
        if not self._active:
            return []
        finished = []
        for slot in sorted(self._active):
            req = self._active[slot]
            req.logits = self.run_prepared(req.prep)
            if req.bucket is not None:
                self._bucket_hits[req.bucket] += 1
            finished.append(self._complete(slot))
        self._steps += 1
        self._maybe_sweep_plan_cache()
        return finished

    def _maybe_sweep_plan_cache(self) -> None:
        """The eviction policy: whenever transient per-request plans push
        the global plan cache past the watermark, sweep everything but
        the pinned bucket plans."""
        if self._plans and \
                plan_cache_stats()["size"] > self.plan_cache_watermark:
            clear_plan_cache(keep=list(self._plans.values()))
            self._cache_sweeps += 1

    # ---------------------------------------------------------------- stats

    def retraces(self) -> int:
        """Compiled-callable traces beyond the one each bucket is allowed
        (> 0 means the zero-retrace serving contract was violated)."""
        return sum(max(0, fn.num_traces - 1) for fn in self._fns.values())

    def stats(self) -> Dict[str, Any]:
        """Core serving stats plus the graph engine's bucket/cache view."""
        out = super().stats()
        out.update(
            warmed=self._warmed,
            bucket_hits=sum(self._bucket_hits.values()),
            bucket_misses=self._bucket_misses,
            retraces=self.retraces(),
            cache_sweeps=self._cache_sweeps,
            plan_cache=plan_cache_stats(),
            buckets=[{"num_seeds": b.num_seeds, "num_inputs": b.num_inputs,
                      "num_edges": b.num_edges,
                      "hits": self._bucket_hits[b],
                      "compiled": self._fns[b].num_traces
                      if b in self._fns else 0}
                     for b in self.buckets])
        return out

    def serving_summary(self) -> Dict[str, Any]:
        """The ``WorkloadReport.serving`` section: request count, latency
        percentiles, throughput, and the bucket/retrace counters the
        smoke gate hard-fails on."""
        s = self.stats()
        return {"requests": s["served"],
                "p50_ms": s["p50_ms"], "p95_ms": s["p95_ms"],
                "p99_ms": s["p99_ms"],
                "throughput_rps": s["throughput_rps"],
                "bucket_misses": s["bucket_misses"],
                "retraces": s["retraces"],
                "buckets": s["buckets"]}

    def workload_report(self, machine=None):
        """One ``WorkloadReport`` for the serving session.

        Per-phase records come from an instrumented eager forward over the
        busiest bucket's template shapes (the same dispatch path the
        compiled callable traced); the per-request latency percentiles /
        throughput / bucket counters ride along as ``report.serving`` and
        are schema-validated with the rest of the report.
        """
        busiest = max(self.buckets,
                      key=lambda b: (self._bucket_hits[b], -b.num_inputs))
        plan, _ = self._bucket_plan(busiest)
        x = jnp.zeros((busiest.num_inputs, self.in_dim), jnp.float32)
        report = plan.instrument(machine=machine or self.machine) \
            .run_model(self.params, x)
        report.serving = self.serving_summary()
        return report.validate()
