"""Distributed GCN execution: vertex-partitioned aggregation via shard_map.

The paper profiles a single GPU; this module is the cluster-scale story its
Table 4 implies (DESIGN.md §8.5): with a 1-D destination partition the
Aggregation phase's remote traffic is one feature row per cut edge, so
running Combination first shrinks the COLLECTIVE term by in_len/out_len --
the multi-chip restatement of the paper's 4.7x.

Two interchangeable aggregation strategies (both exact):

  * ``allgather``  -- one all-gather of the full feature matrix per layer,
    then purely local gather+segment-reduce.  Simple; wire bytes V*F.
  * ``ring``       -- P-1 ``collective_permute`` steps around the data-axis
    ring; at each step every device reduces the contributions of the block
    it currently holds while the next block is in flight.  Same total wire
    bytes, but O(V/P * F) resident and compute/comm OVERLAPPED -- the
    distributed-optimization trick the brief asks for, expressed in
    jax-native collectives.

Both run under shard_map on the ``data`` axis; per-shard edge lists come
from graph.partition (edge-balanced, padded static shapes).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.graph.partition import PartitionedGraph


def pad_features(x: jnp.ndarray, block: int, num_shards: int) -> jnp.ndarray:
    """Pad vertex features to num_shards*block rows (partition layout)."""
    total = block * num_shards
    v = x.shape[0]
    return jnp.pad(x, ((0, total - v), (0, 0)))


def _require_uniform(pg: PartitionedGraph) -> None:
    """The shard_map strategies lay out rows as p*block + local; that needs
    the UNIFORM partition (partition_1d(..., edge_balanced=False)).  The
    edge-balanced variant feeds the analytic load model instead."""
    starts = np.asarray(pg.vtx_start)
    expect = np.arange(pg.num_shards) * pg.block_size
    expect = np.minimum(expect, pg.num_vertices)
    if not np.array_equal(starts, expect):
        raise ValueError(
            "distributed aggregation requires a uniform partition; build "
            "with partition_1d(g, P, edge_balanced=False)")


def _local_agg(x_full, src, dst_local, mask, block):
    rows = jnp.take(x_full, src, axis=0) * mask[:, None]
    return jax.ops.segment_sum(rows, dst_local, num_segments=block)


def aggregate_allgather(pg: PartitionedGraph, x: jnp.ndarray, mesh: Mesh,
                        axis: str = "data") -> jnp.ndarray:
    """x: (P*block, F) sharded over `axis` -> aggregated (P*block, F)."""
    _require_uniform(pg)
    block = pg.block_size

    def fn(x_local, src, dst_local, mask, starts):
        x_full = jax.lax.all_gather(x_local[0], axis, tiled=True)
        out = _local_agg(x_full, src[0] - 0, dst_local[0], mask[0], block)
        return out[None]

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P(axis, None),
                  P(axis)),
        out_specs=P(axis, None), check_rep=False,
    )(x.reshape(pg.num_shards, -1, x.shape[-1]), pg.src, pg.dst_local,
      pg.mask, pg.vtx_start).reshape(x.shape[0], x.shape[-1])


def aggregate_ring(pg: PartitionedGraph, x: jnp.ndarray, mesh: Mesh,
                   axis: str = "data") -> jnp.ndarray:
    """Ring halo exchange: P-1 collective_permutes, partial reduce per hop.

    Device p holds block b_k = (p + k) mod P at hop k and reduces the edges
    whose source lies in b_k.  The permute of hop k+1 can overlap the
    reduce of hop k on real hardware (async collective start).
    """
    _require_uniform(pg)
    block = pg.block_size
    nsh = pg.num_shards

    def fn(x_local, src, dst_local, mask):
        x_loc = x_local[0]
        srcl, dstl, mskl = src[0], dst_local[0], mask[0]
        p = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % nsh) for i in range(nsh)]  # ring

        def hop(carry, k):
            buf, acc = carry
            # ring sends i -> i+1, so after k hops we hold block (p - k)
            owner = jnp.mod(p - k, nsh)               # whose block we hold
            sel = (srcl // block) == owner
            local_src = srcl - owner * block
            rows = jnp.take(buf, jnp.clip(local_src, 0, block - 1), axis=0)
            rows = rows * (mskl * sel)[:, None]
            acc = acc + jax.ops.segment_sum(rows, dstl, num_segments=block)
            buf = jax.lax.ppermute(buf, axis, perm)   # pass block onward
            return (buf, acc), None

        acc0 = jnp.zeros((block, x_loc.shape[-1]), x_loc.dtype)
        (_, acc), _ = jax.lax.scan(hop, (x_loc, acc0), jnp.arange(nsh))
        return acc[None]

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P(axis, None)),
        out_specs=P(axis, None), check_rep=False,
    )(x.reshape(nsh, -1, x.shape[-1]), pg.src, pg.dst_local,
      pg.mask).reshape(x.shape[0], x.shape[-1])


def halo_bytes(pg: PartitionedGraph, feature_len: int,
               dtype_bytes: int = 4) -> dict:
    """Analytic collective cost of one distributed Aggregation (both strats).

    Reported by bench_ordering to show the combine-first collective saving.
    """
    v_padded = pg.block_size * pg.num_shards
    per_device = v_padded * feature_len * dtype_bytes * \
        (pg.num_shards - 1) / pg.num_shards
    # cut edges: sources not owned by the destination shard
    src = np.asarray(pg.src)
    starts = np.asarray(pg.vtx_start)
    owners = np.clip(np.searchsorted(starts, src, side="right") - 1, 0,
                     pg.num_shards - 1)
    mine = owners == np.arange(pg.num_shards)[:, None]
    cut_edges = int((np.asarray(pg.mask) * ~mine).sum())
    return {
        "allgather_bytes_per_device": per_device,
        "ring_bytes_per_device": per_device,  # same total, overlapped
        "cut_edges": cut_edges,
        "min_halo_bytes": cut_edges * feature_len * dtype_bytes,
    }


def _local_graph_view(pg: PartitionedGraph):
    """Minimal |V|/|E| stats view for the scheduler's analytic cost model."""
    import types
    return types.SimpleNamespace(
        num_vertices=pg.num_vertices,
        num_edges=int(np.asarray(pg.mask).sum()))


def distributed_gcn_layer(pg: PartitionedGraph, x, w, bias, in_deg,
                          mesh: Mesh, *, order: Optional[str] = None,
                          strategy: str = "ring", axis: str = "data"):
    """One distributed GCN layer with explicit phase ordering (Table 4).

    combine_first: project locally (embarrassingly parallel GEMM), then
    aggregate projected rows -- halo moves out_len-wide rows.
    aggregate_first: aggregate raw features (halo moves in_len-wide rows),
    then project.  ``order=None`` asks the scheduler's cost model (which at
    cluster scale also prices the collective term -- same in/out ratio).

    This is the shard_map primitive; model-level code reaches it through a
    ``GraphExecutionPlan`` built with ``mesh=``/``num_shards=`` (core/plan.py)
    rather than calling it with hand-threaded flags.
    """
    if order is None:
        from repro.core.scheduler import choose_ordering
        order = choose_ordering(
            _local_graph_view(pg), int(w.shape[0]), int(w.shape[1]),
            agg_op="mean", n_mlp_layers=1)
    agg = aggregate_ring if strategy == "ring" else aggregate_allgather
    deg = jnp.maximum(in_deg.astype(x.dtype) + 1.0, 1.0)[:, None]
    deg = pad_features(deg, pg.block_size, pg.num_shards)
    deg = jnp.where(deg == 0, 1.0, deg)
    if order == "combine_first":
        h = x @ w
        out = (agg(pg, h, mesh, axis) + h) / deg
    else:
        out = ((agg(pg, x, mesh, axis) + x) / deg) @ w
    return out + bias
