"""Pair-redundancy elimination: measured savings of the dedup decision.

GraphACT's observation, as a *planned* decision: sampled minibatch blocks
are fanout-regular, so many destinations share the same leading source
pair -- computing each frequent pair's partial sum ONCE (level 1) and
folding the shortened edge list (level 2) eliminates redundant aggregation
work.  ``build_plan(dedup=...)`` owns the layout; this bench proves the
decision pays off where the paper's characterization says it should:

  * ``dedup/block`` builds a fanout-regular sampled block (every seed
    draws exactly two hub in-neighbors, fanout-2 sampling keeps both) and
    hard-fails unless (a) the matcher finds pairs at all, (b) the
    two-level layout eliminates >= 20% of analytic aggregation FLOPs,
    (c) the dedup plan's f32 output is BITWISE equal to the naive plan's
    under both eager dispatch and ``plan.compile()``, and (d) under full
    (non-dry) timing the dedup plan's compiled forward is measurably
    FASTER than the naive plan on the same block -- analytic savings that
    don't cash out as wall time fail the bench.
  * ``dedup/sparse`` runs the counter-workload (sparse full-graph layer):
    near-zero matchable pairs, where ``dedup="auto"`` must keep "none".
  * ``dedup/choose`` pins the priced flip: ``choose_dedup`` must pick
    "pairs" for the fanout-regular block and "none" for the sparse layer
    on the SAME machine preset -- the decision is workload-shaped, not a
    global switch.

Under dry-run every cell also runs INSTRUMENTED: the WorkloadReport must
carry ``dedup_pairs``/``dedup_flops_saved`` on its aggregation records,
schema-validate, and agree with ``plan.describe()``.
"""

from __future__ import annotations

import dataclasses
import sys

import jax
import numpy as np

from repro.core.phases import aggregate_cost
from repro.core.plan import build_plan
from repro.graph.dedup import dedup_cost, dedup_layout_for_graph
from repro.graph.sampling import sample_neighbors
from repro.graph.structure import graph_from_coo
from repro.models.gcn import PAPER_MODELS
from repro.profile.bench import BenchSpec, run_specs
from repro.profile.machine import TPU_V5E, choose_dedup, dedup_model

#: minimum analytic aggregation-FLOP reduction on the fanout-regular block
MIN_FLOP_REDUCTION = 0.20

#: agg-dominant dims: wide inputs, narrow hidden -- the regime where the
#: paper's characterization puts aggregation's share of runtime highest
IN_DIM, HIDDEN, CLASSES = 256, 16, 8

BLOCK_NAME = "dedup/block/fanout-regular"
SPARSE_NAME = "dedup/sparse/full-graph"
CHOOSE_NAME = f"dedup/choose/{TPU_V5E.name}"


def expected_matrix():
    return [BLOCK_NAME, SPARSE_NAME, CHOOSE_NAME]


def _fanout_regular_block(n_seeds=1024, n_hubs=16, seed=0):
    """Sampled block in GraphACT's favorable shape: every vertex in the
    parent graph has EXACTLY two in-neighbors drawn from ``n_hubs`` hub
    vertices, so fanout-2 sampling keeps both and many destinations share
    a leading pair (C(16,2)=120 possible pairs across ``n_seeds`` dsts)."""
    rng = np.random.default_rng(seed)
    v = n_seeds + n_hubs
    pairs = np.array([(a, b) for a in range(n_hubs)
                      for b in range(a + 1, n_hubs)])
    sel = pairs[rng.integers(0, len(pairs), v)] + n_seeds  # hubs live last
    parent = graph_from_coo(sel.reshape(-1),
                            np.repeat(np.arange(v), 2), v)
    block = sample_neighbors(parent, np.arange(n_seeds, dtype=np.int32),
                             fanout=2, rng=rng)
    return block.graph


def _sparse_graph(v=1000, e=1500, seed=0):
    rng = np.random.default_rng(seed)
    return graph_from_coo(rng.integers(0, v, e), rng.integers(0, v, e), v)


def _cfg():
    return dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(HIDDEN,))


def _plans(g):
    cfg = _cfg()
    p_none = build_plan(g, cfg, IN_DIM, CLASSES, dedup="none")
    p_pairs = build_plan(g, cfg, IN_DIM, CLASSES, dedup="pairs")
    params = p_none.init(jax.random.PRNGKey(0))
    x = jax.numpy.asarray(
        np.random.default_rng(0).standard_normal((g.num_vertices, IN_DIM)),
        jax.numpy.float32)
    return p_none, p_pairs, params, x


def _check_instrumented(name, ctx, plan, params, x):
    report = plan.instrument(machine=ctx.machine).run_model(params, x)
    report.validate()
    drift = report.mismatches(plan)
    if drift:
        raise RuntimeError(f"{name}: describe() disagrees with dispatch: "
                           f"{drift}")
    return report


def _block(ctx, _):
    """The fanout-regular cell: pairs found, >=20% analytic FLOPs
    eliminated, f32 bitwise, and (full runs) measured wall-time win."""
    g = _fanout_regular_block()
    p_none, p_pairs, params, x = _plans(g)

    lay = p_pairs.dedup_layout
    if p_pairs.dedup != "pairs" or lay is None or lay.num_pairs == 0:
        raise RuntimeError(
            f"{BLOCK_NAME}: zero matched pairs on a fanout-regular sampled "
            "block -- the leading-pair matcher found no shared pairs where "
            "matching is possible by construction")

    naive = aggregate_cost(g, IN_DIM)
    two_level = dedup_cost(lay, IN_DIM)
    reduction = 1.0 - two_level["flops"] / naive["flops"]
    if reduction < MIN_FLOP_REDUCTION:
        raise RuntimeError(
            f"{BLOCK_NAME}: analytic aggregation-FLOP reduction "
            f"{reduction:.1%} is below the {MIN_FLOP_REDUCTION:.0%} floor "
            "-- the two-level layout left the redundancy on the table")

    ref = p_none.run_model(params, x)
    for label, out in (("eager", p_pairs.run_model(params, x)),
                       ("compiled", p_pairs.compile()(params, x))):
        if not np.array_equal(np.asarray(out), np.asarray(ref)):
            raise RuntimeError(
                f"{BLOCK_NAME}: dedup='pairs' {label} output drifted from "
                "the naive plan -- the f32 contract is bitwise (the pair "
                "partial regroups the SAME in-order left fold)")

    p_auto = build_plan(g, _cfg(), IN_DIM, CLASSES, dedup="auto")
    if p_auto.dedup != "pairs":
        raise RuntimeError(
            f"{BLOCK_NAME}: dedup='auto' priced this fanout-regular block "
            f"as {p_auto.dedup!r}; the modeled saving must pick 'pairs'")

    derived = dict(pairs=lay.num_pairs, edges=g.num_edges,
                   edges_level2=lay.num_edges2,
                   flop_reduction=f"{reduction:.1%}",
                   flops_saved=int(lay.flops_saved(IN_DIM)))
    if ctx.dry:
        report = _check_instrumented(BLOCK_NAME, ctx, p_pairs, params, x)
        aggs = [r for r in report.records
                if r.phase in ("aggregate", "fused_agg_combine")]
        if not aggs or any(r.dedup_pairs != lay.num_pairs for r in aggs):
            raise RuntimeError(
                f"{BLOCK_NAME}: instrumented aggregation records do not "
                f"carry the layout's pair count {lay.num_pairs}")
        ctx.emit(BLOCK_NAME, 0.0, report_phases=len(report.records),
                 **derived)
    else:
        t_none = ctx.time(p_none.compile(), params, x)
        t_pairs = ctx.time(p_pairs.compile(), params, x)
        if not t_pairs < t_none:
            raise RuntimeError(
                f"{BLOCK_NAME}: dedup compiled forward ({t_pairs:.1f}us) "
                f"is not faster than naive ({t_none:.1f}us) despite "
                f"{reduction:.1%} fewer aggregation FLOPs -- analytic "
                "savings must cash out as wall time")
        ctx.emit(BLOCK_NAME, t_pairs, naive_us=round(t_none, 3),
                 speedup=f"{t_none / t_pairs:.2f}x", **derived)


def _sparse(ctx, _):
    """The counter-workload: sparse full-graph layer, near-zero matchable
    pairs -- 'auto' must keep 'none' and the naive path stays golden."""
    g = _sparse_graph()
    p_auto = build_plan(g, _cfg(), IN_DIM, CLASSES, dedup="auto")
    if p_auto.dedup != "none":
        raise RuntimeError(
            f"{SPARSE_NAME}: dedup='auto' picked {p_auto.dedup!r} on a "
            "sparse full-graph layer where pair savings cannot beat the "
            "layout's own traffic")
    lay = dedup_layout_for_graph(g)
    p_none, _, params, x = _plans(g)
    if ctx.dry:
        report = _check_instrumented(SPARSE_NAME, ctx, p_auto, params, x)
        if any(r.dedup_pairs for r in report.records):
            raise RuntimeError(f"{SPARSE_NAME}: dedup='none' resolution "
                               "still recorded matched pairs")
        ctx.emit(SPARSE_NAME, 0.0, pairs=lay.num_pairs,
                 edges=g.num_edges, resolved=p_auto.dedup,
                 report_phases=len(report.records))
    else:
        ctx.emit(SPARSE_NAME, ctx.time(p_auto.compile(), params, x),
                 pairs=lay.num_pairs, resolved=p_auto.dedup)


def _choose(ctx, _):
    """Pin the priced flip on ONE machine preset: fanout-regular block ->
    'pairs', sparse layer -> 'none'."""
    gd = _fanout_regular_block()
    ld = dedup_layout_for_graph(gd)
    gs = _sparse_graph()
    ls = dedup_layout_for_graph(gs)
    got_d = choose_dedup(gd.num_vertices, gd.num_edges, IN_DIM,
                         num_pairs=ld.num_pairs, num_edges2=ld.num_edges2,
                         machine=TPU_V5E)
    got_s = choose_dedup(gs.num_vertices, gs.num_edges, IN_DIM,
                         num_pairs=ls.num_pairs, num_edges2=ls.num_edges2,
                         machine=TPU_V5E)
    if (got_d, got_s) != ("pairs", "none"):
        raise RuntimeError(
            f"{CHOOSE_NAME}: choose_dedup did not flip between workloads "
            f"on {TPU_V5E.name}: fanout-regular -> {got_d!r} (want "
            f"'pairs'), sparse -> {got_s!r} (want 'none')")
    model = dedup_model(gd.num_vertices, gd.num_edges, IN_DIM,
                        num_pairs=ld.num_pairs, num_edges2=ld.num_edges2,
                        machine=TPU_V5E)
    ctx.emit(CHOOSE_NAME, 0.0, block=got_d, sparse=got_s,
             block_pairs=ld.num_pairs, sparse_pairs=ls.num_pairs,
             saving=f"{model['pairs']['saving']:.1%}")


SPECS = [
    BenchSpec(name="dedup/block", measure=_block, dry="run"),
    BenchSpec(name="dedup/sparse", measure=_sparse, dry="run"),
    BenchSpec(name="dedup/choose", measure=_choose, dry="run"),
]


def post_run(rows, dry: bool = False):
    """Cell accounting: every dedup scenario must have emitted a row --
    a silently missing cell fails the smoke gate."""
    matrix = set(expected_matrix())
    validated = [r["name"] for r in rows if r["name"] in matrix]
    missing = [n for n in expected_matrix() if n not in validated]
    if missing:
        raise RuntimeError(
            "dedup cells silently skipped: " + ", ".join(missing))
    print(f"# dedup matrix: {len(validated)} cell(s) validated, 0 silent")


def run(dry: bool = False):
    """Direct-invocation entry (``python -m benchmarks.bench_dedup
    [--dry-run]``); writes the same CSV artifact benchmarks/run.py does."""
    from repro.profile.bench import BENCH_ARTIFACT_DIR
    rows = run_specs(
        SPECS, dry=dry,
        csv=BENCH_ARTIFACT_DIR / f"bench_dedup{'.dry' if dry else ''}.csv")
    post_run(rows, dry=dry)


if __name__ == "__main__":
    run(dry="--dry-run" in sys.argv)
