"""Pallas GPU kernels: row-blocked segmented aggregation (+ fused combine).

The paper characterizes GCN aggregation on a V100: the scatter kernel's
atomicAdd serializes whenever two warps hit the same destination row, and
the aggregated matrix makes a full HBM round-trip before Combination.
Accel-GCN's answer (arXiv 2308.11825) is *row-partitioned* aggregation:
assign each destination row block to one thread block outright, stream its
edges with coalesced loads, and keep the accumulator on-chip.

These kernels are that design expressed in Pallas, and they differ from the
TPU tier (kernels/seg_agg.py, kernels/fused_agg_combine.py) exactly where
the memory hierarchies differ:

  * **No sequential grid accumulation.**  The TPU kernels run a
    ``(dest_blocks, edge_chunks)`` grid whose second dimension is
    "arbitrary" (sequential) and accumulate into a VMEM scratch buffer
    across grid steps.  GPU grid steps are *independent thread blocks* --
    accumulating across them needs the very atomics the paper indicts.  So
    here the grid is ``(dest_blocks,)`` and each program loops over its
    edge chunks with ``fori_loop``, carrying the accumulator in registers:
    one CTA owns one output block, collisions cannot exist.
  * **Coalesced edge-block loads.**  Edges arrive pre-grouped by
    destination block (the same ``BlockedGraph`` layout the TPU tier uses),
    so every chunk load is a dense ``(tile_e, F)`` slab -- contiguous along
    the feature (last) axis, which is the coalescing axis for a warp.
  * **Occupancy-aware tiling.**  ``tile_m`` defaults come from
    ``core.dataflow.suggest_tile_m(..., backend="pallas-gpu")``, which fits
    the working set into a *fraction* of the SM's shared-memory carveout
    (the A100 Machine preset's ``on_chip_bytes / target_ctas`` --
    ``repro.profile.machine``) instead of the TPU's
    half-VMEM budget: a GPU hides HBM latency with multiple resident CTAs,
    not one giant tile.
  * **Fused epilogue.**  The fused variant multiplies the register
    accumulator by the weight tile before it ever leaves the SM -- the
    paper's F5 dataflow fusion -- with W read once per CTA (it lives in L2
    across the grid, the GPU analogue of the TPU kernels' VMEM-pinned W).

Off-GPU the kernels run in Pallas interpret mode
(``core.backend.interpret_for("pallas-gpu")``), so a CPU-only container
still validates their numerics; on a real GPU they lower through
Pallas/Triton.  Only generic ``pl`` APIs are used -- no ``pltpu`` scratch
or TPU compiler params -- precisely so the same body serves both.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.backend import PALLAS_GPU, resolve_interpret


def _chunk_reduce(seg_ref, mask_ref, rows_ref, tile_m: int, tile_e: int,
                  f: int, acc_dtype=jnp.float32) -> jnp.ndarray:
    """Register-resident reduction of one destination block's edge chunks.

    ``acc_dtype`` is the register accumulator precision -- f32 even for
    bf16 ``rows`` (the reduced-precision plan contract: reduced storage,
    full-precision accumulate)."""
    emax = seg_ref.shape[-1]
    nchunks = emax // tile_e

    def body(c, acc):
        sl = pl.ds(c * tile_e, tile_e)
        seg = seg_ref[0, sl]            # (tile_e,) local dest row ids
        msk = mask_ref[0, sl]           # (tile_e,)
        rows = rows_ref[0, sl, :]       # (tile_e, F) coalesced slab
        row_ids = jax.lax.broadcasted_iota(jnp.int32, (tile_m, tile_e), 0)
        onehot = jnp.where(row_ids == seg[None, :], msk[None, :], 0.0)
        return acc + jax.lax.dot(
            onehot.astype(acc_dtype), rows.astype(acc_dtype),
            preferred_element_type=acc_dtype)

    acc0 = jnp.zeros((tile_m, f), acc_dtype)
    return jax.lax.fori_loop(0, nchunks, body, acc0)


def _seg_agg_gpu_kernel(seg_ref, mask_ref, rows_ref, out_ref, *,
                        tile_m: int, tile_e: int, acc_dtype=jnp.float32):
    f = rows_ref.shape[-1]
    acc = _chunk_reduce(seg_ref, mask_ref, rows_ref, tile_m, tile_e, f,
                        acc_dtype)
    out_ref[0] = acc.astype(out_ref.dtype)


def _fused_gpu_kernel(seg_ref, mask_ref, rows_ref, w_ref, out_ref, *,
                      tile_m: int, tile_e: int, acc_dtype=jnp.float32):
    f = rows_ref.shape[-1]
    acc = _chunk_reduce(seg_ref, mask_ref, rows_ref, tile_m, tile_e, f,
                        acc_dtype)
    # F5 fusion point: the aggregate never leaves the SM before the GEMM.
    out_ref[0] = jax.lax.dot(
        acc, w_ref[...].astype(acc_dtype),
        preferred_element_type=acc_dtype).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_e", "interpret",
                                             "acc_dtype"))
def seg_agg_gpu_blocked(rows: jnp.ndarray, seg_local: jnp.ndarray,
                        mask: jnp.ndarray, *, tile_m: int, tile_e: int = 128,
                        interpret: Optional[bool] = None,
                        acc_dtype=jnp.float32) -> jnp.ndarray:
    """Row-blocked segmented sum, one thread block per destination block.

    Args:
      rows:      (nblocks, emax, F) pre-gathered edge rows grouped by
                 destination block (core.dataflow.block_graph layout).
      seg_local: (nblocks, emax) int32 destination row id LOCAL to the block.
      mask:      (nblocks, emax) 1/0 edge validity.
      tile_m:    output rows per block (static; warp-multiple).
      tile_e:    edge chunk per ``fori_loop`` step (static; emax must be a
                 multiple -- smaller than the TPU default because the chunk
                 slab shares the SM with ``A100.target_ctas`` peers).
      interpret: None = auto (compiled on GPU, interpreted elsewhere --
                 ``core.backend.interpret_for("pallas-gpu")``).
      acc_dtype: register accumulator dtype (static); stays f32 for bf16
                 ``rows`` (reduced storage, full-precision accumulate).

    Returns (nblocks * tile_m, F).
    """
    interpret = resolve_interpret(interpret, backend=PALLAS_GPU)
    nblocks, emax, f = rows.shape
    assert emax % tile_e == 0, (emax, tile_e)

    out = pl.pallas_call(
        functools.partial(_seg_agg_gpu_kernel, tile_m=tile_m, tile_e=tile_e,
                          acc_dtype=acc_dtype),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, emax), lambda b: (b, 0)),       # seg ids
            pl.BlockSpec((1, emax), lambda b: (b, 0)),       # mask
            pl.BlockSpec((1, emax, f), lambda b: (b, 0, 0)),  # rows
        ],
        out_specs=pl.BlockSpec((1, tile_m, f), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, tile_m, f), rows.dtype),
        interpret=interpret,
        name="seg_agg_gpu",
    )(seg_local.reshape(nblocks, emax), mask.reshape(nblocks, emax), rows)
    return out.reshape(nblocks * tile_m, f)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_e", "interpret",
                                             "acc_dtype"))
def fused_agg_combine_gpu_blocked(rows: jnp.ndarray, seg_local: jnp.ndarray,
                                  mask: jnp.ndarray, w: jnp.ndarray, *,
                                  tile_m: int, tile_e: int = 128,
                                  interpret: Optional[bool] = None,
                                  acc_dtype=jnp.float32) -> jnp.ndarray:
    """out[block b] = (sum_seg rows[b]) @ w, fused inside one thread block.

    Same contract as the TPU tier's ``fused_agg_combine_blocked`` but with
    the register accumulator + in-kernel edge loop described in the module
    docstring.  ``acc_dtype`` keeps the register accumulator f32 even for
    bf16 rows/W.  Returns (nblocks * tile_m, F_out) in w.dtype.
    """
    interpret = resolve_interpret(interpret, backend=PALLAS_GPU)
    nblocks, emax, f_in = rows.shape
    f_out = w.shape[1]
    assert w.shape[0] == f_in, (w.shape, f_in)
    assert emax % tile_e == 0, (emax, tile_e)

    out = pl.pallas_call(
        functools.partial(_fused_gpu_kernel, tile_m=tile_m, tile_e=tile_e,
                          acc_dtype=acc_dtype),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, emax), lambda b: (b, 0)),
            pl.BlockSpec((1, emax), lambda b: (b, 0)),
            pl.BlockSpec((1, emax, f_in), lambda b: (b, 0, 0)),
            pl.BlockSpec((f_in, f_out), lambda b: (0, 0)),  # W: one L2 read
        ],
        out_specs=pl.BlockSpec((1, tile_m, f_out), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, tile_m, f_out), w.dtype),
        interpret=interpret,
        name="fused_agg_combine_gpu",
    )(seg_local.reshape(nblocks, emax), mask.reshape(nblocks, emax), rows, w)
    return out.reshape(nblocks * tile_m, f_out)
