"""Offered-load serving sweep: GraphServeEngine latency/throughput curves.

Two ``BenchSpec``s drive the GCN serving engine:

  * ``serve/load`` -- CLOSED loop: for each load level, a fresh
    ``GraphServeEngine`` is warmed up (every bucket compiled before
    admission), a synthetic workload of node-prediction requests
    (1..max-seeds seed batches drawn from a seeded RNG) is submitted up
    front, and ``engine.run()`` drains it through the bucketed compiled
    plans.
  * ``serve/poisson`` -- OPEN loop: requests arrive at Poisson times
    (exponential inter-arrival gaps at offered load lambda req/s, drawn
    from the same seeded ``rng=`` generator that picks the seed batches)
    while the engine ticks (``SlotServeCore.tick``) between arrivals, so
    measured latency includes queueing delay behind the offered load, not
    just service time -- the curve that shows where the engine saturates.

Each sweep point lands one CSV row (under ``experiments/bench/``) with the
per-request latency percentiles (p50/p95/p99 ms), end-to-end throughput
(req/s), and the serving-contract counters (bucket hits/misses, retraces,
plan-cache stats); open-loop rows add the offered load.

Under dry-run (the scripts/smoke.sh gate) the CLOSED-loop sweep is still
the serving acceptance gate (unchanged by the open-loop addition), and it
HARD-FAILS on any contract violation:

  * a bucket miss (every synthetic request must fit the bucket ladder),
  * a retrace after ``warmup()`` (each bucket compiles exactly once),
  * empty serving stats (served != submitted, or zero-latency percentiles),
  * padded-vs-eager drift: for sampled probe requests the bucketed compiled
    result must be BIT-IDENTICAL to the same plan's eager forward on the
    unpadded union block,
  * a ``workload_report()`` that fails schema validation or lacks the
    serving section.

The 200-request point doubles as the repo's serving acceptance criterion
(drain 200 requests through <= 4 buckets with zero retraces).  Wall-clock
convention as everywhere: CPU latencies are correctness-shaped observables,
not accelerator predictions.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.models.gcn import make_paper_model
from repro.profile.bench import BenchSpec, run_specs
from repro.profile.machine import H100
from repro.serve import GraphRequest, GraphServeEngine, default_buckets

#: closed-loop offered loads (requests per drain); 200 is the acceptance run
LOADS = (25, 50, 100, 200)
#: open-loop Poisson offered loads, requests per second
POISSON_RPS = (50, 200)
#: requests per open-loop point (kept small: arrivals are real wall-clock)
POISSON_REQUESTS = 40
FANOUTS = (3, 3)
SEED_LEVELS = (4, 16)       # 2 buckets; acceptance allows <= 4
MAX_SEEDS = SEED_LEVELS[-1]


def _make_engine(ctx) -> GraphServeEngine:
    m = make_paper_model("gcn", ctx.spec)
    eng = GraphServeEngine(
        ctx.g, m.cfg, None, ctx.x, ctx.spec.num_classes,
        buckets=default_buckets(FANOUTS, SEED_LEVELS,
                                max_inputs=ctx.g.num_vertices),
        fanouts=FANOUTS, max_batch=8, seed=0, machine=ctx.machine)
    eng.params = eng.init_params(jax.random.PRNGKey(0))
    return eng


def _request(eng: GraphServeEngine, rid: int,
             rng: np.random.Generator) -> GraphRequest:
    s = rng.choice(eng.g.num_vertices,
                   size=int(rng.integers(1, MAX_SEEDS + 1)), replace=False)
    return GraphRequest(rid=rid, seeds=s)


def _workload(eng: GraphServeEngine, n: int, rng: np.random.Generator):
    for i in range(n):
        eng.submit(_request(eng, i, rng))


def _drive_open_loop(eng: GraphServeEngine, n: int, lam_rps: float,
                     rng: np.random.Generator) -> list:
    """Open-loop driver: submit request i at its Poisson arrival time
    (cumulative exponential gaps at rate ``lam_rps``), ticking the engine
    between arrivals so service overlaps the arrival process.  Requests
    the engine can't keep up with queue -- and their wait shows up in the
    latency percentiles, which is the point of the open loop."""
    arrivals = np.cumsum(rng.exponential(1.0 / lam_rps, size=n))
    done: list = []
    t0 = time.perf_counter()
    i = 0
    while len(done) < n:
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            eng.submit(_request(eng, i, rng))
            i += 1
        got = eng.tick()
        done.extend(got)
        if not got and i < n and eng.outstanding == 0:
            # idle until the next arrival (bounded nap: re-check arrivals)
            gap = arrivals[i] - (time.perf_counter() - t0)
            if gap > 0:
                time.sleep(min(gap, 0.005))
    return done


def _check_contract(name: str, eng: GraphServeEngine, n: int,
                    done: list) -> None:
    """The dry-run serving gate: any violation is a hard smoke failure."""
    s = eng.stats()
    if len(done) != n or s["served"] != n:
        raise RuntimeError(f"{name}: served {s['served']}/{n} requests "
                           "(drain incomplete -- empty/partial stats)")
    if s["bucket_misses"]:
        raise RuntimeError(f"{name}: {s['bucket_misses']} bucket miss(es); "
                           "every synthetic request must fit the ladder")
    if s["retraces"]:
        raise RuntimeError(f"{name}: {s['retraces']} retrace(s) after "
                           "warmup(); each bucket compiles exactly once")
    if not (0 < s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"]):
        raise RuntimeError(f"{name}: degenerate latency percentiles "
                           f"{s['p50_ms']}/{s['p95_ms']}/{s['p99_ms']}")
    if len(eng.buckets) > 4:
        raise RuntimeError(f"{name}: {len(eng.buckets)} buckets > 4")
    if any(r.logits is None or not np.isfinite(r.logits).all()
           for r in done):
        raise RuntimeError(f"{name}: non-finite/missing logits in results")
    # padded-vs-eager bit identity on fresh probe blocks (one per bucket
    # seed level, so both buckets are exercised)
    probe_rng = np.random.default_rng(7)
    for lvl in SEED_LEVELS:
        seeds = probe_rng.choice(eng.g.num_vertices, size=lvl,
                                 replace=False)
        prep = eng.prepare(seeds)
        padded = eng.run_prepared(prep)
        eager = eng.run_eager(prep)
        if not np.array_equal(padded, eager):
            err = float(np.abs(padded - eager).max())
            raise RuntimeError(
                f"{name}: padded compiled result differs from unpadded "
                f"eager forward (max |diff|={err:.3e}); the bucket "
                "contract is bitwise")
    report = eng.workload_report()         # .validate() runs inside
    if report.serving is None or report.serving["requests"] != n:
        raise RuntimeError(f"{name}: workload report lacks the serving "
                           "section")


def _load_point(ctx, num_requests):
    """One offered-load level: fresh engine, warmup, drain, one CSV row."""
    eng = _make_engine(ctx)
    traces = eng.warmup()
    if any(t != 1 for t in traces.values()):
        raise RuntimeError(f"warmup() traced {traces}; expected exactly "
                           "one compile per bucket")
    _workload(eng, num_requests, np.random.default_rng(num_requests))
    done = eng.run()
    s = eng.stats()
    name = f"serve/load/{num_requests}"
    if ctx.dry:
        _check_contract(name, eng, num_requests, done)
    ctx.emit(name, 0.0, requests=num_requests,
             p50_ms=round(s["p50_ms"], 3), p95_ms=round(s["p95_ms"], 3),
             p99_ms=round(s["p99_ms"], 3),
             throughput_rps=round(s["throughput_rps"], 1),
             bucket_hits=s["bucket_hits"],
             bucket_misses=s["bucket_misses"], retraces=s["retraces"],
             buckets=len(eng.buckets),
             plan_cache_size=s["plan_cache"]["size"],
             steps=s["steps"])


def _poisson_point(ctx, lam_rps):
    """One open-loop offered-load level: fresh engine, warmup, Poisson
    arrivals at ``lam_rps`` req/s interleaved with engine ticks."""
    eng = _make_engine(ctx)
    traces = eng.warmup()
    if any(t != 1 for t in traces.values()):
        raise RuntimeError(f"warmup() traced {traces}; expected exactly "
                           "one compile per bucket")
    n = POISSON_REQUESTS
    done = _drive_open_loop(eng, n, float(lam_rps),
                            np.random.default_rng(int(lam_rps)))
    s = eng.stats()
    name = f"serve/poisson/{lam_rps}"
    if ctx.dry:
        # same contract as the closed loop minus the per-bucket probe
        # (padded-vs-eager bit identity is owned by the closed-loop gate)
        if len(done) != n or s["served"] != n:
            raise RuntimeError(f"{name}: served {s['served']}/{n} "
                               "(open loop failed to drain)")
        if s["bucket_misses"] or s["retraces"]:
            raise RuntimeError(
                f"{name}: {s['bucket_misses']} miss(es) / "
                f"{s['retraces']} retrace(s) under open-loop load")
        if not (0 < s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"]):
            raise RuntimeError(f"{name}: degenerate latency percentiles "
                               f"{s['p50_ms']}/{s['p95_ms']}/{s['p99_ms']}")
        if any(r.logits is None or not np.isfinite(r.logits).all()
               for r in done):
            raise RuntimeError(f"{name}: non-finite/missing logits")
    ctx.emit(name, 0.0, requests=n, offered_rps=lam_rps,
             p50_ms=round(s["p50_ms"], 3), p95_ms=round(s["p95_ms"], 3),
             p99_ms=round(s["p99_ms"], 3),
             throughput_rps=round(s["throughput_rps"], 1),
             bucket_hits=s["bucket_hits"],
             bucket_misses=s["bucket_misses"], retraces=s["retraces"],
             buckets=len(eng.buckets),
             plan_cache_size=s["plan_cache"]["size"],
             steps=s["steps"])


SPECS = [
    BenchSpec(name="serve/load", graph="reddit", max_vertices=2048,
              max_feature=64, dry_max_vertices=256, machine=H100,
              sweep=LOADS, measure=_load_point, dry="run"),
    BenchSpec(name="serve/poisson", graph="reddit", max_vertices=2048,
              max_feature=64, dry_max_vertices=256, machine=H100,
              sweep=POISSON_RPS, measure=_poisson_point, dry="run"),
]


def post_run(rows, dry: bool = False):
    """Sweep accounting: every offered-load level must have emitted a row
    (a silently skipped level would merge unvalidated)."""
    names = {r["name"] for r in rows}
    expected = [f"serve/load/{n}" for n in LOADS] + \
               [f"serve/poisson/{r}" for r in POISSON_RPS]
    missing = [n for n in expected if n not in names]
    if missing:
        raise RuntimeError("serving sweep points silently skipped: "
                           + ", ".join(missing))
    print(f"# serving sweep: {len(LOADS)} closed + {len(POISSON_RPS)} "
          "open-loop level(s) validated, 0 silent")


def run(dry: bool = False):
    """Direct-invocation entry (``python -m benchmarks.bench_serve
    [--dry-run]``); writes the same CSV artifact benchmarks/run.py does."""
    from repro.profile.bench import BENCH_ARTIFACT_DIR
    rows = run_specs(
        SPECS, dry=dry,
        csv=BENCH_ARTIFACT_DIR / f"bench_serve{'.dry' if dry else ''}.csv")
    post_run(rows, dry=dry)


if __name__ == "__main__":
    import sys
    run(dry="--dry-run" in sys.argv)
