"""Pallas TPU kernel: blockwise online-softmax (flash) attention.

Substrate kernel for the LM architectures (32k prefill / 500k decode would
materialize O(S^2) score matrices otherwise).  Supports GQA head grouping,
causal masking with decode-style right alignment, sliding windows (gemma2
local layers), logit soft-capping (gemma2), and padded KV caches via a
per-batch valid length.

Tiling: grid (batch, q_heads, Sq/tile_q, Sk/tile_k), KV innermost with
``arbitrary`` semantics; running max/sum and the output accumulator live in
VMEM scratch across KV steps (lane-broadcast (tile_q, 128) layout for the
scalars, the standard Mosaic-friendly shape).  Fully-masked KV blocks are
skipped with ``pl.when`` (causal upper triangle + out-of-window blocks), so
causal attention does ~half the MXU work and sliding-window attention is
O(S * window).

The pure-jnp oracle is ``ref.mha_ref``; tests sweep shapes/dtypes/flags.
"""

from __future__ import annotations

# analysis: allow-file(acc-dtype) -- the online-softmax running max/sum
# and output accumulator are ALWAYS f32 regardless of the plan's dtype
# (numerical requirement of the rescaling recurrence, outside the GCN
# acc_dtype threading contract).

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30


def _flash_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *,
                  tile_q: int, tile_k: int, sk: int, sq: int,
                  causal: bool, window: int, softcap: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions: with a padded cache of kv_len valid entries, the
    # last q row sits at position kv_len - 1 (decode-style right alignment).
    kv_len = kvlen_ref[0]
    q_off = kv_len - sq
    q_lo = q_off + qi * tile_q
    k_lo = ki * tile_k

    # block-level skip: causal => no k block strictly after the last q row;
    # sliding window => no k block before the window of the first q row.
    relevant = k_lo < kv_len
    if causal:
        relevant &= k_lo <= q_lo + tile_q - 1
    if window > 0:
        relevant &= (k_lo + tile_k - 1) > (q_lo - window)

    @pl.when(relevant)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (tile_q, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (tile_k, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (tile_q, tile_k), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (tile_q, tile_k), 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...][:, :1]                   # (tile_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard all-masked rows (m_new == NEG_INF)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(jnp.where(mask, s - m_safe, NEG_INF))
        alpha = jnp.exp(jnp.where(m_prev <= NEG_INF / 2, NEG_INF,
                                  m_prev - m_safe))
        l_new = l_ref[...][:, :1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "tile_q",
                              "tile_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    kv_len: Optional[jnp.ndarray] = None, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, tile_q: int = 128,
                    tile_k: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); returns (B, Hq, Sq, D).

    Sq and Sk are padded to tile multiples internally; ``kv_len`` (B,) marks
    valid KV entries (defaults to Sk).  interpret None = auto-detect
    (core.backend.default_interpret).
    """
    from repro.core.backend import resolve_interpret
    interpret = resolve_interpret(interpret)
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = d ** -0.5

    sq_p = -(-sq // tile_q) * tile_q
    sk_p = -(-sk // tile_k) * tile_k
    if kv_len is None:
        kv_len = jnp.full((b,), sk, jnp.int32)
    qp = jnp.pad(q * scale, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))

    grid = (b, hq, sq_p // tile_q, sk_p // tile_k)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, tile_q=tile_q, tile_k=tile_k, sk=sk, sq=sq,
            causal=causal, window=window, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb, h, qi, ki: (bb,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, tile_q, d),
                         lambda bb, h, qi, ki: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, tile_k, d),
                         lambda bb, h, qi, ki: (bb, h // group, ki, 0)),
            pl.BlockSpec((1, 1, tile_k, d),
                         lambda bb, h, qi, ki: (bb, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tile_q, d),
                               lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q, d), jnp.float32),
            pltpu.VMEM((tile_q, 128), jnp.float32),
            pltpu.VMEM((tile_q, 128), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(kv_len.astype(jnp.int32), qp, kp, vp)
    return out[:, :, :sq]
