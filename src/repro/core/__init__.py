# The paper's primary contribution: phase-split execution of GCNs
# (Aggregation vs Combination), the phase-ordering scheduler (Table 4),
# tiled inter-phase dataflow (F5), the characterization machinery, and the
# GraphExecutionPlan planning/dispatch layer that composes them (plan.py).
from repro.core import (backend, characterize, dataflow, gcn_layers, phases,
                        plan, scheduler)
