"""Degree-aware access scheduling (paper §5.1 guideline 1), as graph reorder.

The paper observes (F4) that Aggregation's L2 hit ratio collapses (6.9% vs
56.2% for PageRank on the same graph) because feature rows are hundreds of
elements long, so the cache holds few vertices and reuse distance explodes.
Its software guideline: schedule accesses so high-degree (highly reused)
vertices are touched close together.

On TPU the "cache" is the HBM->VMEM block stream, so the same idea becomes a
*renumbering + edge-ordering* transform applied once, host-side:

  1. ``degree_reorder``  -- renumber vertices by descending out-degree, so the
     hottest source rows cluster into the lowest feature-matrix blocks; a
     block-resident gather then reuses them across many edges.
  2. Edges stay destination-sorted (collision-free segmented reduce), but
     within a destination segment sources become *ascending*, which makes the
     gather stream quasi-monotonic -- short reuse distance by construction.

``reuse_distance_stats`` quantifies the effect (used by bench_agg_vs_pgr to
reproduce the paper's Fig.2(g) L2 observation in an architecture-neutral way:
we report the fraction of accesses whose reuse distance fits a given budget
of resident feature rows -- a direct proxy for hit ratio under LRU).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.graph.structure import Graph, graph_from_coo


def degree_reorder(g: Graph) -> Tuple[Graph, np.ndarray]:
    """Renumber vertices by descending (out_deg + in_deg).

    Returns (reordered graph, perm) with ``perm[old_id] = new_id`` so callers
    can permute feature/label rows: ``x_new[perm] = x_old`` i.e.
    ``x_new = x_old[inv]``.
    """
    deg = np.asarray(g.out_deg) + np.asarray(g.in_deg)
    order = np.argsort(-deg, kind="stable")  # old ids in new order
    perm = np.empty_like(order)
    perm[order] = np.arange(len(order))
    src = perm[np.asarray(g.src)]
    dst = perm[np.asarray(g.dst)]
    return graph_from_coo(src, dst, g.num_vertices), perm


def apply_vertex_perm(x: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Permute rows so row old-i lands at new position perm[i]."""
    out = np.empty_like(x)
    out[perm] = x
    return out


def reuse_distance_stats(access_stream: np.ndarray,
                         budgets: Tuple[int, ...] = (64, 256, 1024, 4096),
                         ) -> Dict[str, float]:
    """LRU stack-distance analysis of a vertex access stream.

    ``access_stream`` is the sequence of source-vertex ids touched by the
    gather (i.e. ``graph.src`` in edge order).  For each budget B (number of
    feature rows a cache level can hold) we report the hit ratio of a
    fully-associative LRU -- the architecture-neutral restatement of the
    paper's L2 measurements: with 1-element features (PageRank) a 6 MiB L2
    holds ~1.5M vertices; with 602-float rows it holds ~2.6K, which is why the
    hit rate collapses.

    O(N log N) via the classic Bennett-Kruskal BIT algorithm.
    """
    stream = np.asarray(access_stream, dtype=np.int64)
    n = len(stream)
    last_pos: Dict[int, int] = {}
    bit = np.zeros(n + 2, dtype=np.int64)  # Fenwick tree over positions

    def bit_add(i: int, v: int):
        i += 1
        while i < len(bit):
            bit[i] += v
            i += i & (-i)

    def bit_sum(i: int) -> int:  # prefix sum [0, i]
        i += 1
        s = 0
        while i > 0:
            s += bit[i]
            i -= i & (-i)
        return int(s)

    distances = np.empty(n, dtype=np.int64)
    for t, v in enumerate(stream):
        v = int(v)
        if v in last_pos:
            p = last_pos[v]
            # distinct elements touched in (p, t) = stack distance
            distances[t] = bit_sum(t - 1) - bit_sum(p)
            bit_add(p, -1)
        else:
            distances[t] = -1  # cold miss
        bit_add(t, 1)
        last_pos[v] = t

    out: Dict[str, float] = {}
    reuses = distances >= 0
    out["cold_miss_frac"] = float((~reuses).mean()) if n else 0.0
    out["mean_reuse_distance"] = (
        float(distances[reuses].mean()) if reuses.any() else float("inf"))
    for b in budgets:
        hits = (distances >= 0) & (distances < b)
        out[f"hit_ratio@{b}"] = float(hits.mean()) if n else 0.0
    return out


def choose_reorder(g: Graph, g_reordered: Graph, perm: np.ndarray,
                   feature_len: int, machine, threshold: float = 0.02,
                   max_stream: int = 20000) -> str:
    """Decide ``"degree"`` vs ``"none"`` from reuse-distance stats (§5.1-1).

    Prices the paper's L2 observation against a concrete ``machine``
    (``repro.profile.Machine``): the budget is the number of
    ``feature_len``-float rows the machine's fast on-chip memory
    (``machine.on_chip_bytes``) can hold, and the metric is the LRU hit
    ratio of the gather stream (``reuse_distance_stats``) under that
    budget.  Degree reordering is chosen iff it improves the hit ratio by
    more than ``threshold`` (absolute) -- i.e. only when the renumbering
    actually shortens reuse distances *at this machine's capacity*; tiny
    graphs whose working set already fits stay at ``"none"``.

    ``max_stream`` caps the analyzed stream (the Bennett-Kruskal analysis
    is O(N log N) host work).  Crucially both orderings are evaluated on
    the SAME edge population: up to ``max_stream`` edges the full streams,
    beyond that one uniform edge sample traversed in each graph's own
    execution (dst-sorted) order -- ``perm`` (``degree_reorder``'s
    ``perm[old_id] = new_id``) maps the sampled original edges to their
    positions in the reordered stream.  Comparing each graph's stream
    *prefix* instead would be biased: the reordered prefix holds exactly
    the hub destinations.  Used by ``build_plan(..., reorder="auto")``.
    """
    rows = max(1, int(machine.on_chip_bytes) // max(4 * feature_len, 4))
    src = np.asarray(g.src)
    e = len(src)
    if e <= max_stream:
        base_stream = src
        re_stream = np.asarray(g_reordered.src)
    else:
        perm = np.asarray(perm)
        sel = np.zeros(e, bool)
        sel[np.random.default_rng(0).choice(e, max_stream,
                                            replace=False)] = True
        base_stream = src[sel]
        # the same edges at their positions in the reordered execution
        # order (edges re-sort by new destination id, stable)
        order2 = np.argsort(perm[np.asarray(g.dst)], kind="stable")
        re_stream = perm[src][order2][sel[order2]]
    base = reuse_distance_stats(base_stream, budgets=(rows,))
    re = reuse_distance_stats(re_stream, budgets=(rows,))
    gain = re[f"hit_ratio@{rows}"] - base[f"hit_ratio@{rows}"]
    return "degree" if gain > threshold else "none"


def atomic_collision_model(dst: np.ndarray, feature_len: int,
                           warp: int = 32) -> Dict[str, float]:
    """Paper Fig.2(f) model: atomic transactions per request under a warp model.

    In the GPU implementation each scalar element update is an atomic.  With
    feature rows of length F >= warp, consecutive lanes update *different*
    elements of the same row -> no intra-warp collision (paper's observation).
    With F == 1 (PageRank) all lanes update whole words of random vertices ->
    collisions whenever two lanes in a warp share a destination.

    Returns expected transactions-per-request for both layouts; used by
    bench_agg_vs_pgr.  (TPU has no atomics -- this documents the eliminated
    hazard; our sorted-segment layout is collision-free by construction.)
    """
    dst = np.asarray(dst)
    if feature_len >= warp:
        row_collisions = 1.0  # one lane per element: serialization-free
    else:
        # lanes cover warp/feature_len destinations; count duplicates per warp
        per_warp = max(1, warp // max(1, feature_len))
        n = (len(dst) // per_warp) * per_warp
        groups = dst[:n].reshape(-1, per_warp)
        # transactions per request = mean group size among colliding lanes
        txn = []
        for gr in groups[: min(len(groups), 4096)]:
            _, counts = np.unique(gr, return_counts=True)
            txn.append(counts.mean())
        row_collisions = float(np.mean(txn)) if txn else 1.0
    return {"atomic_txn_per_request": row_collisions}
