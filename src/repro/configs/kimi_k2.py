"""kimi-k2-1t-a32b -- trillion-param MoE (paper-table).

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840, MoE 384e top-8.
[arXiv:2501.kimi2; unverified]

Analytic params ~1.04T total / ~32B active (matches '1t-a32b'); SwiGLU
experts (3 matrices) reproduce the published ratio.
Pure full attention -> long_500k skipped (DESIGN.md §4).
"""

import dataclasses

from repro.config import AttentionConfig, LMConfig, MoEConfig, register


def _base() -> LMConfig:
    return LMConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        d_ff=2048,
        vocab_size=163840,
        attention=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128),
        moe=MoEConfig(num_experts=384, top_k=8, expert_d_ff=2048,
                      capacity_factor=1.25),
        mlp_activation="swiglu",
        shape_skips=("long_500k",),
        skip_reason="pure full attention; 500k decode needs sub-quadratic",
        source="arXiv:2501.kimi2; unverified",
    )


@register("kimi-k2-1t-a32b")
def config() -> LMConfig:
    return _base()


def reduced() -> LMConfig:
    c = _base()
    return dataclasses.replace(
        c, name=c.name + "-smoke", num_layers=2, d_model=64, d_ff=32,
        vocab_size=256,
        attention=dataclasses.replace(c.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=16),
        moe=dataclasses.replace(c.moe, num_experts=8, top_k=2,
                                expert_d_ff=32))
