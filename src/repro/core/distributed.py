"""Distributed GCN execution: vertex-partitioned aggregation via shard_map.

The paper profiles a single GPU; this module is the cluster-scale story its
Table 4 implies (DESIGN.md §8.5): with a 1-D destination partition the
Aggregation phase's remote traffic is one feature row per cut edge, so
running Combination first shrinks the COLLECTIVE term by in_len/out_len --
the multi-chip restatement of the paper's 4.7x.

Two interchangeable aggregation strategies (both exact):

  * ``allgather``  -- one all-gather of the full feature matrix per layer,
    then purely local gather+segment-reduce.  Simple; wire bytes V*F.
  * ``ring``       -- collective_permute steps around the data-axis ring; at
    each step every device reduces the contributions of the block it
    currently holds.  Same total wire bytes as all-gather but only
    O(V/P * F) resident.

The ring strategy additionally has two SCHEDULES, selected by the
``overlap=`` plan decision (``build_plan(overlap=...)``, priced by
:func:`choose_overlap`):

  * ``overlap="none"``       -- ``_ring_local``: single-buffered; each hop
    reduces the resident slab and only then passes it onward (P sends, the
    send serialized behind the hop's partial combine).
  * ``overlap="pipelined"``  -- ``_ring_local_pipelined``: double-buffered;
    each hop issues the ``ppermute`` FIRST, so hop k+1's slab is in flight
    while hop k's resident slab is matmul-reduced into the accumulator --
    the collective rides under the per-hop partial combine instead of in
    front of it.  P-1 sends (the last resident slab is reduced without a
    send).  The per-hop partials are accumulated in exactly the same order
    as the single-buffered schedule, so both schedules are bit-for-bit
    equal -- eager and under ``plan.compile()``.

Both strategies run under shard_map on the ``data`` axis; per-shard edge
lists come from graph.partition (edge-balanced, padded static shapes).
:func:`overlap_model` / :func:`choose_overlap` price the schedules against
a ``Machine`` (per-hop link bytes vs. per-hop partial-combine work), and
``plan.instrument()`` reports the resulting exposed vs. overlapped
collective time per distributed record.

**2-D (node x feature) partitioning** (``distributed_gcn_layer_2d``)
generalizes the same halo patterns to a multi-host mesh: device (p, q) owns
node block p restricted to feature columns q, the ring/all-gather halo runs
along the *node* axis on rows that are only F/Q wide (per-device halo bytes
/ Q), and the Combination GEMM is a feature-parallel partial matmul closed
with one reduce-scatter (``psum_scatter``) over the *feature* axis.  The
intended placement is node
axis across hosts (the expensive, DCN-crossing halo shrinks by Q) and
feature axis across the fast intra-host links (the reduce-scatter stays
local).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.graph.partition import Partition2D, PartitionedGraph


def pad_features(x: jnp.ndarray, block: int, num_shards: int) -> jnp.ndarray:
    """Pad vertex features to num_shards*block rows (partition layout)."""
    total = block * num_shards
    v = x.shape[0]
    return jnp.pad(x, ((0, total - v), (0, 0)))


def _require_uniform(pg: PartitionedGraph) -> None:
    """The shard_map strategies lay out rows as p*block + local; that needs
    the UNIFORM partition (partition_1d(..., edge_balanced=False)).  The
    edge-balanced variant feeds the analytic load model instead."""
    starts = np.asarray(pg.vtx_start)
    expect = np.arange(pg.num_shards) * pg.block_size
    expect = np.minimum(expect, pg.num_vertices)
    if not np.array_equal(starts, expect):
        raise ValueError(
            "distributed aggregation requires a uniform partition; build "
            "with partition_1d(g, P, edge_balanced=False)")


def _local_agg(x_full, src, dst_local, mask, block):
    rows = jnp.take(x_full, src, axis=0) * mask[:, None]
    return jax.ops.segment_sum(rows, dst_local, num_segments=block)


def _allgather_local(x_loc, srcl, dstl, mskl, block, nsh, axis):
    """Per-device all-gather halo body (inside shard_map, over ``axis``)."""
    del nsh
    x_full = jax.lax.all_gather(x_loc, axis, tiled=True)
    return _local_agg(x_full, srcl, dstl, mskl, block)


def _hop_partial(buf, k, p, srcl, dstl, mskl, block, nsh):
    """Partial combine of hop k's resident slab: the contributions of the
    block currently held (``(p - k) mod P`` -- ring sends i -> i+1), masked
    so neither padding rows nor edges owned by other blocks enter the
    accumulator.  Shared by BOTH ring schedules so their per-hop math -- and
    therefore their accumulation order -- is structurally identical
    (bitwise-equal outputs are part of the overlap contract)."""
    owner = jnp.mod(p - k, nsh)                   # whose block we hold
    sel = (srcl // block) == owner
    local_src = srcl - owner * block
    rows = jnp.take(buf, jnp.clip(local_src, 0, block - 1), axis=0)
    rows = rows * (mskl * sel)[:, None]
    return jax.ops.segment_sum(rows, dstl, num_segments=block)


def _ring_local(x_loc, srcl, dstl, mskl, block, nsh, axis):
    """Per-device ring halo body, single-buffered (``overlap="none"``):
    nsh hops of collective_permute over ``axis``, each hop reducing the
    currently-held block's contributions and THEN passing it onward -- the
    send waits behind the hop's partial combine, so the wire time is fully
    exposed.  Shared by the 1-D path (axis = the single data axis) and the
    2-D path (axis = the node axis of the mesh; feature columns ride
    along).
    """
    p = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % nsh) for i in range(nsh)]  # ring

    def hop(carry, k):
        buf, acc = carry
        acc = acc + _hop_partial(buf, k, p, srcl, dstl, mskl, block, nsh)
        buf = jax.lax.ppermute(buf, axis, perm)   # pass block onward
        return (buf, acc), None

    # acc dtype: _hop_partial's f32 mask multiply promotes reduced (bf16)
    # slabs to f32 partials, so the accumulator must be the promoted type
    # while the ppermute wire keeps carrying the reduced x_loc slab.
    # f32 slabs: promote_types(f32, f32) == f32 -- unchanged.
    acc0 = jnp.zeros((block, x_loc.shape[-1]),
                     jnp.promote_types(x_loc.dtype, mskl.dtype))
    (_, acc), _ = jax.lax.scan(hop, (x_loc, acc0), jnp.arange(nsh))
    return acc


def _ring_local_pipelined(x_loc, srcl, dstl, mskl, block, nsh, axis):
    """Per-device ring halo body, double-buffered (``overlap="pipelined"``).

    Each hop issues the ``ppermute`` FIRST -- hop k+1's slab is in flight
    while hop k's resident slab is reduced into the accumulator -- and the
    final resident slab is reduced without a send, so the ring costs P-1
    sends (vs. P single-buffered) and every send rides under a partial
    combine.  This is the collective restatement of the accelerator
    double-buffering discipline (start the next transfer, process the
    current slot).

    Bitwise contract: the per-hop partials (``_hop_partial``) accumulate in
    the SAME order as ``_ring_local`` -- hop 0..P-1 added left to right
    onto a zero accumulator -- so both schedules return bit-identical
    results; only the issue order of communication vs. compute differs.
    """
    p = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % nsh) for i in range(nsh)]  # ring

    def hop(carry, k):
        buf, acc = carry
        nxt = jax.lax.ppermute(buf, axis, perm)   # in flight during reduce
        acc = acc + _hop_partial(buf, k, p, srcl, dstl, mskl, block, nsh)
        return (nxt, acc), None

    # same promoted accumulator as _ring_local (f32 partials over a reduced
    # bf16 wire slab); identical type for f32 slabs
    acc0 = jnp.zeros((block, x_loc.shape[-1]),
                     jnp.promote_types(x_loc.dtype, mskl.dtype))
    (buf, acc), _ = jax.lax.scan(hop, (x_loc, acc0), jnp.arange(nsh - 1))
    # last hop: the slab is already resident -- reduce it, send nothing
    return acc + _hop_partial(buf, nsh - 1, p, srcl, dstl, mskl, block, nsh)


_STRATEGIES = {"ring": _ring_local, "allgather": _allgather_local}

#: resolved overlap schedules a distributed layer accepts ("auto" is a
#: plan-level request resolved by ``choose_overlap`` before dispatch)
OVERLAP_MODES = ("none", "pipelined")


def _halo_body(strategy: str, overlap: str):
    """Resolve (strategy, overlap) to the per-device halo body, validating
    the combination: pipelining needs the ring's per-hop structure."""
    if strategy not in _STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"expected one of {sorted(_STRATEGIES)}")
    if overlap not in OVERLAP_MODES:
        raise ValueError(
            f"unknown overlap {overlap!r}; expected 'none' | 'pipelined' "
            "('auto' is resolved at plan build -- see choose_overlap)")
    if overlap == "pipelined":
        if strategy != "ring":
            raise ValueError(
                "overlap='pipelined' requires strategy='ring'; the "
                "all-gather halo is one collective with no per-hop "
                "structure to pipeline")
        return _ring_local_pipelined
    return _STRATEGIES[strategy]


def aggregate_allgather(pg: PartitionedGraph, x: jnp.ndarray, mesh: Mesh,
                        axis: str = "data") -> jnp.ndarray:
    """x: (P*block, F) sharded over `axis` -> aggregated (P*block, F)."""
    _require_uniform(pg)
    block = pg.block_size

    def fn(x_local, src, dst_local, mask, starts):
        out = _allgather_local(x_local[0], src[0], dst_local[0], mask[0],
                               block, pg.num_shards, axis)
        return out[None]

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P(axis, None),
                  P(axis)),
        out_specs=P(axis, None), check_rep=False,
    )(x.reshape(pg.num_shards, -1, x.shape[-1]), pg.src, pg.dst_local,
      pg.mask, pg.vtx_start).reshape(x.shape[0], x.shape[-1])


def aggregate_ring(pg: PartitionedGraph, x: jnp.ndarray, mesh: Mesh,
                   axis: str = "data", *,
                   overlap: str = "none") -> jnp.ndarray:
    """Ring halo exchange: collective_permutes with a partial reduce per
    hop.  ``overlap`` picks the schedule: ``"none"`` = single-buffered
    (``_ring_local``), ``"pipelined"`` = double-buffered with each send in
    flight under the resident slab's reduce (``_ring_local_pipelined``);
    both are bit-for-bit equal."""
    _require_uniform(pg)
    block = pg.block_size
    nsh = pg.num_shards
    local = _halo_body("ring", overlap)

    def fn(x_local, src, dst_local, mask):
        out = local(x_local[0], src[0], dst_local[0], mask[0],
                    block, nsh, axis)
        return out[None]

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P(axis, None)),
        out_specs=P(axis, None), check_rep=False,
    )(x.reshape(nsh, -1, x.shape[-1]), pg.src, pg.dst_local,
      pg.mask).reshape(x.shape[0], x.shape[-1])


def halo_bytes(pg: PartitionedGraph, feature_len: int,
               dtype_bytes: int = 4) -> dict:
    """Analytic collective cost of one distributed Aggregation (both strats).

    Reported by bench_ordering to show the combine-first collective saving.
    """
    v_padded = pg.block_size * pg.num_shards
    per_device = v_padded * feature_len * dtype_bytes * \
        (pg.num_shards - 1) / pg.num_shards
    # cut edges: sources not owned by the destination shard
    src = np.asarray(pg.src)
    starts = np.asarray(pg.vtx_start)
    owners = np.clip(np.searchsorted(starts, src, side="right") - 1, 0,
                     pg.num_shards - 1)
    mine = owners == np.arange(pg.num_shards)[:, None]
    cut_edges = int((np.asarray(pg.mask) * ~mine).sum())
    return {
        "allgather_bytes_per_device": per_device,
        "ring_bytes_per_device": per_device,  # same total, spread over hops
        "bytes_per_hop_per_device":           # one slab per ring hop
            pg.block_size * feature_len * dtype_bytes,
        "ring_hops": max(pg.num_shards - 1, 0),
        "cut_edges": cut_edges,
        "min_halo_bytes": cut_edges * feature_len * dtype_bytes,
    }


# ---------------------------------------------------------------------------
# Overlap pricing (the plan's ``overlap="auto"`` decision model)
# ---------------------------------------------------------------------------

#: minimum modeled saving (fraction of the exchange's single-buffered time)
#: at which ``choose_overlap`` commits to the pipelined schedule -- below
#: this the double-buffer's extra resident slab and scheduling constraints
#: buy nothing material, so auto keeps the simpler single-buffered ring.
OVERLAP_SAVING_THRESHOLD = 0.02


def overlap_model(pg: PartitionedGraph, feature_len: int, machine, *,
                  strategy: str = "ring", dtype_bytes: int = 4) -> dict:
    """Price both ring schedules for ONE halo exchange on ``machine``.

    The model the plan's ``overlap="auto"`` decision (and the exposed /
    overlapped split in ``plan.instrument()`` reports) is built on:

      * per hop, every device sends one (block, feature_len) slab over a
        single interconnect link -- ``t_wire_hop = Machine.hop_time(bytes)``
        (per-hop link bandwidth + link latency, NOT the aggregate
        ``interconnect_total``: a ring saturates one link per direction);
      * per hop, the resident slab's partial combine walks the device's
        whole local edge list (the owner mask zeroes foreign and padding
        rows), so per-hop compute is the full aggregation roofline divided
        by the shard count.

    Single-buffered (``overlap="none"``) exposes every hop's wire time;
    the pipelined schedule hides ``min(t_wire, t_comp)`` per hop under the
    partial combine.  ``feature_len`` is the row width the exchange
    actually moves: dout under combine-first, din under aggregate-first,
    divided by the feature-shard count on a 2-D partition (callers pass
    ``p2.feature_block(...)``).

    Returns a dict with per-hop terms (``t_wire_hop_s`` / ``t_comp_hop_s``
    / ``bytes_per_hop``), both schedules' exposed collective seconds
    (``exposed_none_s`` / ``exposed_pipelined_s``), the pipelined hidden
    time (``overlapped_pipelined_s``), the single-buffered exchange time
    (``t_none_s``) and the relative saving (``saving_frac``).
    """
    from repro.core.phases import aggregate_cost
    from repro.profile.machine import get_machine
    m = get_machine(machine)
    nsh = pg.num_shards
    hops = max(nsh - 1, 0)
    bytes_hop = pg.block_size * feature_len * dtype_bytes
    agg = aggregate_cost(_local_graph_view(pg), feature_len, dtype_bytes)
    # resident-slab partial combine, per device per hop (see docstring)
    t_comp_hop = max(agg["flops"] / nsh / m.peak_flops,
                     agg["bytes"] / nsh / m.hbm_bw)
    if strategy == "ring" and hops > 0:
        t_wire_hop = m.hop_time(bytes_hop)
        exposed_none = hops * t_wire_hop
        overlapped = hops * min(t_wire_hop, t_comp_hop)
        exposed_pipelined = hops * max(t_wire_hop - t_comp_hop, 0.0)
    else:
        # all-gather (one collective, nothing to hide) or a single shard
        v_padded = pg.block_size * nsh
        total = v_padded * feature_len * dtype_bytes * hops / max(nsh, 1)
        t_wire_hop = m.hop_time(total) if total else 0.0
        exposed_none = exposed_pipelined = t_wire_hop
        overlapped = 0.0
    t_none = hops * t_comp_hop + exposed_none
    return {
        "strategy": strategy, "hops": hops, "bytes_per_hop": bytes_hop,
        "t_wire_hop_s": t_wire_hop, "t_comp_hop_s": t_comp_hop,
        "exposed_none_s": exposed_none,
        "exposed_pipelined_s": exposed_pipelined,
        "overlapped_pipelined_s": overlapped,
        "t_none_s": t_none,
        "saving_frac": overlapped / t_none if t_none > 0 else 0.0,
    }


def choose_overlap(pg: PartitionedGraph, feature_lens, machine, *,
                   strategy: str = "ring", dtype_bytes: int = 4) -> str:
    """Resolve ``overlap="auto"`` -> ``"none" | "pipelined"`` for a plan.

    ``feature_lens`` is the exchanged row width -- one int, or a sequence
    (one per layer; a model's layers share one schedule, so the decision
    sums modeled savings across them).  Commits to the pipelined schedule
    iff the hidden collective time is at least ``OVERLAP_SAVING_THRESHOLD``
    of the single-buffered exchange time -- so the decision flips with the
    ``Machine``'s interconnect: a near-infinite link leaves nothing worth
    hiding (``"none"``), a link comparable to the per-hop combine hides
    half the wire time (``"pipelined"``), and the all-gather strategy
    (no per-hop structure) is always ``"none"``.

    Worked example::

        >>> choose_overlap(pg, [128, 7], TPU_V5E)
        'pipelined'
        >>> fast = replace(TPU_V5E, interconnect_bw=1e18, link_latency_s=0)
        >>> choose_overlap(pg, [128, 7], fast)
        'none'
    """
    if strategy != "ring":
        return "none"
    if isinstance(feature_lens, (int, np.integer)):
        feature_lens = [feature_lens]
    models = [overlap_model(pg, int(fl), machine, strategy=strategy,
                            dtype_bytes=dtype_bytes)
              for fl in feature_lens]
    saving = sum(m["overlapped_pipelined_s"] for m in models)
    t_none = sum(m["t_none_s"] for m in models)
    if t_none <= 0.0:
        return "none"
    return "pipelined" if saving >= OVERLAP_SAVING_THRESHOLD * t_none \
        else "none"


def _local_graph_view(pg: PartitionedGraph):
    """Minimal |V|/|E| stats view for the scheduler's analytic cost model."""
    import types
    return types.SimpleNamespace(
        num_vertices=pg.num_vertices,
        num_edges=int(np.asarray(pg.mask).sum()))


def _reduce_wire(h: jnp.ndarray, dtype: str) -> jnp.ndarray:
    """Reduce the halo-exchange operand to the plan dtype's wire width:
    bf16 cast (half the ppermute bytes), int8 per-row fake-quant (the
    values an int8 wire + f32 accumulate would move; the 1-byte width is
    priced analytically), identity for f32."""
    if dtype == "bf16":
        return h.astype(jnp.bfloat16)
    if dtype == "int8-agg":
        from repro.core.phases import quantize_int8
        return quantize_int8(h)
    return h


def distributed_gcn_layer(pg: PartitionedGraph, x, w, bias, in_deg,
                          mesh: Mesh, *, order: Optional[str] = None,
                          strategy: str = "ring", axis: str = "data",
                          overlap: str = "none", dtype: str = "f32"):
    """One distributed GCN layer with explicit phase ordering (Table 4).

    combine_first: project locally (embarrassingly parallel GEMM), then
    aggregate projected rows -- halo moves out_len-wide rows.
    aggregate_first: aggregate raw features (halo moves in_len-wide rows),
    then project.  ``order=None`` asks the scheduler's cost model (which at
    cluster scale also prices the collective term -- same in/out ratio).

    ``overlap`` picks the ring halo SCHEDULE (``"none"`` single-buffered |
    ``"pipelined"`` double-buffered, each send in flight under the resident
    slab's partial combine); both return bit-identical results, and
    pipelining requires ``strategy="ring"``.  ``"auto"`` is resolved at
    plan build by :func:`choose_overlap`, never passed here.

    ``dtype`` is the plan's resolved execution precision: ``"f32"`` is the
    unchanged (bitwise-golden) path; ``"bf16"`` casts operands to bf16 so
    the halo's ppermute wire moves HALF the bytes while every partial
    combine still accumulates f32; ``"int8-agg"`` fake-quantizes only the
    exchanged aggregation operand (per-row scales, f32 accumulate) and
    keeps the GEMM in f32.

    This is the shard_map primitive; model-level code reaches it through a
    ``GraphExecutionPlan`` built with ``mesh=``/``num_shards=`` (core/plan.py)
    rather than calling it with hand-threaded flags.
    """
    from repro.core.phases import _mm
    if order is None:
        from repro.core.scheduler import choose_ordering
        order = choose_ordering(
            _local_graph_view(pg), int(w.shape[0]), int(w.shape[1]),
            agg_op="mean", n_mlp_layers=1)
    _halo_body(strategy, overlap)     # validate the (strategy, overlap) pair
    agg = functools.partial(aggregate_ring, overlap=overlap) \
        if strategy == "ring" else aggregate_allgather
    if dtype == "bf16":
        x = x.astype(jnp.bfloat16)
        w = w.astype(jnp.bfloat16)
        bias = bias.astype(jnp.bfloat16)
    deg = jnp.maximum(
        in_deg.astype(jnp.promote_types(x.dtype, jnp.float32)) + 1.0,
        1.0)[:, None]
    deg = pad_features(deg, pg.block_size, pg.num_shards)
    # reciprocal-multiply normalization (not broadcast division) so the
    # jitted plan.compile() path stays bit-for-bit equal to eager dispatch
    rdeg = 1.0 / jnp.where(deg == 0, 1.0, deg)
    if order == "combine_first":
        h = _reduce_wire(_mm(x, w), dtype)   # the wire carries the reduced h
        out = (agg(pg, h, mesh, axis) + h) * rdeg
    else:
        xw = _reduce_wire(x, dtype)          # the wire carries the reduced x
        out = _mm((agg(pg, xw, mesh, axis) + xw) * rdeg, w)
    out = out + bias
    return out.astype(jnp.bfloat16) if dtype == "bf16" else out


# ---------------------------------------------------------------------------
# 2-D (node x feature) partitioned execution
# ---------------------------------------------------------------------------


def pad_features_2d(x: jnp.ndarray, p2: Partition2D) -> jnp.ndarray:
    """Pad (V, F) features to the (P*block, Q*fblock) partition layout."""
    fb = p2.feature_block(x.shape[1])
    rows = p2.block_size * p2.node_shards - x.shape[0]
    cols = fb * p2.feat_shards - x.shape[1]
    return jnp.pad(x, ((0, rows), (0, cols)))


def distributed_gcn_layer_2d(p2: Partition2D, x, w, bias, in_deg,
                             mesh: Mesh, *, order: Optional[str] = None,
                             strategy: str = "ring",
                             axes=("node", "feat"),
                             overlap: str = "none", dtype: str = "f32"):
    """One GCN layer on a 2-D (node x feature) device mesh (exact).

    Device (p, q) owns node block p's rows restricted to feature block q.
    Per ordering:

    combine_first: partial GEMM with the device's W row-block, closed by a
    reduce-scatter over the feature axis (fast intra-host links, each device
    receiving its own output column block), then the ring/all-gather halo along the node axis moves
    rows only ``F_out/Q`` wide -- the per-device halo bytes of the 1-D
    partition divided by Q *on top of* Table 4's in/out ratio saving.

    aggregate_first: halo first on the raw ``F_in/Q``-wide column slice
    (purely feature-parallel -- each feature shard's halo is independent),
    then the same partial-GEMM + reduce-scatter.

    Args mirror :func:`distributed_gcn_layer`; ``x`` must be in the padded
    ``(P*block, Q*fblock_in)`` layout (see :func:`pad_features_2d`) and the
    result is ``(P*block, Q*fblock_out)`` -- pad columns are exact zeros.
    ``axes`` names the (node, feature) mesh axes; ``order=None`` asks the
    scheduler's cost model.  ``overlap`` picks the node-axis ring schedule
    exactly as in :func:`distributed_gcn_layer` (the pipelined double
    buffer hides each F/Q-wide slab's wire time under the resident partial
    combine; bit-identical to the single-buffered schedule).  ``dtype``
    mirrors :func:`distributed_gcn_layer`: f32 is the unchanged bitwise
    path; bf16 halves the node-axis halo slab the ring actually moves
    (the feature-axis reduce-scatter keeps f32 partials -- its cross-
    device sum IS the accumulator); int8-agg fake-quantizes only the
    exchanged aggregation operand.  Model-level
    code reaches this through a ``GraphExecutionPlan`` built with a 2-D
    ``mesh=`` (core/plan.py).
    """
    from repro.core.phases import _mm
    pg = p2.nodes
    _require_uniform(pg)
    node_ax, feat_ax = axes
    nsh, q_sh = pg.num_shards, p2.feat_shards
    block = pg.block_size
    f_in, f_out = int(w.shape[0]), int(w.shape[1])
    fb_in, fb_out = p2.feature_block(f_in), p2.feature_block(f_out)
    if order is None:
        from repro.core.scheduler import choose_ordering
        order = choose_ordering(_local_graph_view(pg), f_in, f_out,
                                agg_op="mean", n_mlp_layers=1)
    local = _halo_body(strategy, overlap)

    if dtype == "bf16":
        x = x.astype(jnp.bfloat16)
        w = w.astype(jnp.bfloat16)
        bias = bias.astype(jnp.bfloat16)

    # zero-pad W/bias onto the (Q*fb_in, Q*fb_out) grid: pad x columns hit
    # zero W rows, pad W columns produce zero outputs -- exactness is free
    wp = jnp.zeros((q_sh * fb_in, q_sh * fb_out), w.dtype)
    wp = wp.at[:f_in, :f_out].set(w)
    bp = jnp.zeros((q_sh * fb_out,), w.dtype).at[:f_out].set(bias)

    deg = jnp.maximum(
        in_deg.astype(jnp.promote_types(x.dtype, jnp.float32)) + 1.0,
        1.0)[:, None]
    deg = pad_features(deg, block, nsh)
    # reciprocal of the (rows, 1) degree column: multiplied, never divided
    # (bitwise eager/compiled equality -- see distributed_gcn_layer)
    rdeg = 1.0 / jnp.where(deg == 0, 1.0, deg)

    expect = (nsh * block, q_sh * fb_in)
    if x.shape != expect:
        raise ValueError(f"x must be in the padded 2-D layout {expect}, "
                         f"got {tuple(x.shape)} (see pad_features_2d)")

    def fn(x_blk, src, dstl, msk, rdeg_blk, wp_, bp_):
        x_loc = x_blk.reshape(block, fb_in)
        srcl, dl, ml = src[0], dstl[0], msk[0]
        rdg = rdeg_blk[0]
        qi = jax.lax.axis_index(feat_ax)

        def w_block(fb):
            return jax.lax.dynamic_slice(wp_, (qi * fb, 0),
                                         (fb, q_sh * fb_out))

        def combine(h):
            # partial GEMM closed with a reduce-scatter over the feature
            # axis: each device receives only its own (block, fb_out)
            # column slice -- 1/Q the wire bytes of psum + local slice.
            # _mm keeps reduced (bf16) partials accumulating f32; f32
            # operands take the identical plain matmul.
            return jax.lax.psum_scatter(_mm(h, w_block(fb_in)), feat_ax,
                                        scatter_dimension=1, tiled=True)

        if order == "combine_first":
            # the node-axis halo wire carries the reduced combine output
            hq = _reduce_wire(combine(x_loc), dtype)     # (block, fb_out)
            out = (local(hq, srcl, dl, ml, block, nsh, node_ax) + hq) * rdg
        else:
            xw = _reduce_wire(x_loc, dtype)
            agg = local(xw, srcl, dl, ml, block, nsh, node_ax)
            out = combine((agg + xw) * rdg)
        out = out + jax.lax.dynamic_slice(bp_, (qi * fb_out,), (fb_out,))
        return out.reshape(1, block, 1, fb_out)

    out = shard_map(
        fn, mesh=mesh,
        in_specs=(P(node_ax, None, feat_ax, None), P(node_ax, None),
                  P(node_ax, None), P(node_ax, None), P(node_ax, None, None),
                  P(None, None), P(None)),
        out_specs=P(node_ax, None, feat_ax, None), check_rep=False,
    )(x.reshape(nsh, block, q_sh, fb_in), pg.src, pg.dst_local, pg.mask,
      rdeg.reshape(nsh, block, 1), wp, bp)
    out = out.reshape(nsh * block, q_sh * fb_out)
    return out.astype(jnp.bfloat16) if dtype == "bf16" else out


def halo_bytes_2d(p2: Partition2D, feature_len: int,
                  dtype_bytes: int = 4) -> dict:
    """Analytic per-device halo cost of the 2-D partition: the 1-D numbers
    evaluated at the F/Q column slice each device actually exchanges."""
    out = halo_bytes(p2.nodes, p2.feature_block(feature_len), dtype_bytes)
    out["feat_shards"] = p2.feat_shards
    return out


# ---------------------------------------------------------------------------
# Schedule-exact wire accounting (the static analyzer's ground truth)
# ---------------------------------------------------------------------------


def wire_dtype_bytes(dtype: str) -> int:
    """Bytes per element ACTUALLY moved by the halo collectives.

    ``_reduce_wire`` casts the exchanged slab to bf16 (2 bytes) under
    ``dtype="bf16"``; ``int8-agg`` fake-quantizes but keeps the f32
    carrier on the wire (4 bytes -- the 1-byte width is the analytic
    model's aspiration, not what the traced program ships), and f32
    ships f32.  This is the itemsize a jaxpr-level byte extraction
    (``repro.analysis.jaxpr_lint.collective_bytes``) must see.
    """
    return {"f32": 4, "bf16": 2, "int8-agg": 4}[dtype]


def schedule_wire_bytes(partition, feature_len: int, *,
                        strategy: str = "ring", overlap: str = "none",
                        dtype: str = "f32", combine_out_len=None) -> dict:
    """Schedule-exact per-device collective bytes of ONE distributed
    layer's TRACED schedule, by collective primitive.

    Unlike :func:`halo_bytes` (an analytic lower bound: cut edges x
    feature width) this prices the program the trace actually emits, so
    ``repro.analysis`` can equate it to jaxpr-extracted totals byte for
    byte:

      * single-buffered ring (``overlap="none"``): the scan body sends
        one slab per iteration over ``num_shards`` iterations (the last
        send is the schedule's redundant wrap-around hop), so
        ``ppermute`` moves ``num_shards * block * flen * wire`` bytes;
      * pipelined ring (``overlap="pipelined"``): ``num_shards - 1``
        in-flight sends, the resident slab never moves;
      * ``strategy="allgather"``: one tiled ``all_gather`` whose operand
        is the local slab (``block * flen * wire`` bytes in);
      * 2-D partitions (pass a ``Partition2D``): the halo slab narrows
        to ``feature_block(feature_len)`` columns and every layer adds
        one feature-axis ``psum_scatter`` (jaxpr ``reduce_scatter``)
        whose operand is the f32 partial GEMM ``(block,
        feat_shards * feature_block(combine_out_len))`` -- always 4
        bytes/elt: bf16 operands accumulate to f32 via
        ``preferred_element_type``.

    Wire element width comes from :func:`wire_dtype_bytes` (NOT the
    analytic ``DTYPE_BYTES`` -- int8-agg ships its f32 carrier).
    Returns per-primitive byte totals plus ``total_bytes``.
    """
    from repro.graph.partition import Partition2D
    two_d = isinstance(partition, Partition2D)
    pg = partition.nodes if two_d else partition
    if two_d and combine_out_len is None:
        raise ValueError("2-D schedules need combine_out_len (the layer's "
                         "dout) to price the feature-axis psum_scatter")
    wire = wire_dtype_bytes(dtype)
    flen = partition.feature_block(feature_len) if two_d else feature_len
    out = {"ppermute_sends": 0, "ppermute_bytes_per_send": 0,
           "ppermute_bytes": 0, "all_gather_bytes": 0,
           "reduce_scatter_bytes": 0, "psum_bytes": 0,
           "wire_dtype_bytes": wire}
    if strategy == "ring":
        sends = pg.num_shards if overlap == "none" \
            else max(pg.num_shards - 1, 0)
        per = pg.block_size * flen * wire
        out.update(ppermute_sends=sends, ppermute_bytes_per_send=per,
                   ppermute_bytes=sends * per)
    elif strategy == "allgather":
        out["all_gather_bytes"] = pg.block_size * flen * wire
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    if two_d:
        fb_out = partition.feature_block(combine_out_len)
        out["reduce_scatter_bytes"] = \
            pg.block_size * partition.feat_shards * fb_out * 4
    out["total_bytes"] = (out["ppermute_bytes"] + out["all_gather_bytes"]
                          + out["reduce_scatter_bytes"] + out["psum_bytes"])
    return out
