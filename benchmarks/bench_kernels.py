"""Beyond-paper: Pallas kernel benchmarks (interpret-mode correctness +
modeled TPU utilization) and the fused-dataflow guideline (paper §5.1-3).

Interpret-mode timing is meaningless for TPU perf; what we measure:
  * XLA path wall-clock for fused vs unfused dataflow (the HBM-traffic
    effect is visible even on CPU),
  * analytic VMEM footprint + MXU-alignment of the kernel tilings against
    the spec's Machine (``ctx.machine.on_chip_bytes``),
  * numerics of the Pallas kernels at benchmark shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import plan_for_phases
from repro.kernels import ops
from repro.kernels.ref import seg_agg_ref
from repro.profile.bench import BenchSpec, run_specs


def _fused_dataflow(ctx, _):
    """Fused vs unfused dataflow (XLA backend), both as planner scenarios."""
    g, x = ctx.g, ctx.x
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 128)) * 0.05
    weights = [(w, None)]
    fused_plan = plan_for_phases(g, weights, order="combine_first",
                                 agg_op="mean", backend="xla", fused=True)
    unfused_plan = plan_for_phases(g, weights, order="combine_first",
                                   agg_op="mean", backend="xla")
    fused = jax.jit(lambda xx: fused_plan.run_phases(
        xx, weights, activation="none"))
    unfused = jax.jit(lambda xx: unfused_plan.run_phases(
        xx, weights, activation="none"))
    t_f = ctx.time(fused, x)
    t_u = ctx.time(unfused, x)
    err = float(jnp.abs(fused(x) - unfused(x)).max())
    ctx.emit("kernels/fused_dataflow", t_f,
             unfused_us=round(t_u, 1),
             speedup=round(t_u / max(t_f, 1e-9), 2),
             max_err=f"{err:.1e}", tile_m=fused_plan.layers[0].tile_m)


def _vmem_budgets(ctx, shape):
    """VMEM budget of one kernel tiling (structural roofline input)."""
    fi, fo, tm, te = shape
    vmem_total = ctx.machine.on_chip_bytes
    vmem = (fi * fo + tm * fi + tm * fo + te * fi) * 4
    ctx.emit(f"kernels/fused_vmem_f{fi}", 0.0,
             vmem_bytes=vmem, vmem_frac=round(vmem / vmem_total, 3),
             mxu_aligned=bool(fo % ctx.machine.matrix_tile == 0
                              and tm % ctx.machine.row_align == 0))


def _pallas_numerics(ctx, _):
    """Pallas numerics at benchmark shapes (interpret mode)."""
    rng = np.random.default_rng(0)
    nb, emax, f, tm = 2, 512, 128, 128
    rows = jnp.asarray(rng.standard_normal((nb, emax, f)), jnp.float32)
    seg = jnp.asarray(np.sort(rng.integers(0, tm, (nb, emax))), jnp.int32)
    mask = jnp.ones((nb, emax), jnp.float32)
    out = ops.seg_agg_pregrouped(rows, seg, mask, tile_m=tm)
    gseg = (seg + jnp.arange(nb)[:, None] * tm).reshape(-1)
    ref = seg_agg_ref(rows.reshape(-1, f), gseg, mask.reshape(-1), nb * tm)
    ctx.emit("kernels/seg_agg_numerics", 0.0,
             max_err=f"{float(jnp.abs(out - ref).max()):.1e}",
             mxu_reduction=True)


SPECS = [
    BenchSpec(name="kernels/dataflow", graph="reddit", max_vertices=4096,
              max_feature=256, measure=_fused_dataflow),
    BenchSpec(name="kernels/vmem",
              sweep=((602, 128, 128, 512), (256, 128, 256, 512)),
              measure=_vmem_budgets),
    BenchSpec(name="kernels/numerics", measure=_pallas_numerics),
]


def run():
    from repro.profile.bench import BENCH_ARTIFACT_DIR
    run_specs(SPECS, csv=BENCH_ARTIFACT_DIR / "bench_kernels.csv")


if __name__ == "__main__":
    run()
