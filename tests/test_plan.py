"""GraphExecutionPlan: equivalence across backend x ordering x fusion,
plan/BlockedGraph caching, auto-detection, and the no-raw-flags contract."""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import CORA, GraphSpec, reduced_graph
from repro.core import backend as backend_mod
from repro.core import phases
from repro.core.backend import (default_interpret, interpret_for,
                                resolve_backend)
from repro.core.plan import (build_plan, clear_plan_cache, plan_for_conv,
                             plan_for_phases)
from repro.core.scheduler import AGGREGATE_FIRST, COMBINE_FIRST
from repro.graph.datasets import make_features, make_synthetic_graph
from repro.models.gcn import PAPER_MODELS, make_paper_model

# non-native tiers run in interpret mode off their platform
BACKENDS = ("xla", "pallas-tpu", "pallas-gpu")
ORDERINGS = (COMBINE_FIRST, AGGREGATE_FIRST)  # both legal for GCN (mean, 1-mlp)


@pytest.fixture(scope="module")
def data():
    spec = reduced_graph(CORA, 220, 24)
    g = make_synthetic_graph(spec)
    return spec, g, make_features(spec)


def _model_and_ref(name, spec, g, x, key=0):
    m = make_paper_model(name, spec)
    p = m.init(jax.random.PRNGKey(key))
    ref = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                     backend="xla", fused=False).run_model(p, x)
    return m, p, ref


# ---------------------------------------------------------------------------
# The equivalence property: every planned scenario computes the same model
# ---------------------------------------------------------------------------


@given(st.integers(40, 200), st.integers(8, 24))
@settings(max_examples=4, deadline=None)
def test_run_model_equivalence_property(num_vertices, feature_len):
    """plan.run_model is identical (atol 1e-5) across backend x fusion x
    ordering on random graphs -- the planner only changes HOW, never WHAT."""
    spec = GraphSpec("t", num_vertices, feature_len, num_vertices * 4,
                     num_classes=5, seed=num_vertices)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    m, p, ref = _model_and_ref("gcn", spec, g, x)
    for backend in BACKENDS:
        for fused in (False, True):
            for order in ORDERINGS + (None,):
                plan = build_plan(g, m.cfg, spec.feature_len,
                                  spec.num_classes, backend=backend,
                                  fused=fused, ordering=order)
                out = plan.run_model(p, x)
                np.testing.assert_allclose(
                    np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5,
                    err_msg=f"{backend}/fused={fused}/order={order}")


def test_gin_fused_no_longer_ignored(data):
    """GIN now fuses aggregation with the first MLP matmul (exact)."""
    spec, g, x = data
    m, p, ref = _model_and_ref("gin", spec, g, x, key=1)
    for backend in BACKENDS:
        plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                          backend=backend, fused=True)
        assert plan.layers[0].fused and plan.layers[0].blocked is not None
        out = plan.run_model(p, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5, err_msg=backend)


def test_gin_ordering_pinned_even_when_forced(data):
    spec, g, _ = data
    plan = build_plan(g, PAPER_MODELS["gin"], spec.feature_len,
                      spec.num_classes, ordering=COMBINE_FIRST)
    assert all(lp.order == AGGREGATE_FIRST for lp in plan.layers)


def test_fused_single_matmul_keeps_inline_bias(data):
    """Regression: fusion must fold an inline (W, b) bias into the output,
    not drop it (exact for mean agg / aggregate-first -- see _can_fuse)."""
    spec, g, x = data
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((x.shape[1], 8)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((8,)) * 2.0, jnp.float32)
    weights = [(w, b)]
    ref = phases.phase_ordered_layer(g, x, weights, order=COMBINE_FIRST,
                                     agg_op="mean", activation="none")
    fused = plan_for_phases(g, weights, order=COMBINE_FIRST, agg_op="mean",
                            fused=True)
    assert fused.layers[0].fused
    out = fused.run_phases(x, weights, activation="none")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_phase_ordered_layer_dispatches_and_chooses(data):
    """order=None lets the planner's cost model decide (F2)."""
    spec, g, x = data
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((x.shape[1], 8)) * 0.3, jnp.float32)
    auto = phases.phase_ordered_layer(g, x, [(w, None)], agg_op="mean",
                                      activation="none")
    # 24 -> 8 shrinks: combine_first must have been selected
    cf = phases.phase_ordered_layer(g, x, [(w, None)], order=COMBINE_FIRST,
                                    agg_op="mean", activation="none")
    np.testing.assert_allclose(np.asarray(auto), np.asarray(cf), rtol=1e-6)
    plan = plan_for_phases(g, [(w, None)], order=None, agg_op="mean")
    assert plan.layers[0].order == COMBINE_FIRST


# ---------------------------------------------------------------------------
# Caching
# ---------------------------------------------------------------------------


def test_plan_and_blocked_caching(data):
    spec, g, x = data
    clear_plan_cache()
    p1 = build_plan(g, PAPER_MODELS["gcn"], spec.feature_len,
                    spec.num_classes, fused=True)
    p2 = build_plan(g, PAPER_MODELS["gcn"], spec.feature_len,
                    spec.num_classes, fused=True)
    assert p1 is p2  # identical build -> cached plan
    assert p1.layers[0].blocked is p2.layers[0].blocked
    # a DIFFERENT plan on the same graph still shares the BlockedGraph
    # (host-side regrouping is done once per (graph, tile_m))
    p3 = build_plan(g, PAPER_MODELS["gcn"], spec.feature_len,
                    spec.num_classes, fused=True, ordering=AGGREGATE_FIRST)
    assert p3 is not p1
    assert p3.layers[0].blocked is p1.layers[0].blocked


def test_conv_apply_uses_cached_plan(data):
    spec, g, x = data
    m = make_paper_model("gcn", spec)
    pl1 = plan_for_conv(m.convs[0], g)
    pl2 = plan_for_conv(m.convs[0], g)
    assert pl1 is pl2


# ---------------------------------------------------------------------------
# Auto-detection + API contract
# ---------------------------------------------------------------------------


def test_interpret_autodetect(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert default_interpret() == (jax.default_backend() != "tpu")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert default_interpret() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert default_interpret() is True


def test_backend_auto_resolution():
    assert resolve_backend("xla") == "xla"
    assert resolve_backend("pallas-tpu") == "pallas-tpu"
    assert resolve_backend("pallas-gpu") == "pallas-gpu"
    plat = jax.default_backend()
    expected = {"tpu": "pallas-tpu", "gpu": "pallas-gpu"}.get(plat, "xla")
    assert resolve_backend("auto") == expected
    # legacy alias: the platform's native Pallas tier
    assert resolve_backend("pallas") == (
        "pallas-gpu" if plat == "gpu" else "pallas-tpu")
    with pytest.raises(ValueError):
        resolve_backend("cuda")


@pytest.mark.parametrize("plat,auto,alias", [
    ("cpu", "xla", "pallas-tpu"),
    ("gpu", "pallas-gpu", "pallas-gpu"),
    ("tpu", "pallas-tpu", "pallas-tpu"),
])
def test_backend_resolution_mocked_platforms(monkeypatch, plat, auto, alias):
    """resolve_backend picks the platform's tier (paper F3 per platform);
    every tier is a distinct string so plans record WHICH kernel family ran."""
    monkeypatch.setattr(backend_mod, "platform", lambda: plat)
    assert resolve_backend("auto") == auto
    assert resolve_backend("pallas") == alias
    # explicit tiers are never rewritten, even cross-platform
    assert resolve_backend("pallas-gpu") == "pallas-gpu"
    assert resolve_backend("pallas-tpu") == "pallas-tpu"
    assert resolve_backend("xla") == "xla"


@pytest.mark.parametrize("plat", ["cpu", "gpu", "tpu"])
def test_interpret_per_tier_mocked_platforms(monkeypatch, plat):
    """A Pallas tier compiles only on its native platform; anywhere else it
    interprets (so a CPU container still validates GPU/TPU kernel numerics)."""
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    monkeypatch.setattr(backend_mod, "platform", lambda: plat)
    assert interpret_for("pallas-tpu") == (plat != "tpu")
    assert interpret_for("pallas-gpu") == (plat != "gpu")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert interpret_for("pallas-tpu") and interpret_for("pallas-gpu")


def test_no_raw_impl_blocked_flags():
    """Acceptance: no public layer API takes raw impl=/blocked= flags."""
    from repro.core.gcn_layers import GCNConv, GINConv
    from repro.models.gcn import GCNModel
    for fn in (GCNConv.apply, GINConv.apply, GCNModel.apply,
               phases.phase_ordered_layer, phases.aggregate):
        params = inspect.signature(fn).parameters
        assert "impl" not in params and "blocked" not in params, fn


def test_describe_reports_decisions(data):
    spec, g, _ = data
    plan = build_plan(g, PAPER_MODELS["gcn"], spec.feature_len,
                      spec.num_classes, fused=True)
    d = plan.describe()
    assert len(d) == PAPER_MODELS["gcn"].num_layers
    for row in d:
        assert {"order", "backend", "fused", "tile_m", "interpret",
                "agg_bytes"} <= set(row)
    # layer 2 shrinks 128->7: the cost model must pick combine_first
    assert d[-1]["order"] == COMBINE_FIRST


def test_gpu_tile_picker_is_occupancy_aware():
    """The GPU tier's suggested tile is warp-aligned and small enough to
    keep several CTAs resident per SM; the TPU tier fills half of VMEM."""
    from repro.core.dataflow import suggest_tile_m
    tpu = suggest_tile_m(128, 128, 8.0)
    gpu = suggest_tile_m(128, 128, 8.0, backend="pallas-gpu")
    assert gpu % 32 == 0 and 32 <= gpu <= 256
    assert tpu > gpu  # one giant sequential tile vs many resident CTAs


def test_partition_2d_structure(data):
    """partition_2d: node axis is the uniform 1-D partition; feature axis is
    a runtime columnwise split (ceil-divided block per feature length)."""
    from repro.graph.partition import partition_1d, partition_2d
    spec, g, _ = data
    p2 = partition_2d(g, 4, 2)
    assert p2.node_shards == 4 and p2.feat_shards == 2
    ref = partition_1d(g, 4, edge_balanced=False)
    assert p2.block_size == ref.block_size
    assert np.array_equal(np.asarray(p2.nodes.vtx_start),
                          np.asarray(ref.vtx_start))
    assert p2.feature_block(24) == 12
    assert p2.feature_block(7) == 4   # ceil(7/2): pad columns are zeros
    with pytest.raises(ValueError):
        partition_2d(g, 0, 2)


def test_plan_2d_mesh_requires_two_axes(data):
    """partition_kind reflects the mesh rank; a local plan reports "none"."""
    spec, g, _ = data
    plan = build_plan(g, PAPER_MODELS["gcn"], spec.feature_len,
                      spec.num_classes)
    assert plan.partition_kind == "none"
    d = plan.describe()[0]
    assert d["partition"] == "none"


def test_build_plan_rejects_traced_graph(data):
    spec, g, x = data

    def f(src):
        g2 = g._replace(src=src)
        return build_plan(g2, PAPER_MODELS["gcn"], spec.feature_len,
                          spec.num_classes)

    with pytest.raises(Exception):
        jax.jit(f)(g.src)
