"""Paper Table 3 + Fig. 2/3: hybrid execution patterns per phase.

Characterizes Aggregation vs Combination (vs PageRank and MLP-MNIST
baselines) with architecture-neutral metrics:

  * bytes / FLOPs / arithmetic intensity + memory-vs-compute classification
    (Table 3's "Execution Bound" row) -- swept across Machine presets
    (the paper's V100 plus TPU v5e and A100), one spec sweep axis,
  * bytes-per-op (Table 3's "DRAM Byte per Operation"),
  * LRU reuse-distance hit ratios at L2-like capacities (Fig. 2(g): the
    6.9% vs 56.2% L2 story, restated capacity-neutrally),
  * the atomic-collision model (Fig. 2(f): 1.1 vs 17.9 txn/request).
"""

from __future__ import annotations

import numpy as np

from repro.core.characterize import phase_report
from repro.core.phases import aggregate_cost, combine_cost
from repro.graph.reorder import atomic_collision_model, reuse_distance_stats
from repro.models.mlp import mlp_cost
from repro.models.pagerank import pagerank_cost
from repro.profile.bench import BenchSpec, run_specs
from repro.profile.machine import A100, TPU_V5E, V100


def _table3(ctx, machine):
    """Table 3's bound classification, re-evaluated on one Machine."""
    g = ctx.g
    agg = aggregate_cost(g, feature_len=128)      # SAG post-combination
    comb = combine_cost(g.num_vertices, (602, 128))
    rep = phase_report(agg, comb, machine=machine)
    ctx.emit(f"table3/{machine.name}/aggregation", 0.0,
             arithmetic_intensity=round(rep["aggregation"][
                 "arithmetic_intensity"], 4),
             bytes_per_op=round(rep["aggregation"]["bytes_per_op"], 3),
             bound_paper=rep["aggregation"]["bound"],
             bound=rep["aggregation"]["bound_machine"],
             machine_balance=round(machine.balance, 1),
             paper_reference="memory-bound, 2.35 B/op")
    ctx.emit(f"table3/{machine.name}/combination", 0.0,
             arithmetic_intensity=round(rep["combination"][
                 "arithmetic_intensity"], 2),
             bytes_per_op=round(rep["combination"]["bytes_per_op"], 4),
             bound_paper=rep["combination"]["bound"],
             bound=rep["combination"]["bound_machine"],
             machine_balance=round(machine.balance, 1),
             paper_reference="compute-bound, 0.01 B/op",
             note="a lone 602x128 GEMM flips memory-bound past balance "
                  "~30 -- fuse or widen (see fused_agg_combine)")


def _baselines(ctx, _):
    """PageRank / MLP baselines + Fig 2(f,g) locality models."""
    g = ctx.g
    pgr = pagerank_cost(g)
    ctx.emit("table3/pagerank", 0.0,
             arithmetic_intensity=round(pgr["arithmetic_intensity"], 4),
             bytes_per_op=round(1 / max(pgr["arithmetic_intensity"], 1e-9),
                                2))
    mlp = mlp_cost()
    ctx.emit("table3/mlp_mnist", 0.0,
             arithmetic_intensity=round(mlp["arithmetic_intensity"], 2),
             param_reuse=mlp["param_reuse"])

    # --- Fig 2(g): reuse distance (L2 hit-rate restatement) ----------------
    # A 6 MiB L2 holds ~1.5M scalar ranks (PGR) but only ~2.5K 602-float
    # rows.  The scaled graph preserves the BUDGET/|V| ratio of full Reddit
    # (2.6K rows / 233K vertices), so the hit-rate collapse reproduces.
    from repro.config import GRAPHS
    full_v = GRAPHS["reddit"].num_vertices
    scale = g.num_vertices / full_v
    stream = np.asarray(g.src)[:200_000]
    gcn_budget = max(4, int(6 * 2 ** 20 // (602 * 4) * scale))
    pgr_budget = min(int(6 * 2 ** 20 // 4 * scale), g.num_vertices)
    st = reuse_distance_stats(stream, budgets=(gcn_budget, pgr_budget))
    ctx.emit("fig2g/reuse_distance", 0.0,
             gcn_hit_ratio=round(st[f"hit_ratio@{gcn_budget}"], 3),
             pgr_hit_ratio=round(st[f"hit_ratio@{pgr_budget}"], 3),
             gcn_rows_budget=gcn_budget, pgr_rows_budget=pgr_budget,
             mean_reuse_distance=round(st["mean_reuse_distance"], 1),
             paper_reference="6.9% vs 56.2%")

    # --- Fig 2(f): atomic collisions ---------------------------------------
    dst = np.asarray(g.dst)
    gcn_c = atomic_collision_model(dst, feature_len=602)
    pgr_c = atomic_collision_model(dst, feature_len=1)
    ctx.emit("fig2f/atomic_collisions", 0.0,
             gcn_txn_per_request=round(gcn_c["atomic_txn_per_request"], 2),
             pgr_txn_per_request=round(pgr_c["atomic_txn_per_request"], 2),
             paper_reference="1.1 vs 17.9",
             tpu_note="sorted-segment layout eliminates the hazard entirely")


SPECS = [
    # machine sweep: same phases classified on the paper's V100, TPU v5e,
    # and A100 (analytic -- runs under dry-run too)
    BenchSpec(name="table3", graph="reddit", max_vertices=8192,
              sweep=(V100, TPU_V5E, A100), measure=_table3, dry="run",
              dry_max_vertices=1024),
    BenchSpec(name="phase_locality", graph="reddit", max_vertices=8192,
              measure=_baselines),
]


def run():
    from repro.profile.bench import BENCH_ARTIFACT_DIR
    run_specs(SPECS, csv=BENCH_ARTIFACT_DIR / "bench_phase_metrics.csv")


if __name__ == "__main__":
    run()
