"""Overlap sweep: overlap x strategy x partition for the distributed halo.

The tentpole measurement for overlapped halo pipelining
(``core.distributed``): every cell builds a distributed plan with an
explicit ``build_plan(overlap=...)`` and validates the whole overlap
contract on 8 fake host devices (subprocess, same rule as bench_plan's
partition matrix):

  * ``overlap="pipelined"`` output is BIT-IDENTICAL (``np.array_equal``)
    to the ``overlap="none"`` plan's output, eager AND compiled -- the two
    schedules share the per-hop partial combine, only the ppermute issue
    order differs, so pipelining may never change a single bit;
  * the compiled contract holds per cell (compiled == eager bitwise, no
    retrace on the second call);
  * the instrumented ``WorkloadReport`` schema-validates and its
    exposed/overlapped collective split agrees with ``describe()``
    (``report.mismatches``);
  * ``overlap="auto"`` resolves to a concrete schedule on the plan (the
    stored decision is never the literal "auto"), and for the all-gather
    strategy it resolves to "none" (one fused collective has no per-hop
    structure to pipeline);
  * the MODELED wall time of the pipelined schedule is <= the
    single-buffered one on every multi-shard ring cell (the overlap model
    guarantees this by construction -- ``min(wire, comp)`` per hop -- so a
    violation means the pricing broke).

Rows carry both the modeled times (``modeled_none_us`` /
``modeled_pipe_us``, the deterministic gate) and the measured compiled
wall time (``measured_us``, informational: 8 fake devices timeshare one
CPU, so measured numbers are correctness-shaped observables, not
accelerator predictions -- the same convention as every other bench).
``post_run`` accounts for every cell in the matrix and hard-fails any
silent skip or modeled-gate violation.
"""

from __future__ import annotations

import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.plan import build_plan
from repro.models.gcn import make_paper_model
from repro.profile.bench import BenchSpec, run_specs
from repro.profile.machine import TPU_V5E

#: (kind, mesh shape, mesh axis names) -- 1-D node sharding and a 2-D
#: node x feature mesh, both on the 8 fake devices
PARTITIONS = (
    ("1d", (8,), ("data",)),
    ("2d", (4, 2), ("node", "feat")),
)

#: (strategy, overlap) cells per partition; allgather has no per-hop
#: structure, so only "none" and the auto-resolves-to-none check apply
CELLS = (
    ("ring", "none"),
    ("ring", "pipelined"),
    ("ring", "auto"),
    ("allgather", "none"),
    ("allgather", "auto"),
)


def _cell_name(kind, shape, strategy, overlap):
    return (f"overlap/{kind}/{'x'.join(map(str, shape))}/"
            f"{strategy}/{overlap}")


def expected_matrix():
    """Every cell name the dry run must account for."""
    return [_cell_name(kind, shape, st, ov)
            for kind, shape, _ in PARTITIONS
            for st, ov in CELLS]


def _modeled_times(plan):
    """(t_none_s, t_pipelined_s) summed over the plan's layers from the
    same ``overlap_model`` pricing ``choose_overlap`` applies -- the
    deterministic wall-time gate (measured times on fake devices are
    noise-dominated)."""
    from repro.core.distributed import overlap_model
    from repro.core.scheduler import AGGREGATE_FIRST
    from repro.graph.partition import Partition2D
    part = plan.partition
    if isinstance(part, Partition2D):
        pg, width = part.nodes, part.feature_block
    else:
        pg, width = part, (lambda f: f)
    t_none = t_pipe = 0.0
    for lp in plan.layers:
        flen = width(lp.din if lp.order == AGGREGATE_FIRST else lp.dout)
        m = overlap_model(pg, flen, TPU_V5E, strategy=plan.strategy)
        t_none += m["t_none_s"]
        t_pipe += m["t_none_s"] - m["overlapped_pipelined_s"]
    return t_none, t_pipe


_CHILD_FLAG = "--overlap-child"


def _overlap_child(csv_out: str):
    """Subprocess body (8 fake devices): validate every overlap cell and
    write rows to ``csv_out`` for the parent to re-emit."""
    from repro.graph.datasets import make_features, make_synthetic_graph
    from repro.profile.bench import BenchContext, bench_graph, write_csv

    spec = bench_graph("reddit", max_vertices=256, max_feature=64)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    m = make_paper_model("gcn", spec)
    params = m.init(jax.random.PRNGKey(0))
    ctx = BenchContext(bench=None, machine=TPU_V5E, dry=True)

    for kind, shape, names in PARTITIONS:
        mesh = jax.make_mesh(shape, names)
        baselines = {}          # strategy -> overlap="none" output
        for strategy, overlap in CELLS:
            name = _cell_name(kind, shape, strategy, overlap)
            plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                              mesh=mesh, strategy=strategy, overlap=overlap)
            assert plan.partition_kind == kind, (plan.partition_kind, kind)
            assert plan.overlap in ("none", "pipelined"), plan.overlap
            if strategy == "allgather":
                # no per-hop structure: auto must price allgather to "none"
                assert plan.overlap == "none", (name, plan.overlap)
            with mesh:
                report = plan.instrument(machine=TPU_V5E).run_model(
                    params, x)
                report.validate()
                drift = report.mismatches(plan)
                assert not drift, (name, drift)
                fn = plan.compile()
                out_c = np.asarray(fn(params, x))
                t0 = time.perf_counter()
                np.asarray(fn(params, x))
                measured_us = (time.perf_counter() - t0) * 1e6
                assert fn.num_traces == 1, (name, fn.num_traces)
            eager = np.asarray(report.output)
            assert np.array_equal(out_c, eager), \
                f"{name}: compiled != eager (the compiled contract is " \
                "bitwise)"
            base = baselines.setdefault(strategy, eager)
            assert np.array_equal(eager, base), \
                f"{name}: overlap={plan.overlap} output differs from the " \
                "overlap='none' plan -- pipelining changed bits"
            t_none, t_pipe = _modeled_times(plan)
            exp = sum(r.exposed_collective_time for r in report.records)
            ovl = sum(r.overlapped_collective_time for r in report.records)
            d0 = plan.describe()[0]
            ctx.emit(name, 0.0,
                     overlap=d0["overlap"], strategy=strategy,
                     partition=d0["partition"],
                     modeled_none_us=round(t_none * 1e6, 3),
                     modeled_pipe_us=round(t_pipe * 1e6, 3),
                     measured_us=round(measured_us, 1),
                     exposed_us=round(exp * 1e6, 3),
                     overlapped_us=round(ovl * 1e6, 3))
    write_csv(ctx.rows, csv_out)
    print("OVERLAP-CHILD-OK")


def _overlap_matrix(ctx, _):
    """Spawn the overlap matrix on 8 fake devices and re-emit its rows
    (dry and full runs alike: the halo paths NEED a multi-shard mesh, and
    fake devices are the only kind this container has)."""
    import csv as _csv
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        out = Path(td) / "overlap_child.csv"
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.pathsep.join(
            [str(Path(__file__).resolve().parents[1] / "src"),
             str(Path(__file__).resolve().parents[1])])
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_overlap",
             _CHILD_FLAG, str(out)],
            capture_output=True, text=True, env=env, timeout=900)
        if res.returncode != 0 or "OVERLAP-CHILD-OK" not in res.stdout:
            sys.stdout.write(res.stdout)
            raise RuntimeError(
                f"overlap subprocess failed:\n{res.stderr[-3000:]}")
        with out.open(newline="") as f:
            child_rows = list(_csv.DictReader(f))
    for row in child_rows:
        name = row.pop("name")
        us = float(row.pop("us_per_call"))
        ctx.emit(name, us, **row)


SPECS = [
    BenchSpec(name="overlap/matrix", measure=_overlap_matrix, dry="run"),
]


def post_run(rows, dry: bool = False):
    """Matrix accounting + the modeled wall-time gate.

    Every expected cell must have emitted a row (a silently skipped
    overlap scenario would merge unvalidated -- scripts/smoke.sh
    hard-fails on the exception this raises), and on every multi-shard
    ring cell the modeled pipelined time must be <= the single-buffered
    one."""
    byname = {r["name"]: r for r in rows}
    missing = [n for n in expected_matrix() if n not in byname]
    if missing:
        raise RuntimeError("overlap matrix cells silently skipped: "
                           + ", ".join(missing))
    bad = []
    for name, r in byname.items():
        if r.get("strategy") != "ring":
            continue
        if float(r["modeled_pipe_us"]) > float(r["modeled_none_us"]):
            bad.append(f"{name}: pipelined {r['modeled_pipe_us']}us > "
                       f"none {r['modeled_none_us']}us")
    if bad:
        raise RuntimeError("overlap model regressed -- pipelined modeled "
                           "time above single-buffered: " + "; ".join(bad))
    print(f"# overlap matrix: {len(expected_matrix())} cell(s) validated "
          "(bitwise + compiled + modeled gate), 0 silent")


def run(dry: bool = False):
    """Direct-invocation entry (``python -m benchmarks.bench_overlap
    [--dry-run]``); writes the same CSV artifact benchmarks/run.py does."""
    from repro.profile.bench import BENCH_ARTIFACT_DIR
    rows = run_specs(
        SPECS, dry=dry,
        csv=BENCH_ARTIFACT_DIR / f"bench_overlap{'.dry' if dry else ''}.csv")
    post_run(rows, dry=dry)


if __name__ == "__main__":
    if _CHILD_FLAG in sys.argv:
        _overlap_child(sys.argv[sys.argv.index(_CHILD_FLAG) + 1])
    else:
        run(dry="--dry-run" in sys.argv)
