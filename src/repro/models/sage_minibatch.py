"""GraphSAGE mini-batch training (paper §2: "GraphSAGE only updates a batch
of vertexes along with their 2-hop neighbors in an iteration").

Couples graph/sampling.two_hop_batch with the plan-dispatched SAGE layers:
layer 1 runs over the hop-2 block (farthest frontier -> hop-1 inputs),
layer 2 over the hop-1 block (hop-1 inputs -> seed logits).  Each sampled
block gets its own ``GraphExecutionPlan`` (built/cached per block graph by
core/plan.py) — the ordering decision (Table 4) is a property of
(in_len, out_len, |E|/|V|), which sampling changes (fanout-regular degree),
so the demo shows the planner re-deciding per block.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import GraphSpec
from repro.core.gcn_layers import SAGEConv
from repro.core.plan import plan_for_conv
from repro.graph.sampling import SampledBlock


class SageMiniBatchModel:
    def __init__(self, in_dim: int, hidden: int, num_classes: int):
        self.layer1 = SAGEConv(in_dim, hidden, ordering="auto")
        self.layer2 = SAGEConv(hidden, num_classes, ordering="auto")

    def init(self, key) -> Dict:
        k1, k2 = jax.random.split(key)
        return {"l1": self.layer1.init(k1), "l2": self.layer2.init(k2)}

    def apply(self, params, hop2: SampledBlock, hop1: SampledBlock,
              x_inputs: jnp.ndarray) -> jnp.ndarray:
        """x_inputs: features of hop2.input_ids (the full required frontier).

        Returns logits for hop1.seed_ids (the mini-batch seeds).
        """
        p1 = plan_for_conv(self.layer1, hop2.graph)
        p2 = plan_for_conv(self.layer2, hop1.graph)
        h = self.layer1.apply(params["l1"], hop2.graph, x_inputs, plan=p1)
        h = jax.nn.relu(h)
        # hop1's input vertices are a prefix-compatible subset: map rows
        h1_inputs = h[_index_of(hop2.input_ids, hop1.input_ids)]
        out = self.layer2.apply(params["l2"], hop1.graph, h1_inputs, plan=p2)
        return out[: len(hop1.seed_ids)]

    def loss(self, params, hop2, hop1, x_inputs, labels) -> jnp.ndarray:
        logits = self.apply(params, hop2, hop1, x_inputs)
        ll = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(ll, labels[:, None], axis=-1).mean()

    def orderings(self, hop2: SampledBlock, hop1: SampledBlock
                  ) -> Tuple[str, str]:
        return (self.layer1.resolve_order(hop2.graph),
                self.layer2.resolve_order(hop1.graph))


def _index_of(haystack: np.ndarray, needles: np.ndarray) -> np.ndarray:
    """Positions of `needles` inside sorted unique `haystack`."""
    haystack = np.asarray(haystack)
    needles = np.asarray(needles)
    pos = np.searchsorted(haystack, needles)
    assert (haystack[pos] == needles).all(), "frontier must cover hop-1"
    return pos


def train_minibatch_sage(graph, spec: GraphSpec, features, labels, *,
                         steps: int = 20, batch_size: int = 32,
                         fanouts=(5, 5), lr: float = 0.1, seed: int = 0):
    """Host-side mini-batch loop (sampling is pipeline work, not jit)."""
    from repro.graph.sampling import two_hop_batch
    rng = np.random.default_rng(seed)
    model = SageMiniBatchModel(spec.feature_len, 128, spec.num_classes)
    params = model.init(jax.random.PRNGKey(seed))
    feats = np.asarray(features)
    labs = np.asarray(labels)
    losses = []
    for step in range(steps):
        seeds = rng.choice(spec.num_vertices, size=batch_size,
                           replace=False).astype(np.int32)
        hop2, hop1 = two_hop_batch(graph, seeds, fanouts,
                                   seed=seed * 1000 + step)
        x_in = jnp.asarray(feats[hop2.input_ids])
        y = jnp.asarray(labs[hop1.seed_ids])
        loss, grads = jax.value_and_grad(model.loss)(params, hop2, hop1,
                                                     x_in, y)
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        losses.append(float(loss))
    return params, losses, model
