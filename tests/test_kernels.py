"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from tolerance import assert_allclose_dtype

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention as flash_pallas
from repro.kernels.fused_agg_combine import fused_agg_combine_blocked
from repro.kernels.ref import fused_agg_combine_ref, mha_ref, seg_agg_ref
from repro.kernels.seg_agg import seg_agg_blocked

RNG = np.random.default_rng(42)


def _blocked_inputs(nblocks, emax, f, tile_m, dtype, density=0.8):
    rows = jnp.asarray(RNG.standard_normal((nblocks, emax, f)), dtype)
    seg = jnp.asarray(RNG.integers(0, tile_m, (nblocks, emax)), jnp.int32)
    mask = jnp.asarray(RNG.random((nblocks, emax)) < density, jnp.float32)
    return rows, seg, mask


# ---------------------------------------------------------------- seg_agg
@pytest.mark.parametrize("nblocks,emax,f,tile_m,tile_e", [
    (2, 256, 32, 16, 128),
    (4, 512, 128, 128, 256),
    (1, 1024, 64, 8, 512),
    (3, 256, 100, 64, 256),   # non-128-multiple feature dim
])
def test_seg_agg_shapes(nblocks, emax, f, tile_m, tile_e):
    rows, seg, mask = _blocked_inputs(nblocks, emax, f, tile_m, jnp.float32)
    out = seg_agg_blocked(rows, seg, mask, tile_m=tile_m, tile_e=tile_e)
    gseg = (seg + jnp.arange(nblocks)[:, None] * tile_m).reshape(-1)
    ref = seg_agg_ref(rows.reshape(-1, f), gseg, mask.reshape(-1),
                      nblocks * tile_m)
    assert_allclose_dtype(out, ref)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_seg_agg_dtypes(dtype):
    rows, seg, mask = _blocked_inputs(2, 256, 64, 32, dtype)
    out = seg_agg_blocked(rows, seg, mask, tile_m=32, tile_e=128)
    gseg = (seg + jnp.arange(2)[:, None] * 32).reshape(-1)
    ref = seg_agg_ref(rows.astype(jnp.float32).reshape(-1, 64),
                      gseg, mask.reshape(-1), 64)
    assert_allclose_dtype(out, ref, dtype=dtype,
                          scale=2.0 if dtype == jnp.bfloat16 else 1.0)


def test_seg_agg_wrapper_sorted_ids():
    e, f, v = 999, 48, 117
    seg = np.sort(RNG.integers(0, v, e)).astype(np.int32)
    rows = jnp.asarray(RNG.standard_normal((e, f)), jnp.float32)
    out = ops.seg_agg(rows, jnp.asarray(seg), v)
    ref = seg_agg_ref(rows, jnp.asarray(seg), jnp.ones(e), v)
    assert_allclose_dtype(out, ref)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(16, 64))
@settings(max_examples=10, deadline=None)
def test_seg_agg_permutation_invariance(nblocks, echunks, f):
    """Segmented sum is invariant to edge order within a block."""
    emax, tile_m = 128 * echunks, 16
    rows, seg, mask = _blocked_inputs(nblocks, emax, f, tile_m, jnp.float32)
    out1 = seg_agg_blocked(rows, seg, mask, tile_m=tile_m, tile_e=128)
    perm = RNG.permutation(emax)
    out2 = seg_agg_blocked(rows[:, perm], seg[:, perm], mask[:, perm],
                           tile_m=tile_m, tile_e=128)
    assert_allclose_dtype(out1, out2, scale=10)


def test_seg_agg_mass_conservation():
    """sum over segments == sum over (masked) rows."""
    rows, seg, mask = _blocked_inputs(2, 256, 32, 64, jnp.float32)
    out = seg_agg_blocked(rows, seg, mask, tile_m=64, tile_e=128)
    lhs = np.asarray(out).sum(0)
    rhs = np.asarray(rows * mask[..., None]).sum((0, 1))
    assert_allclose_dtype(lhs, rhs, scale=10)


# ------------------------------------------------------- fused agg+combine
@pytest.mark.parametrize("fi,fo,tile_m", [(64, 32, 32), (100, 16, 16),
                                          (256, 128, 64)])
def test_fused_agg_combine(fi, fo, tile_m):
    nblocks, emax = 3, 512
    rows, seg, mask = _blocked_inputs(nblocks, emax, fi, tile_m, jnp.float32)
    w = jnp.asarray(RNG.standard_normal((fi, fo)) * 0.1, jnp.float32)
    out = fused_agg_combine_blocked(rows, seg, mask, w, tile_m=tile_m,
                                    tile_e=256)
    gseg = (seg + jnp.arange(nblocks)[:, None] * tile_m).reshape(-1)
    ref = fused_agg_combine_ref(rows.reshape(-1, fi), gseg, mask.reshape(-1),
                                w, nblocks * tile_m)
    assert_allclose_dtype(out, ref, scale=10)


def test_fused_equals_unfused_composition():
    """Fusion is a pure execution change: == seg_agg then matmul."""
    rows, seg, mask = _blocked_inputs(2, 256, 64, 32, jnp.float32)
    w = jnp.asarray(RNG.standard_normal((64, 48)) * 0.2, jnp.float32)
    fused = fused_agg_combine_blocked(rows, seg, mask, w, tile_m=32,
                                      tile_e=128)
    unfused = seg_agg_blocked(rows, seg, mask, tile_m=32, tile_e=128) @ w
    assert_allclose_dtype(fused, unfused, scale=10)


# --------------------------------------------------------- flash attention
CASES = [
    # b, hq, hkv, sq, sk, d, causal, window, cap
    (2, 4, 2, 128, 128, 64, True, 0, 0.0),
    (1, 8, 4, 100, 260, 32, True, 0, 50.0),
    (2, 2, 1, 64, 192, 64, True, 48, 0.0),
    (1, 4, 4, 1, 300, 64, True, 0, 0.0),          # decode shape
    (1, 2, 2, 96, 96, 128, False, 0, 0.0),        # non-causal (encoder)
]


@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,causal,window,cap", CASES)
def test_flash_pallas_vs_ref(b, hq, hkv, sq, sk, d, causal, window, cap):
    q = jnp.asarray(RNG.standard_normal((b, hq, sq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, sk, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, sk, d)), jnp.float32)
    o1 = flash_pallas(q, k, v, causal=causal, window=window, softcap=cap,
                      tile_q=64, tile_k=64)
    o2 = mha_ref(q, k, v, causal=causal, sliding_window=window,
                 logit_softcap=cap)
    assert_allclose_dtype(o1, o2, scale=20)


def test_flash_pallas_kv_len():
    b, hq, hkv, sq, sk, d = 2, 4, 2, 8, 192, 32
    q = jnp.asarray(RNG.standard_normal((b, hq, sq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, hkv, sk, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, hkv, sk, d)), jnp.float32)
    kvl = jnp.asarray([50, 192], jnp.int32)
    o1 = flash_pallas(q, k, v, kvl, tile_q=64, tile_k=64)
    o2 = mha_ref(q, k, v, kv_len=kvl)
    assert_allclose_dtype(o1, o2, scale=20)


@pytest.mark.parametrize("dtype,scale", [(jnp.float32, 20), (jnp.bfloat16, 1)])
def test_flash_pallas_dtypes(dtype, scale):
    q = jnp.asarray(RNG.standard_normal((1, 2, 64, 32)), dtype)
    k = jnp.asarray(RNG.standard_normal((1, 2, 64, 32)), dtype)
    v = jnp.asarray(RNG.standard_normal((1, 2, 64, 32)), dtype)
    o1 = flash_pallas(q, k, v, tile_q=32, tile_k=32)
    o2 = mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                 v.astype(jnp.float32))
    assert_allclose_dtype(o1, o2, dtype=dtype, scale=scale)
