"""InstrumentedPlan / WorkloadReport: one forward pass -> Table-3/4 breakdown.

``plan.instrument(machine=A100)`` wraps a ``GraphExecutionPlan`` so that one
``run_model`` call records, per layer and per *executed* phase, what the
paper's Tables 3-5 tabulate: phase name, backend tier, ordering, analytic
FLOPs / bytes / arithmetic intensity, collective bytes (distributed plans),
and measured wall time -- into a typed ``WorkloadReport`` with ``to_json()``
and ``to_markdown()`` renderers.

The records come from a probe threaded through the SAME dispatch code the
plan replays in production (``core.plan._execute_layer``), not a parallel
re-implementation -- so ``WorkloadReport.mismatches(plan)`` is a real
regression guard: it cross-checks the decisions ``plan.describe()`` *claims*
against the phases that actually executed (ordering from the phase sequence,
backend from the aggregation record, fusion from whether the fused phase
ran).

``run_model(..., compiled=True)`` additionally times the plan's COMPILED
path (``plan.compile()`` -- whole forward and per layer) and attaches the
wall times to the report, so one call states the eager-vs-compiled speedup
per layer alongside the per-phase breakdown.

Wall times follow the repo-wide convention (repro.profile.bench): on CPU
they are correctness-shaped observables, not accelerator predictions; the
analytic FLOP/byte columns are machine-independent and exact.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax

from repro.profile.machine import Machine, machine_for_backend

_DTYPE_BYTES = 4  # the framework's f32 feature convention

#: every phase name a record may carry (schema-validated)
PHASES = ("aggregate", "combine", "fused_agg_combine", "distributed")

SCHEMA = "repro.profile/workload-report"
SCHEMA_VERSION = 1


class WorkloadReportError(ValueError):
    """A WorkloadReport violated its schema (empty/ill-typed records)."""


@dataclass(frozen=True)
class PhaseRecord:
    """One executed phase of one layer, with analytic costs + wall time.

    ``feature_len`` is the feature length the phase actually moved (for
    aggregation phases this is the paper's Table-4 variable: dout under
    combine-first, din under aggregate-first).  ``bound`` classifies the
    phase's arithmetic intensity against the report's Machine balance.

    ``dtype`` is the storage precision this phase's reduced operand used
    (the plan's ``dtype=`` decision as dispatched: ``"int8-agg"`` plans
    record their combine phases as ``"f32"`` because only the aggregation
    operand is quantized).  ``quant_error`` is the max-abs difference
    between the phase's full-precision operand and its reduced form,
    observed at probe time -- exactly 0.0 on f32 plans (the bitwise-golden
    contract), necessarily nonzero somewhere on any reduced-precision run
    (``validate()`` enforces both directions).

    Distributed records additionally split the modeled collective wall
    time by the plan's halo SCHEDULE (``overlap=``):
    ``exposed_collective_time`` is the seconds of wire time the schedule
    leaves on the critical path, ``overlapped_collective_time`` the
    seconds hidden under the per-hop partial combine -- analytic from
    ``core.distributed.overlap_model`` on the report's Machine, priced for
    the overlap mode the dispatch actually ran (the probe receives it from
    the dispatch call, and ``mismatches()`` cross-checks it against
    ``describe()``).  Both are 0.0 on non-distributed phases.
    """

    layer: int
    phase: str              # one of PHASES
    order: str
    backend: str
    fused: bool
    feature_len: int
    flops: float
    bytes: float
    collective_bytes: float
    wall_time_s: float
    bound: str              # "memory" | "compute" vs the report's Machine
    exposed_collective_time: float = 0.0     # modeled s, on critical path
    overlapped_collective_time: float = 0.0  # modeled s, hidden under hops
    dtype: str = "f32"      # storage precision of the dispatched operand
    quant_error: float = 0.0  # max|full - reduced| observed at probe time
    #: schedule-exact collective bytes of the TRACED halo program
    #: (``core.distributed.schedule_wire_bytes``): what the ppermute /
    #: all_gather / psum_scatter eqns actually put on the wire, per
    #: device -- the quantity ``repro.analysis.jaxpr_lint`` extracts
    #: from the jaxpr and equates byte-for-byte.  ``collective_bytes``
    #: stays the analytic cut-edge LOWER BOUND (min_halo_bytes); both
    #: are 0.0 on non-distributed phases.
    wire_collective_bytes: float = 0.0
    #: pair-redundancy elimination (``dedup="pairs"`` plans): matched pair
    #: count of the two-level layout this aggregation dispatched over, and
    #: the analytic adds it eliminated vs. the naive fold at this record's
    #: feature length (``graph.dedup.DedupLayout.flops_saved``).  Both 0
    #: on non-aggregation phases and on ``dedup="none"`` plans; the flops/
    #: bytes columns of a dedup record already price the TWO-LEVEL layout
    #: (``graph.dedup.dedup_cost``), so these state the delta explicitly.
    dedup_pairs: int = 0
    dedup_flops_saved: float = 0.0

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(1.0, self.bytes)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "layer": self.layer, "phase": self.phase, "order": self.order,
            "backend": self.backend, "fused": self.fused,
            "feature_len": self.feature_len, "flops": self.flops,
            "bytes": self.bytes,
            "arithmetic_intensity": self.arithmetic_intensity,
            "collective_bytes": self.collective_bytes,
            "exposed_collective_time": self.exposed_collective_time,
            "overlapped_collective_time": self.overlapped_collective_time,
            "wall_time_s": self.wall_time_s, "bound": self.bound,
            "dtype": self.dtype, "quant_error": self.quant_error,
            "wire_collective_bytes": self.wire_collective_bytes,
            "dedup_pairs": self.dedup_pairs,
            "dedup_flops_saved": self.dedup_flops_saved,
        }


class _Probe:
    """Threaded through ``core.plan._execute_layer`` to observe dispatch.

    ``run(name, thunk, lp=..., **meta)`` executes the phase, blocks on its
    result for a wall time, derives the phase's analytic cost from the
    graph + layer plan, and appends a PhaseRecord.  Record order IS
    execution order (the ordering consistency check depends on that).
    """

    def __init__(self, plan, machine: Machine):
        self.plan = plan
        self.machine = machine
        self.records: List[PhaseRecord] = []
        self.reorder_applied = False   # set by the plan's ingress permute

    def note_reorder(self) -> None:
        """Called by ``GraphExecutionPlan._ingress`` when the planned vertex
        renumbering is actually applied -- the observation
        ``WorkloadReport.mismatches`` checks describe()'s ``reorder``
        against."""
        self.reorder_applied = True

    def run(self, name: str, thunk, *, lp, **meta):
        from repro.core.backend import resolve_backend
        t0 = time.perf_counter()
        out = thunk()
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        flops, byt, coll, flen, exp_s, ovl_s = self._cost(name, lp, meta)
        ai = flops / max(1.0, byt)
        # the phase's storage precision as the plan dispatched it: int8-agg
        # quantizes ONLY the aggregation operand, so its combine records
        # stay f32 (mismatches() checks describe() against this rule)
        pd = getattr(self.plan, "dtype", "f32")
        rec_dtype = "f32" if (pd == "int8-agg" and name == "combine") else pd
        # backend as the dispatch layer resolves it at call time (the same
        # resolution phases.aggregate applies) -- NOT lp.backend verbatim,
        # so a plan that regressed to storing an unresolved alias ("auto" /
        # "pallas") is caught by mismatches() as describe-vs-dispatch drift
        self.records.append(PhaseRecord(
            layer=lp.index, phase=name, order=lp.order,
            backend=resolve_backend(lp.backend) if name != "combine"
            else "xla",
            fused=(name == "fused_agg_combine"),
            feature_len=int(flen), flops=float(flops), bytes=float(byt),
            collective_bytes=float(coll), wall_time_s=float(dt),
            bound=self.machine.classify(ai),
            exposed_collective_time=float(exp_s),
            overlapped_collective_time=float(ovl_s),
            dtype=rec_dtype,
            quant_error=float(meta.get("quant_error", 0.0)),
            wire_collective_bytes=(
                self._wire_bytes(lp, flen, meta)
                if name == "distributed" else 0.0),
            dedup_pairs=self._dedup_layout(name).num_pairs
            if self._dedup_layout(name) else 0,
            dedup_flops_saved=float(
                self._dedup_layout(name).flops_saved(int(flen)))
            if self._dedup_layout(name) else 0.0))
        return out

    def _dedup_layout(self, phase_name: str):
        """The plan's two-level layout when this phase dispatched over it
        (aggregation phases of a resolved ``dedup="pairs"`` plan)."""
        if phase_name not in ("aggregate", "fused_agg_combine"):
            return None
        if getattr(self.plan, "dedup", "none") != "pairs":
            return None
        return getattr(self.plan, "dedup_layout", None)

    # -- analytic per-phase costs (same models the scheduler prices) --------

    def _agg_cost(self, name, lp, flen):
        """Aggregation-side analytic cost: the two-level ``dedup_cost``
        when this phase dispatched over the plan's pair layout (that IS
        the program the probe timed), ``aggregate_cost`` otherwise."""
        from repro.core.phases import aggregate_cost
        lay = self._dedup_layout(name)
        if lay is not None:
            from repro.graph.dedup import dedup_cost
            return dedup_cost(lay, flen, include_self=lp.include_self)
        return aggregate_cost(self.plan.g, flen,
                              include_self=lp.include_self)

    def _cost(self, name, lp, meta):
        from repro.core.phases import aggregate_cost, combine_cost
        g = self.plan.g
        v = g.num_vertices
        if name == "aggregate":
            flen = meta["feature_len"]
            c = self._agg_cost(name, lp, flen)
            return c["flops"], c["bytes"], 0.0, flen, 0.0, 0.0
        if name == "combine":
            dims = meta["dims"]
            c = combine_cost(v, dims)
            return c["flops"], c["bytes"], 0.0, dims[-1], 0.0, 0.0
        if name == "fused_agg_combine":
            # aggregate + first matmul in one tile: the (V, din) intermediate
            # never round-trips HBM, so its write+read bytes are subtracted.
            din, dout = meta["dims"]
            agg = self._agg_cost(name, lp, din)
            comb = combine_cost(v, (din, dout))
            saved = 2 * v * din * _DTYPE_BYTES
            byt = max(agg["bytes"] + comb["bytes"] - saved, 1)
            return agg["flops"] + comb["flops"], byt, 0.0, din, 0.0, 0.0
        if name == "distributed":
            # whole layer behind shard_map; collective term from the halo
            # model at the feature length the exchange actually moves, and
            # the exposed/overlapped wall-time split from the overlap model
            # priced for the halo schedule the dispatch passed along.
            flen = meta["feature_len"]
            agg = aggregate_cost(g, flen, include_self=lp.include_self)
            comb = combine_cost(v, lp.dims)
            coll = self._halo_bytes(flen)
            exp_s, ovl_s = self._overlap_times(
                flen, meta.get("overlap",
                               getattr(self.plan, "overlap", "none")))
            return (agg["flops"] + comb["flops"],
                    agg["bytes"] + comb["bytes"], coll, flen, exp_s, ovl_s)
        raise ValueError(f"unknown phase {name!r}")

    def _halo_bytes(self, feature_len: int) -> float:
        from repro.core.distributed import halo_bytes, halo_bytes_2d
        from repro.profile.machine import DTYPE_BYTES
        if self.plan.partition_kind == "2d":
            base = float(halo_bytes_2d(self.plan.partition,
                                       feature_len)["min_halo_bytes"])
        elif self.plan.partition_kind == "1d":
            base = float(halo_bytes(self.plan.partition,
                                    feature_len)["min_halo_bytes"])
        else:
            return 0.0
        # the halo model counts f32 elements; a reduced-precision plan
        # exchanges the wire slab at its storage width, so the collective
        # bytes scale by the dtype's element size (bf16 = exactly half f32)
        pd = getattr(self.plan, "dtype", "f32")
        return base * DTYPE_BYTES.get(pd, 4) / 4.0

    def _wire_bytes(self, lp, feature_len: int, meta) -> float:
        """Schedule-exact per-device collective bytes of this layer's
        traced halo program (``schedule_wire_bytes``) -- the side of the
        accounting the static analyzer equates to jaxpr extraction."""
        from repro.core.distributed import schedule_wire_bytes
        kind = self.plan.partition_kind
        if kind == "none":
            return 0.0
        acc = schedule_wire_bytes(
            self.plan.partition, int(feature_len),
            strategy=getattr(self.plan, "strategy", "ring"),
            overlap=meta.get("overlap",
                             getattr(self.plan, "overlap", "none")),
            dtype=getattr(self.plan, "dtype", "f32"),
            combine_out_len=lp.dout if kind == "2d" else None)
        return float(acc["total_bytes"])

    def _overlap_times(self, feature_len: int, overlap: str):
        """(exposed_s, overlapped_s) collective wall-time split for one
        distributed layer, from the same ``overlap_model`` pricing that
        ``choose_overlap`` applies -- analytic, so eager and compiled runs
        of one plan report the identical split.  ``overlap="pipelined"``
        moves the hidden share of each hop's wire time into the overlapped
        column; ``"none"`` leaves every hop fully exposed."""
        from repro.core.distributed import overlap_model
        kind = self.plan.partition_kind
        if kind == "2d":
            p2 = self.plan.partition
            pg, flen = p2.nodes, p2.feature_block(feature_len)
        elif kind == "1d":
            pg, flen = self.plan.partition, feature_len
        else:
            return 0.0, 0.0
        m = overlap_model(pg, flen, self.machine,
                          strategy=getattr(self.plan, "strategy", "ring"))
        if overlap == "pipelined":
            return (float(m["exposed_pipelined_s"]),
                    float(m["overlapped_pipelined_s"]))
        return float(m["exposed_none_s"]), 0.0


# ---------------------------------------------------------------------------
# WorkloadReport
# ---------------------------------------------------------------------------


_FIELD_TYPES = {
    "layer": int, "phase": str, "order": str, "backend": str, "fused": bool,
    "feature_len": int, "flops": (int, float), "bytes": (int, float),
    "arithmetic_intensity": (int, float), "collective_bytes": (int, float),
    "exposed_collective_time": (int, float),
    "overlapped_collective_time": (int, float),
    "wall_time_s": (int, float), "bound": str,
    "dtype": str, "quant_error": (int, float),
    "wire_collective_bytes": (int, float),
    "dedup_pairs": int, "dedup_flops_saved": (int, float),
}


def validate_report_dict(d: Dict[str, Any]) -> List[str]:
    """Structural validation of a report in dict form; returns problems.

    Works on freshly rendered ``to_dict()`` output AND on deserialized
    ``to_json()`` artifacts -- the totals-vs-phases cross-check is only
    meaningful for the latter (a live report recomputes totals from its
    records, a JSON file can be edited or truncated independently).
    """
    problems: List[str] = []
    if d.get("schema") != SCHEMA or d.get("version") != SCHEMA_VERSION:
        problems.append("schema header mismatch")
    phases_list = d.get("phases", [])
    if not phases_list:
        problems.append("empty phase records")
    for i, rec in enumerate(phases_list):
        for k, t in _FIELD_TYPES.items():
            if k not in rec:
                problems.append(f"phases[{i}]: missing field {k!r}")
            elif not isinstance(rec[k], t) or isinstance(rec[k], bool) \
                    and t is not bool:
                problems.append(
                    f"phases[{i}].{k}: bad type {type(rec[k]).__name__}")
        if rec.get("phase") not in PHASES:
            problems.append(f"phases[{i}]: unknown phase "
                            f"{rec.get('phase')!r}")
        if rec.get("bound") not in ("memory", "compute"):
            problems.append(f"phases[{i}]: bad bound {rec.get('bound')!r}")
        if rec.get("dtype") not in ("f32", "bf16", "int8-agg"):
            problems.append(f"phases[{i}]: bad dtype {rec.get('dtype')!r}")
        for k in ("flops", "bytes", "collective_bytes", "wall_time_s",
                  "exposed_collective_time", "overlapped_collective_time",
                  "quant_error", "wire_collective_bytes"):
            if isinstance(rec.get(k), (int, float)) and rec[k] < 0:
                problems.append(f"phases[{i}].{k}: negative")
        if rec.get("dtype") == "f32" and \
                isinstance(rec.get("quant_error"), (int, float)) and \
                rec["quant_error"] != 0:
            problems.append(
                f"phases[{i}].quant_error: nonzero on an f32 record "
                "(the bitwise-golden contract forbids rounding)")
        if rec.get("phase") != "distributed":
            for k in ("exposed_collective_time",
                      "overlapped_collective_time",
                      "wire_collective_bytes"):
                if isinstance(rec.get(k), (int, float)) and rec[k] != 0:
                    problems.append(
                        f"phases[{i}].{k}: nonzero on non-distributed phase")
        if rec.get("phase") not in ("aggregate", "fused_agg_combine"):
            for k in ("dedup_pairs", "dedup_flops_saved"):
                if isinstance(rec.get(k), (int, float)) and rec[k] != 0:
                    problems.append(
                        f"phases[{i}].{k}: nonzero on non-aggregation phase")
    # a plan that RESOLVED to dedup="pairs" proved matchable pairs exist at
    # build time (zero-match graphs coerce back to "none"), so a report
    # whose aggregation records all carry dedup_pairs == 0 means the
    # two-level dispatch silently did not run
    layer_descr = (d.get("plan") or {}).get("layers", [])
    if any(ld.get("dedup") == "pairs" for ld in layer_descr
           if isinstance(ld, dict)):
        agg_recs = [rec for rec in phases_list
                    if rec.get("phase") in ("aggregate",
                                            "fused_agg_combine")]
        if agg_recs and not any(
                isinstance(rec.get("dedup_pairs"), int)
                and rec["dedup_pairs"] > 0 for rec in agg_recs):
            problems.append(
                "dedup='pairs' plan with dedup_pairs == 0 on every "
                "aggregation record (matching was possible -- the plan "
                "resolved to 'pairs' -- but the two-level path did not "
                "dispatch)")
    reduced = [rec for rec in phases_list
               if rec.get("dtype") in ("bf16", "int8-agg")]
    if reduced and not any(
            isinstance(rec.get("quant_error"), (int, float))
            and rec["quant_error"] > 0 for rec in reduced):
        problems.append(
            "reduced-dtype report with quant_error == 0 everywhere "
            "(rounding must be observed somewhere, or the reduced path "
            "silently did not run)")
    tot = d.get("totals", {})
    for k in ("flops", "bytes", "collective_bytes"):
        if k not in tot:
            problems.append(f"totals.{k}: missing")
            continue
        s = sum(r[k] for r in phases_list
                if isinstance(r.get(k), (int, float)))
        if abs(s - tot[k]) > 1e-6 * max(1.0, abs(s)):
            problems.append(f"totals.{k} != sum of phases")
    comp = d.get("compiled")
    if comp is not None:            # optional: compiled-timing reports only
        if not isinstance(comp.get("model_s"), (int, float)) \
                or comp["model_s"] < 0:
            problems.append("compiled.model_s: missing/negative")
        layers_s = comp.get("layers_s", [])
        if not isinstance(layers_s, list) or any(
                not isinstance(t, (int, float)) or t < 0 for t in layers_s):
            problems.append("compiled.layers_s: ill-typed")
    serving = d.get("serving")
    if serving is not None:         # optional: serving-session reports only
        problems += _validate_serving(serving)
    return problems


def _validate_serving(s: Dict[str, Any]) -> List[str]:
    """Schema checks for a report's ``serving`` section (the per-request
    latency/throughput view ``GraphServeEngine.workload_report`` attaches):
    required counters present, non-negative, percentiles monotone."""
    problems: List[str] = []
    for k in ("requests", "bucket_misses", "retraces"):
        v = s.get(k)
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            problems.append(f"serving.{k}: missing/negative")
    for k in ("p50_ms", "p95_ms", "p99_ms", "throughput_rps"):
        v = s.get(k)
        if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
            problems.append(f"serving.{k}: missing/negative")
    pcts = [s.get(k) for k in ("p50_ms", "p95_ms", "p99_ms")]
    if all(isinstance(p, (int, float)) for p in pcts) and \
            not (pcts[0] <= pcts[1] <= pcts[2]):
        problems.append("serving percentiles not monotone "
                        "(p50 <= p95 <= p99)")
    buckets = s.get("buckets")
    if not isinstance(buckets, list):
        problems.append("serving.buckets: missing")
    else:
        for i, b in enumerate(buckets):
            for k in ("num_seeds", "num_inputs", "num_edges", "hits"):
                v = b.get(k) if isinstance(b, dict) else None
                if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                    problems.append(
                        f"serving.buckets[{i}].{k}: missing/negative")
    return problems


@dataclass
class WorkloadReport:
    """Typed per-phase characterization of one instrumented forward pass.

    ``records`` are in execution order.  ``output`` carries the forward
    result (so ``plan.instrument(...).run_model(...)`` is one call that
    yields BOTH the model output and the report); it is excluded from
    ``to_dict``/``to_json``.
    """

    machine: Machine
    plan_summary: Dict[str, Any]
    records: List[PhaseRecord]
    output: Any = None
    #: compiled wall times when the run also measured ``plan.compile()``:
    #: {"model_s": float, "layers_s": [float per layer]} (None otherwise)
    compiled_times: Optional[Dict[str, Any]] = None
    #: whether the plan's ingress reorder permute was observed executing
    reorder_applied: bool = False
    #: serving-session stats when the report describes a serving workload
    #: (``GraphServeEngine.workload_report``): requests, p50/p95/p99 ms,
    #: throughput_rps, bucket_misses, retraces, per-bucket hit counts
    #: (None for plain characterization reports)
    serving: Optional[Dict[str, Any]] = None
    #: which instrumented entry produced the records ("model" sees the
    #: full ingress/egress path; "layer"/"phases" skip it)
    entry: str = "model"

    # -- aggregation ---------------------------------------------------------

    def totals(self) -> Dict[str, float]:
        """Summed FLOPs / bytes / collective bytes / wall time over phases."""
        return {
            "flops": sum(r.flops for r in self.records),
            "bytes": sum(r.bytes for r in self.records),
            "collective_bytes": sum(r.collective_bytes
                                    for r in self.records),
            "wall_time_s": sum(r.wall_time_s for r in self.records),
        }

    def layer_records(self, layer: int) -> List[PhaseRecord]:
        return [r for r in self.records if r.layer == layer]

    def eager_layer_time(self, layer: int) -> float:
        """Summed eager wall time of one layer's recorded phases."""
        return sum(r.wall_time_s for r in self.layer_records(layer))

    def compiled_speedup(self) -> Optional[Dict[str, Any]]:
        """Eager-vs-compiled speedups when compiled times were measured.

        Returns ``{"model": eager_total/compiled_model, "layers": [per
        layer]}`` -- the paper-style "how much does removing the eager
        dispatch + phase barriers buy" number -- or None for eager-only
        reports.  CPU-container caveat as everywhere in ``repro.profile``:
        wall times are correctness-shaped observables, not accelerator
        predictions.
        """
        ct = self.compiled_times
        if not ct:
            return None
        eager_total = sum(r.wall_time_s for r in self.records)
        layers = []
        for i, ls in enumerate(ct.get("layers_s", [])):
            layers.append(self.eager_layer_time(i) / max(ls, 1e-12))
        return {"model": eager_total / max(ct["model_s"], 1e-12),
                "layers": layers}

    # -- renderers -----------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        m = self.machine
        out = {
            "schema": SCHEMA,
            "version": SCHEMA_VERSION,
            "machine": {"name": m.name, "kind": m.kind,
                        "peak_flops": m.peak_flops, "hbm_bw": m.hbm_bw,
                        "balance": m.balance},
            "plan": dict(self.plan_summary),
            "phases": [r.to_dict() for r in self.records],
            "totals": self.totals(),
        }
        if self.compiled_times is not None:
            out["compiled"] = {**self.compiled_times,
                               "speedup": self.compiled_speedup()}
        if self.serving is not None:
            out["serving"] = dict(self.serving)
        return out

    def to_json(self, indent: int = 2) -> str:
        """Stable JSON rendering (sorted keys) of ``to_dict``."""
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def to_markdown(self) -> str:
        """Paper-style per-phase breakdown table (Tables 3/4 in one view)."""
        m = self.machine
        tot = self.totals()
        t_all = max(tot["wall_time_s"], 1e-12)
        lines = [
            f"## Workload report — {m.name}",
            "",
            f"Machine: {m.name} ({m.kind}): peak "
            f"{m.peak_flops / 1e12:.1f} TFLOP/s, HBM "
            f"{m.hbm_bw / 1e9:.0f} GB/s, balance {m.balance:.1f} FLOP/B",
            f"Plan: {self.plan_summary.get('num_layers', '?')} layer(s), "
            f"partition={self.plan_summary.get('partition', 'none')}, "
            f"interpret={self.plan_summary.get('interpret')}",
            "",
            "| layer | phase | order | backend | FLOPs | bytes | AI (F/B) "
            "| bound | collective B | time (us) | time % |",
            "|---|---|---|---|---|---|---|---|---|---|---|",
        ]
        for r in self.records:
            lines.append(
                f"| {r.layer} | {r.phase} | {r.order} | {r.backend} | "
                f"{r.flops:.3e} | {r.bytes:.3e} | "
                f"{r.arithmetic_intensity:.2f} | {r.bound} | "
                f"{r.collective_bytes:.3g} | {r.wall_time_s * 1e6:.1f} | "
                f"{100 * r.wall_time_s / t_all:.1f} |")
        lines.append(
            f"| total |  |  |  | {tot['flops']:.3e} | {tot['bytes']:.3e} | "
            f"{tot['flops'] / max(1.0, tot['bytes']):.2f} |  | "
            f"{tot['collective_bytes']:.3g} | "
            f"{tot['wall_time_s'] * 1e6:.1f} | 100.0 |")
        ded = [r for r in self.records if r.dedup_pairs > 0]
        if ded:
            saved = sum(r.dedup_flops_saved for r in ded)
            naive = saved + tot["flops"]
            lines += [
                "",
                f"Dedup: {ded[0].dedup_pairs} matched pairs — "
                f"{saved:.3e} aggregation FLOPs eliminated "
                f"({100 * saved / max(naive, 1e-12):.1f}% of the naive "
                "fold's total)",
            ]
        exp = sum(r.exposed_collective_time for r in self.records)
        ovl = sum(r.overlapped_collective_time for r in self.records)
        if exp or ovl:
            lines += [
                "",
                f"Collective: {exp * 1e6:.1f} us exposed, "
                f"{ovl * 1e6:.1f} us overlapped "
                f"({100 * ovl / max(exp + ovl, 1e-12):.0f}% hidden behind "
                "the combine GEMM)",
            ]
        if self.serving is not None:
            s = self.serving
            lines += [
                "",
                f"Serving: {s['requests']} requests at "
                f"{s['throughput_rps']:.1f} req/s — p50 {s['p50_ms']:.2f} ms"
                f", p95 {s['p95_ms']:.2f} ms, p99 {s['p99_ms']:.2f} ms "
                f"({s['bucket_misses']} bucket misses, "
                f"{s['retraces']} retraces)",
            ]
        sp = self.compiled_speedup()
        if sp is not None:
            ct = self.compiled_times
            per_layer = ", ".join(
                f"layer {i}: {s:.2f}x" for i, s in enumerate(sp["layers"]))
            lines += [
                "",
                f"Compiled (plan.compile): {ct['model_s'] * 1e6:.1f} us vs "
                f"eager {t_all * 1e6:.1f} us — {sp['model']:.2f}x"
                + (f" ({per_layer})" if per_layer else ""),
            ]
        return "\n".join(lines)

    # -- validation ----------------------------------------------------------

    def validate(self) -> "WorkloadReport":
        """Raise ``WorkloadReportError`` on schema violations.

        Checked (``validate_report_dict``): non-empty phase records, every
        record field present with the right type, phase/bound vocabulary,
        non-negative costs, totals consistent with the records.  Returns
        self so call sites can chain
        (``plan.instrument().run_model(p, x).validate()``).
        """
        problems = validate_report_dict(self.to_dict())
        if problems:
            raise WorkloadReportError(
                "WorkloadReport schema violations: " + "; ".join(problems))
        return self

    def mismatches(self, plan) -> List[str]:
        """Cross-check ``plan.describe()`` against the dispatched phases.

        What is genuinely *observed* (not copied from the plan) and
        therefore guarded: the executed phase sequence (ordering -- the
        combine/aggregate records are appended in execution order),
        whether the fused path actually ran (``run_phases`` with an inline
        bias may legitimately fall back at call time -- that fallback is
        exactly the drift this reports; model-path plans must always come
        back clean), the call-time backend *resolution* (a plan
        storing an unresolved "auto"/"pallas" alias disagrees with what
        dispatch resolves), whether the planned ``reorder`` permute
        actually ran at ingress (observed only by ``run_model`` -- the
        entry that owns ingress/egress), the storage ``dtype`` each phase
        record carries (must equal describe()'s planned dtype, except
        combine under ``"int8-agg"`` which stays ``"f32"`` -- only the
        aggregation operand is quantized), the halo ``overlap`` schedule the
        distributed dispatch actually priced (a record with overlapped
        collective time on a plan describing ``overlap="none"`` -- or the
        reverse -- is describe-vs-dispatch drift), and the ``compiled``
        capability
        (a report carrying compiled times contradicts a describe() that
        claims ``plan.compile()`` is unsupported).  Kernel-entry tier
        selection below this layer is covered by tests/test_plan.py's
        mocked-platform tests, not here.  Empty list == describe() is
        truthful.
        """
        out: List[str] = []
        for d in plan.describe():
            if self.entry == "model" and "reorder" in d:
                observed_reorder = "degree" if self.reorder_applied \
                    else "none"
                if d["reorder"] != observed_reorder:
                    out.append(
                        f"layer {d['layer']}: describe reorder="
                        f"{d['reorder']} but ingress observed "
                        f"{observed_reorder}")
            if self.compiled_times is not None and \
                    d.get("compiled") is False:
                out.append(f"layer {d['layer']}: describe compiled=False "
                           "but a compiled run was measured")
            recs = self.layer_records(d["layer"])
            if not recs:
                continue
            seq = [r.phase for r in recs]
            fused_ran = "fused_agg_combine" in seq
            if bool(d["fused"]) != fused_ran:
                out.append(f"layer {d['layer']}: describe fused={d['fused']} "
                           f"but executed phases {seq}")
            if "dtype" in d:
                for r in recs:
                    want = "f32" if (d["dtype"] == "int8-agg"
                                     and r.phase == "combine") else d["dtype"]
                    if r.dtype != want:
                        out.append(
                            f"layer {d['layer']}: describe dtype="
                            f"{d['dtype']} but {r.phase} record carries "
                            f"{r.dtype}")
            agg = [r for r in recs
                   if r.phase in ("aggregate", "fused_agg_combine",
                                  "distributed")]
            if "dedup" in d:
                for r in recs:
                    if r.phase not in ("aggregate", "fused_agg_combine"):
                        continue
                    observed_dd = "pairs" if r.dedup_pairs > 0 else "none"
                    if d["dedup"] != observed_dd:
                        out.append(
                            f"layer {d['layer']}: describe dedup="
                            f"{d['dedup']} but {r.phase} record carries "
                            f"dedup_pairs={r.dedup_pairs}")
            for r in agg:
                if r.backend != d["backend"]:
                    out.append(f"layer {d['layer']}: describe backend="
                               f"{d['backend']} but {r.phase} used "
                               f"{r.backend}")
            dist = [r for r in recs if r.phase == "distributed"]
            if "overlap" in d:
                for r in dist:
                    if r.exposed_collective_time == 0 and \
                            r.overlapped_collective_time == 0:
                        continue   # single shard: nothing moves, no signal
                    observed_ov = ("pipelined"
                                   if r.overlapped_collective_time > 0
                                   else "none")
                    if d["overlap"] != observed_ov:
                        out.append(
                            f"layer {d['layer']}: describe overlap="
                            f"{d['overlap']} but probe recorded "
                            f"{observed_ov} collective split")
            if not fused_ran and "aggregate" in seq and "combine" in seq:
                observed = ("combine_first"
                            if seq.index("combine") < seq.index("aggregate")
                            else "aggregate_first")
                if observed != d["order"]:
                    out.append(f"layer {d['layer']}: describe order="
                               f"{d['order']} but executed {seq}")
        return out


# ---------------------------------------------------------------------------
# InstrumentedPlan
# ---------------------------------------------------------------------------


class InstrumentedPlan:
    """A ``GraphExecutionPlan`` whose runs yield ``WorkloadReport``s.

    Built by ``plan.instrument(machine=...)``; ``machine`` defaults to the
    plan's own (``build_plan(..., machine=)``) or the first layer backend's
    natural preset.  Each ``run_*`` executes the plan's REAL dispatch path
    eagerly (per-phase wall times need phase boundaries, so no whole-model
    jit) and returns a fresh report whose ``.output`` is the forward result.
    """

    def __init__(self, plan, machine: Optional[Machine] = None,
                 warmup: int = 0):
        self.plan = plan
        self.machine = machine or getattr(plan, "machine", None) or \
            machine_for_backend(plan.layers[0].backend)
        self.warmup = warmup

    def _summary(self) -> Dict[str, Any]:
        p = self.plan
        return {
            "num_layers": p.num_layers,
            "partition": p.partition_kind,
            "interpret": p.interpret,
            "layers": p.describe(),
        }

    def _report(self, probe: _Probe, out, entry: str) -> WorkloadReport:
        return WorkloadReport(machine=self.machine,
                              plan_summary=self._summary(),
                              records=probe.records, output=out,
                              reorder_applied=probe.reorder_applied,
                              entry=entry)

    @staticmethod
    def _time(fn, *args) -> float:
        """Median wall seconds of ``fn(*args)`` via the ONE shared timing
        harness (``repro.profile.bench.timeit``, warmup absorbs the jit
        trace/compile) -- compiled and bench numbers share a protocol."""
        from repro.profile.bench import timeit
        return timeit(fn, *args, warmup=1, iters=3) / 1e6

    def _compiled_times(self, params, x) -> Dict[str, Any]:
        """Wall times of ``plan.compile()`` -- the whole forward plus each
        planned layer compiled standalone (``plan.compile(layer=i)``), so
        the report can state eager-vs-compiled speedup per layer.  The
        replay walks the same ingress/layer/ReLU sequence ``run_model``
        executes, in the plan's execution layout."""
        plan = self.plan
        model_s = self._time(plan.compile(), params, x)
        layers_s = []
        h = plan._ingress(x)
        for i in range(plan.num_layers):
            sub = params[f"conv{i}"]
            fl = plan.compile(layer=i)
            layers_s.append(self._time(fl, sub, h))
            h = fl(sub, h)
            if i < plan.num_layers - 1:
                h = jax.nn.relu(h)
        return {"model_s": model_s, "layers_s": layers_s}

    def run_model(self, params, x, *, compiled: bool = False
                  ) -> WorkloadReport:
        """Instrumented full forward; returns the WorkloadReport (the model
        output rides along as ``report.output``).

        ``compiled=True`` additionally measures the ``plan.compile()`` path
        (whole model and per layer) and attaches the wall times as
        ``report.compiled_times`` -- ``report.compiled_speedup()`` /
        ``to_markdown()`` then state the eager-vs-compiled speedup.  The
        eager per-phase records are unchanged: phase boundaries need eager
        dispatch, so the compiled executable is timed as a whole.
        """
        for _ in range(self.warmup):
            jax.block_until_ready(self.plan.run_model(params, x))
        probe = _Probe(self.plan, self.machine)
        out = self.plan.run_model(params, x, _probe=probe)
        report = self._report(probe, out, "model")
        if compiled:
            report.compiled_times = self._compiled_times(params, x)
        return report

    def run_layer(self, params, x, *, layer: int = 0) -> WorkloadReport:
        """Instrumented single layer (conv param subtree)."""
        probe = _Probe(self.plan, self.machine)
        out = self.plan.run_layer(params, x, layer=layer, _probe=probe)
        return self._report(probe, out, "layer")

    def run_phases(self, x, weights, **kw) -> WorkloadReport:
        """Instrumented raw weight-list layer (``plan.run_phases``)."""
        probe = _Probe(self.plan, self.machine)
        out = self.plan.run_phases(x, weights, _probe=probe, **kw)
        return self._report(probe, out, "phases")
