"""Jaxpr/HLO contract linter: prove planner contracts from the trace.

Given a :class:`~repro.core.plan.GraphExecutionPlan`, :func:`lint_plan`
traces (never executes) the eager forward AND the ``plan.compile()``
callable to closed jaxprs plus lowered StableHLO, then runs the rule
registry over them:

  * ``no-callbacks``      -- no host callbacks / device transfers inside
    traced code (``pure_callback``, ``io_callback``, ``device_put``, ...).
  * ``no-f64``            -- no float64 avals or constants anywhere.
  * ``bf16-f32-accum``    -- every dot with a bf16 operand must carry
    ``preferred_element_type=float32`` (the PR 8 accumulator contract).
  * ``donation``          -- ``donate=True`` compiles must show the
    ``tf.aliasing_output`` marker in lowered HLO whenever an output can
    alias the donated buffer (info finding when none can).
  * ``collective-bytes``  -- ppermute/all_gather/psum_scatter byte totals
    extracted from the jaxpr (scan trip counts multiplied through) must
    equal :func:`repro.core.distributed.schedule_wire_bytes` exactly,
    dtype-scaled, 1-D and 2-D.
  * ``dynamic-edge-free`` -- dynamic bucket plans re-proven edge-content
    free from the jaxpr consts (not trusted from ``_check_dynamic_ok``).
  * ``dedup-accounting``  -- a ``dedup='pairs'`` plan's trace must run
    the SHORTENED two-level fold its :class:`DedupLayout` prices
    (scatter over ``num_edges2`` rows, pair-partial gathers over
    ``num_pairs``), never the naive ``num_edges`` fold -- the priced
    FLOP/byte savings are proven against the jaxpr, not bookkeeping.

:func:`lint_callable` runs the jaxpr-level rules over any traceable
function (the self-test plants use it); :func:`collective_bytes` is the
raw per-primitive byte extraction, and
:func:`plan_expected_collectives` the analytic side of the equation.

Doctest-shaped usage (any local plan, single device)::

    >>> # report = lint_plan(plan)          # doctest: +SKIP
    >>> # assert report.ok(strict=True)     # doctest: +SKIP

The collective/donation/dynamic rules are exercised by
``scripts/analyze.py`` over the full plan matrix on 8 fake devices.
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.analysis.report import AnalysisReport

#: primitives that move data to/from the host or escape the trace
HOST_PRIMS = ("pure_callback", "io_callback", "debug_callback", "callback",
              "infeed", "outfeed", "device_put")

#: jaxpr names of the collectives the halo schedules emit
#: (``jax.lax.psum_scatter`` lowers to the ``reduce_scatter`` primitive)
COLLECTIVE_PRIMS = ("ppermute", "all_gather", "reduce_scatter", "psum")


# ---------------------------------------------------------------------------
# Jaxpr walking + byte extraction
# ---------------------------------------------------------------------------


def _sub_jaxprs(value) -> list:
    """Sub-jaxprs hiding inside one eqn param value (ClosedJaxpr, bare
    Jaxpr, or lists/tuples of either -- scan, pjit, shard_map,
    pallas_call, custom_jvp all stash theirs differently)."""
    if hasattr(value, "jaxpr") and hasattr(getattr(value, "jaxpr"), "eqns"):
        return [value.jaxpr]
    if hasattr(value, "eqns"):
        return [value]
    if isinstance(value, (list, tuple)):
        out = []
        for v in value:
            out.extend(_sub_jaxprs(v))
        return out
    return []


def iter_eqns(jaxpr, mult: int = 1):
    """Yield ``(eqn, trip_multiplier)`` over a jaxpr and every sub-jaxpr.

    The multiplier is the product of enclosing ``scan`` lengths, so an
    eqn inside a ``scan(length=7)`` body yields with ``mult*7`` -- the
    number of times the traced program executes it.
    """
    for eqn in jaxpr.eqns:
        yield eqn, mult
        m = mult
        if eqn.primitive.name == "scan":
            m = mult * int(eqn.params.get("length", 1))
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from iter_eqns(sub, m)


def _aval_bytes(var) -> int:
    aval = getattr(var, "aval", None)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape)) * np.dtype(dtype).itemsize


def collective_bytes(closed) -> Dict[str, int]:
    """Per-primitive collective byte totals extracted from a closed jaxpr.

    For every ``ppermute`` / ``all_gather`` / ``reduce_scatter`` /
    ``psum`` eqn, sums the INPUT aval bytes (what the device puts on the
    wire) times the enclosing scan trip count.  This is the per-device
    accounting :func:`repro.core.distributed.schedule_wire_bytes`
    predicts analytically.
    """
    out = {name: 0 for name in COLLECTIVE_PRIMS}
    for eqn, mult in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        if name in out:
            out[name] += sum(_aval_bytes(v) for v in eqn.invars) * mult
    return out


# ---------------------------------------------------------------------------
# Rules over a closed jaxpr
# ---------------------------------------------------------------------------


def check_no_callbacks(closed, where: str,
                       report: AnalysisReport) -> None:
    """Rule no-callbacks: traced code must stay on device."""
    hits: Dict[str, int] = {}
    for eqn, _ in iter_eqns(closed.jaxpr):
        if eqn.primitive.name in HOST_PRIMS:
            hits[eqn.primitive.name] = hits.get(eqn.primitive.name, 0) + 1
    for name, n in sorted(hits.items()):
        report.add("no-callbacks", "error", where,
                   f"host primitive {name!r} inside traced code",
                   f"{n} occurrence(s)")


def check_no_f64(closed, where: str, report: AnalysisReport) -> None:
    """Rule no-f64: no float64 avals or constants anywhere in the trace."""
    n_avals = 0
    for eqn, _ in iter_eqns(closed.jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None and np.dtype(dt) == np.float64:
                n_avals += 1
    n_consts = sum(1 for c in getattr(closed, "consts", [])
                   if getattr(c, "dtype", None) is not None
                   and np.dtype(c.dtype) == np.float64)
    if n_avals or n_consts:
        report.add("no-f64", "error", where,
                   "float64 values inside traced code",
                   f"{n_avals} aval(s), {n_consts} const(s)")


def check_bf16_accum(closed, where: str, report: AnalysisReport) -> None:
    """Rule bf16-f32-accum: any dot consuming bf16 must accumulate f32
    (``preferred_element_type=float32``) -- the PR 8 contract that keeps
    reduced-precision storage from becoming reduced-precision math."""
    import jax.numpy as jnp
    bad = 0
    example = ""
    for eqn, _ in iter_eqns(closed.jaxpr):
        if eqn.primitive.name != "dot_general":
            continue
        ins = [getattr(getattr(v, "aval", None), "dtype", None)
               for v in eqn.invars]
        if not any(d is not None and d == jnp.bfloat16 for d in ins):
            continue
        pref = eqn.params.get("preferred_element_type")
        if pref is None or np.dtype(pref) != np.float32:
            bad += 1
            example = f"operands {ins}, preferred_element_type={pref}"
    if bad:
        report.add("bf16-f32-accum", "error", where,
                   "bf16 dot without f32 preferred_element_type "
                   "accumulation", f"{bad} dot(s); e.g. {example}")


#: the StableHLO argument attribute jax emits for a donated buffer that
#: aliases an output; absent entirely when no output can take the alias
DONATION_MARKER = "tf.aliasing_output"


def check_donation(lowered_text: str, donate: bool, where: str,
                   report: AnalysisReport, *,
                   alias_possible: bool = True) -> None:
    """Rule donation: a ``donate=True`` compile must show the
    ``tf.aliasing_output`` marker in lowered HLO.  When no output matches
    the donated buffer's shape/dtype jax silently drops the donation --
    that is reported as info (unprovable), not error."""
    if not donate:
        return
    if DONATION_MARKER in lowered_text:
        return
    if alias_possible:
        report.add("donation", "error", where,
                   "donate=True but lowered HLO shows no donated buffer",
                   f"marker {DONATION_MARKER!r} absent")
    else:
        report.add("donation", "info", where,
                   "donation declared but no output can alias the donated "
                   "buffer (shape/dtype mismatch); donation is a no-op")


def check_collective_bytes(closed, expected: Dict[str, int], where: str,
                           report: AnalysisReport) -> None:
    """Rule collective-bytes: jaxpr-extracted per-primitive byte totals
    must equal the analytic schedule accounting EXACTLY."""
    got = collective_bytes(closed)
    for name in COLLECTIVE_PRIMS:
        if got[name] != int(expected.get(name, 0)):
            report.add("collective-bytes", "error", where,
                       f"{name} bytes diverge from the analytic schedule",
                       f"extracted {got[name]}, "
                       f"expected {int(expected.get(name, 0))}")


def dedup_fold_dims(closed) -> Dict[str, list]:
    """Leading dims of every fold in a trace: ``scatter`` collects each
    scatter-add's updates rows (how many edge contributions the fold
    actually sums), ``gather`` each gather's output rows.  What the
    dedup-accounting rule compares against the layout's priced lengths."""
    dims = {"scatter": [], "gather": []}
    for eqn, _ in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        if name == "scatter-add" and eqn.invars:
            shape = getattr(getattr(eqn.invars[-1], "aval", None),
                            "shape", None)
            if shape:
                dims["scatter"].append(int(shape[0]))
        elif name == "gather" and eqn.outvars:
            shape = getattr(getattr(eqn.outvars[0], "aval", None),
                            "shape", None)
            if shape:
                dims["gather"].append(int(shape[0]))
    return dims


def check_dedup_fold(closed, layout, where: str,
                     report: AnalysisReport) -> None:
    """Rule dedup-accounting: the trace must execute the two-level fold
    the layout prices.

    ``repro.graph.dedup.dedup_cost`` keys its FLOP/byte accounting on
    ``(num_pairs, num_edges2)``; this rule proves those are the lengths
    the traced program actually folds -- a scatter-add over the NAIVE
    edge count means the dedup decision was priced but not executed, a
    missing ``num_edges2`` scatter or ``num_pairs`` pair gather means
    the two-level layout never reached the trace.
    """
    e, e2, p = layout.naive_edges, layout.num_edges2, layout.num_pairs
    dims = dedup_fold_dims(closed)
    scatter, gather = set(dims["scatter"]), set(dims["gather"])
    if e != e2 and e in scatter:
        report.add("dedup-accounting", "error", where,
                   "naive-length fold inside a dedup='pairs' trace",
                   f"scatter-add over {e} rows; the layout prices the "
                   f"shortened {e2}-edge fold")
    if e2 not in scatter:
        report.add("dedup-accounting", "error", where,
                   "two-level fold absent from the trace",
                   f"no scatter-add over the layout's {e2} level-2 edges "
                   f"(scatter rows seen: {sorted(scatter)})")
    if p and p not in gather:
        report.add("dedup-accounting", "error", where,
                   "pair-partial gathers absent from the trace",
                   f"no gather of the layout's {p} pair rows "
                   f"(gather rows seen: {sorted(gather)})")


def check_dynamic_consts(closed, graph, where: str,
                         report: AnalysisReport) -> None:
    """Rule dynamic-edge-free: a dynamic bucket plan's trace must not
    close over the template graph's edge content.  Re-proves
    ``_check_dynamic_ok`` from the jaxpr consts: any const equal to the
    template ``src``/``dst``/``in_deg`` array means the trace baked the
    edges and every bucket would replay THIS graph."""
    templates = {"src": np.asarray(graph.src), "dst": np.asarray(graph.dst),
                 "in_deg": np.asarray(graph.in_deg)}
    for c in getattr(closed, "consts", []):
        arr = np.asarray(c)
        for name, tpl in templates.items():
            if arr.shape == tpl.shape and arr.dtype == tpl.dtype \
                    and np.array_equal(arr, tpl):
                report.add("dynamic-edge-free", "error", where,
                           f"trace closes over the template graph's "
                           f"{name} array",
                           f"const shape {arr.shape}, dtype {arr.dtype}")


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def lint_callable(fn, *args, where: str = "callable",
                  expected_collectives: Optional[Dict[str, int]] = None
                  ) -> AnalysisReport:
    """Trace ``fn(*args)`` and run every jaxpr-level rule over it.

    The self-test plants route through this so a seeded violation
    exercises the same detection path as a real plan.  Pass
    ``expected_collectives`` to also run the collective-bytes rule.
    """
    import jax
    report = AnalysisReport()
    closed = jax.make_jaxpr(fn)(*args)
    check_no_callbacks(closed, where, report)
    check_no_f64(closed, where, report)
    check_bf16_accum(closed, where, report)
    if expected_collectives is not None:
        check_collective_bytes(closed, expected_collectives, where, report)
    return report


def plan_label(plan) -> str:
    """Stable cell label for findings, e.g.
    ``plan[backend=xla,fused=False,partition=1d,dtype=bf16,...]``."""
    lp = plan.layers[0]
    return (f"plan[backend={lp.backend},fused={lp.fused},"
            f"partition={plan.partition_kind},strategy={plan.strategy},"
            f"overlap={plan.overlap},dtype={plan.dtype},"
            f"reorder={plan.reorder}]")


def plan_expected_collectives(plan) -> Dict[str, int]:
    """Analytic per-primitive byte totals for one full forward of
    ``plan`` -- :func:`~repro.core.distributed.schedule_wire_bytes`
    summed over layers (halo width follows each layer's phase order:
    din under aggregate-first, dout under combine-first)."""
    from repro.core.distributed import schedule_wire_bytes
    from repro.core.scheduler import AGGREGATE_FIRST
    totals = {name: 0 for name in COLLECTIVE_PRIMS}
    if not plan.distributed:
        return totals
    two_d = plan.partition_kind == "2d"
    for lp in plan.layers:
        flen = lp.din if lp.order == AGGREGATE_FIRST else lp.dout
        acc = schedule_wire_bytes(
            plan.partition, flen, strategy=plan.strategy,
            overlap=plan.overlap, dtype=plan.dtype,
            combine_out_len=lp.dout if two_d else None)
        totals["ppermute"] += acc["ppermute_bytes"]
        totals["all_gather"] += acc["all_gather_bytes"]
        totals["reduce_scatter"] += acc["reduce_scatter_bytes"]
        totals["psum"] += acc["psum_bytes"]
    return totals


def _alias_possible(in_avals: Iterable, out_avals: Iterable) -> bool:
    """True when some output aval matches a donated input aval -- the
    precondition for XLA to establish input/output aliasing."""
    outs = [(tuple(a.shape), np.dtype(a.dtype)) for a in out_avals]
    return any((tuple(a.shape), np.dtype(a.dtype)) in outs
               for a in in_avals)


def lint_plan(plan, *, params=None, x=None, donate: bool = False,
              dynamic: bool = False, seed: int = 0) -> AnalysisReport:
    """Statically verify one ``GraphExecutionPlan`` -- trace, never execute.

    Traces the eager forward (``plan.run_model``) and the compiled
    callable (``plan.compile(donate=...)._fn.trace(...)``) to closed
    jaxprs plus lowered StableHLO, then applies the full rule registry:
    no-callbacks, no-f64, bf16-f32-accum on both traces; donation on the
    lowered text (``donate=True``); collective-bytes against
    ``plan_expected_collectives`` (distributed plans); and, with
    ``dynamic=True``, dynamic-edge-free over the dynamic dispatch trace's
    consts.

    ``params``/``x`` default to ``plan.init(PRNGKey(seed))`` and a zero
    feature matrix -- tracing only reads shapes/dtypes, never values.

    >>> # lint_plan(build_plan(g, cfg, fin, nc)).ok()   # doctest: +SKIP
    """
    import jax
    import jax.numpy as jnp
    report = AnalysisReport()
    where = plan_label(plan)
    if params is None:
        params = plan.init(jax.random.PRNGKey(seed))
    if x is None:
        x = jnp.zeros((plan.g.num_vertices, plan.layers[0].din),
                      jnp.float32)

    eager = jax.make_jaxpr(lambda p, xx: plan.run_model(p, xx))(params, x)
    cp = plan.compile(donate=donate)
    traced = cp._fn.trace(params, x)
    compiled = traced.jaxpr

    expected = plan_expected_collectives(plan)
    # the two-level fold is only visible as scatter/gather dims on the
    # plain-XLA unfused path; Pallas/fused plans hide it inside kernels
    dedup_visible = (getattr(plan, "dedup", "none") == "pairs"
                     and plan.dedup_layout is not None
                     and all(lp.backend == "xla" and not lp.fused
                             for lp in plan.layers))
    for tag, closed in (("eager", eager), ("compiled", compiled)):
        w = f"{where}:{tag}"
        check_no_callbacks(closed, w, report)
        check_no_f64(closed, w, report)
        check_bf16_accum(closed, w, report)
        check_collective_bytes(closed, expected, w, report)
        if dedup_visible:
            check_dedup_fold(closed, plan.dedup_layout, w, report)

    if donate:
        lowered = traced.lower().as_text()
        check_donation(lowered, donate, f"{where}:compiled", report,
                       alias_possible=_alias_possible([x],
                                                      compiled.out_avals))

    if dynamic:
        g = plan.g
        cpd = plan.compile(dynamic=True)
        traced_dyn = cpd._fn.trace(params, x, jnp.asarray(g.src),
                                   jnp.asarray(g.dst),
                                   jnp.asarray(g.in_deg))
        w = f"{where}:dynamic"
        check_no_callbacks(traced_dyn.jaxpr, w, report)
        check_no_f64(traced_dyn.jaxpr, w, report)
        check_dynamic_consts(traced_dyn.jaxpr, g, w, report)
    return report
