"""Public jit'd wrappers for the Pallas kernels.

These own tile selection (VMEM-budget-aware, MXU-aligned), static-shape
padding, and the host<->kernel layout glue so the rest of the framework calls
plain functions.  Interpret mode is auto-detected per platform
(``core.backend.default_interpret``: interpreted off-TPU, compiled on TPU;
override with ``REPRO_PALLAS_INTERPRET=0/1``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import PALLAS_GPU, PALLAS_TPU
from repro.core.backend import default_interpret as _interpret
from repro.core.backend import interpret_for, resolve_backend
from repro.kernels import ref as kref
from repro.profile.machine import machine_for_backend
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_agg_combine import fused_agg_combine_blocked
from repro.kernels.gpu_agg import (fused_agg_combine_gpu_blocked,
                                   seg_agg_gpu_blocked)
from repro.kernels.seg_agg import seg_agg_blocked


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


#: ONE remediation text shared by the ``seg_agg`` tracing ValueError and
#: the ``host-in-trace`` AST lint rule (repro.analysis.ast_lint), so the
#: error a user hits and the finding a reviewer reads agree verbatim on
#: the fix: route through the trace-pure planned entry points.
SEG_AGG_REMEDIATION = (
    "seg_agg regroups edges on the host and cannot run inside jit/grad; "
    "dispatch the trace-pure seg_agg_planned instead -- via a plan from "
    "build_plan, plan_for_conv, or plan_for_phases (each owns a blocked "
    "layout), or call seg_agg_planned directly with a "
    "core.dataflow.block_graph layout")


# ---------------------------------------------------------------------------
# Segmented aggregation over a destination-sorted edge list
# ---------------------------------------------------------------------------


def _seg_agg_entry(backend: str):
    """Pick the tier's blocked kernel (TPU sequential-grid vs GPU row-owned).
    ``backend`` must already be resolved (the callers below resolve the
    legacy "pallas" alias so entry and interpret mode can never disagree)."""
    return seg_agg_gpu_blocked if backend == PALLAS_GPU else seg_agg_blocked


def seg_agg(rows: jnp.ndarray, seg_ids: jnp.ndarray, num_segments: int,
            tile_m: int = 128, tile_e: int = 512,
            backend: str = PALLAS_TPU) -> jnp.ndarray:
    """Drop-in segment_sum(rows, seg_ids) -- the SLOW ad-hoc fallback.

    Requires ``seg_ids`` sorted (destination-sorted edges -- the framework
    invariant).  This entry performs the O(E) block regrouping on the HOST
    on *every call* (``device_get`` + numpy), so it cannot be traced
    (``jax.jit`` / ``grad`` raise) and it re-pays the regrouping cost per
    invocation.  It exists only for one-off calls on un-planned graphs.

    Repeated-graph callers must go through the plan-owned blocked layout
    instead: ``GraphExecutionPlan`` builds it once per (graph, tile_m)
    (``core.plan._blocked_for``) and dispatches ``seg_agg_planned`` --
    trace-pure, zero host transfers.  ``phases.aggregate(..., layout=...)``
    is the phase-level door.  ``backend`` selects the kernel tier
    ("pallas-tpu" | "pallas-gpu"; "pallas"/"auto" resolve per platform --
    see core/backend.py).
    """
    backend = resolve_backend(backend)
    if backend == PALLAS_GPU:
        tile_e = min(tile_e, 128)  # SM-resident chunk, not a VMEM slab
    e, f = rows.shape
    if isinstance(seg_ids, jax.core.Tracer):
        raise ValueError(SEG_AGG_REMEDIATION)
    # documented host fallback -- the Tracer guard above is the contract
    seg_np = np.asarray(jax.device_get(seg_ids))  # analysis: allow(host-in-trace)
    nblocks = _round_up(num_segments, tile_m) // tile_m
    blk = seg_np // tile_m
    counts = np.bincount(blk, minlength=nblocks)
    emax = _round_up(max(int(counts.max()) if len(counts) else 1, 1), tile_e)
    bs_rows = jnp.zeros((nblocks, emax, f), rows.dtype)
    seg_l = np.zeros((nblocks, emax), np.int32)
    mask = np.zeros((nblocks, emax), np.float32)
    from repro.core.dataflow import block_offsets
    _, offs = block_offsets(blk, nblocks)
    seg_l[blk, offs] = seg_np - blk * tile_m
    mask[blk, offs] = 1.0
    bs_rows = bs_rows.at[jnp.asarray(blk), jnp.asarray(offs)].set(rows)
    out = _seg_agg_entry(backend)(
        bs_rows, jnp.asarray(seg_l), jnp.asarray(mask),
        tile_m=tile_m, tile_e=tile_e, interpret=interpret_for(backend))
    return out[:num_segments]


def seg_agg_pregrouped(rows_blocked, seg_local, mask, tile_m: int,
                       tile_e: int = 512,
                       backend: str = PALLAS_TPU) -> jnp.ndarray:
    """Kernel entry for already block-grouped inputs (BlockedGraph layout)."""
    backend = resolve_backend(backend)
    if backend == PALLAS_GPU:
        tile_e = min(tile_e, 128)
    return _seg_agg_entry(backend)(
        rows_blocked, seg_local, mask, tile_m=tile_m, tile_e=tile_e,
        interpret=interpret_for(backend))


def seg_agg_planned(bg, x: jnp.ndarray, edge_weight=None, *,
                    tile_e: int = 512,
                    backend: str = PALLAS_TPU) -> jnp.ndarray:
    """Trace-pure segmented aggregation over a plan-owned blocked layout.

    ``bg`` is a ``core.dataflow.BlockedGraph`` (with ``eidx``) built ONCE at
    plan time; everything here is jnp gathers and the Pallas kernel, so the
    whole call traces under ``jax.jit``/``grad`` with zero host transfers --
    the production replacement for the ad-hoc ``seg_agg`` regrouping.

    x: (V, F) vertex features; ``edge_weight``: optional (E,) per-edge
    scalar, regrouped into the blocked layout via ``bg.eidx`` (one gather).
    Returns (V, F) -- ``sum_{(u,v) in E} w_uv * x_u`` per destination v.

    The gather source may carry MORE rows than the destination space: a
    ``dedup="pairs"`` plan (``graph.dedup.DedupLayout``) passes a
    ``(V+P, F)`` matrix -- the V inputs plus P pair partial sums -- and a
    blocked layout whose ``src`` ids reach into the partial rows, so the
    kernel folds the SHORTENED level-2 edge list unchanged; only the
    first-dim bound differs, never the kernel body.
    """
    backend = resolve_backend(backend)
    if backend == PALLAS_GPU:
        tile_e = min(tile_e, 128)
    nblocks, emax = bg.src.shape
    rows = jnp.take(x, bg.src.reshape(-1), axis=0).reshape(
        nblocks, emax, x.shape[-1])
    if edge_weight is not None:
        if bg.eidx is None:
            raise ValueError("BlockedGraph built without eidx cannot "
                             "regroup edge weights; rebuild via block_graph")
        w_blk = jnp.take(edge_weight, bg.eidx.reshape(-1),
                         axis=0).reshape(nblocks, emax)
        rows = rows * w_blk[..., None].astype(rows.dtype)
    emax_p = _round_up(emax, tile_e)
    seg_l, mask = bg.dstl, bg.mask
    if emax_p != emax:
        pad = ((0, 0), (0, emax_p - emax))
        rows = jnp.pad(rows, pad + ((0, 0),))
        seg_l = jnp.pad(seg_l, pad)
        mask = jnp.pad(mask, pad)
    out = _seg_agg_entry(backend)(
        rows, seg_l, mask, tile_m=bg.tile_m, tile_e=tile_e,
        interpret=interpret_for(backend))
    return out[:bg.num_vertices]


# ---------------------------------------------------------------------------
# Fused aggregation + combination (paper F5)
# ---------------------------------------------------------------------------


def fused_agg_combine(src, dst_local, mask, x, w, *, tile_m: int,
                      tile_e: int = 0,
                      backend: str = PALLAS_TPU) -> jnp.ndarray:
    """Gather x rows by ``src`` (XLA DMA gather), then fused reduce+GEMM.

    src/dst_local/mask: (nblocks, emax) BlockedGraph layout.
    x: (V, F_in); w: (F_in, F_out).  Returns (nblocks*tile_m, F_out).
    ``backend`` selects the kernel tier: "pallas-tpu" (sequential edge-chunk
    grid + VMEM scratch) or "pallas-gpu" (one CTA per block, register
    accumulator -- kernels/gpu_agg.py); "pallas"/"auto" resolve per platform.
    """
    backend = resolve_backend(backend)
    nblocks, emax = src.shape
    f_in, f_out = w.shape
    if tile_e == 0:
        if backend == PALLAS_GPU:
            # edge chunk shares the SM with A100.target_ctas peers;
            # keep the (tile_e, F_in) slab small and warp-aligned
            tile_e = 128
        else:
            # VMEM budget: rows chunk + W + acc within half VMEM
            # (the TPU tier's Machine tile budget).  The streamed rows slab
            # and W are sized at the INPUT element width (2 for bf16 plan
            # operands -- wider edge chunks fit), the accumulator stays 4
            # bytes (acc_dtype=f32 regardless of storage dtype).
            elt = jnp.dtype(x.dtype).itemsize
            budget = machine_for_backend(backend).tile_budget()
            fixed = (f_in * f_out * elt
                     + (tile_m * f_in + tile_m * f_out) * 4)
            tile_e = max(256, min(2048,
                                  (budget - fixed) // max(f_in * elt, 1)))
            tile_e = max(256, (tile_e // 256) * 256)
    emax_p = _round_up(emax, tile_e)
    if emax_p != emax:
        pad = ((0, 0), (0, emax_p - emax))
        src = jnp.pad(src, pad)
        dst_local = jnp.pad(dst_local, pad)
        mask = jnp.pad(mask, pad)
    rows = jnp.take(x, src.reshape(-1), axis=0).reshape(nblocks, emax_p, -1)
    entry = (fused_agg_combine_gpu_blocked if backend == PALLAS_GPU
             else fused_agg_combine_blocked)
    return entry(rows, dst_local, mask, w, tile_m=tile_m, tile_e=tile_e,
                 interpret=interpret_for(backend))


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, kv_len=None, *, causal: bool = True,
                    window: int = 0, softcap: float = 0.0,
                    tile_q: int = 128, tile_k: int = 128) -> jnp.ndarray:
    return _flash(q, k, v, kv_len, causal=causal, window=window,
                  softcap=softcap, tile_q=tile_q, tile_k=tile_k,
                  interpret=_interpret())


# Re-export oracles for convenience in tests/benchmarks.
ref = kref
