"""Step functions lowered by the dry-run, trainer, and serving engine.

One factory per step kind; each returns a pure function over (state/params,
batch) pytrees so jit in_shardings apply cleanly.  VLM embeds / audio frames
are threaded through per the arch family.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.config import LMConfig, OptimizerConfig
from repro.models import encdec as encdec_lib
from repro.models.transformer import (lm_decode_step, lm_forward, lm_loss,
                                      lm_prefill)
from repro.optim.optimizer import TrainState, adamw_update


def make_train_step(cfg: LMConfig, opt: OptimizerConfig,
                    remat: str = "none", microbatch: int = 0) -> Callable:
    """(TrainState, batch) -> (TrainState, metrics).

    ``microbatch`` > 1 enables gradient accumulation: the global batch is
    split along dim 0 into that many slices processed under a lax.scan;
    peak activation memory scales down ~1/microbatch at unchanged math
    (grads accumulated in ``opt.accum_dtype``).
    """

    def loss_fn(params, batch):
        if cfg.family == "audio":
            return encdec_lib.encdec_loss(params, cfg, batch["frames"],
                                          batch["tokens"], batch["labels"])
        return lm_loss(params, cfg, batch["tokens"], batch["labels"],
                       batch.get("embeds"), remat=remat)

    def train_step(state: TrainState, batch: Dict[str, Any]):
        if microbatch and microbatch > 1:
            n = microbatch
            adt = jnp.dtype(getattr(opt, "accum_dtype", "float32"))
            mb = jax.tree.map(
                lambda a: a.reshape(n, a.shape[0] // n, *a.shape[1:]),
                batch)

            def body(acc, one):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, one)
                acc = jax.tree.map(lambda a, g: a + g.astype(adt), acc,
                                   grads)
                metrics = dict(metrics)
                metrics["loss"] = loss
                return acc, metrics

            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt),
                                state.params)
            grads, metrics_stack = jax.lax.scan(body, acc0, mb)
            grads = jax.tree.map(lambda g: g / n, grads)
            metrics = jax.tree.map(lambda m: m.mean(), metrics_stack)
            loss = metrics.pop("loss")
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
            metrics = dict(metrics)
        new_state, opt_metrics = adamw_update(state, grads, opt)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: LMConfig, cache_size: int = 0) -> Callable:
    """(params, batch) -> (last logits, caches, [memory,] length)."""

    def prefill_step(params, batch):
        if cfg.family == "audio":
            return encdec_lib.encdec_prefill(
                params, cfg, batch["frames"], batch["tokens"],
                cache_size or batch["tokens"].shape[1])
        # VLM: frontend embeds occupy the first positions of the cache too
        n_front = batch["embeds"].shape[1] if "embeds" in batch else 0
        size = cache_size or (batch["tokens"].shape[1] + n_front)
        return lm_prefill(params, cfg, batch["tokens"], size,
                          batch.get("embeds"))

    return prefill_step


def make_decode_step(cfg: LMConfig) -> Callable:
    """(params, batch{token, caches, [memory,] length}) -> (logits, caches, length)."""

    def decode_step(params, batch):
        if cfg.family == "audio":
            return encdec_lib.encdec_decode_step(
                params, cfg, batch["token"], batch["caches"],
                batch["memory"], batch["length"])
        return lm_decode_step(params, cfg, batch["token"], batch["caches"],
                              batch["length"])

    return decode_step


def make_eval_step(cfg: LMConfig) -> Callable:
    def eval_step(params, batch):
        if cfg.family == "audio":
            loss, m = encdec_lib.encdec_loss(params, cfg, batch["frames"],
                                             batch["tokens"],
                                             batch["labels"])
        else:
            loss, m = lm_loss(params, cfg, batch["tokens"], batch["labels"],
                              batch.get("embeds"))
        return m
    return eval_step
