#!/usr/bin/env bash
# Pre-merge smoke check (the documented gate for every PR):
#   1. tier-1 pytest (ROADMAP.md "Tier-1 verify"),
#   2. the benchmark harness dry-run, which builds + validates the full
#      backend x ordering x fusion x reorder x partition (1-D and 2-D)
#      matrix through the GraphExecutionPlan -- every scenario runs
#      INSTRUMENTED and emits a WorkloadReport that is schema-validated
#      (empty phase records or violations fail) and cross-checked against
#      plan.describe() (planner drift fails), every scenario ALSO checks
#      the compiled contract (plan.compile() output bit-for-bit equal to
#      eager dispatch, no retrace on the second call), the plan/compiled
#      cells land the eager-vs-compiled speedup CSV under
#      experiments/bench/ -- and the run FAILS if any scenario in the
#      matrix is skipped without a logged reason.  The dry run ALSO runs
#      the halo-overlap matrix (bench_overlap: overlap x strategy x
#      partition on 8 fake devices -- HARD-FAILS if any overlap cell is
#      silently skipped, if the pipelined schedule's output differs by a
#      single bit from the single-buffered one eager or compiled, or if
#      the modeled pipelined time exceeds the single-buffered model) and
#      drains the GraphServeEngine offered-load sweep (bench_serve):
#      every closed-loop level AND the open-loop Poisson points warm up
#      the bucket ladder, serve the synthetic workload, and HARD-FAIL on
#      bucket misses, retraces after warmup(), empty serving stats, or
#      padded-vs-eager bit drift (docs/serving.md).  The dry run ALSO
#      sweeps the dtype x feature_len precision matrix (bench_dtype):
#      every cell builds through build_plan(dtype=...) and HARD-FAILS if
#      the f32 plan is not bitwise-identical under plan.compile(), if a
#      reduced-precision (bf16 / int8-agg) cell drifts outside the ONE
#      shared tolerance band or silently runs f32 (no observed
#      quant_error), if choose_dtype fails to flip between the V100 and
#      TPU_V5E presets, if the instrumented bf16 halo bytes are not
#      EXACTLY half of f32's on 8 fake devices, or if any dtype cell is
#      skipped without a logged reason.  The dry run ALSO gates the
#      pair-redundancy elimination (bench_dedup): the fanout-regular
#      sampled block HARD-FAILS on zero matched pairs, on an analytic
#      aggregation-FLOP reduction below the 20% floor, on any f32 bit
#      drift between the dedup='pairs' plan (eager or compiled) and the
#      naive plan, on instrumented aggregation records missing their
#      dedup_pairs counts, or if choose_dedup fails to flip between the
#      fanout-regular block ('pairs') and the sparse full-graph layer
#      ('none') on the same machine preset,
#   3. the docs gate (README + docs/planner.md + docs/characterization.md
#      + docs/serving.md + docs/analysis.md exist, public
#      planner/profile/serving/analysis symbols documented --
#      scripts/check_docs.py),
#   4. the static analysis gate (scripts/analyze.py): --strict traces the
#      full backend x fusion x partition x dtype x overlap plan matrix to
#      jaxprs + lowered HLO WITHOUT executing and hard-fails on any
#      error-severity contract violation (host callbacks, f64, bf16
#      accumulation, missing donation markers, collective byte totals
#      that disagree with schedule_wire_bytes, edge-content leaking into
#      dynamic bucket plans, plus the AST rules over src/repro/);
#      --selftest then seeds one known violation per rule and hard-fails
#      if ANY rule misses its plant (docs/analysis.md).
#
# Usage: scripts/smoke.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# test_ctx_parallel_attention_sharded hits a known jax-0.4.x shard_map x
# custom_vjp incompatibility (pre-existing since the seed; fails identically
# there) -- deselected until the LM attention substrate gains a compat path.
# Rationale documented in README.md "Known failure".
python -m pytest -x -q \
  --deselect tests/test_distributed.py::test_ctx_parallel_attention_sharded \
  "$@"

echo "== planner + overlap + serving + dtype dry-run (backend x ordering x"
echo "   fusion x reorder x partition; instrumented: one schema-validated"
echo "   WorkloadReport per scenario, compiled contract: bitwise eager"
echo "   equality + no retrace; overlap matrix: silently skipped overlap"
echo "   cells or a compiled-bitwise/pipelined-schedule break hard-fail;"
echo "   serving: bucketed offered-load drain, closed- and open-loop --"
echo "   bucket misses, retraces, or empty serving stats hard-fail;"
echo "   dtype matrix: f32 bitwise drift, band violations, a missing"
echo "   choose_dtype preset flip, or non-halved bf16 halo bytes"
echo "   hard-fail; dedup matrix: zero matched pairs on the fanout-"
echo "   regular block, an unreduced analytic aggregation-FLOP count,"
echo "   f32 drift from the naive plan, or a missing choose_dedup"
echo "   workload flip hard-fail) =="
python -m benchmarks.run --dry-run

echo "== docs gate =="
python scripts/check_docs.py

echo "== static analysis gate (plan matrix -> jaxpr/HLO, no execution;"
echo "   then the rule self-test: every rule must catch its plant) =="
python scripts/analyze.py --strict
python scripts/analyze.py --selftest

echo "smoke: OK"
