"""Property-based dtype equivalence for planned execution (build_plan dtype=).

The two-sided contract under test:

  * ``dtype="f32"`` (and the default) is BITWISE-golden -- eager ==
    ``plan.compile()`` exactly, on every (backend, fusion, ordering,
    reorder) combination, and building/running reduced-precision plans in
    between must not perturb it.
  * ``"bf16"`` / ``"int8-agg"`` are tolerance-banded equivalent to the f32
    plan through the ONE shared harness (tests/tolerance.py) -- same band
    regardless of which planner axes are in play -- and resolve onto the
    plan (``plan.dtype`` never stays ``"auto"``).

The sharded case (8 fake devices, subprocess per the dry-run rule) drives
the reduced-precision halo exchange with a ragged V and checks the
instrument()-reported bf16 collective bytes are exactly half of f32's.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from tolerance import assert_allclose_dtype

from repro.core.plan import build_plan
from repro.graph.structure import graph_from_coo
from repro.models.gcn import PAPER_MODELS

DTYPES = ("f32", "bf16", "int8-agg")


def _case_graph(seed, v, deg, f):
    rng = np.random.default_rng(seed)
    e = max(v, v * deg)
    g = graph_from_coo(rng.integers(0, v, e), rng.integers(0, v, e), v)
    x = jnp.asarray(rng.standard_normal((v, f)), jnp.float32)
    return g, x


@st.composite
def planner_case(draw):
    """One point of the planner decision space x a random graph shape."""
    return dict(
        seed=draw(st.integers(0, 2 ** 16)),
        v=draw(st.integers(40, 160)),
        deg=draw(st.integers(2, 5)),
        f=draw(st.sampled_from([8, 24, 48])),
        backend=draw(st.sampled_from(["xla", "pallas-tpu", "pallas-gpu"])),
        ordering=draw(st.sampled_from(["combine_first", "aggregate_first",
                                       None])),
        fused=draw(st.sampled_from([False, True, None])),
        reorder=draw(st.sampled_from(["none", "degree"])),
    )


def _plans_for(case):
    g, x = _case_graph(case["seed"], case["v"], case["deg"], case["f"])
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
    kw = dict(backend=case["backend"], ordering=case["ordering"],
              fused=case["fused"], reorder=case["reorder"])
    plans = {dt: build_plan(g, cfg, case["f"], 7, dtype=dt, **kw)
             for dt in DTYPES}
    params = plans["f32"].init(jax.random.PRNGKey(0))
    return g, x, plans, params


@given(planner_case())
@settings(max_examples=5, deadline=None)
def test_dtype_equivalence_across_planner_axes(case):
    """eager == compiled within the dtype band on every planner combo;
    f32 stays bitwise and is not perturbed by reduced runs in between."""
    _, x, plans, params = _plans_for(case)

    ref = plans["f32"].run_model(params, x)
    assert_allclose_dtype(plans["f32"].compile()(params, x), ref,
                          bitwise=True, err_msg=str(case))

    for dt in ("bf16", "int8-agg"):
        p = plans[dt]
        assert p.dtype == dt                      # resolved, stored
        assert p.describe()[0]["dtype"] == dt
        out = p.run_model(params, x)
        # compiled replays the same reduced path within the band (bf16 is
        # a pure cast schedule, int8 rounding may fuse differently)
        assert_allclose_dtype(p.compile()(params, x), out, dtype=dt,
                              err_msg=f"compiled {dt}: {case}")
        # reduced output tracks the f32 plan within the dtype's band
        # (scale 2: two layers of rounding at the phase boundaries)
        assert_allclose_dtype(out, ref, dtype=dt, scale=2,
                              err_msg=f"{dt} vs f32: {case}")

    # the reduced builds/runs above must not have perturbed f32
    assert_allclose_dtype(plans["f32"].run_model(params, x), ref,
                          bitwise=True, err_msg=f"f32 perturbed: {case}")


def test_auto_dtype_resolves_and_caches_distinctly():
    """"auto" resolves against the machine before the plan is stored: the
    plan never carries "auto", and the cache keys the RESOLVED request --
    one graph can hold f32 and bf16 plans side by side."""
    from repro.profile.machine import TPU_V5E, V100, choose_dtype
    g, x = _case_graph(7, 96, 3, 24)
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
    pa = build_plan(g, cfg, 24, 7, dtype="auto", machine=TPU_V5E)
    assert pa.dtype in ("f32", "bf16") and pa.dtype != "auto"
    p32 = build_plan(g, cfg, 24, 7, dtype="f32", machine=TPU_V5E)
    pbf = build_plan(g, cfg, 24, 7, dtype="bf16", machine=TPU_V5E)
    assert p32 is not pbf
    assert build_plan(g, cfg, 24, 7, machine=TPU_V5E) is p32
    # the decision function itself flips across presets at the paper's
    # GCN-scale widths (the bench_dtype matrix pins the exact workload)
    assert choose_dtype(256, 1024, 128, machine=V100) == "f32"
    assert choose_dtype(256, 1024, 128, machine=TPU_V5E) == "bf16"
    with pytest.raises(ValueError):
        build_plan(g, cfg, 24, 7, dtype="f16")


def test_int8_agg_quantizes_only_aggregation():
    """int8-agg: combine stays f32 (records + describe agree), and the
    instrument report carries the quantization error it observed."""
    g, x = _case_graph(3, 80, 3, 24)
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
    p = build_plan(g, cfg, 24, 7, dtype="int8-agg")
    params = p.init(jax.random.PRNGKey(0))
    rep = p.instrument().run_model(params, x).validate()
    assert not rep.mismatches(p)
    by_phase = {r.phase: r for r in rep.records}
    assert by_phase["combine"].dtype == "f32"
    assert by_phase["aggregate"].dtype == "int8-agg"
    assert max(r.quant_error for r in rep.records) > 0
    # int8-agg keeps f32 storage at the output (only the agg operand is
    # fake-quantized); bf16 rounds the phase outputs down
    assert p.run_model(params, x).dtype == jnp.float32
    pb = build_plan(g, cfg, 24, 7, dtype="bf16")
    assert pb.run_model(params, x).dtype == jnp.bfloat16


@pytest.mark.slow
def test_sharded_bf16_halo_halves_collective_bytes():
    """8 fake devices, ragged V: the bf16 distributed plan matches the
    local f32 reference within band, and instrument() reports EXACTLY half
    the f32 plan's collective (halo) bytes -- the wire slab is the thing
    the reduced dtype shrinks."""
    from test_distributed import run_sub
    out = run_sub("""
        import dataclasses
        from repro.config import CORA, reduced_graph
        from repro.graph.datasets import make_synthetic_graph, make_features
        from repro.core.plan import build_plan
        from repro.models.gcn import PAPER_MODELS
        spec = reduced_graph(CORA, 301, 32)       # 301 % 8 != 0: ragged
        g = make_synthetic_graph(spec); x = make_features(spec)
        cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
        mesh = jax.make_mesh((8,), ("data",))
        local = build_plan(g, cfg, spec.feature_len, spec.num_classes)
        params = local.init(jax.random.PRNGKey(0))
        ref = local.run_model(params, x)
        kw = dict(mesh=mesh, num_shards=8, strategy="ring")
        d32 = build_plan(g, cfg, spec.feature_len, spec.num_classes, **kw)
        dbf = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                         dtype="bf16", **kw)
        with mesh:
            o32 = d32.run_model(params, x)
            obf = dbf.run_model(params, x)
        assert_allclose_dtype(o32, ref, scale=100)
        assert_allclose_dtype(obf, ref, dtype="bf16", scale=2)
        with mesh:
            r32 = d32.instrument().run_model(params, x).validate()
            rbf = dbf.instrument().run_model(params, x).validate()
        assert not rbf.mismatches(dbf)
        c32 = sum(r.collective_bytes for r in r32.records)
        cbf = sum(r.collective_bytes for r in rbf.records)
        assert c32 > 0, "halo model reported no collective traffic"
        assert cbf * 2 == c32, (cbf, c32)
        assert max(r.quant_error for r in rbf.records) > 0
        assert all(r.quant_error == 0 for r in r32.records)
        print("DTYPE-OK")
    """)
    assert "DTYPE-OK" in out
