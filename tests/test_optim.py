"""Optimizer, schedule, clipping, gradient compression, checkpointing."""

import shutil
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.config import OptimizerConfig
from repro.optim.compression import (_quantize, compression_wire_bytes,
                                     init_residuals)
from repro.optim.optimizer import (adamw_update, cosine_lr, global_norm,
                                   make_train_state)


def test_adamw_converges_quadratic():
    opt = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, grad_clip=0.0)
    target = jnp.asarray(np.random.default_rng(0).standard_normal((4, 4)),
                         jnp.float32)
    params = {"w": jnp.zeros((4, 4))}
    state = make_train_state(params, opt)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)  # noqa: E731
    for _ in range(150):
        g = jax.grad(loss)(state.params)
        state, _ = adamw_update(state, g, opt)
    assert float(loss(state.params)) < 1e-2


def test_cosine_schedule_shape():
    opt = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_lr(opt, jnp.asarray(0))) == 0.0
    assert float(cosine_lr(opt, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(cosine_lr(opt, jnp.asarray(100))) == pytest.approx(0.0,
                                                                    abs=1e-6)
    mid = float(cosine_lr(opt, jnp.asarray(55)))
    assert 0.4 < mid < 0.6


def test_grad_clip_caps_norm():
    opt = OptimizerConfig(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros((8,))}
    state = make_train_state(params, opt)
    g = {"w": jnp.full((8,), 100.0)}
    _, metrics = adamw_update(state, g, opt)
    assert float(metrics["grad_norm"]) > 100


def test_weight_decay_skips_vectors():
    opt = OptimizerConfig(lr=0.1, warmup_steps=0, weight_decay=1.0)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    state = make_train_state(params, opt)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    state, _ = adamw_update(state, zero_g, opt)
    assert float(jnp.abs(state.params["w"] - 1.0).max()) > 0  # decayed
    assert float(jnp.abs(state.params["b"] - 1.0).max()) == 0  # not decayed


def test_moment_dtype_bf16():
    opt = OptimizerConfig(moment_dtype="bfloat16")
    state = make_train_state({"w": jnp.ones((4,))}, opt)
    assert state.m["w"].dtype == jnp.bfloat16


# ----------------------------------------------------------- compression
def test_quantize_error_feedback_unbiased_over_time():
    """Accumulated (q*scale + residual) must equal accumulated gradients."""
    rng = np.random.default_rng(0)
    residual = jnp.zeros((64,), jnp.float32)
    total_g, total_sent = np.zeros(64), np.zeros(64)
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(64), jnp.float32)
        q, scale, residual = _quantize(g, residual)
        total_g += np.asarray(g)
        total_sent += np.asarray(q, np.float64) * float(scale)
    # error feedback: cumulative sent tracks cumulative true gradient
    np.testing.assert_allclose(total_sent + np.asarray(residual), total_g,
                               rtol=1e-4, atol=1e-4)


def test_quantize_range():
    g = jnp.asarray([-1000.0, 0.0, 1000.0])
    q, scale, r = _quantize(g, jnp.zeros(3))
    assert int(jnp.abs(q).max()) <= 127
    np.testing.assert_allclose(np.asarray(q, np.float32) * float(scale),
                               np.asarray(g), rtol=1e-2, atol=float(scale))


def test_wire_bytes_model():
    w = compression_wire_bytes(1_000_000, dp=16)
    assert w["fp32_bytes"] / w["int8_ef_bytes"] == pytest.approx(4.0)


# ----------------------------------------------------------- checkpointer
def _state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip():
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, keep=2)
        st = _state()
        ck.save(3, st, extra={"pipeline": {"step": 3, "seed": 0}},
                blocking=True)
        abstract = jax.eval_shape(lambda: _state())
        restored, step, extra = ck.restore(abstract)
        assert step == 3 and extra["pipeline"]["step"] == 3
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(st["params"]["w"]))
        assert restored["params"]["b"].dtype == jnp.bfloat16
    finally:
        shutil.rmtree(d)


def test_checkpoint_retention_and_latest():
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, _state(), blocking=True)
        assert ck.all_steps() == [3, 4]
        assert ck.latest_step() == 4
    finally:
        shutil.rmtree(d)


def test_checkpoint_async_then_wait():
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, keep=1)
        ck.save(1, _state(), blocking=False)
        ck.wait()
        assert ck.latest_step() == 1
    finally:
        shutil.rmtree(d)


def test_checkpoint_atomicity_no_partial_dirs():
    """A .tmp dir must never be listed as a restorable step."""
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, keep=3)
        (Path(d) / "step_000000000099.tmp").mkdir()
        ck.save(1, _state(), blocking=True)
        assert ck.all_steps() == [1]
    finally:
        shutil.rmtree(d)


def test_checkpoint_shape_mismatch_raises():
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d)
        ck.save(1, _state(), blocking=True)
        bad = jax.eval_shape(
            lambda: {"params": {"w": jnp.zeros((5, 4)),
                                "b": jnp.zeros((4,), jnp.bfloat16)},
                     "step": jnp.asarray(0, jnp.int32)})
        with pytest.raises(ValueError):
            ck.restore(bad)
    finally:
        shutil.rmtree(d)
