"""Paper core: phases, ordering scheduler, dataflow, characterization."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from tolerance import assert_allclose_dtype

from repro.config import CORA, REDDIT, GraphSpec, reduced_graph
from repro.core import phases
from repro.core.characterize import (Roofline, StepCost, phase_report,
                                     roofline)
from repro.core.dataflow import block_graph, fused_gcn_layer, suggest_tile_m
from repro.core.scheduler import (AGGREGATE_FIRST, COMBINE_FIRST,
                                  choose_ordering, ordering_cost,
                                  reduction_ratios, swap_is_legal)
from repro.graph.datasets import make_features, make_synthetic_graph
from repro.graph.structure import to_dense_adj


@pytest.fixture(scope="module")
def setup():
    spec = reduced_graph(CORA, 200, 24)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    return spec, g, x


def test_aggregate_matches_dense(setup):
    _, g, x = setup
    a = np.asarray(to_dense_adj(g))
    xn = np.asarray(x)
    for op, ref in [
        ("sum", a @ xn + xn),
        ("mean", (a @ xn + xn) / (np.asarray(g.in_deg)[:, None] + 1)),
    ]:
        out = phases.aggregate(g, x, op=op)
        assert_allclose_dtype(out, ref)


def test_aggregate_max(setup):
    _, g, x = setup
    out = np.asarray(phases.aggregate(g, x, op="max"))
    a = np.asarray(to_dense_adj(g)) > 0
    xn = np.asarray(x)
    for v in range(8):
        nbrs = np.where(a[v])[0]
        ref = np.maximum(xn[nbrs].max(0) if len(nbrs) else -np.inf, xn[v])
        assert_allclose_dtype(out[v], ref)


def test_ordering_equivalence_linear(setup):
    """F2: combine-first == aggregate-first for linear combination."""
    _, g, x = setup
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((x.shape[1], 16)) * 0.3, jnp.float32)
    cf = phases.phase_ordered_layer(g, x, [(w, None)], order=COMBINE_FIRST,
                                    agg_op="mean", activation="none")
    af = phases.phase_ordered_layer(g, x, [(w, None)], order=AGGREGATE_FIRST,
                                    agg_op="mean", activation="none")
    assert_allclose_dtype(cf, af, scale=10)


def test_swap_legality():
    assert swap_is_legal("mean", 1)
    assert swap_is_legal("sum", 1)
    assert not swap_is_legal("max", 1)      # nonlinear reduce
    assert not swap_is_legal("sum", 2)      # GIN MLP with interior ReLU


def test_scheduler_picks_smaller_agg_bytes(setup):
    _, g, _ = setup
    # shrinking projection (602 -> 128): combine first
    assert choose_ordering(g, 602, 128) == COMBINE_FIRST
    # expanding projection (128 -> 602): aggregate first
    assert choose_ordering(g, 128, 602) == AGGREGATE_FIRST
    # GIN semantics pinned regardless of dims
    assert choose_ordering(g, 602, 128, agg_op="sum", n_mlp_layers=2,
                           semantic_order=AGGREGATE_FIRST) == AGGREGATE_FIRST


def test_table4_reduction_ratio_matches_paper():
    """Reddit 602->128 must reproduce the paper's ~4.7x (Table 4)."""
    spec = reduced_graph(REDDIT, 4096, 602)
    g = make_synthetic_graph(spec)
    r = reduction_ratios(g, 602, 128)
    assert 4.0 < r["data_access_reduction"] < 5.0
    assert 4.2 < r["computation_reduction"] < 5.0


@given(st.integers(8, 512), st.integers(8, 512))
@settings(max_examples=20, deadline=None)
def test_ordering_cost_monotonic(in_len, out_len):
    """Aggregation cost under combine-first depends ONLY on out_len (Fig 5)."""
    spec = GraphSpec("t", 128, in_len, 512)
    g = make_synthetic_graph(spec)
    c = ordering_cost(g, in_len, out_len, COMBINE_FIRST)
    c2 = ordering_cost(g, in_len * 2 if in_len <= 256 else in_len, out_len,
                       COMBINE_FIRST)
    assert c.agg_bytes == c2.agg_bytes  # independent of in_len
    a = ordering_cost(g, in_len, out_len, AGGREGATE_FIRST)
    assert a.agg_bytes == ordering_cost(g, in_len, out_len * 2,
                                        AGGREGATE_FIRST).agg_bytes


def test_fused_dataflow_matches_unfused(setup):
    _, g, x = setup
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((x.shape[1], 16)) * 0.3, jnp.float32)
    bg = block_graph(g, 32)
    fused = fused_gcn_layer(bg, x, w, None, agg_op="mean", in_deg=g.in_deg)
    ref = phases.phase_ordered_layer(g, x, [(w, None)], order=COMBINE_FIRST,
                                     agg_op="mean", activation="none")
    assert_allclose_dtype(fused, ref, scale=10)


def test_suggest_tile_m_fits_vmem():
    from repro.profile.machine import TPU_V5E
    m = suggest_tile_m(602, 128, avg_deg=50.0)
    w = 602 * 128 * 4
    per_row = (602 + 128 + 2 * 50 * 602) * 4
    assert w + m * per_row <= TPU_V5E.on_chip_bytes // 2 + per_row * 8


def test_suggest_tile_m_dtype_aware():
    """bf16 halves the per-row VMEM footprint, so the suggested tile for
    the SAME layer geometry on the SAME machine must be larger than f32's
    (roughly 2x, modulo alignment rounding)."""
    f32 = suggest_tile_m(512, 256, avg_deg=16.0, dtype_bytes=4)
    bf16 = suggest_tile_m(512, 256, avg_deg=16.0, dtype_bytes=2)
    assert bf16 > f32
    assert bf16 >= int(f32 * 1.5)


def test_plan_tile_sizing_consumes_dtype():
    """End to end: a bf16 fused plan gets a larger Pallas tile than the
    f32 plan on a graph big enough that the VMEM budget (not the |V|
    clamp or the 4096 cap) decides the tile."""
    import dataclasses

    from repro.core.plan import build_plan
    from repro.graph.datasets import make_synthetic_graph
    from repro.models.gcn import PAPER_MODELS

    spec = dataclasses.replace(
        CORA, num_vertices=4096, num_edges=65536, feature_len=512)
    g = make_synthetic_graph(spec)
    cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(256,))

    def tile(dtype):
        plan = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                          backend="pallas-tpu", fused=True, dtype=dtype)
        return plan.layers[0].tile_m

    assert tile("bf16") > tile("f32")
    # int8-agg carries f32 on the wire and in VMEM -> sized like f32
    assert tile("int8-agg") == tile("f32")


def test_phase_report_classification(setup):
    """Table 3: Aggregation memory-bound, Combination compute-bound."""
    _, g, _ = setup
    agg = phases.aggregate_cost(g, 128)
    comb = phases.combine_cost(100_000, (602, 128))
    rep = phase_report(agg, comb)
    assert rep["aggregation"]["bound"] == "memory"
    assert rep["aggregation"]["arithmetic_intensity"] < 1.0
    # dense GEMM at scale approaches compute-bound on the V100-era balance;
    # on v5e (balance ~240) large GEMMs must at least beat aggregation by 10x
    assert rep["combination"]["arithmetic_intensity"] > \
        50 * rep["aggregation"]["arithmetic_intensity"]


def test_roofline_terms():
    cost = StepCost(flops=197e12, hbm_bytes=819e9,
                    collective={"total": 200e9})
    r = roofline(cost, chips=256, model_flops=197e12 * 256)
    assert abs(r.compute_s - 1.0) < 1e-6
    assert abs(r.memory_s - 1.0) < 1e-6
    assert abs(r.collective_s - 1.0) < 1e-6
    assert r.roofline_fraction == pytest.approx(1.0)
    r2 = roofline(StepCost(flops=1e12, hbm_bytes=819e9 * 10,
                           collective={"total": 0}), chips=2)
    assert r2.dominant == "memory"
