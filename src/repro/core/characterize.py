"""Characterization machinery: paper metrics + roofline terms from compiled HLO.

The paper's V100 counters (L2 hit rate, occupancy, IPC...) do not exist here;
the architecture-neutral quantities behind them do.  This module derives:

  * per-phase FLOPs / bytes / arithmetic intensity  (Table 3),
  * bound classification against a machine balance point,
  * HLO-level cost extraction (``cost_analysis``) for any jitted step,
  * collective-byte extraction by parsing lowered HLO text (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute),
  * the three roofline terms for TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI), per DESIGN.md §7.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import numpy as np

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW_PER_LINK = 50e9        # bytes/s per link
ICI_LINKS = 4                 # v5e: 4 ICI links per chip (2D torus: +-x, +-y)
VMEM_BYTES = 128 * 1024 * 1024
MXU_DIM = 128

#: machine balance: FLOPs per byte at which compute and HBM time are equal
MACHINE_BALANCE = PEAK_FLOPS_BF16 / HBM_BW  # ~240 flop/byte

# --- GPU (A100-class) hardware constants (per SM) --------------------------
# Used by the occupancy-aware GPU tile picker (core.dataflow.suggest_tile_m
# with the pallas-gpu backend): unlike the TPU's one big VMEM, a GPU hides
# latency by keeping SEVERAL thread blocks resident per SM, so the per-block
# working set must fit a fraction of the SM's shared-memory/L1 carveout.
GPU_SMEM_PER_SM = 192 * 1024      # unified SMEM/L1 carveout per SM (bytes)
GPU_REGFILE_PER_SM = 256 * 1024   # register file per SM (bytes)
GPU_TARGET_CTAS_PER_SM = 4        # resident CTAs needed to hide HBM latency
GPU_WARP_ROWS = 32                # threads per warp = natural row granularity


# ---------------------------------------------------------------------------
# HLO parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

_COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(tok_dtype: str, tok_dims: str) -> int:
    if tok_dims.strip() == "":
        n = 1
    else:
        n = int(np.prod([int(d) for d in tok_dims.split(",") if d]))
    return n * _DTYPE_BYTES[tok_dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in lowered/compiled HLO text.

    Returns {op_name: bytes, ..., "total": bytes}.  Counts the bytes each
    collective *moves in* (operand side), matching the roofline convention of
    DESIGN.md §7.  Start ops (``all-gather-start``) are counted; matching
    ``-done`` ops are skipped to avoid double counting, as are fusion-internal
    mentions of collectives inside metadata strings.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    count: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        # HLO instruction lines look like:  %name = TYPE[dims] op-name(operands...)
        m = re.match(r"%?[\w\.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        opname = m.group(2)
        base = None
        for c in _COLLECTIVE_OPS:
            if opname == c or opname == c + "-start":
                base = c
                break
        if base is None:
            continue
        # operand shapes: everything inside the call parens
        call = s[s.index(opname + "(") + len(opname) + 1:]
        depth, end = 1, 0
        for i, ch in enumerate(call):
            depth += (ch == "(") - (ch == ")")
            if depth == 0:
                end = i
                break
        operands = call[:end]
        b = sum(shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(operands))
        out[base] += b
        count[base] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVE_OPS)
    out["counts"] = dict(count)  # type: ignore[assignment]
    return out


# ---------------------------------------------------------------------------
# Compiled-step cost extraction
# ---------------------------------------------------------------------------


@dataclass
class StepCost:
    flops: float
    hbm_bytes: float
    collective: Dict[str, int] = field(default_factory=dict)
    peak_memory_per_device: Optional[float] = None
    output_bytes: Optional[float] = None

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(1.0, self.hbm_bytes)


def cost_from_compiled(compiled, lowered=None) -> StepCost:
    """Extract FLOPs/bytes from ``compiled.cost_analysis()`` + HLO collectives."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    coll = {}
    try:
        coll = collective_bytes(compiled.as_text())
    except Exception:
        if lowered is not None:
            coll = collective_bytes(lowered.as_text())
    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = float(getattr(ma, "temp_size_in_bytes", 0) +
                     getattr(ma, "argument_size_in_bytes", 0) +
                     getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    return StepCost(flops=flops, hbm_bytes=byt, collective=coll,
                    peak_memory_per_device=peak)


def cost_of(fn, *args, static_argnums=(), **jit_kw) -> StepCost:
    """Lower+compile ``fn(*args)`` (abstract -- args may be ShapeDtypeStructs)."""
    lowered = jax.jit(fn, static_argnums=static_argnums, **jit_kw).lower(*args)
    compiled = lowered.compile()
    return cost_from_compiled(compiled, lowered)


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    chips: int
    flops: float
    hbm_bytes: float
    collective_bytes: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def step_time_s(self) -> float:
        """Lower bound on step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound step time (the score we hillclimb).

        Uses MODEL_FLOPS (6ND, already per-device here) when available so
        redundant compiled compute (remat, dispatch overhead) counts
        against us, per the brief.
        """
        useful = self.model_flops or self.flops
        ideal = useful / PEAK_FLOPS_BF16
        return ideal / max(self.step_time_s, 1e-30)

    @property
    def mfu(self) -> float:
        return self.roofline_fraction

    def row(self) -> Dict[str, Any]:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_ratio": (self.model_flops / self.flops) if self.flops else 0,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline(cost: StepCost, chips: int, model_flops: float = 0.0) -> Roofline:
    """Three-term roofline per DESIGN.md §7.

    Conventions (verified empirically on this backend, see EXPERIMENTS.md
    §Dry-run methodology): the compiled module is the PER-DEVICE SPMD
    program, so ``cost`` carries per-device FLOPs/bytes/collective-bytes
    (trip-count-aware, via core.hlo_cost).  Terms are therefore per-device
    quantities over per-chip peaks; ``model_flops`` is the GLOBAL 6ND number
    and is divided by ``chips`` for the useful-compute comparison.
    """
    flops = cost.flops
    byt = cost.hbm_bytes
    coll = float(cost.collective.get("total", 0))
    return Roofline(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=byt / HBM_BW,
        collective_s=coll / (ICI_LINKS * ICI_BW_PER_LINK),
        chips=chips, flops=flops, hbm_bytes=byt, collective_bytes=coll,
        model_flops=model_flops / max(chips, 1))


# ---------------------------------------------------------------------------
# Paper Table 3: hybrid execution pattern report
# ---------------------------------------------------------------------------


#: V100 fp32 balance (15.7 TFLOP/s / 900 GB/s) -- the PAPER's classification
#: point.  v5e bf16 balance is ~240: a GEMM that is compute-bound on V100
#: (AI ~50) is memory-bound on v5e unless batched/fused wider -- a real
#: hardware-adaptation finding, reported alongside (DESIGN.md §2).
V100_BALANCE = 15.7e12 / 900e9


def phase_report(agg_cost: dict, comb_cost: dict) -> Dict[str, Any]:
    """Classify each phase against machine balance (Table 3 reproduction)."""
    def classify(c):
        ai = c["arithmetic_intensity"]
        return {
            "arithmetic_intensity": ai,
            # paper-faithful classification (V100 balance)
            "bound": "memory" if ai < V100_BALANCE else "compute",
            # TPU v5e adaptation
            "bound_v5e": "memory" if ai < MACHINE_BALANCE else "compute",
            "bytes": c["bytes"], "flops": c["flops"],
            # paper's "DRAM bytes per operation"
            "bytes_per_op": c["bytes"] / max(1, c["flops"]),
        }
    return {"aggregation": classify(agg_cost),
            "combination": classify(comb_cost),
            "machine_balance_v100": V100_BALANCE,
            "machine_balance_v5e": MACHINE_BALANCE}
