"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition, written with plain jnp ops and
no tiling -- tests sweep shapes/dtypes and assert_allclose kernels against
these.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def seg_agg_ref(rows: jnp.ndarray, seg_ids: jnp.ndarray, mask: jnp.ndarray,
                num_segments: int) -> jnp.ndarray:
    """Segmented row sum: out[s] = sum_{e: seg_ids[e]==s} rows[e] * mask[e].

    rows: (E, F); seg_ids: (E,) int32 in [0, num_segments); mask: (E,).
    """
    w = rows * mask[:, None].astype(rows.dtype)
    return jax.ops.segment_sum(w, seg_ids, num_segments=num_segments)


def fused_agg_combine_ref(rows: jnp.ndarray, seg_ids: jnp.ndarray,
                          mask: jnp.ndarray, w: jnp.ndarray,
                          num_segments: int) -> jnp.ndarray:
    """out[s] = (sum_{e in seg s} rows[e]) @ w  -- aggregation fused into GEMM."""
    agg = seg_agg_ref(rows, seg_ids, mask, num_segments)
    return agg.astype(w.dtype) @ w


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
            causal: bool = True, sliding_window: int = 0,
            logit_softcap: float = 0.0, scale: Optional[float] = None,
            kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Reference attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0 (GQA).
    ``kv_len``: optional (B,) valid KV length (decode with padded cache).
    Positions: query i sits at absolute position Sk - Sq + i (decode-style
    right alignment), matching the serving engine's cache layout.
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    if logit_softcap > 0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    sk = k.shape[2]
    if kv_len is None:
        kv_len = jnp.full((b,), sk, jnp.int32)
    # (B, Sq): last q row sits at position kv_len - 1
    qpos = jnp.arange(sq)[None, :] + (kv_len[:, None] - sq)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((b, sq, sk), bool)
    if causal:
        mask &= kpos[:, None, :] <= qpos[:, :, None]
    if sliding_window > 0:
        mask &= kpos[:, None, :] > qpos[:, :, None] - sliding_window
    mask &= (kpos < kv_len[:, None])[:, None, :]
    mask = mask[:, None]
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)
