"""repro.profile: the one characterization API.

Everything the paper calls *characterization* -- per-phase time/FLOP/byte
breakdowns (Tables 3-5), bound classification, roofline terms, benchmark
sweeps -- hangs off three surfaces:

  * ``Machine`` (machine.py): hardware presets (``TPU_V5E`` | ``TPU_V5P``
    | ``A100`` | ``H100`` | the paper's ``V100``); every cost model takes
    one instead of importing module-level constants, and the per-hop
    interconnect fields (``interconnect_bw``, ``link_latency_s``,
    ``hop_time``) price the distributed halo overlap decision.
  * ``InstrumentedPlan`` / ``WorkloadReport`` (instrument.py): wrap a
    ``GraphExecutionPlan`` (``plan.instrument(machine=...)``) so one forward
    pass records per-layer, per-phase FLOPs / bytes / wall time into a typed
    report with ``to_json()`` / ``to_markdown()`` renderers.
  * ``BenchSpec`` / ``run_specs`` (bench.py): declarative benchmark specs
    (graph x model x machine x sweep axis) executed by one shared harness
    that owns warmup, timing, CSV artifacts, and dry-run validation.

One call end to end::

    report = build_plan(g, cfg, in_dim, classes).instrument(
        machine=A100).run_model(params, x)
    print(report.to_markdown())        # paper-style per-phase breakdown

Submodules avoid importing ``repro.core`` at module scope so ``repro.core``
internals (dataflow, characterize) may import presets from here without a
cycle; plan/phase types are imported lazily inside functions.
"""

from repro.profile.machine import (A100, H100, MACHINES, TPU_V5E, TPU_V5P,
                                   V100, Machine, get_machine,
                                   machine_for_backend)

__all__ = [
    "Machine", "TPU_V5E", "TPU_V5P", "A100", "H100", "V100", "MACHINES",
    "get_machine", "machine_for_backend",
    # lazy (instrument.py / bench.py):
    "InstrumentedPlan", "WorkloadReport", "PhaseRecord",
    "WorkloadReportError", "validate_report_dict",
    "BenchSpec", "BenchContext", "run_specs", "timeit", "write_csv",
    "bench_graph", "latency_percentiles",
]

_LAZY = {
    "InstrumentedPlan": "repro.profile.instrument",
    "WorkloadReport": "repro.profile.instrument",
    "PhaseRecord": "repro.profile.instrument",
    "WorkloadReportError": "repro.profile.instrument",
    "validate_report_dict": "repro.profile.instrument",
    "BenchSpec": "repro.profile.bench",
    "BenchContext": "repro.profile.bench",
    "run_specs": "repro.profile.bench",
    "timeit": "repro.profile.bench",
    "write_csv": "repro.profile.bench",
    "bench_graph": "repro.profile.bench",
    "latency_percentiles": "repro.profile.bench",
}


def __getattr__(name):
    # Lazy so `repro.core.*` can import the machine presets mid-init
    # without pulling the instrument/bench layers (which need core types).
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(name)
    import importlib
    return getattr(importlib.import_module(mod), name)
