"""deepseek-67b -- dense llama-arch.  [arXiv:2401.02954; hf]

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""

import dataclasses

from repro.config import AttentionConfig, LMConfig, register


def _base() -> LMConfig:
    return LMConfig(
        name="deepseek-67b",
        family="dense",
        num_layers=95,
        d_model=8192,
        d_ff=22016,
        vocab_size=102400,
        attention=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128),
        mlp_activation="swiglu",
        shape_skips=("long_500k",),
        skip_reason="pure full attention; 500k decode needs sub-quadratic",
        source="arXiv:2401.02954",
    )


@register("deepseek-67b")
def config() -> LMConfig:
    return _base()


def reduced() -> LMConfig:
    c = _base()
    return dataclasses.replace(
        c, name=c.name + "-smoke", num_layers=3, d_model=64, d_ff=128,
        vocab_size=256,
        attention=dataclasses.replace(c.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=16))
