"""Pallas TPU kernel: collision-free segmented row aggregation (paper F3).

GPU baseline (paper): the ``scatter`` kernel -- one thread per feature element,
atomicAdd into the destination row; serialization whenever two warps hit the
same row.  The paper's guideline is "vectorize the atomic operation".

TPU adaptation (DESIGN.md §2): there are no atomics and no warps; we
restructure the reduction so collisions cannot exist:

  * edges are destination-sorted and regrouped into destination row blocks
    (``tile_m`` rows per grid step) host-side -- every grid step owns a
    disjoint output block, so grid steps never write the same row;
  * within a block, the segmented reduction is expressed as a ONE-HOT MATMUL
    on the MXU: ``out[m, f] = sum_e onehot[m, e] * rows[e, f]``.  The one-hot
    matrix is built in-register from ``broadcasted_iota == seg_ids`` --
    this is the "vectorized atomic": 128x128 row-updates per MXU pass,
    serialization-free by construction.

Inputs are pre-gathered edge rows (the ``indexSelect`` product).  The gather
itself is XLA's native dynamic-gather (DMA-based on TPU); what the paper's
scatter kernel loses to atomics, this kernel recovers with dense MXU math.

VMEM working set per grid step (defaults tile_m=128, tile_e=512, f=128,
fp32): rows 256 KiB + onehot 256 KiB + acc 64 KiB << 128 MiB VMEM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

from repro.core.backend import resolve_interpret


def _seg_agg_kernel(seg_ref, mask_ref, rows_ref, out_ref, acc_ref, *,
                    tile_m: int, tile_e: int, acc_dtype=jnp.float32):
    """Grid: (dest_blocks, edge_chunks). Edge chunks accumulate into acc.

    ``acc_dtype`` is the VMEM accumulator precision -- f32 regardless of
    the input rows' dtype (the reduced-precision plan contract: bf16 rows
    on the wire/HBM, full-precision accumulate, one rounding at flush).
    """
    ei = pl.program_id(1)
    n_e = pl.num_programs(1)

    @pl.when(ei == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seg = seg_ref[0, :]           # (tile_e,) int32, local row ids of dest block
    mask = mask_ref[0, :]         # (tile_e,) float32
    rows = rows_ref[0]            # (tile_e, F)
    # one-hot: (tile_m, tile_e); rows with mask==0 contribute nothing
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (tile_m, tile_e), 0)
    onehot = jnp.where(row_ids == seg[None, :], mask[None, :], 0.0)
    acc_ref[...] += jax.lax.dot(
        onehot.astype(acc_dtype), rows.astype(acc_dtype),
        preferred_element_type=acc_dtype)

    @pl.when(ei == n_e - 1)
    def _flush():
        out_ref[0] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_e", "interpret",
                                             "acc_dtype"))
def seg_agg_blocked(rows: jnp.ndarray, seg_local: jnp.ndarray,
                    mask: jnp.ndarray, *, tile_m: int, tile_e: int = 512,
                    interpret: Optional[bool] = None,
                    acc_dtype=jnp.float32) -> jnp.ndarray:
    """Blocked segmented sum.

    Args:
      rows:      (nblocks, emax, F) pre-gathered edge rows, grouped by
                 destination block (see core.dataflow.block_graph).
      seg_local: (nblocks, emax) int32 destination row id LOCAL to the block.
      mask:      (nblocks, emax) 1/0 edge validity.
      tile_m:    output rows per block (static).
      tile_e:    edge chunk per grid step (static; emax must be a multiple).
      interpret: None = auto (compiled on TPU, interpreted elsewhere --
                 core.backend.default_interpret).
      acc_dtype: VMEM accumulator dtype (static).  Stays f32 even when
                 ``rows`` is bf16 (the plan's reduced-precision contract:
                 reduced storage, full-precision accumulate); the output is
                 rounded once at flush to ``rows.dtype``.

    Returns (nblocks * tile_m, F) in ``rows.dtype``.
    """
    interpret = resolve_interpret(interpret)
    nblocks, emax, f = rows.shape
    assert emax % tile_e == 0, (emax, tile_e)
    n_e = emax // tile_e
    grid = (nblocks, n_e)

    out = pl.pallas_call(
        functools.partial(_seg_agg_kernel, tile_m=tile_m, tile_e=tile_e,
                          acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_e), lambda b, e: (b, e)),       # seg ids
            pl.BlockSpec((1, tile_e), lambda b, e: (b, e)),       # mask
            pl.BlockSpec((1, tile_e, f), lambda b, e: (b, e, 0)),  # rows
        ],
        out_specs=pl.BlockSpec((1, tile_m, f), lambda b, e: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, tile_m, f), rows.dtype),
        scratch_shapes=[pltpu.VMEM((tile_m, f), acc_dtype)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="seg_agg",
    )(seg_local.reshape(nblocks, emax),
      mask.reshape(nblocks, emax),
      rows)
    return out.reshape(nblocks * tile_m, f)


def _squeeze_kernel_wrapper():  # pragma: no cover - doc helper
    """The (1, tile_e)/(1, tile_e, f) leading block dims arrive squeezed or
    not depending on BlockSpec semantics; the kernel body indexes with [...]
    and reshapes, so both layouts work."""
