"""Dtype sweep: precision as a planned decision, dtype x feature_len.

One matrix cell per (dtype, feature_len): the plan is built through
``build_plan(dtype=...)`` -- the SAME dispatch layer production uses -- and
validated against the two-sided precision contract:

  * **f32 cells** enforce the bitwise side: the explicit ``dtype="f32"``
    plan must BE the no-dtype-argument plan (same cache entry), its
    ``plan.compile()`` output bit-for-bit equal to eager, no retrace.
  * **Reduced cells** (bf16 / int8-agg) are banded against the f32 plan
    through the suite's ONE tolerance table (tests/tolerance.py, loaded by
    path so the bands cannot drift from the tests), and must leave the f32
    plan's output bitwise-unchanged afterwards -- a reduced build/run that
    perturbs the golden path hard-fails the smoke gate.

Under dry-run every cell also runs INSTRUMENTED: the WorkloadReport is
schema-validated (reduced reports must carry observed quant_error; f32
reports must carry none) and cross-checked against ``plan.describe()``
(dtype drift included).

The ``dtype/choose`` spec pins the ``choose_dtype`` decision model: on the
paper-scale workload (V=256, E=1024, F=128) it must pick ``"f32"`` on the
V100 preset (no native bf16 matmul: halving storage doubles GEMM time)
and ``"bf16"`` on TPU_V5E -- the machine-dependent flip that makes dtype a
*planned* decision rather than a global switch.  The ``dtype/halo`` spec
spawns an 8-fake-device subprocess (the dry-run rule) and asserts the
instrumented bf16 distributed plan reports EXACTLY half the f32 plan's
collective halo bytes.

``post_run`` accounts for every expected cell: silently skipped dtype
cells raise.
"""

from __future__ import annotations

import importlib.util
import itertools
import os
import subprocess
import sys
from pathlib import Path

import dataclasses
import jax
import numpy as np

from repro.core.plan import build_plan
from repro.models.gcn import make_paper_model
from repro.profile.bench import BenchSpec, run_specs
from repro.profile.machine import (A100, TPU_V5E, V100, choose_dtype,
                                   dtype_model)

DTYPES = ("f32", "bf16", "int8-agg")
FEATURE_LENS = (32, 128)

CELLS = tuple(itertools.product(DTYPES, FEATURE_LENS))

#: (machine preset, expected choose_dtype pick) on the pinned flip workload
FLIP_WORKLOAD = dict(num_vertices=256, num_edges=1024, feature_len=128)
FLIP_EXPECT = ((V100, "f32"), (TPU_V5E, "bf16"), (A100, "bf16"))


def _bands():
    """The tests' tolerance module, loaded by path (tests/ is not a
    package): ONE band table for suite and smoke gate alike."""
    spec = importlib.util.spec_from_file_location(
        "tolerance", Path(__file__).resolve().parents[1] / "tests" /
        "tolerance.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _cell_name(dtype, fl):
    return f"dtype/gcn/{dtype}/fl{fl}"


def _flip_name(machine):
    return f"dtype/choose/{machine.name}"


HALO_NAME = "dtype/halo/bf16-half"


def expected_matrix():
    """Every scenario name the dry run must account for."""
    return ([_cell_name(dt, fl) for dt, fl in CELLS]
            + [_flip_name(m) for m, _ in FLIP_EXPECT]
            + [HALO_NAME])


def _check_compiled_bitwise(name, plan, params, x, eager_out):
    fn = plan.compile()
    out_c = fn(params, x)
    fn(params, x)
    if not np.array_equal(np.asarray(out_c), np.asarray(eager_out)):
        raise RuntimeError(f"{name}: plan.compile() differs from eager "
                           "dispatch; the f32 contract is bitwise")
    if fn.num_traces != 1:
        raise RuntimeError(f"{name}: plan.compile() traced "
                           f"{fn.num_traces}x for one signature")


def _cell_inputs(ctx, fl):
    """Per-feature-length model/features on the spec's shared graph."""
    from repro.graph.datasets import make_features

    mspec = dataclasses.replace(ctx.spec, feature_len=fl)
    m = make_paper_model("gcn", mspec)
    params = m.init(jax.random.PRNGKey(0))
    x = make_features(mspec)
    return mspec, m, params, x


def _cell(ctx, point):
    """One (dtype, feature_len) cell of the matrix."""
    dt, fl = point
    tol = ctx.state
    mspec, m, params, x = _cell_inputs(ctx, fl)
    g = ctx.g
    name = _cell_name(dt, fl)

    p32 = build_plan(g, m.cfg, fl, mspec.num_classes)       # no dtype arg
    ref = p32.run_model(params, x)
    plan = build_plan(g, m.cfg, fl, mspec.num_classes, dtype=dt)
    out = plan.run_model(params, x)

    if dt == "f32":
        if plan is not p32:
            raise RuntimeError(
                f"{name}: explicit dtype='f32' built a different plan than "
                "the no-dtype-argument default (cache key drift)")
        _check_compiled_bitwise(name, plan, params, x, out)
        if not np.array_equal(np.asarray(out), np.asarray(ref)):
            raise RuntimeError(f"{name}: f32 output drifted from the "
                               "pre-dtype default path")
    else:
        # compiled replays the reduced schedule within the dtype band, and
        # the reduced output tracks the f32 plan within the band (scale 2:
        # two layers of phase-boundary rounding)
        tol.assert_allclose_dtype(plan.compile()(params, x), out, dtype=dt,
                                  err_msg=f"{name}: compiled vs eager")
        tol.assert_allclose_dtype(out, ref, dtype=dt, scale=2,
                                  err_msg=f"{name}: vs f32 plan")
        again = p32.run_model(params, x)
        if not np.array_equal(np.asarray(again), np.asarray(ref)):
            raise RuntimeError(
                f"{name}: building/running the {dt} plan perturbed the f32 "
                "plan's output -- the bitwise-golden contract is broken")

    derived = dict(dtype=plan.dtype, feature_len=fl,
                   order=plan.describe()[0]["order"])
    if ctx.dry:
        report = plan.instrument(machine=ctx.machine).run_model(params, x)
        report.validate()
        drift = report.mismatches(plan)
        if drift:
            raise RuntimeError(
                f"{name}: describe() disagrees with dispatch: {drift}")
        qerr = max(r.quant_error for r in report.records)
        if dt == "f32" and qerr != 0:
            raise RuntimeError(f"{name}: f32 report observed quantization")
        if dt != "f32" and qerr == 0:
            raise RuntimeError(f"{name}: reduced report observed no "
                               "quantization -- cell silently ran f32")
        ctx.emit(name, 0.0, quant_error=f"{qerr:.2e}",
                 report_phases=len(report.records), **derived)
    else:
        ctx.emit(name, ctx.time(plan.compile(), params, x), **derived)


def _flip(ctx, point):
    """Pin the choose_dtype decision per machine preset on one workload --
    the planner must demonstrably FLIP across presets, not apply a global
    preference."""
    machine, expect = point
    got = choose_dtype(machine=machine, **FLIP_WORKLOAD)
    if got != expect:
        raise RuntimeError(
            f"{_flip_name(machine)}: choose_dtype picked {got!r}, expected "
            f"{expect!r} on {machine.name} for {FLIP_WORKLOAD}")
    model = dtype_model(machine=machine, **FLIP_WORKLOAD)
    ctx.emit(_flip_name(machine), 0.0, picked=got,
             f32_us=round(model["f32"]["total_s"] * 1e6, 3),
             bf16_us=round(model["bf16"]["total_s"] * 1e6, 3),
             f32_tile_rows=model["f32"]["tile_rows"],
             bf16_tile_rows=model["bf16"]["tile_rows"])


_DTYPE_CHILD_FLAG = "--dtype-child"


def _dtype_child(csv_out: str):
    """Subprocess body (8 fake devices): the bf16 distributed plan's
    instrumented collective bytes must be EXACTLY half the f32 plan's on
    the same partition, with the bf16 output banded against the local f32
    reference."""
    from repro.profile.bench import BenchContext, bench_graph, write_csv
    from repro.graph.datasets import make_features, make_synthetic_graph

    tol = _bands()
    spec = bench_graph("reddit", max_vertices=301, max_feature=32)  # ragged
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    m = make_paper_model("gcn", spec)
    params = m.init(jax.random.PRNGKey(0))
    ref = build_plan(g, m.cfg, spec.feature_len,
                     spec.num_classes).run_model(params, x)
    mesh = jax.make_mesh((8,), ("data",))
    kw = dict(mesh=mesh, num_shards=8, strategy="ring")
    d32 = build_plan(g, m.cfg, spec.feature_len, spec.num_classes, **kw)
    dbf = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                     dtype="bf16", **kw)
    with mesh:
        r32 = d32.instrument(machine=TPU_V5E).run_model(params, x).validate()
        rbf = dbf.instrument(machine=TPU_V5E).run_model(params, x).validate()
    drift = rbf.mismatches(dbf)
    assert not drift, drift
    tol.assert_allclose_dtype(rbf.output, ref, dtype="bf16", scale=2,
                              err_msg="sharded bf16 vs local f32")
    c32 = sum(r.collective_bytes for r in r32.records)
    cbf = sum(r.collective_bytes for r in rbf.records)
    if not c32 > 0:
        raise RuntimeError("f32 halo model reported no collective traffic")
    if cbf * 2 != c32:
        raise RuntimeError(
            f"bf16 halo bytes {cbf} are not exactly half of f32's {c32}")
    ctx = BenchContext(bench=None, machine=TPU_V5E, dry=True)
    ctx.emit(HALO_NAME, 0.0, f32_collective_bytes=int(c32),
             bf16_collective_bytes=int(cbf),
             quant_error=f"{max(r.quant_error for r in rbf.records):.2e}")
    write_csv(ctx.rows, csv_out)
    print("DTYPE-CHILD-OK")


def _halo(ctx, _):
    """Spawn the halo-halving check on 8 fake devices (dry-run only: the
    reduced-wire *timing* needs a real multi-device mesh)."""
    if not ctx.dry:
        return
    import csv as _csv
    import tempfile

    with tempfile.TemporaryDirectory() as td:
        out = Path(td) / "dtype_child.csv"
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.pathsep.join(
            [str(Path(__file__).resolve().parents[1] / "src"),
             str(Path(__file__).resolve().parents[1])])
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_dtype",
             _DTYPE_CHILD_FLAG, str(out)],
            capture_output=True, text=True, env=env, timeout=900)
        if res.returncode != 0 or "DTYPE-CHILD-OK" not in res.stdout:
            sys.stdout.write(res.stdout)
            raise RuntimeError(
                f"dtype halo subprocess failed:\n{res.stderr[-3000:]}")
        with out.open(newline="") as f:
            child_rows = list(_csv.DictReader(f))
    for row in child_rows:
        name = row.pop("name")
        us = float(row.pop("us_per_call"))
        ctx.emit(name, us, **row)


SPECS = [
    BenchSpec(name="dtype/matrix", graph="reddit", max_vertices=2048,
              max_feature=128, dry_max_vertices=256, machine=TPU_V5E,
              sweep=CELLS, setup=lambda ctx: _bands(), measure=_cell,
              dry="run"),
    BenchSpec(name="dtype/choose", sweep=FLIP_EXPECT, measure=_flip,
              dry="run"),
    BenchSpec(name="dtype/halo", measure=_halo, dry="run"),
]


def post_run(rows, dry: bool = False):
    """Cell accounting: every expected (dtype, feature_len) cell, flip
    check, and halo check must have emitted a row or carry a skip reason
    -- a silently missing dtype cell fails the smoke gate."""
    matrix = set(expected_matrix())
    validated = [r["name"] for r in rows if r["name"] in matrix]
    skipped = {}
    if not dry:
        skipped[HALO_NAME] = "halo halving needs the fake-device subprocess"
    missing = [n for n in expected_matrix()
               if n not in validated and n not in skipped]
    for name, why in skipped.items():
        print(f"# skipped: {name} ({why})")
    if missing:
        raise RuntimeError(
            "dtype cells silently skipped: " + ", ".join(missing))
    print(f"# dtype matrix: {len(validated)} cell(s) validated, "
          f"{len(skipped)} skipped with reasons, 0 silent")


def run(dry: bool = False):
    """Direct-invocation entry (``python -m benchmarks.bench_dtype
    [--dry-run]``); writes the same CSV artifact benchmarks/run.py does."""
    from repro.profile.bench import BENCH_ARTIFACT_DIR
    rows = run_specs(
        SPECS, dry=dry,
        csv=BENCH_ARTIFACT_DIR / f"bench_dtype{'.dry' if dry else ''}.csv")
    post_run(rows, dry=dry)


if __name__ == "__main__":
    if _DTYPE_CHILD_FLAG in sys.argv:
        _dtype_child(sys.argv[sys.argv.index(_DTYPE_CHILD_FLAG) + 1])
    else:
        run(dry="--dry-run" in sys.argv)
