"""Version-compat shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams`` around
0.5; the kernels target the new name and this module backfills it on older
installs so one source tree runs on both.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
