"""jamba-1.5-large-398b -- Mamba+attention 1:7 interleave + MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
[arXiv:2403.19887; hf]

Period-8 blocks: one attention layer per 8 (placed mid-block), seven Mamba-2
layers; MoE replaces the dense FFN on every other layer.  Analytic totals
~399B params / ~94B active, matching the published 398B/94B.
Hybrid -> long_500k RUNS (SSM state is O(1); the sparse attention layers use
the sequence-sharded KV path).
"""

import dataclasses

from repro.config import (AttentionConfig, LMConfig, MoEConfig, SSMConfig,
                          register)


def _base() -> LMConfig:
    return LMConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        d_ff=24576,
        vocab_size=65536,
        attention=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128),
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=256,
                      compute_dtype="bfloat16"),
        moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=24576,
                      layer_pattern="every_2", capacity_factor=1.25),
        attn_every=8,
        mlp_activation="swiglu",
        source="arXiv:2403.19887",
    )


@register("jamba-1.5-large-398b")
def config() -> LMConfig:
    return _base()


def reduced() -> LMConfig:
    c = _base()
    return dataclasses.replace(
        c, name=c.name + "-smoke", num_layers=8, d_model=64, d_ff=64,
        vocab_size=256,
        attention=dataclasses.replace(c.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=16),
        ssm=dataclasses.replace(c.ssm, d_state=16, head_dim=8,
                                chunk_size=16,
                                compute_dtype="float32"),
        moe=dataclasses.replace(c.moe, num_experts=4, top_k=2,
                                expert_d_ff=64))
