"""Benchmark harness entry: one module per paper table/figure.

Prints ``name,us_per_call,derived...`` CSV rows (benchmarks/common.emit).

  bench_breakdown       Fig. 1  execution-time breakdown
  bench_agg_vs_pgr      Fig. 2  Aggregation vs PageRank + reorder guideline
  bench_phase_metrics   Fig. 2(f,g)/Table 3  hybrid execution patterns
  bench_ordering        Table 4 phase-ordering impact (+distributed halo)
  bench_feature_length  Fig. 5  input/output length sweeps
  bench_kernels         beyond-paper: Pallas kernels + fused dataflow
  roofline              deliverable (g): dry-run roofline table

Usage: PYTHONPATH=src python -m benchmarks.run [module ...]
"""

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_agg_vs_pgr, bench_breakdown,
                            bench_feature_length, bench_kernels,
                            bench_ordering, bench_phase_metrics, roofline)
    modules = {
        "bench_breakdown": bench_breakdown,
        "bench_agg_vs_pgr": bench_agg_vs_pgr,
        "bench_phase_metrics": bench_phase_metrics,
        "bench_ordering": bench_ordering,
        "bench_feature_length": bench_feature_length,
        "bench_kernels": bench_kernels,
        "roofline": roofline,
    }
    selected = sys.argv[1:] or list(modules)
    failures = 0
    for name in selected:
        print(f"# === {name} ===")
        try:
            modules[name].run()
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == '__main__':
    main()
