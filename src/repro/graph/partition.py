"""1-D and 2-D graph partitioning for distributed aggregation.

**1-D (node)**: each device owns a contiguous block of destination vertices
(all edges whose dst falls in the block).  Blocks are *edge-balanced*:
boundaries are chosen so every shard carries ~|E|/P edges, not ~|V|/P
vertices -- heavy-tailed degree distributions otherwise leave one shard with
most of the work (the cluster analogue of the paper's load-imbalance
remarks).

**2-D (node x feature)**: a P-way node partition crossed with a Q-way split
of the feature axis (``partition_2d``).  Every (p, q) device owns node block
p's rows restricted to feature block q, so the halo exchange along the node
axis moves rows that are only ``F/Q`` wide -- per-device halo bytes shrink
by Q relative to the 1-D partition at the same world size, which is how the
paper's Table 4 collective term keeps shrinking once a single node axis
saturates (multi-host meshes: node axis across hosts, feature axis across
the devices within each host).

Shards are padded to identical static shapes so the whole structure stacks
into (P, ...) arrays consumable by shard_map.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph


class PartitionedGraph(NamedTuple):
    """Stacked per-shard edge lists (all shapes static, padded).

    src:        (P, Emax) int32 global source ids.
    dst_local:  (P, Emax) int32 destination id LOCAL to the shard block.
    mask:       (P, Emax) f32   1.0 for real edges, 0.0 padding.
    vtx_start:  (P,)      int32 first global vertex id of each shard block.
    block_size: python int      vertices per shard (padded).
    num_vertices: python int    real global vertex count.
    """

    src: jnp.ndarray
    dst_local: jnp.ndarray
    mask: jnp.ndarray
    vtx_start: jnp.ndarray
    block_size: int
    num_vertices: int

    @property
    def num_shards(self) -> int:
        return int(self.src.shape[0])


def partition_1d(g: Graph, num_shards: int, edge_balanced: bool = True
                 ) -> PartitionedGraph:
    """1-D destination-vertex partition of ``g`` into ``num_shards`` blocks.

    ``edge_balanced=True`` picks block boundaries equalizing edge counts
    (feeds the analytic load model); ``edge_balanced=False`` gives the
    uniform layout the shard_map execution paths require
    (core.distributed._require_uniform).
    """
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)  # already sorted by dst
    v = g.num_vertices
    block = -(-v // num_shards)  # ceil; every shard owns `block` vertex slots

    if edge_balanced:
        # Choose vertex boundaries so edge counts are ~equal, but keep the
        # owned vertex ranges within each shard's static `block` capacity.
        row_ptr = np.asarray(g.row_ptr)
        target = len(src) / num_shards
        bounds = [0]
        for p in range(1, num_shards):
            ideal = int(np.searchsorted(row_ptr, target * p))
            lo = bounds[-1] + 1
            hi = min(v, bounds[-1] + block)
            bounds.append(int(np.clip(ideal, lo, hi)))
        bounds.append(v)
    else:
        bounds = [min(v, p * block) for p in range(num_shards)] + [v]

    per_src, per_dst = [], []
    for p in range(num_shards):
        lo, hi = bounds[p], bounds[p + 1]
        sel = (dst >= lo) & (dst < hi)
        per_src.append(src[sel])
        per_dst.append(dst[sel] - lo)
    emax = max(1, max(len(s) for s in per_src))
    # pad to multiple of 8 for clean TPU sublane tiling
    emax = -(-emax // 8) * 8

    ps = np.zeros((num_shards, emax), np.int32)
    pd = np.zeros((num_shards, emax), np.int32)
    pm = np.zeros((num_shards, emax), np.float32)
    for p in range(num_shards):
        e = len(per_src[p])
        ps[p, :e] = per_src[p]
        pd[p, :e] = per_dst[p]
        pm[p, :e] = 1.0
    starts = np.array([bounds[p] for p in range(num_shards)], np.int32)
    return PartitionedGraph(
        src=jnp.asarray(ps), dst_local=jnp.asarray(pd), mask=jnp.asarray(pm),
        vtx_start=jnp.asarray(starts), block_size=block, num_vertices=v)


def edge_balance(pg: PartitionedGraph) -> float:
    """max/mean edge load across shards (1.0 = perfect)."""
    loads = np.asarray(pg.mask).sum(axis=1)
    return float(loads.max() / max(loads.mean(), 1e-9))


class Partition2D(NamedTuple):
    """2-D (node x feature) partition: P node shards x Q feature shards.

    The graph structure is only partitioned along the node axis (``nodes``,
    a uniform :class:`PartitionedGraph`); the feature axis is a dense
    columnwise split whose block size depends on the per-layer feature
    length, so it is computed at execution time via ``feature_block``.
    """

    nodes: PartitionedGraph
    feat_shards: int

    @property
    def node_shards(self) -> int:
        return self.nodes.num_shards

    @property
    def block_size(self) -> int:
        """Vertex rows per node shard (padded) -- mirrors PartitionedGraph."""
        return self.nodes.block_size

    @property
    def num_vertices(self) -> int:
        return self.nodes.num_vertices

    def feature_block(self, feature_len: int) -> int:
        """Columns per feature shard for one layer's feature length
        (ceil-divided; callers zero-pad to ``feat_shards * feature_block``)."""
        return -(-int(feature_len) // self.feat_shards)


def partition_2d(g: Graph, node_shards: int, feat_shards: int
                 ) -> Partition2D:
    """Partition ``g`` for a (node_shards x feat_shards) device mesh.

    The node axis reuses the *uniform* 1-D partition (the shard_map layout
    requirement -- see core.distributed._require_uniform); the feature axis
    needs no host-side structure beyond its cardinality.
    """
    if node_shards < 1 or feat_shards < 1:
        raise ValueError(f"need positive shard counts, got "
                         f"{node_shards}x{feat_shards}")
    return Partition2D(nodes=partition_1d(g, node_shards,
                                          edge_balanced=False),
                       feat_shards=feat_shards)
