"""Logical-axis sharding rules (MaxText-style) + activation constraints.

Models annotate activations with LOGICAL axis names; this module maps them to
mesh axes according to the active rule set.  Without an active mesh every
annotation is a no-op, so the same model code runs single-device tests and
512-chip dry-runs unchanged.

Default rules:
  batch    -> ("pod", "data")     (DP/FSDP axes)
  seq      -> None                (replicated; long_500k remaps to ("data",))
  embed    -> None                (activation d_model replicated)
  heads    -> "model"             (TP over attention heads)
  kv_heads -> "model"             (only when divisible; else None)
  mlp      -> "model"             (TP over FFN hidden)
  experts  -> "model"             (EP)
  vocab    -> "model"             (TP over logits)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    # Megatron-style sequence parallelism: the residual stream (and with it
    # every saved-for-backward layer carry) is sharded over `model` between
    # blocks; attention/MLP gather it on entry and the TP all-reduce after
    # each block becomes a reduce-scatter.  Same collective bytes, 1/tp the
    # activation memory.
    "seq": ("model",),
    "seq_q": None,   # context-parallel attention: remapped to ("model",)
    "embed": None,   # for archs whose head count doesn't divide the TP axis
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "expert_cap": ("pod", "data"),
    "vocab": ("model",),
    "state": None,
}


def rules_for(cfg, mesh: Mesh) -> Dict[str, Optional[Tuple[str, ...]]]:
    """Per-arch rule overrides.

    Head-sharded TP requires num_heads % model-axis == 0.  When it doesn't
    divide (internvl 14H, arctic 56H), GSPMD otherwise replicates attention
    or -- worse -- all-reduces score tiles.  We switch those archs to
    CONTEXT-PARALLEL attention: q's sequence dim shards over `model`, K/V
    stay model-replicated (they are small: kv_heads*head_dim columns), and
    each device computes its query chunk against the full KV.
    """
    rules = dict(DEFAULT_RULES)
    tp = mesh.shape.get("model", 1)
    a = getattr(cfg, "attention", None)
    if a is not None and (a.num_heads % tp != 0):
        # sequence-parallel profile: activations stay seq-sharded through
        # norm/attention/MLP; only K/V (tiny: kv_dim columns) are gathered.
        rules["heads"] = None
        rules["kv_heads"] = None
        rules["seq"] = ("model",)
        rules["seq_q"] = ("model",)
        rules["mlp"] = None
        rules["vocab"] = None
    return rules


def _current():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def sharding_rules(mesh: Mesh, rules: Optional[Dict] = None):
    """Activate a mesh + logical rules for model-internal constraints."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # drop mesh axes the mesh doesn't actually have (e.g. single-pod: no "pod")
    axis_names = set(mesh.axis_names)
    clean = {}
    for k, v in merged.items():
        if v is None:
            clean[k] = None
        else:
            kept = tuple(a for a in v if a in axis_names)
            clean[k] = kept if kept else None
    prev = _current()
    _state.ctx = (mesh, clean)
    try:
        yield
    finally:
        _state.ctx = prev


def logical_to_spec(axes: Tuple[Optional[str], ...]) -> P:
    ctx = _current()
    assert ctx is not None
    _, rules = ctx
    parts = []
    for a in axes:
        if a is None:
            parts.append(None)
        else:
            r = rules.get(a)
            parts.append(r if r else None)
    return P(*parts)


def constrain(x, *axes: Optional[str]):
    """with_sharding_constraint by logical axis names; no-op without a mesh.

    ``axes`` length must equal x.ndim; None entries are unsharded dims.
    Divisibility guard: a dim that doesn't divide by its mesh-axes product is
    left unsharded rather than failing (e.g. 8 kv heads on a 16-way model
    axis -> replicated, the documented fallback).
    """
    ctx = _current()
    if ctx is None:
        return x
    mesh, rules = ctx
    parts = []
    used: set = set()
    for i, a in enumerate(axes):
        if a is None:
            parts.append(None)
            continue
        r = rules.get(a)
        if r:  # a mesh axis may appear once per spec; first dim wins
            r = tuple(ax for ax in r if ax not in used)
        if not r:
            parts.append(None)
            continue
        size = 1
        for ax in r:
            size *= mesh.shape[ax]
        if x.shape[i] % size != 0:
            parts.append(None)
        else:
            used.update(r)
            parts.append(r if len(r) > 1 else r[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def named_sharding(mesh: Mesh, *parts) -> NamedSharding:
    return NamedSharding(mesh, P(*parts))


def ctx_mesh_axes():
    """(mesh, batch_axes, seq_axes) under an active sharding context, for
    modules that build explicit shard_map regions (MoE EP)."""
    ctx = _current()
    if ctx is None:
        return None
    mesh, rules = ctx
    batch = tuple(rules.get("batch") or ())
    seq = tuple(rules.get("seq") or ())
    return mesh, batch, seq


class _CtxInfo:
    def __init__(self, mesh, tp, batch):
        self.mesh, self.tp, self.batch = mesh, tp, batch


def ctx_parallel_info():
    """Non-None when the active rules request context-parallel attention."""
    ctx = _current()
    if ctx is None:
        return None
    mesh, rules = ctx
    if rules.get("seq_q") and "model" in mesh.axis_names:
        batch = rules.get("batch") or ()
        return _CtxInfo(mesh, mesh.shape["model"], tuple(batch))
    return None
