"""GraphSAGE mini-batch neighbor sampling (paper §2: SAG updates a batch of
vertices along with their 2-hop neighbors per iteration).

Static-shape, padded sampling: for each seed vertex we draw up to ``fanout``
in-neighbors per hop with replacement-free reservoir-style numpy sampling, and
pad with the seed itself (mask-weighted zero contribution downstream).
Host-side (numpy) by design -- sampling is part of the data pipeline, not the
jit graph.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph, graph_from_coo


class SampledBlock(NamedTuple):
    """One bipartite sampling layer: edges from sampled srcs -> seed dsts."""

    graph: "Graph"          # destination-sorted subgraph over compacted ids
    real_edges: int
    seed_ids: np.ndarray    # global ids of the layer's destination vertices
    input_ids: np.ndarray   # global ids of required input (source) vertices


def sample_neighbors(g: Graph, seeds: np.ndarray, fanout: int,
                     rng: np.random.Generator) -> SampledBlock:
    row_ptr = np.asarray(g.row_ptr)
    src_all = np.asarray(g.src)
    seeds = np.asarray(seeds, dtype=np.int32)
    n = len(seeds)
    samp_src = np.empty((n, fanout), dtype=np.int32)
    samp_msk = np.zeros((n, fanout), dtype=bool)
    for i, v in enumerate(seeds):
        lo, hi = row_ptr[v], row_ptr[v + 1]
        deg = hi - lo
        if deg == 0:
            samp_src[i] = v  # isolated: self only
            continue
        take = min(fanout, deg)
        # one no-replacement draw; degree <= fanout keeps every neighbor
        idx = rng.choice(deg, size=take, replace=False) if take < deg \
            else np.arange(deg)
        samp_src[i, :take] = src_all[lo + idx]
        samp_src[i, take:] = v
        samp_msk[i, :take] = True

    flat_src = samp_src.reshape(-1)
    flat_dst = np.repeat(np.arange(n, dtype=np.int32), fanout)
    # compact global source ids -> local input ids (seeds come first so the
    # self-features line up with destination rows)
    input_ids, inv = np.unique(np.concatenate([seeds, flat_src]),
                               return_inverse=True)
    local_src = inv[n:].astype(np.int32)
    sub = graph_from_coo(local_src, flat_dst, max(len(input_ids), n))
    return SampledBlock(graph=sub, real_edges=int(samp_msk.sum()),
                        seed_ids=seeds, input_ids=input_ids)


def two_hop_batch(g: Graph, batch: np.ndarray, fanouts: Tuple[int, int],
                  seed: int = 0,
                  rng: Optional[np.random.Generator] = None
                  ) -> Tuple[SampledBlock, SampledBlock]:
    """Paper's SAG setting: a batch of vertices + their sampled 2-hop frontier.

    ``rng`` (a ``np.random.Generator``) takes precedence over ``seed``: a
    streaming caller (the serving loop, a training pipeline) passes one
    long-lived generator and gets fresh, reproducible draws per call instead
    of rebuilding ``default_rng(seed)`` -- and therefore identical samples --
    every time.  ``seed`` keeps the one-shot contract for existing callers.
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    hop1 = sample_neighbors(g, batch, fanouts[0], rng)
    hop2 = sample_neighbors(g, hop1.input_ids, fanouts[1], rng)
    return hop2, hop1  # execution order: farthest hop first
