"""Full GCN / GIN / GraphSAGE models (paper Table 1 configurations).

Node-classification networks whose execution is owned by a
``GraphExecutionPlan`` (core/plan.py): per-layer phase ordering, aggregation
backend, fused-dataflow tiling, and (optionally) the shard partition are
planned once per graph and cached.  ``GCNModel.apply`` is plan dispatch --
there are no per-call ``impl=``/``blocked=`` flags.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import GCNModelConfig, GraphSpec
from repro.core.backend import AUTO
from repro.core.gcn_layers import CONVS
from repro.core.plan import GraphExecutionPlan, build_plan
from repro.graph.structure import Graph

# Paper Table 1 model configs: |h|->128 single layer (GCN/SAG);
# |h|->128->128 MLP (GIN).  num_layers=2 gives the usual 2-conv network;
# the paper profiles the FIRST conv layer, which bench code isolates.
PAPER_MODELS: Dict[str, GCNModelConfig] = {
    "gcn": GCNModelConfig("gcn", conv="gcn", aggregator="mean",
                          hidden_dims=(128,), ordering="auto"),
    "sage": GCNModelConfig("sage", conv="sage", aggregator="mean",
                           hidden_dims=(128,), ordering="auto"),
    "gin": GCNModelConfig("gin", conv="gin", aggregator="sum",
                          hidden_dims=(128, 128), ordering="aggregate_first"),
}


class GCNModel:
    """num_layers stacked convolutions + classifier head, plan-dispatched."""

    def __init__(self, cfg: GCNModelConfig, in_dim: int, num_classes: int,
                 backend: str = AUTO):
        self.cfg = cfg
        self.in_dim = in_dim
        self.num_classes = num_classes
        self.backend = backend
        hid = cfg.hidden_dims[0]
        conv_cls = CONVS[cfg.conv]
        self.convs = []
        d = in_dim
        for i in range(cfg.num_layers):
            dout = hid if i < cfg.num_layers - 1 else num_classes
            if cfg.conv == "gin":
                self.convs.append(conv_cls(d, dout, hidden=cfg.hidden_dims[-1],
                                           backend=backend, fused=cfg.fused))
            else:
                self.convs.append(conv_cls(d, dout, ordering=cfg.ordering,
                                           backend=backend, fused=cfg.fused))
            d = dout

    def init(self, key) -> Dict:
        keys = jax.random.split(key, len(self.convs))
        return {f"conv{i}": c.init(k) for i, (c, k) in
                enumerate(zip(self.convs, keys))}

    def plan_for(self, g: Graph, **overrides) -> GraphExecutionPlan:
        """The model's execution plan over ``g`` (cached in core/plan.py)."""
        return build_plan(g, self.cfg, self.in_dim, self.num_classes,
                          backend=overrides.pop("backend", self.backend),
                          **overrides)

    def apply(self, params, g: Graph, x,
              plan: Optional[GraphExecutionPlan] = None) -> jnp.ndarray:
        plan = plan or self.plan_for(g)
        return plan.run_model(params, x)

    def loss_fn(self, params, g: Graph, x, labels,
                mask: Optional[jnp.ndarray] = None,
                plan: Optional[GraphExecutionPlan] = None):
        logits = self.apply(params, g, x, plan=plan)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, labels[:, None], axis=-1)[:, 0]
        if mask is not None:
            return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return nll.mean()

    # -- analytic per-phase costs (drives benchmarks + Table 3/4) ----------
    def layer_costs(self, g: Graph, layer: int = 0) -> Dict:
        return self.plan_for(g).layer_costs(layer)


def make_paper_model(name: str, spec: GraphSpec, backend: str = AUTO,
                     **overrides) -> GCNModel:
    import dataclasses
    cfg = PAPER_MODELS[name]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return GCNModel(cfg, in_dim=spec.feature_len,
                    num_classes=spec.num_classes, backend=backend)
