"""Compiled plan execution: plan.compile() bitwise-vs-eager equivalence
across the planner matrix, grad-through-compile, the retrace guard, and
locality reordering as a planned decision (ISSUE 5 acceptance suite)."""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CORA, reduced_graph
from repro.core.plan import (CompiledPlan, GraphExecutionPlan, build_plan,
                             plan_for_conv, plan_for_phases)
from repro.core.scheduler import AGGREGATE_FIRST, COMBINE_FIRST
from repro.graph.datasets import make_features, make_synthetic_graph
from repro.models.gcn import make_paper_model
from repro.profile import A100, TPU_V5E

SRC = str(Path(__file__).resolve().parents[1] / "src")

BACKENDS = ("xla", "pallas-tpu", "pallas-gpu")


@pytest.fixture(scope="module")
def data():
    spec = reduced_graph(CORA, 220, 24)
    g = make_synthetic_graph(spec)
    return spec, g, make_features(spec)


def _assert_compiled_contract(plan, params, x):
    """The acceptance contract: compiled == eager bit-for-bit, one trace."""
    eager = plan.run_model(params, x)
    fn = plan.compile()
    out = fn(params, x)
    fn(params, x)                       # second call: must not retrace
    np.testing.assert_array_equal(np.asarray(out), np.asarray(eager))
    assert fn.num_traces == 1
    return eager


# ---------------------------------------------------------------------------
# Equivalence matrix: compiled == eager across the planner's decisions
# ---------------------------------------------------------------------------


#: backend x fusion at reorder="none", plus the reorder axis on the xla
#: tier (the pallas x degree product is exercised end-to-end by the
#: benchmarks/run.py --dry-run gate; interpret-mode compiles are slow)
_MATRIX = ([(b, f, "none") for b in BACKENDS for f in (False, True)]
           + [("xla", f, "degree") for f in (False, True)])


@pytest.mark.parametrize("backend,fused,reorder", _MATRIX)
def test_compiled_matrix_gcn(data, backend, fused, reorder):
    """plan.compile() output is BIT-FOR-BIT the eager forward on every
    backend x fusion x reorder cell, with exactly one trace."""
    spec, g, x = data
    m = make_paper_model("gcn", spec)
    p = m.init(jax.random.PRNGKey(0))
    plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                      backend=backend, fused=fused, reorder=reorder)
    _assert_compiled_contract(plan, p, x)


@pytest.mark.parametrize("model,kw", [
    ("gin", dict(fused=True)),
    ("gin", dict(fused=False)),
    ("gcn", dict(ordering=COMBINE_FIRST)),
    ("gcn", dict(ordering=AGGREGATE_FIRST, reorder="degree")),
    ("sage", dict(fused=True, reorder="degree")),
])
def test_compiled_models_and_orderings(data, model, kw):
    spec, g, x = data
    m = make_paper_model(model, spec)
    p = m.init(jax.random.PRNGKey(1))
    plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes, **kw)
    _assert_compiled_contract(plan, p, x)


def test_reorder_matches_unreordered(data):
    """Degree reordering only changes the execution schedule; logits come
    back in the natural vertex order (equal to the unreordered plan up to
    summation-order float noise)."""
    spec, g, x = data
    m = make_paper_model("gcn", spec)
    p = m.init(jax.random.PRNGKey(2))
    base = build_plan(g, m.cfg, spec.feature_len, spec.num_classes)
    reord = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                       reorder="degree")
    assert reord is not base            # reorder is part of the cache key
    assert reord.reorder == "degree" and base.reorder == "none"
    assert reord.perm is not None
    # the execution graph is renumbered, the describe() row says so
    assert reord.describe()[0]["reorder"] == "degree"
    assert reord.describe()[0]["compiled"] is True
    np.testing.assert_allclose(
        np.asarray(reord.run_model(p, x)), np.asarray(base.run_model(p, x)),
        rtol=1e-4, atol=1e-5)


def test_reorder_auto_resolves_and_caches(data):
    spec, g, x = data
    m = make_paper_model("gcn", spec)
    plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                      reorder="auto")
    assert plan.reorder in ("none", "degree")   # resolved, never "auto"
    again = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                       reorder="auto")
    assert again is plan
    with pytest.raises(ValueError, match="reorder"):
        build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                   reorder="hilbert")


def test_run_phases_on_reordered_plan(data):
    """run_phases honors the natural-order contract on reordered plans
    (regression: it used to execute the renumbered graph against
    natural-order rows and return silently corrupted values), and rejects
    per-edge weights whose order the renumbering re-sorts."""
    spec, g, x = data
    m = make_paper_model("gcn", spec)
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((spec.feature_len, 8)) * 0.3,
                    jnp.float32)
    base = build_plan(g, m.cfg, spec.feature_len, spec.num_classes)
    reord = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                       reorder="degree")
    ref = base.run_phases(x, [(w, None)], activation="none")
    out = reord.run_phases(x, [(w, None)], activation="none")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    ew = jnp.ones((g.num_edges,), jnp.float32)
    with pytest.raises(ValueError, match="edge_weight"):
        reord.run_phases(x, [(w, None)], edge_weight=ew, activation="none")


def test_reordered_plan_requires_natural_layout(data):
    spec, g, x = data
    m = make_paper_model("gcn", spec)
    p = m.init(jax.random.PRNGKey(0))
    plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                      reorder="degree")
    with pytest.raises(ValueError, match="natural"):
        plan.run_model(p, jnp.zeros((g.num_vertices + 5, spec.feature_len)))


# ---------------------------------------------------------------------------
# Training: grad flows through the compiled callable
# ---------------------------------------------------------------------------


def test_grad_through_compile_training_step(data):
    """One SGD step through plan.compile(): grads match the eager path and
    the step reduces the loss -- compiled execution is trainable."""
    spec, g, x = data
    m = make_paper_model("gcn", spec)
    p = m.init(jax.random.PRNGKey(3))
    labels = jnp.asarray(
        np.random.default_rng(0).integers(0, spec.num_classes,
                                          g.num_vertices))
    plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                      backend="xla")
    fn = plan.compile()

    def loss_c(pp):
        ll = jax.nn.log_softmax(fn(pp, x), axis=-1)
        return -jnp.take_along_axis(ll, labels[:, None], axis=-1).mean()

    def loss_e(pp):
        ll = jax.nn.log_softmax(plan.run_model(pp, x), axis=-1)
        return -jnp.take_along_axis(ll, labels[:, None], axis=-1).mean()

    l0, grads = jax.value_and_grad(loss_c)(p)
    grads_e = jax.grad(loss_e)(p)
    for gc, ge in zip(jax.tree_util.tree_leaves(grads),
                      jax.tree_util.tree_leaves(grads_e)):
        assert np.isfinite(np.asarray(gc)).all()
        np.testing.assert_allclose(np.asarray(gc), np.asarray(ge),
                                   rtol=1e-4, atol=1e-6)
    p1 = jax.tree_util.tree_map(lambda w, d: w - 0.5 * d, p, grads)
    assert float(loss_c(p1)) < float(l0)


# ---------------------------------------------------------------------------
# Retrace guard + caching + capability
# ---------------------------------------------------------------------------


def test_compile_is_cached_per_plan(data):
    spec, g, x = data
    m = make_paper_model("gcn", spec)
    plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes)
    assert plan.compile() is plan.compile()
    assert plan.compile(layer=0) is plan.compile(layer=0)
    assert plan.compile(layer=0) is not plan.compile()


def test_retrace_guard_fires_on_cache_bust(data):
    """The guard is not vacuous: clearing the underlying jit cache (the
    stand-in for anything that silently busts it) makes the second call
    retrace an already-seen signature, which must raise."""
    spec, g, x = data
    m = make_paper_model("gcn", spec)
    p = m.init(jax.random.PRNGKey(0))
    plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes)
    fn = CompiledPlan(plan)             # fresh, bypasses the plan cache
    fn(p, x)
    if not hasattr(fn._fn, "clear_cache"):
        pytest.skip("jax version without jit clear_cache")
    fn._fn.clear_cache()
    with pytest.raises(RuntimeError, match="retraced"):
        fn(p, x)
    assert fn.num_traces == 2


def test_compile_unsupported_without_layout(data):
    """A hand-built Pallas plan lacking the plan-owned blocked layout is
    reported compiled=False and refused by compile() -- the capability
    field in describe() is observable, not decorative."""
    from dataclasses import replace
    spec, g, x = data
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.standard_normal((x.shape[1], 8)) * 0.3, jnp.float32)
    good = plan_for_phases(g, [(w, None)], order=COMBINE_FIRST,
                           agg_op="mean", backend="pallas-tpu")
    assert good.compile_supported
    assert good.layers[0].agg_layout is not None
    bad = GraphExecutionPlan(
        g, [replace(good.layers[0], agg_layout=None)], interpret=True)
    assert not bad.compile_supported
    assert bad.describe()[0]["compiled"] is False
    with pytest.raises(ValueError, match="trace-pure"):
        bad.compile()


def test_plan_run_model_compiled_sugar(data):
    spec, g, x = data
    m = make_paper_model("gcn", spec)
    p = m.init(jax.random.PRNGKey(0))
    plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes)
    np.testing.assert_array_equal(
        np.asarray(plan.run_model(p, x, compiled=True)),
        np.asarray(plan.run_model(p, x)))


# ---------------------------------------------------------------------------
# machine= threading through the standalone-plan entry points (satellite)
# ---------------------------------------------------------------------------


def test_plan_for_conv_threads_machine(data):
    """Bugfix: plan_for_conv/plan_for_phases accept machine=, thread it
    into layer planning, and key the cache on it (previously standalone
    convs always planned with preset defaults)."""
    from repro.core.gcn_layers import GCNConv
    spec, g, x = data
    conv = GCNConv(din=spec.feature_len, dout=8, fused=True)
    base = plan_for_conv(conv, g)
    a100 = plan_for_conv(conv, g, machine=A100)
    assert a100 is not base             # machine is part of the cache key
    assert plan_for_conv(conv, g, machine="a100") is a100
    assert a100.machine is A100
    assert a100.instrument().machine is A100
    # the machine actually reaches _plan_layer: fused tile sizing follows
    # the memory hierarchy (A100's per-CTA budget vs v5e's half-VMEM)
    v5e = plan_for_conv(conv, g, machine=TPU_V5E)
    assert a100.layers[0].tile_m != v5e.layers[0].tile_m


def test_plan_for_phases_threads_machine(data):
    spec, g, x = data
    w = jnp.zeros((spec.feature_len, 8), jnp.float32)
    base = plan_for_phases(g, [(w, None)], agg_op="mean")
    a100 = plan_for_phases(g, [(w, None)], agg_op="mean", machine=A100)
    assert a100 is not base
    assert a100.machine is A100


# ---------------------------------------------------------------------------
# Instrumented compiled timing (repro.profile threading)
# ---------------------------------------------------------------------------


def test_instrumented_compiled_report(data):
    spec, g, x = data
    m = make_paper_model("gcn", spec)
    p = m.init(jax.random.PRNGKey(0))
    plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                      reorder="degree")
    report = plan.instrument(machine=A100).run_model(p, x, compiled=True)
    report.validate()
    assert report.mismatches(plan) == []
    ct = report.compiled_times
    assert ct is not None and ct["model_s"] > 0
    assert len(ct["layers_s"]) == plan.num_layers
    sp = report.compiled_speedup()
    assert sp["model"] > 0 and len(sp["layers"]) == plan.num_layers
    assert "compiled" in report.to_dict()
    assert "Compiled (plan.compile)" in report.to_markdown()
    # the reorder permute was observed at ingress; a plan that claims a
    # different reorder decision is flagged as drift
    base = build_plan(g, m.cfg, spec.feature_len, spec.num_classes)
    drift = report.mismatches(base)
    assert drift and "reorder" in drift[0]


# ---------------------------------------------------------------------------
# Distributed plans compile too (8 fake devices, subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_partition_compiled_subprocess():
    """1-D and 2-D partitioned plans (with and without reorder) satisfy the
    compiled contract on an 8-fake-device mesh: bitwise eager equality,
    single trace, and agreement with the unsharded reference."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import GRAPHS, reduced_graph
        from repro.graph.datasets import make_features, make_synthetic_graph
        from repro.core.plan import build_plan
        from repro.models.gcn import make_paper_model

        spec = reduced_graph(GRAPHS["reddit"], 256, 64)
        g = make_synthetic_graph(spec); x = make_features(spec)
        m = make_paper_model("gcn", spec)
        p = m.init(jax.random.PRNGKey(0))
        ref = build_plan(g, m.cfg, spec.feature_len,
                         spec.num_classes).run_model(p, x)
        cases = ((( 8,), ("data",), "none"),
                 (( 8,), ("data",), "degree"),
                 ((4, 2), ("node", "feat"), "none"),
                 ((4, 2), ("node", "feat"), "degree"))
        for shape, names, reorder in cases:
            mesh = jax.make_mesh(shape, names)
            plan = build_plan(g, m.cfg, spec.feature_len, spec.num_classes,
                              mesh=mesh, reorder=reorder)
            with mesh:
                eager = plan.run_model(p, x)
                fn = plan.compile()
                out = fn(p, x); fn(p, x)
            assert np.array_equal(np.asarray(out), np.asarray(eager)), \\
                (shape, reorder)
            assert fn.num_traces == 1, (shape, reorder)
            err = np.abs(np.asarray(eager) - np.asarray(ref)).max()
            assert err < 1e-3, (shape, reorder, err)

        # regression: run_phases on a distributed+reordered plan applies
        # ONLY the reorder permute, never the partition padding (V=249 is
        # deliberately not a multiple of the shard count)
        from repro.config import GraphSpec
        sp = GraphSpec("t", 249, 64, 1200, num_classes=5)
        g2 = make_synthetic_graph(sp); x2 = make_features(sp)
        m2 = make_paper_model("gcn", sp)
        w = jnp.asarray(np.random.default_rng(0).standard_normal(
            (64, 8)) * 0.2, jnp.float32)
        mesh = jax.make_mesh((8,), ("data",))
        pr = build_plan(g2, m2.cfg, sp.feature_len, sp.num_classes,
                        mesh=mesh, reorder="degree")
        pb = build_plan(g2, m2.cfg, sp.feature_len, sp.num_classes)
        d = np.abs(np.asarray(
            pr.run_phases(x2, [(w, None)], activation="none")
            - pb.run_phases(x2, [(w, None)], activation="none"))).max()
        assert d < 1e-5, d
        print("OK")
    """)
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin", "HOME": "/root"},
        timeout=600)
    assert res.returncode == 0, f"subprocess failed:\n{res.stderr[-3000:]}"
    assert "OK" in res.stdout
