"""Minimal stand-in for the ``hypothesis`` API surface this suite uses.

The container image has no ``hypothesis`` wheel and nothing may be pip
installed, so ``conftest.py`` registers this module under
``sys.modules["hypothesis"]`` when the real package is missing.  It covers
exactly what the tests import -- ``given``, ``settings``,
``strategies.integers`` -- by running each property against a deterministic
sample of draws (endpoints first, then seeded-random interior points).
Installing real hypothesis transparently takes precedence.
"""

from __future__ import annotations

import itertools

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class _IntStrategy:
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def draws(self, rng: np.random.Generator, n: int):
        fixed = [self.lo, self.hi] if self.hi > self.lo else [self.lo]
        rand = [int(rng.integers(self.lo, self.hi + 1))
                for _ in range(max(0, n - len(fixed)))]
        return (fixed + rand)[:n]


class strategies:  # noqa: N801 - mimics the hypothesis module name
    @staticmethod
    def integers(min_value: int, max_value: int) -> _IntStrategy:
        return _IntStrategy(min_value, max_value)


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats: _IntStrategy):
    def deco(fn):
        # NOT functools.wraps: pytest must see a zero-arg signature, or it
        # would treat the drawn parameters as fixture requests.
        def runner():
            n = getattr(fn, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(0)
            columns = [s.draws(rng, n) for s in strats]
            for drawn in itertools.islice(zip(*columns), n):
                fn(*drawn)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco
