from repro.graph.structure import Graph, graph_from_coo
from repro.graph.datasets import make_synthetic_graph, load_dataset
from repro.graph.reorder import degree_reorder, reuse_distance_stats
from repro.graph.partition import partition_1d, PartitionedGraph
