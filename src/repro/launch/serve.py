"""Serving launcher: `python -m repro.launch.serve --arch <id> [...]`.

Builds the engine over whatever mesh exists and serves a synthetic request
wave (stands in for an RPC front-end; the engine API is the integration
point).  Reduced configs run on CPU:

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
      --reduced --requests 8
"""

from __future__ import annotations

import argparse
import dataclasses
import importlib
import time

import jax
import numpy as np

from repro.config import get_config
from repro.launch.train import MODULES
from repro.models.transformer import init_lm
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-size", type=int, default=256)
    ap.add_argument("--max-tokens", type=int, default=16)
    args = ap.parse_args()

    if args.reduced:
        mod = importlib.import_module(f"repro.configs.{MODULES[args.arch]}")
        cfg = dataclasses.replace(mod.reduced(), dtype="float32")
    else:
        cfg = get_config(args.arch)
    if cfg.family == "audio":
        raise SystemExit("audio archs serve via the encdec prefill/decode "
                         "steps; see launch/dryrun.py decode cells")

    params = init_lm(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         cache_size=args.cache_size)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(4, 32))),
            max_tokens=args.max_tokens))
    done = engine.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
