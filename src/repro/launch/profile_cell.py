import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run profiler: top HBM-traffic / FLOPs contributors for one cell.

The CPU-container substitute for a real TPU profile (per the brief, the
"profile" is the lowered HLO): walks the compiled module with trip-count
scaling and attributes bytes/flops to instructions, aggregated by shape --
this is what the §Perf iterations read to pick the next change.

  PYTHONPATH=src python -m repro.launch.profile_cell --arch mamba2-2.7b \
      --shape train_4k [--mesh single] [--top 20] [--microbatch 4]
"""

import argparse  # noqa: E402
from collections import Counter  # noqa: E402

from repro.core import hlo_cost as H  # noqa: E402


def profile(arch: str, shape: str, mesh_kind: str = "single", top: int = 20,
            remat: str = "auto", microbatch: int = 0, rules_override=None):
    from repro.config import get_config
    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_production_mesh
    from repro.launch.sharding import rules_for, sharding_rules

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg0 = get_config(arch)
    rules = rules_for(cfg0, mesh)
    if rules_override:
        rules.update(rules_override)
    with mesh, sharding_rules(mesh, rules):
        jf, args, cfg, sh = build_cell(arch, shape, mesh, remat=remat,
                                       microbatch=microbatch)
        comp = jf.lower(*args).compile()
    an = H.Analyzer(comp.as_text())

    # computation -> total trip multiplier
    trips: Counter = Counter()

    def walk(cname, mult):
        c = an.comps.get(cname)
        if c is None:
            return
        trips[cname] += mult
        for inst in c.instructions:
            called = H._CALLED.findall(inst.attrs) or \
                H._CALLED.findall(inst.line)
            t = mult
            if inst.opcode == "while":
                cond = H._COND.search(inst.attrs) or \
                    H._COND.search(inst.line)
                if cond:
                    t = mult * an._trip_count(cond.group(1))
            for callee in called:
                walk(callee, t)

    walk(an.entry, 1)

    by_bytes: Counter = Counter()
    by_flops: Counter = Counter()
    for cname, c in an.comps.items():
        t = trips.get(cname, 0)
        if t == 0 or cname.startswith("fused_") or ".fused" in cname:
            continue
        for inst in c.instructions:
            if inst.opcode in ("while", "call", "conditional"):
                continue  # bodies attributed via their own trip entries
            ic = an._inst_cost(c, inst, False)
            key = (inst.opcode, inst.result_text[:56], cname[:24])
            if ic.bytes_accessed:
                by_bytes[key] += ic.bytes_accessed * t
            if ic.flops:
                by_flops[key] += ic.flops * t
    total_b = sum(by_bytes.values())
    total_f = sum(by_flops.values())
    print(f"== {arch} x {shape} x {mesh_kind} (remat={remat}, "
          f"microbatch={microbatch}) ==")
    print(f"bytes={total_b:.3e} ({total_b/819e9:.2f}s) "
          f"flops={total_f:.3e} ({total_f/197e12:.2f}s)\n")
    print("-- top HBM traffic --")
    for (op, shp, cn), v in by_bytes.most_common(top):
        print(f"{v:9.2e} ({100*v/total_b:4.1f}%) {op:16s} {shp:58s} {cn}")
    print("\n-- top FLOPs --")
    for (op, shp, cn), v in by_flops.most_common(max(6, top // 2)):
        print(f"{v:9.2e} ({100*v/total_f:4.1f}%) {op:16s} {shp:58s} {cn}")
    return by_bytes, by_flops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--remat", default="auto")
    ap.add_argument("--microbatch", type=int, default=0)
    args = ap.parse_args()
    profile(args.arch, args.shape, args.mesh, args.top, args.remat,
            args.microbatch)


if __name__ == "__main__":
    main()
