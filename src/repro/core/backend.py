"""Backend & runtime detection shared by the kernels and the planner.

One place answers three questions every execution path used to answer
ad-hoc (and sometimes wrongly, e.g. a hardcoded ``interpret=True``):

  * which platform are we on (``platform`` / ``on_tpu`` / ``on_gpu``)?
  * should a Pallas kernel run compiled or interpreted
    (``interpret_for``: compiled only where the kernel's tier matches the
    real platform, interpreted everywhere else so the whole suite runs on
    CPU containers; overridable via ``REPRO_PALLAS_INTERPRET``)?
  * which aggregation backend should a plan use when asked for "auto"
    (``resolve_backend``)?

Backends form three *tiers*, one per accelerator family the paper's
guidelines differentiate (F3: specialized aggregation kernels beat the
generic segmented reduction, but the winning kernel shape depends on the
memory hierarchy):

  * ``"xla"``        -- ``jax.ops.segment_sum``; the portable baseline and
    the CPU resolution of "auto".
  * ``"pallas-tpu"`` -- the one-hot-MXU ``seg_agg`` kernel
    (kernels/seg_agg.py): sequential edge-chunk grid dimension with a VMEM
    scratch accumulator; collisions are impossible by construction.
  * ``"pallas-gpu"`` -- the row-blocked GPU kernel (kernels/gpu_agg.py):
    one CTA owns one destination row block outright and loops over its
    edge chunks in-register (GPU grid steps are independent thread blocks,
    so the TPU trick of accumulating across a sequential grid axis would
    need atomics -- exactly the serialization the paper measures).

``"pallas"`` is accepted as a legacy alias and resolves to the current
platform's native Pallas tier.  The execution planner (core/plan.py)
consults this module once at plan-build time; kernels consult it only when
a caller passes ``interpret=None``.
"""

from __future__ import annotations

import os
from typing import Optional

import jax

XLA = "xla"
PALLAS_TPU = "pallas-tpu"
PALLAS_GPU = "pallas-gpu"
PALLAS = "pallas"  # legacy alias: the current platform's native Pallas tier
AUTO = "auto"
BACKENDS = (XLA, PALLAS_TPU, PALLAS_GPU)

#: platform a Pallas tier compiles natively on (anything else -> interpret)
_NATIVE_PLATFORM = {PALLAS_TPU: "tpu", PALLAS_GPU: "gpu"}


def platform() -> str:
    """The JAX default backend platform: "cpu" | "gpu" | "tpu"."""
    return jax.default_backend()


def on_tpu() -> bool:
    return platform() == "tpu"


def on_gpu() -> bool:
    return platform() == "gpu"


def pallas_tier() -> str:
    """The current platform's native Pallas tier (GPU -> pallas-gpu,
    everything else -> pallas-tpu, which interprets fine off-TPU)."""
    return PALLAS_GPU if on_gpu() else PALLAS_TPU


def is_pallas(backend: str) -> bool:
    """True for any Pallas tier (including the legacy "pallas" alias)."""
    return backend in (PALLAS, PALLAS_TPU, PALLAS_GPU)


def _interpret_env() -> Optional[bool]:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env != "0"
    return None


def default_interpret() -> bool:
    """Pallas interpret mode default: compiled on TPU, interpreted elsewhere.

    ``REPRO_PALLAS_INTERPRET=0``/``1`` overrides the detection (e.g. to force
    interpret mode on a TPU while debugging a kernel).  Tier-aware callers
    (the planner, kernels/ops.py) should prefer ``interpret_for(backend)``,
    which also compiles the GPU tier on real GPUs.
    """
    env = _interpret_env()
    if env is not None:
        return env
    return not on_tpu()


def interpret_for(backend: str) -> bool:
    """Interpret-mode decision for one backend tier.

    A Pallas kernel compiles only on the platform its tier targets
    (pallas-tpu on TPU, pallas-gpu on GPU); everywhere else -- including a
    GPU-tier kernel validated on a CPU container, or a TPU-tier kernel
    forced onto a GPU box -- it runs in interpret mode so the numerics are
    still exercised.  ``REPRO_PALLAS_INTERPRET`` overrides either way.
    """
    env = _interpret_env()
    if env is not None:
        return env
    if backend == PALLAS:
        backend = pallas_tier()
    native = _NATIVE_PLATFORM.get(backend)
    return platform() != native


def resolve_interpret(interpret=None, backend: Optional[str] = None) -> bool:
    if interpret is not None:
        return bool(interpret)
    if backend is not None:
        return interpret_for(backend)
    return default_interpret()


def default_machine(requested: str = AUTO):
    """Machine preset matching a (possibly unresolved) backend tier.

    Resolves the tier first (``resolve_backend``), then maps it to the
    preset the characterization subsystem models it with: ``pallas-gpu`` ->
    A100, everything else -> TPU_V5E (``repro.profile.machine``).  Lets
    plan-level code stay machine-implicit until a caller overrides it.
    """
    from repro.profile.machine import machine_for_backend
    return machine_for_backend(resolve_backend(requested))


def resolve_backend(requested: str = AUTO) -> str:
    """Map a requested backend to a concrete tier (never "auto"/"pallas").

    Resolution table (paper F3 restated per platform)::

        requested      cpu          gpu          tpu
        -----------    ----------   ----------   ----------
        "auto"         xla          pallas-gpu   pallas-tpu
        "pallas"       pallas-tpu*  pallas-gpu   pallas-tpu
        "xla" / "pallas-tpu" / "pallas-gpu"   (returned as requested)

    ``*`` = runs in interpret mode there (``interpret_for``), so every tier
    stays testable on a CPU-only container.

    Example::

        >>> resolve_backend("xla")
        'xla'
        >>> resolve_backend()           # on a CPU container
        'xla'
        >>> resolve_backend("pallas")   # on a CPU container: TPU tier,
        'pallas-tpu'                    # auto-interpreted off-TPU

    Raises ``ValueError`` for anything outside ``BACKENDS + (PALLAS, AUTO)``.
    """
    if requested == PALLAS:
        return pallas_tier()
    if requested in BACKENDS:
        return requested
    if requested != AUTO:
        raise ValueError(
            f"unknown backend {requested!r}; expected one of "
            f"{BACKENDS + (PALLAS, AUTO)}")
    p = platform()
    if p == "tpu":
        return PALLAS_TPU
    if p == "gpu":
        return PALLAS_GPU
    return XLA
