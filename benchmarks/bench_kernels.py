"""Beyond-paper: Pallas kernel benchmarks (interpret-mode correctness +
modeled TPU utilization) and the fused-dataflow guideline (paper §5.1-3).

Interpret-mode timing is meaningless for TPU perf; what we measure:
  * XLA path wall-clock for fused vs unfused dataflow (the HBM-traffic
    effect is visible even on CPU),
  * analytic VMEM footprint + MXU-alignment of the kernel tilings,
  * numerics of the Pallas kernels at benchmark shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_graph, emit, timeit
from repro.core.characterize import VMEM_BYTES
from repro.core.plan import plan_for_phases
from repro.graph.datasets import make_features, make_synthetic_graph
from repro.kernels import ops
from repro.kernels.ref import seg_agg_ref


def run():
    spec = bench_graph("reddit", max_vertices=4096, max_feature=256)
    g = make_synthetic_graph(spec)
    x = make_features(spec)
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 128)) * 0.05

    # fused vs unfused dataflow (XLA backend), both as planner scenarios
    weights = [(w, None)]
    fused_plan = plan_for_phases(g, weights, order="combine_first",
                                 agg_op="mean", backend="xla", fused=True)
    unfused_plan = plan_for_phases(g, weights, order="combine_first",
                                   agg_op="mean", backend="xla")
    fused = jax.jit(lambda xx: fused_plan.run_phases(
        xx, weights, activation="none"))
    unfused = jax.jit(lambda xx: unfused_plan.run_phases(
        xx, weights, activation="none"))
    t_f = timeit(fused, x)
    t_u = timeit(unfused, x)
    err = float(jnp.abs(fused(x) - unfused(x)).max())
    emit("kernels/fused_dataflow", t_f,
         unfused_us=round(t_u, 1), speedup=round(t_u / t_f, 2),
         max_err=f"{err:.1e}", tile_m=fused_plan.layers[0].tile_m)

    # VMEM budgets of the kernel tilings (structural roofline inputs)
    for (fi, fo, tm, te) in [(602, 128, 128, 512), (256, 128, 256, 512)]:
        vmem = (fi * fo + tm * fi + tm * fo + te * fi) * 4
        emit(f"kernels/fused_vmem_f{fi}", 0.0,
             vmem_bytes=vmem, vmem_frac=round(vmem / VMEM_BYTES, 3),
             mxu_aligned=bool(fo % 128 == 0 and tm % 8 == 0))

    # Pallas numerics at benchmark shapes (interpret mode)
    rng = np.random.default_rng(0)
    nb, emax, f, tm = 2, 512, 128, 128
    rows = jnp.asarray(rng.standard_normal((nb, emax, f)), jnp.float32)
    seg = jnp.asarray(np.sort(rng.integers(0, tm, (nb, emax))), jnp.int32)
    mask = jnp.ones((nb, emax), jnp.float32)
    out = ops.seg_agg_pregrouped(rows, seg, mask, tile_m=tm)
    gseg = (seg + jnp.arange(nb)[:, None] * tm).reshape(-1)
    ref = seg_agg_ref(rows.reshape(-1, f), gseg, mask.reshape(-1), nb * tm)
    emit("kernels/seg_agg_numerics", 0.0,
         max_err=f"{float(jnp.abs(out - ref).max()):.1e}",
         mxu_reduction=True)


if __name__ == "__main__":
    run()
