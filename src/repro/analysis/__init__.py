"""Static contract verification for plans, jaxprs, and Pallas kernels.

The paper's guidelines (2001.10160) assume the *measured* execution
matches the *planned* one.  ``repro.analysis`` proves the planner's
contracts from the traced program without executing it:

  * :mod:`repro.analysis.jaxpr_lint` -- trace a ``GraphExecutionPlan``
    (eager forward and ``plan.compile()`` callable) to closed jaxprs and
    lowered HLO, then verify trace purity, f32 accumulation under bf16,
    donation, schedule-exact collective byte totals, and edge-content
    freedom of dynamic bucket plans.
  * :mod:`repro.analysis.ast_lint` -- a source-level pass over
    ``src/repro/`` for retrace/bitwise hazards (tracer branching, host
    materialization in traced scopes, broadcast division, Pallas scratch
    dtypes not threaded through ``acc_dtype``, grid/BlockSpec arity).
  * :mod:`repro.analysis.report` -- the typed ``Finding`` /
    ``AnalysisReport`` core (JSON + markdown, severity levels,
    per-rule suppression pragmas).

``scripts/analyze.py`` runs both front ends over the full static plan
matrix and is the third leg of ``scripts/smoke.sh``; rule catalog and
pragma syntax live in ``docs/analysis.md``.
"""

from repro.analysis.report import AnalysisReport, Finding  # noqa: F401
