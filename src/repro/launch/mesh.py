"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state -- the 512-placeholder-device environment is set up
only by launch/dryrun.py before its first jax import.

Mesh shapes (TPU v5e pods):
  single-pod: (16, 16)      axes ("data", "model")   = 256 chips
  multi-pod:  (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

Axis roles (see launch/sharding.py):
  pod+data -> DP/FSDP (params + batch), sequence sharding for long-context
  model    -> TP (heads / ffn) + EP (experts) + vocab-parallel logits
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices=None) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (subprocess multi-device tests)."""
    devices = devices or jax.devices()
    n = len(devices)
    if n >= 4:
        dp, tp = n // 2, 2
    else:
        dp, tp = n, 1
    return jax.make_mesh((dp, tp), ("data", "model"),
                         devices=devices[: dp * tp])


def fsdp_axes(mesh: jax.sharding.Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def num_chips(mesh: jax.sharding.Mesh) -> int:
    import numpy as np
    return int(np.prod(list(mesh.shape.values())))
