"""Multi-device tests: run in SUBPROCESSES with 8 fake CPU devices so the
main pytest process keeps its single real device (per the dry-run rule)."""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")
TESTS = str(Path(__file__).resolve().parent)  # tolerance.py for subprocesses


def run_sub(body: str):
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import warnings; warnings.filterwarnings("ignore")
        import jax, jax.numpy as jnp, numpy as np
        from tolerance import assert_allclose_dtype
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True,
                         env={"PYTHONPATH": f"{SRC}:{TESTS}",
                              "PATH": "/usr/bin:/bin", "HOME": "/root"},
                         timeout=600)
    assert res.returncode == 0, f"subprocess failed:\n{res.stderr[-3000:]}"
    return res.stdout


@pytest.mark.slow
def test_distributed_aggregation_strategies():
    out = run_sub("""
        from repro.config import CORA, reduced_graph
        from repro.graph.datasets import make_synthetic_graph, make_features
        from repro.graph.partition import partition_1d
        from repro.core.distributed import (aggregate_allgather,
            aggregate_ring, pad_features)
        from repro.core.phases import aggregate
        mesh = jax.make_mesh((8,), ("data",))
        spec = reduced_graph(CORA, 300, 32)
        g = make_synthetic_graph(spec); x = make_features(spec)
        pg = partition_1d(g, 8, edge_balanced=False)
        xp = pad_features(x, pg.block_size, 8)
        ref = aggregate(g, x, op="sum", include_self=False)
        with mesh:
            a1 = aggregate_allgather(pg, xp, mesh)[:g.num_vertices]
            a2 = aggregate_ring(pg, xp, mesh)[:g.num_vertices]
        assert_allclose_dtype(a1, ref, scale=10)
        assert_allclose_dtype(a2, ref, scale=10)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_distributed_phase_ordering_halo_reduction():
    """Cluster Table 4: combine-first shrinks halo bytes by in/out ratio."""
    out = run_sub("""
        from repro.config import GraphSpec
        from repro.graph.datasets import make_synthetic_graph, make_features
        from repro.graph.partition import partition_1d
        from repro.core.distributed import (distributed_gcn_layer,
            pad_features, halo_bytes)
        from repro.core.phases import phase_ordered_layer
        spec = GraphSpec("t", 256, 64, 2048)
        g = make_synthetic_graph(spec); x = make_features(spec)
        pg = partition_1d(g, 8, edge_balanced=False)
        xp = pad_features(x, pg.block_size, 8)
        w = jnp.asarray(np.random.default_rng(0).standard_normal(
            (64, 16)) * 0.2, jnp.float32)
        b = jnp.zeros(16)
        mesh = jax.make_mesh((8,), ("data",))
        ref = phase_ordered_layer(g, x, [(w, b)], order="combine_first",
                                  agg_op="mean", activation="none")
        with mesh:
            for order in ("combine_first", "aggregate_first"):
                for strat in ("ring", "allgather"):
                    o = distributed_gcn_layer(pg, xp, w, b, g.in_deg, mesh,
                        order=order, strategy=strat)[:g.num_vertices]
                    assert_allclose_dtype(o, ref, scale=100,
                                          err_msg=f"{order}/{strat}")
        hb_in = halo_bytes(pg, 64)["min_halo_bytes"]
        hb_out = halo_bytes(pg, 16)["min_halo_bytes"]
        assert hb_in / hb_out == 4.0   # in_len/out_len = 64/16
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_distributed_plan_matches_local():
    """A mesh-built GraphExecutionPlan runs the whole model sharded and
    matches the local (single-device) plan output."""
    out = run_sub("""
        from repro.config import CORA, reduced_graph
        from repro.graph.datasets import make_synthetic_graph, make_features
        from repro.core.plan import build_plan
        from repro.models.gcn import PAPER_MODELS
        import dataclasses
        spec = reduced_graph(CORA, 300, 32)
        g = make_synthetic_graph(spec); x = make_features(spec)
        cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
        mesh = jax.make_mesh((8,), ("data",))
        local = build_plan(g, cfg, spec.feature_len, spec.num_classes)
        dist = build_plan(g, cfg, spec.feature_len, spec.num_classes,
                          mesh=mesh, num_shards=8, strategy="ring")
        assert dist.distributed and not local.distributed
        params = local.init(jax.random.PRNGKey(0))
        ref = local.run_model(params, x)
        with mesh:
            out = dist.run_model(params, x)
        assert out.shape == ref.shape
        assert_allclose_dtype(out, ref, scale=100)
        # ordering decisions stay cost-model driven in the sharded plan:
        # both layers shrink (32->16->7) => combine-first halo everywhere
        assert [lp.order for lp in dist.layers] == ["combine_first"] * 2
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_distributed_2d_plan_matches_local():
    """A 2-D (node x feature) mesh plan equals the unsharded reference for
    both orderings and both halo strategies, and its per-device halo bytes
    shrink by the feature-shard count Q vs the 1-D partition."""
    out = run_sub("""
        import dataclasses
        from repro.config import CORA, reduced_graph
        from repro.graph.datasets import make_synthetic_graph, make_features
        from repro.graph.partition import partition_1d, partition_2d
        from repro.core.distributed import (distributed_gcn_layer_2d,
            halo_bytes, halo_bytes_2d, pad_features_2d)
        from repro.core.plan import build_plan
        from repro.models.gcn import PAPER_MODELS
        spec = reduced_graph(CORA, 300, 32)
        g = make_synthetic_graph(spec); x = make_features(spec)
        cfg = dataclasses.replace(PAPER_MODELS["gcn"], hidden_dims=(16,))
        local = build_plan(g, cfg, spec.feature_len, spec.num_classes)
        params = local.init(jax.random.PRNGKey(0))
        ref = local.run_model(params, x)
        # ordering=None resolves to one of the two explicit orders (covered
        # below); the (2, 4) shape and cost-model ordering are exercised by
        # the dry-run partition matrix (benchmarks/bench_plan.py) on every
        # smoke run -- keep this sweep inside run_sub's 600 s budget
        combos = [((4, 2), "ring"), ((4, 2), "allgather")]
        for shape, strat in combos:
            mesh = jax.make_mesh(shape, ("node", "feat"))
            for order in ("combine_first", "aggregate_first"):
                plan = build_plan(g, cfg, spec.feature_len,
                                  spec.num_classes, mesh=mesh,
                                  strategy=strat, ordering=order)
                assert plan.partition_kind == "2d"
                with mesh:
                    out = plan.run_model(params, x)
                assert out.shape == ref.shape
                assert_allclose_dtype(out, ref, scale=100,
                                      err_msg=f"{shape}/{strat}/{order}")
        # bare-layer entry: padded layout in, padded layout out
        p2 = partition_2d(g, 4, 2)
        mesh = jax.make_mesh((4, 2), ("node", "feat"))
        w = jnp.asarray(np.random.default_rng(0).standard_normal(
            (32, 16)) * 0.2, jnp.float32)
        b = jnp.zeros(16)
        from repro.core.phases import phase_ordered_layer
        lref = phase_ordered_layer(g, x, [(w, b)], order="combine_first",
                                   agg_op="mean", activation="none")
        with mesh:
            lo = distributed_gcn_layer_2d(p2, pad_features_2d(x, p2), w, b,
                g.in_deg, mesh, order="combine_first")
        assert_allclose_dtype(lo[:g.num_vertices, :16], lref, scale=100)
        # Q-fold halo saving on top of Table 4's in/out ratio
        pg = partition_1d(g, 4, edge_balanced=False)
        assert halo_bytes_2d(p2, 32)["min_halo_bytes"] * 2 == \
            halo_bytes(pg, 32)["min_halo_bytes"]
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_compressed_allreduce_matches_mean():
    out = run_sub("""
        from jax.sharding import Mesh
        from repro.optim.compression import (make_compressed_allreduce,
            init_residuals)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)}
        res = init_residuals(g)
        ar = make_compressed_allreduce(mesh, "data")
        with mesh:
            out, res2 = ar(g, res)
        # every shard held the same replica here, so mean == input (up to
        # int8 quantization); residual carries the quantization error
        err = np.abs(np.asarray(out["w"] - g["w"])).max()
        scale = np.abs(np.asarray(g["w"])).max() / 127
        assert err <= scale * 1.01 + 1e-6
        recon = np.asarray(out["w"]) + np.asarray(res2["w"])
        assert np.abs(recon - np.asarray(g["w"])).max() < 1e-5
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_ctx_parallel_attention_sharded():
    out = run_sub("""
        from repro.launch.sharding import sharding_rules, DEFAULT_RULES
        from repro.nn.attention import flash_attention_xla, direct_attention
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((2, 14, 512, 32)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, 2, 512, 32)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, 2, 512, 32)), jnp.float32)
        rules = dict(DEFAULT_RULES)
        rules.update({"heads": None, "kv_heads": None, "seq": ("model",),
                      "seq_q": ("model",), "mlp": None, "vocab": None})
        with mesh, sharding_rules(mesh, rules):
            f = lambda q, k, v: flash_attention_xla(
                q, k, v, causal=True, q_chunk=64, kv_chunk=64)
            o1 = jax.jit(f)(q, k, v)
            g1 = jax.jit(jax.grad(
                lambda q, k, v: f(q, k, v).sum() * 0.01,
                argnums=(0, 1, 2)))(q, k, v)
        o2 = direct_attention(q, k, v, causal=True, window=0, cap=0.0)
        g2 = jax.grad(lambda q, k, v: direct_attention(
            q, k, v, causal=True, window=0, cap=0.0).sum() * 0.01,
            argnums=(0, 1, 2))(q, k, v)
        assert np.abs(np.asarray(o1 - o2)).max() < 1e-4
        for a, b in zip(g1, g2):
            assert np.abs(np.asarray(a - b)).max() < 1e-5
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_lm_train_step_matches_single_device():
    """pjit train step on a 4x2 mesh == single-device step (same math)."""
    out = run_sub("""
        import dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import granite_3_8b
        from repro.config import OptimizerConfig
        from repro.launch.sharding import sharding_rules, rules_for
        from repro.launch.specs import param_pspecs, state_pspecs
        from repro.launch.steps import make_train_step
        from repro.models.transformer import init_lm
        from repro.optim.optimizer import make_train_state
        cfg = dataclasses.replace(granite_3_8b.reduced(), dtype="float32")
        opt = OptimizerConfig(warmup_steps=1, total_steps=10)
        params = init_lm(cfg, jax.random.PRNGKey(0))
        state = make_train_state(params, opt)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        step = make_train_step(cfg, opt)
        s_ref, m_ref = jax.jit(step)(state, batch)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with mesh, sharding_rules(mesh, rules_for(cfg, mesh)):
            st_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 state_pspecs(jax.eval_shape(
                                     lambda: state), mesh),
                                 is_leaf=lambda x: isinstance(x, P))
            bt_sh = {"tokens": NamedSharding(mesh, P("data", None)),
                     "labels": NamedSharding(mesh, P("data", None))}
            jstep = jax.jit(step, in_shardings=(st_sh, bt_sh))
            s_sh, m_sh = jstep(jax.device_put(state, st_sh),
                               {k: jax.device_put(v, bt_sh[k])
                                for k, v in batch.items()})
        l1 = float(np.asarray(m_ref["loss"]))
        l2 = float(np.asarray(m_sh["loss"]))
        assert abs(l1 - l2) < 1e-3, (l1, l2)
        d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         s_ref.params, jax.device_get(s_sh.params))
        assert max(jax.tree.leaves(d)) < 1e-3
        print("OK")
    """)
    assert "OK" in out
