"""Batched serving driver: continuous batching over a shared KV cache.

Submits a wave of requests with mixed prompt/generation lengths to the
ServeEngine (prefill-into-slot admission, per-slot cache lengths, greedy or
temperature sampling) and reports throughput + per-request latency.

  PYTHONPATH=src python examples/serve_lm.py --requests 12 --max-batch 4
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import granite_3_8b
from repro.models.transformer import init_lm
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-size", type=int, default=128)
    ap.add_argument("--max-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = dataclasses.replace(granite_3_8b.reduced(), dtype="float32",
                              num_layers=4)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         cache_size=args.cache_size)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 24)))
        engine.submit(Request(rid=i, prompt=prompt,
                              max_tokens=int(rng.integers(
                                  4, args.max_tokens)),
                              temperature=args.temperature))
    done = engine.run()
    dt = time.time() - t0

    total_tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {total_tokens} tokens in "
          f"{dt:.2f}s  ({total_tokens / dt:.1f} tok/s, "
          f"{engine.stats()['decode_steps']} decode steps, "
          f"batch slots: {args.max_batch})")
    for r in done[:5]:
        lat = r.finish_t - r.enqueue_t
        print(f"  req {r.rid}: prompt {len(r.prompt):3d} -> "
              f"{len(r.output):3d} tokens, latency {lat:.2f}s")


if __name__ == "__main__":
    main()
