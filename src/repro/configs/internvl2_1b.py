"""internvl2-1b -- VLM: InternViT frontend (STUB) + Qwen2-0.5B LM backbone.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.  [arXiv:2404.16821; hf]

Per the assignment, the vision frontend is a stub: ``input_specs`` provides
precomputed patch embeddings (256 tokens, ViT-L/14 448px -> 256 patches after
pixel-shuffle) occupying the first positions; the backbone is exercised in
full.
"""

import dataclasses

from repro.config import AttentionConfig, LMConfig, register

NUM_PATCH_TOKENS = 256


def _base() -> LMConfig:
    return LMConfig(
        name="internvl2-1b",
        family="vlm",
        num_layers=24,
        d_model=896,
        d_ff=4864,
        vocab_size=151655,
        attention=AttentionConfig(num_heads=14, num_kv_heads=2, head_dim=64),
        mlp_activation="swiglu",
        tie_embeddings=True,
        frontend_stub=True,
        shape_skips=("long_500k",),
        skip_reason="pure full attention; 500k decode needs sub-quadratic",
        source="arXiv:2404.16821",
    )


@register("internvl2-1b")
def config() -> LMConfig:
    return _base()


def reduced() -> LMConfig:
    c = _base()
    return dataclasses.replace(
        c, name=c.name + "-smoke", num_layers=2, d_model=64, d_ff=128,
        vocab_size=256,
        attention=dataclasses.replace(c.attention, num_heads=4,
                                      num_kv_heads=2, head_dim=16))
