# NOTE: no XLA_FLAGS here by design -- smoke tests and benches must see the
# single real CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (tests/test_distributed.py).
import sys

import numpy as np
import pytest

try:  # the container has no hypothesis wheel; fall back to the local stub
    import hypothesis  # noqa: F401
except ImportError:
    import importlib.util
    from pathlib import Path

    _spec = importlib.util.spec_from_file_location(
        "hypothesis", Path(__file__).parent / "_hypothesis_stub.py")
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def tol():
    """The suite-wide per-dtype tolerance asserter (tests/tolerance.py).

    Usage: ``tol(actual, desired, dtype="bf16", scale=2)``.  Prefer this
    (or a direct ``from tolerance import assert_allclose_dtype``) over
    ad-hoc ``np.testing.assert_allclose`` literals -- the band table is
    owned in ONE place.
    """
    from tolerance import assert_allclose_dtype
    return assert_allclose_dtype


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
