"""Roofline table from dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
one row per (arch x shape x mesh): the three roofline terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs useful ratio, roofline fraction, and
fits-HBM.  This is a REPORTER -- it never touches jax devices, so it runs
inside the normal benchmark process.
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run():
    if not DRYRUN_DIR.exists():
        emit("roofline/missing", 0.0,
             note="run `python -m repro.launch.dryrun` first")
        return
    recs = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        try:
            recs.append(json.loads(p.read_text()))
        except Exception:  # noqa: BLE001
            continue
    for r in recs:
        if r.get("status") != "ok":
            emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                 tag=r.get("tag", "baseline"), status="ERROR",
                 error=r.get("error", "")[:80])
            continue
        rl = r["roofline"]
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             rl["compute_s"] * 1e6,
             tag=r.get("tag", "baseline"),
             compute_s=f"{rl['compute_s']:.4f}",
             memory_s=f"{rl['memory_s']:.4f}",
             collective_s=f"{rl['collective_s']:.4f}",
             dominant=rl["dominant"],
             useful_ratio=round(rl["useful_ratio"], 3),
             roofline_fraction=round(rl["roofline_fraction"], 4),
             peak_gib=round(r.get("peak_bytes_per_device", 0) / 2 ** 30, 2),
             fits_16g=r.get("fits_16g"))


if __name__ == "__main__":
    run()
