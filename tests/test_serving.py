"""Serving: prefill/decode consistency, engine continuous batching, and
the bucketed GraphServeEngine (smallest-fit selection, padded-vs-eager
bit-identity, slot reuse, zero-retrace warm-up, latency percentiles,
plan-cache eviction, serving report schema)."""

import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CORA, reduced_graph
from repro.configs import (gemma2_9b, granite_3_8b, jamba_1_5_large,
                           kimi_k2, mamba2_2_7b, seamless_m4t_medium)
from repro.core.plan import build_plan, clear_plan_cache, plan_cache_stats
from repro.core.scheduler import AGGREGATE_FIRST
from repro.graph.datasets import make_features, make_synthetic_graph
from repro.models import encdec
from repro.models.gcn import PAPER_MODELS
from repro.models.transformer import (init_lm, lm_decode_step, lm_forward,
                                      lm_prefill)
from repro.serve import (Bucket, GraphRequest, GraphServeEngine,
                         default_buckets)
from repro.serve.engine import Request, ServeEngine

GOLDEN = Path(__file__).parent / "golden" / "workload_report.schema.json"


def _fp32(mod, cap=8.0):
    cfg = dataclasses.replace(mod.reduced(), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cap))
    return cfg


@pytest.mark.parametrize("mod", [granite_3_8b, gemma2_9b, kimi_k2,
                                 jamba_1_5_large, mamba2_2_7b])
def test_decode_matches_full_forward(mod):
    cfg = _fp32(mod)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full, _ = lm_forward(params, cfg, toks)
    lg, caches, length = lm_prefill(params, cfg, toks[:, :S - 1],
                                    cache_size=S + 4)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full[:, -2]), rtol=1e-3, atol=1e-3)
    lg2, caches, length = lm_decode_step(params, cfg, toks[:, S - 1:S],
                                         caches, length)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(full[:, -1]), rtol=1e-3, atol=1e-3)


def test_decode_multi_step_consistency():
    cfg = _fp32(granite_3_8b)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 24), 0,
                              cfg.vocab_size)
    full, _ = lm_forward(params, cfg, toks)
    lg, caches, length = lm_prefill(params, cfg, toks[:, :16],
                                    cache_size=32)
    for t in range(16, 24):
        lg, caches, length = lm_decode_step(params, cfg, toks[:, t:t + 1],
                                            caches, length)
        np.testing.assert_allclose(np.asarray(lg[0, 0]),
                                   np.asarray(full[0, t]), rtol=1e-3,
                                   atol=1e-3)


def test_encdec_decode_consistency():
    cfg = _fp32(seamless_m4t_medium)
    p = encdec.init_encdec(cfg, jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0,
                              cfg.vocab_size)
    memory = encdec.encode(p, cfg, frames)
    full, _ = encdec.decode_stack(p, cfg, toks, memory)
    lg, caches, mem, length = encdec.encdec_prefill(p, cfg, frames,
                                                    toks[:, :11],
                                                    cache_size=16)
    lg2, caches, length = encdec.encdec_decode_step(p, cfg, toks[:, 11:12],
                                                    caches, mem, length)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(full[:, -1]), rtol=1e-3, atol=1e-3)


@pytest.fixture(scope="module")
def engine_setup():
    cfg = _fp32(granite_3_8b)
    params = init_lm(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_greedy_matches_naive(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, max_batch=2, cache_size=48)
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                    max_tokens=6) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 3
    for r in done:
        toks = list(r.prompt)
        for _ in range(r.max_tokens):
            logits, _ = lm_forward(params, cfg,
                                   jnp.asarray([toks], jnp.int32))
            toks.append(int(np.asarray(logits)[0, -1].argmax()))
        assert toks[len(r.prompt):] == r.output[:r.max_tokens]


def test_engine_continuous_batching_slot_reuse(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, max_batch=2, cache_size=64)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=np.arange(3) % cfg.vocab_size,
                           max_tokens=3 + i))
    done = eng.run()
    assert len(done) == 5
    assert {r.rid for r in done} == set(range(5))
    # slots were reused: max concurrent = 2 but 5 requests served
    assert eng.stats()["decode_steps"] < sum(3 + i for i in range(5))


def test_engine_eos_stop(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, max_batch=1, cache_size=64)
    # find the greedy first token, then use it as EOS: generation stops at 1
    eng.submit(Request(rid=0, prompt=np.arange(4), max_tokens=32))
    done = eng.run()
    first = done[0].output[0]
    eng2 = ServeEngine(cfg, params, max_batch=1, cache_size=64)
    eng2.submit(Request(rid=1, prompt=np.arange(4), max_tokens=32,
                        eos_id=first))
    done2 = eng2.run()
    assert len(done2[0].output) == 1


# --------------------------------------------------------------------------
# GraphServeEngine: GCN node prediction through bucketed compiled plans
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def graph_setup():
    spec = reduced_graph(CORA, max_vertices=220, max_feature=24)
    return spec, make_synthetic_graph(spec), make_features(spec)


def _graph_engine(graph_setup, **kw):
    spec, g, x = graph_setup
    kw.setdefault("fanouts", (3, 3))
    kw.setdefault("max_batch", 4)
    eng = GraphServeEngine(g, PAPER_MODELS["gcn"], None, x,
                           spec.num_classes, **kw)
    eng.params = eng.init_params(jax.random.PRNGKey(0))
    return eng


@pytest.fixture(scope="module")
def drained_engine(graph_setup):
    """The acceptance drain: 200 requests through <= 4 buckets."""
    spec, g, x = graph_setup
    eng = _graph_engine(
        graph_setup, max_batch=8,
        buckets=default_buckets((3, 3), seed_levels=(4, 16),
                                max_inputs=g.num_vertices))
    traces = eng.warmup()
    rng = np.random.default_rng(7)
    for i in range(200):
        seeds = rng.choice(g.num_vertices,
                           size=int(rng.integers(1, 17)), replace=False)
        eng.submit(GraphRequest(rid=i, seeds=seeds))
    done = eng.run()
    return eng, traces, done


def test_bucket_fits_rule():
    b = Bucket(num_seeds=4, num_inputs=10, num_edges=20)
    assert b.fits(4, 10, 20)          # exact fit: no pad edges needed
    assert b.fits(4, 9, 19)           # pad edges -> last row is the sink
    assert not b.fits(4, 10, 19)      # pad edges but no free sink row
    assert not b.fits(5, 9, 19)       # too many seeds
    assert not b.fits(4, 9, 21)       # too many edges


def test_default_buckets_worst_case_fit():
    f1, f2 = 3, 3
    buckets = default_buckets((f1, f2), seed_levels=(2, 4))
    assert len(buckets) == 2
    for s, b in zip((2, 4), sorted(buckets, key=lambda b: b.num_seeds)):
        frontier = s * (1 + f1) * (1 + f2)
        edges = s * f1 + s * (1 + f1) * f2
        assert b.fits(s, frontier, edges)   # worst case fits by design


def test_select_bucket_smallest_fitting(graph_setup):
    eng = _graph_engine(graph_setup,
                        buckets=[(8, 80, 160), (2, 20, 30), (4, 40, 80)])
    assert eng.select_bucket(1, 10, 10) == Bucket(2, 20, 30)
    # full frontier with pad edges pending: the sink row rule kicks in
    assert eng.select_bucket(2, 20, 29) == Bucket(4, 40, 80)
    assert eng.select_bucket(3, 10, 10) == Bucket(4, 40, 80)
    assert eng.select_bucket(8, 80, 160) == Bucket(8, 80, 160)
    assert eng.select_bucket(9, 10, 10) is None


def test_graph_padded_bit_identical_to_eager(graph_setup):
    spec, g, _ = graph_setup
    eng = _graph_engine(graph_setup)
    eng.warmup()
    rng = np.random.default_rng(3)
    for s in (1, 4, 13):
        prep = eng.prepare(rng.choice(g.num_vertices, size=s, replace=False))
        assert prep.bucket is not None
        compiled = eng.run_prepared(prep)
        assert compiled.shape == (s, spec.num_classes)
        # exactness contract: array_equal, not allclose (docs/serving.md)
        assert np.array_equal(compiled, eng.run_eager(prep))


def test_graph_bucket_donation_no_retrace_and_exact(graph_setup):
    """Satellite: bucket callables compile with donate=True by default --
    each call pads a FRESH feature buffer, so donation must neither
    retrace nor perturb the padded-vs-eager bitwise contract."""
    spec, g, _ = graph_setup
    eng = _graph_engine(graph_setup)
    assert eng.donate is True                       # the default
    eng.warmup()
    assert all(fn.donate for fn in eng._fns.values())
    rng = np.random.default_rng(11)
    for s in (2, 4, 2, 9, 4):                       # sustained bucket reuse
        prep = eng.prepare(rng.choice(g.num_vertices, size=s,
                                      replace=False))
        assert prep.bucket is not None
        compiled = eng.run_prepared(prep)
        assert np.array_equal(compiled, eng.run_eager(prep))
    assert eng.retraces() == 0                      # one trace per bucket
    # opting out still works (callers that reuse x across calls)
    eng2 = _graph_engine(graph_setup, donate=False)
    eng2.warmup()
    assert all(not fn.donate for fn in eng2._fns.values())


def test_graph_slot_reuse(graph_setup):
    spec, g, _ = graph_setup
    eng = _graph_engine(graph_setup, max_batch=2)
    eng.warmup()
    for i in range(7):
        eng.submit(GraphRequest(rid=i, seeds=np.array([i, i + 1], np.int32)))
    done = eng.run()
    assert {r.rid for r in done} == set(range(7))
    s = eng.stats()
    assert s["served"] == 7 and s["queued"] == 0 and s["active"] == 0
    # 2 slots served 7 requests: every request got a slot, steps batched
    assert s["slot_assignments"] == 7
    assert s["steps"] < s["served"]
    for r in done:
        assert r.logits.shape == (2, spec.num_classes)
        assert np.isfinite(r.logits).all()


def test_graph_warmup_once_and_zero_retraces(drained_engine):
    eng, traces, done = drained_engine
    assert len(eng.buckets) <= 4
    assert traces == {eng._bucket_name(b): 1 for b in eng.buckets}
    assert eng.warmup() == traces          # idempotent: no second trace
    s = eng.stats()
    assert s["served"] == len(done) == 200
    assert s["retraces"] == 0 and s["bucket_misses"] == 0
    assert s["bucket_hits"] == 200
    assert all(b["compiled"] == 1 for b in s["buckets"])


def test_graph_latency_percentiles_monotone(drained_engine):
    eng, _, _ = drained_engine
    s = eng.stats()
    assert 0 < s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"]
    assert s["throughput_rps"] > 0


def test_graph_bucket_miss_eager_path_and_cache_sweep(graph_setup):
    spec, g, _ = graph_setup
    # one bucket too small for any 2-seed request: every request misses,
    # is served eagerly, and the transient plans trip the watermark sweep
    eng = _graph_engine(graph_setup, buckets=[(1, 2, 1)], max_batch=2,
                        plan_cache_watermark=2)
    eng.warmup()
    for i in range(6):
        eng.submit(GraphRequest(rid=i,
                                seeds=np.array([i, i + 1], np.int32)))
    done = eng.run()
    s = eng.stats()
    assert s["bucket_misses"] == 6 and s["bucket_hits"] == 0
    assert all(r.bucket is None for r in done)
    for r in done:
        assert r.logits.shape == (2, spec.num_classes)
    assert s["cache_sweeps"] >= 2          # warmup pin + watermark sweeps
    assert s["plan_cache"]["size"] <= 1 + 2 * eng.max_batch
    assert s["plan_cache"]["evictions"] >= 1


def test_plan_cache_stats_and_eviction(graph_setup):
    spec, g, x = graph_setup
    clear_plan_cache()
    assert plan_cache_stats() == {"size": 0, "limit": 64, "blocked_size": 0,
                                  "reorder_size": 0, "hits": 0, "misses": 0,
                                  "evictions": 0}
    p1 = build_plan(g, PAPER_MODELS["gcn"], spec.feature_len,
                    spec.num_classes, backend="xla", fused=False)
    assert plan_cache_stats()["misses"] == 1
    assert build_plan(g, PAPER_MODELS["gcn"], spec.feature_len,
                      spec.num_classes, backend="xla", fused=False) is p1
    assert plan_cache_stats()["hits"] == 1
    build_plan(g, PAPER_MODELS["gcn"], spec.feature_len, spec.num_classes,
               backend="xla", fused=False, ordering=AGGREGATE_FIRST)
    assert plan_cache_stats()["size"] == 2
    clear_plan_cache(keep=[p1])            # explicit eviction policy
    s = plan_cache_stats()
    assert s["size"] == 1 and s["evictions"] >= 1
    assert build_plan(g, PAPER_MODELS["gcn"], spec.feature_len,
                      spec.num_classes, backend="xla", fused=False) is p1
    clear_plan_cache()                     # full wipe resets the counters
    assert plan_cache_stats()["size"] == 0
    assert plan_cache_stats()["hits"] == 0


def test_plan_cache_eviction_accounting(graph_setup):
    """``clear_plan_cache(keep=...)`` counts EVERY dropped cache line --
    plan entries plus the blocked/reorder layouts swept with them -- and
    the hit/miss counters survive the eviction cycle."""
    spec, g, x = graph_setup
    clear_plan_cache()
    p_keep = build_plan(g, PAPER_MODELS["gcn"], spec.feature_len,
                        spec.num_classes, backend="xla", fused=False)
    # a second graph seeds blocked (fused pallas) and reorder (degree)
    # cache lines -- all swept together with its plan entries
    spec2 = dataclasses.replace(spec, seed=spec.seed + 1)
    g2 = make_synthetic_graph(spec2)
    build_plan(g2, PAPER_MODELS["gcn"], spec.feature_len, spec.num_classes,
               backend="pallas-tpu", fused=True)
    build_plan(g2, PAPER_MODELS["gcn"], spec.feature_len, spec.num_classes,
               backend="xla", fused=False, reorder="degree")
    s0 = plan_cache_stats()
    assert s0["blocked_size"] >= 1 and s0["reorder_size"] >= 1
    dropped = clear_plan_cache(keep=[p_keep])
    s1 = plan_cache_stats()
    assert dropped == s0["size"] - 1
    # every dropped line counted, plan entries AND swept layouts
    assert s1["evictions"] == \
        dropped + s0["blocked_size"] + s0["reorder_size"]
    assert s1["size"] == 1
    assert s1["blocked_size"] == 0 and s1["reorder_size"] == 0
    # hit/miss counters accumulate ACROSS the sweep: the kept plan is
    # still a cache hit afterwards
    assert s1["hits"] == s0["hits"] and s1["misses"] == s0["misses"]
    assert build_plan(g, PAPER_MODELS["gcn"], spec.feature_len,
                      spec.num_classes, backend="xla", fused=False) is p_keep
    assert plan_cache_stats()["hits"] == s0["hits"] + 1
    clear_plan_cache()


def test_graph_workload_report_golden_schema(drained_engine):
    eng, _, _ = drained_engine
    report = eng.workload_report()         # .validate() runs inside
    d = json.loads(report.to_json())
    golden = json.loads(GOLDEN.read_text())
    assert sorted(d) == golden["top_serving"]
    assert sorted(d["serving"]) == golden["serving"]
    for b in d["serving"]["buckets"]:
        assert sorted(b) == golden["serving_bucket"]
    assert d["serving"]["requests"] == 200
    assert d["serving"]["bucket_misses"] == 0
    assert d["serving"]["retraces"] == 0
    assert "Serving: 200 requests" in report.to_markdown()
