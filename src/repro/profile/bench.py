"""Declarative benchmark specs + the ONE harness that executes them.

Every ``benchmarks/bench_*.py`` module used to hand-roll the same three
halves: build a scaled graph, time jitted callables, print ad-hoc CSV rows.
This module owns all three.  A benchmark is now a ``BenchSpec`` -- one
(graph x machine x sweep axis) declaration plus a ``measure`` callback that
only computes and emits -- and ``run_specs`` executes any list of them:

  * scaled-graph construction, cached per (dataset, size) across specs,
  * warmup/timing (``ctx.time``; a no-op returning 0.0 under ``--dry-run``),
  * row collection, stdout echo, and the CSV artifact (``write_csv``:
    header row, stable column order) that ``experiments/make_tables.py``
    reads instead of re-parsing stdout,
  * dry-run participation (``BenchSpec.dry``): "run" specs validate their
    scenarios without timing, "skip" specs are reported and skipped.

Wall-clock conventions (repo-wide):
CPU times are correctness-shaped observables (relative effects), never
accelerator predictions -- those come from the analytic columns and the
dry-run roofline artifacts.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.profile.machine import Machine, TPU_V5E

#: where benchmark CSV artifacts land (owned HERE, next to the writer --
#: benchmarks/run.py and experiments/make_tables.py import it rather than
#: re-deriving the path)
BENCH_ARTIFACT_DIR = (Path(__file__).resolve().parents[3] /
                      "experiments" / "bench")

# ---------------------------------------------------------------------------
# Timing + rows + CSV (the shared halves every bench module used to copy)
# ---------------------------------------------------------------------------


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of ``fn(*args)``; blocks on result leaves."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def latency_percentiles(samples_s: List[float]) -> Dict[str, float]:
    """Serving-latency percentiles from per-request wall seconds.

    Returns ``{"p50_ms", "p95_ms", "p99_ms"}`` (milliseconds; zeros for an
    empty sample set so callers can always emit the columns).  The ONE
    percentile definition shared by ``repro.serve`` engine stats, the
    ``WorkloadReport`` serving section, and the ``bench_serve`` CSV --
    numpy's linear interpolation, so p50 <= p95 <= p99 always holds.
    """
    if not samples_s:
        return {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0}
    arr = np.asarray(samples_s, dtype=np.float64) * 1e3
    p50, p95, p99 = np.percentile(arr, [50.0, 95.0, 99.0])
    return {"p50_ms": float(p50), "p95_ms": float(p95), "p99_ms": float(p99)}


def make_row(name: str, us_per_call: float, **derived) -> Dict[str, Any]:
    row = {"name": name, "us_per_call": round(us_per_call, 2)}
    row.update(derived)
    return row


def format_row(row: Dict[str, Any]) -> str:
    """The harness's stdout echo: ``name,us,k=v,...`` (legacy format)."""
    extras = ",".join(f"{k}={v}" for k, v in row.items()
                      if k not in ("name", "us_per_call"))
    return f"{row['name']},{row['us_per_call']},{extras}"


def csv_columns(rows: List[Dict[str, Any]]) -> List[str]:
    """Stable column order: name, us_per_call, then sorted derived keys."""
    keys = sorted({k for r in rows for k in r}
                  - {"name", "us_per_call"})
    return ["name", "us_per_call"] + keys


def write_csv(rows: List[Dict[str, Any]], path) -> Optional[Path]:
    """Write rows as a real CSV artifact: header row, stable column order,
    empty cells for missing keys.  Returns the path (None if no rows)."""
    if not rows:
        return None
    import csv

    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    cols = csv_columns(rows)
    with path.open("w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=cols, restval="",
                           extrasaction="raise")
        w.writeheader()
        for r in rows:
            w.writerow(r)
    return path


# ---------------------------------------------------------------------------
# Scaled datasets (cached across specs within a process)
# ---------------------------------------------------------------------------

_GRAPH_CACHE: Dict[Tuple[str, int, int], Tuple[Any, Any, Any]] = {}


def bench_graph(name: str, max_vertices: int = 8192,
                max_feature: int = 100000):
    """Scaled dataset spec preserving |E|/|V| and feature length (capped)."""
    from repro.config import GRAPHS, reduced_graph
    return reduced_graph(GRAPHS[name], max_vertices, max_feature)


def _graph_for(name: str, max_vertices: int, max_feature: int):
    key = (name, max_vertices, max_feature)
    hit = _GRAPH_CACHE.get(key)
    if hit is None:
        from repro.graph.datasets import make_features, make_synthetic_graph
        spec = bench_graph(name, max_vertices, max_feature)
        g = make_synthetic_graph(spec)
        x = make_features(spec)
        hit = _GRAPH_CACHE[key] = (spec, g, x)
    return hit


# ---------------------------------------------------------------------------
# BenchSpec + BenchContext + run_specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BenchSpec:
    """One declarative benchmark: graph x machine x sweep axis.

    ``measure(ctx, point)`` is called once per sweep point with a
    ``BenchContext``; it computes and calls ``ctx.emit`` / ``ctx.time`` --
    no timing or CSV code of its own.  ``setup(ctx)`` (optional) runs once
    per spec; its return value is available as ``ctx.state``.

    ``dry`` declares dry-run behavior: "run" = execute measure with timing
    disabled (scenario validation, the smoke gate), "skip" = report and
    skip (timing-only specs).  ``dry_max_vertices`` optionally shrinks the
    graph under dry-run so validation stays fast.
    """

    name: str
    measure: Callable[["BenchContext", Any], None]
    graph: Optional[str] = None
    max_vertices: int = 8192
    max_feature: int = 100000
    machine: Machine = TPU_V5E
    sweep: Tuple = (None,)
    dry: str = "skip"                       # "run" | "skip"
    dry_max_vertices: Optional[int] = None
    setup: Optional[Callable[["BenchContext"], Any]] = None

    def __post_init__(self):
        assert self.dry in ("run", "skip"), self.dry


@dataclass
class BenchContext:
    """What a ``measure`` callback sees: data, machine, emit, time."""

    bench: BenchSpec
    machine: Machine
    dry: bool
    rows: List[Dict[str, Any]] = field(default_factory=list)
    spec: Any = None          # GraphSpec (None for graph-less specs)
    g: Any = None             # Graph
    x: Any = None             # features
    state: Any = None         # BenchSpec.setup result

    def emit(self, name: str, us_per_call: float, **derived
             ) -> Dict[str, Any]:
        """Record one result row (echoed to stdout, lands in the CSV)."""
        row = make_row(name, us_per_call, **derived)
        self.rows.append(row)
        print(format_row(row))
        return row

    def time(self, fn: Callable, *args, warmup: int = 2,
             iters: int = 5) -> float:
        """Median wall time (us); 0.0 without executing under dry-run."""
        if self.dry:
            return 0.0
        return timeit(fn, *args, warmup=warmup, iters=iters)


def _context(spec: BenchSpec, dry: bool) -> BenchContext:
    ctx = BenchContext(bench=spec, machine=spec.machine, dry=dry)
    if spec.graph is not None:
        mv = spec.max_vertices
        if dry and spec.dry_max_vertices:
            mv = min(mv, spec.dry_max_vertices)
        ctx.spec, ctx.g, ctx.x = _graph_for(spec.graph, mv,
                                            spec.max_feature)
    return ctx


def run_specs(specs: List[BenchSpec], dry: bool = False,
              csv=None) -> List[Dict[str, Any]]:
    """Execute specs through the shared harness; returns all emitted rows.

    Under ``dry=True`` only specs declaring ``dry="run"`` execute (with
    ``ctx.time`` disabled); the rest are reported as skipped.  ``csv``
    names the artifact ``write_csv`` produces from the collected rows
    (the file ``experiments/make_tables.py::bench_tables`` consumes).
    """
    all_rows: List[Dict[str, Any]] = []
    for spec in specs:
        if dry and spec.dry == "skip":
            print(f"# skipped: {spec.name} (timing-only spec under dry-run)")
            continue
        ctx = _context(spec, dry)
        if spec.setup is not None:
            ctx.state = spec.setup(ctx)
        for point in spec.sweep:
            spec.measure(ctx, point)
        all_rows.extend(ctx.rows)
    if csv is not None:
        p = write_csv(all_rows, csv)
        if p is not None:
            print(f"# csv artifact: {p}")
    return all_rows
