"""Inter-phase dataflow execution at adaptive granularity (paper F5, §5.1-3).

The paper: "a vertex is able to start the execution in Combination phase after
this vertex completes its aggregation ... the implementation of GCNs on GPU
misses this inter-phase dataflow", causing the aggregated intermediate to make
a full HBM round-trip and phase-level barriers to serialize memory-bound and
compute-bound work.

This module provides the *tiled* executor: destination vertices are processed
in blocks of ``tile_m`` rows; each block is aggregated and immediately
combined while the next block's edges stream in.  Two backends:

  * ``xla``        -- lax.scan over vertex blocks; XLA keeps the per-block
    aggregate in registers/cache rather than a (V, F) HBM intermediate.
  * ``pallas-tpu`` -- the fused gather->reduce->GEMM kernel
    (kernels/fused_agg_combine.py) where the block accumulator lives in VMEM
    and the weight tile is VMEM-resident across all blocks.
  * ``pallas-gpu`` -- the row-blocked GPU variant (kernels/gpu_agg.py):
    one thread block owns one destination block, edge chunks loop in-kernel
    with a register accumulator (no cross-CTA atomics), coalesced slab loads.

Granularity (``tile_m``) is the paper's "adaptive execution granularity":
large tiles amortize the weight-tile reuse (compute efficiency), small tiles
shrink the working set and expose pipeline overlap.  ``suggest_tile_m`` picks
the largest tile whose working set fits VMEM.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import is_pallas
from repro.graph.structure import Graph
from repro.profile.machine import Machine, machine_for_backend


class BlockedGraph(NamedTuple):
    """Edges regrouped by destination block with per-block static capacity.

    src:   (nblocks, emax) int32 global source ids (padded).
    dstl:  (nblocks, emax) int32 destination row LOCAL to the block.
    mask:  (nblocks, emax) f32.
    tile_m: rows per block; num_vertices: real vertex count.
    eidx:  (nblocks, emax) int32 ORIGINAL edge index of each slot (pad
           slots point at edge 0 and are masked) -- lets traced per-edge
           data (edge weights) be regrouped into this layout with one
           gather, no host round-trip (kernels/ops.seg_agg_planned).
    """

    src: jnp.ndarray
    dstl: jnp.ndarray
    mask: jnp.ndarray
    tile_m: int
    num_vertices: int
    eidx: Optional[jnp.ndarray] = None

    @property
    def nblocks(self) -> int:
        return int(self.src.shape[0])

    @property
    def emax(self) -> int:
        return int(self.src.shape[1])


def block_offsets(block_ids: np.ndarray, nblocks: int
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Per-edge offset within its (sorted) block, fully vectorized.

    ``block_ids`` must be non-decreasing (edges are dst-sorted).  Returns
    (counts, offsets): edge e lands at [block_ids[e], offsets[e]] in any
    (nblocks, emax) padded layout.  O(E) numpy, no Python loop.
    """
    counts = np.bincount(block_ids, minlength=nblocks)
    starts = np.zeros(nblocks + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    offsets = np.arange(len(block_ids), dtype=np.int64) - starts[block_ids]
    return counts, offsets


def block_graph(g: Graph, tile_m: int) -> BlockedGraph:
    """Host-side regroup of a destination-sorted graph into row blocks."""
    return block_graph_arrays(np.asarray(g.src), np.asarray(g.dst),
                              g.num_vertices, tile_m)


def block_graph_arrays(src: np.ndarray, dst: np.ndarray, num_vertices: int,
                       tile_m: int) -> BlockedGraph:
    """``block_graph`` over raw dst-sorted arrays (no ``Graph`` container).

    Exists for edge lists whose SOURCE ids live outside the destination
    row space — the dedup two-level layout (graph/dedup.py) gathers from
    the (V + P)-row ``[x ; partials]`` concatenation while its output rows
    stay the original V destinations, so a ``Graph`` (which ties both
    endpoints to one vertex count) cannot carry it.  ``num_vertices`` is
    the DESTINATION row count only; ``src`` values are unconstrained.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    v = int(num_vertices)
    nblocks = -(-v // tile_m)
    blk = dst // tile_m
    counts, offs = block_offsets(blk, nblocks)
    emax = max(8, int(-(-(counts.max() if len(src) else 1) // 8) * 8))
    bs = np.zeros((nblocks, emax), np.int32)
    bd = np.zeros((nblocks, emax), np.int32)
    bm = np.zeros((nblocks, emax), np.float32)
    be = np.zeros((nblocks, emax), np.int32)
    bs[blk, offs] = src
    bd[blk, offs] = dst - blk * tile_m
    bm[blk, offs] = 1.0
    be[blk, offs] = np.arange(len(src), dtype=np.int32)
    return BlockedGraph(jnp.asarray(bs), jnp.asarray(bd), jnp.asarray(bm),
                        tile_m, v, jnp.asarray(be))


def suggest_tile_m(in_len: int, out_len: int, avg_deg: float,
                   dtype_bytes: int = 4, vmem_budget: Optional[int] = None,
                   backend: str = "pallas-tpu",
                   machine: Optional[Machine] = None) -> int:
    """Largest aligned tile whose fused working set fits the on-chip budget.

    Working set per block: W (in*out) + accumulator (m*in) + output (m*out)
    + gathered rows stream (avg_deg*m*in, double-buffered factor 2).

    The budget and alignment come from one coherent ``machine``
    (``repro.profile.Machine``; default: the tier's natural preset via
    ``machine_for_backend`` -- A100 for ``pallas-gpu``, TPU_V5E otherwise),
    the paper's F3 point that the winning kernel shape follows the memory
    hierarchy.  The occupancy model is selected by ``machine.kind`` (NOT by
    the backend string, so an explicit GPU machine is never priced with the
    TPU formula or vice versa):

      * ``kind="tpu"``: fit one giant tile into half of VMEM
        (``machine.tile_budget()``) -- a single sequential grid walks the
        blocks, so bigger tiles only amortize the VMEM-pinned W further.
        Sublane alignment (``machine.row_align`` = 8).
      * ``kind="gpu"``: fit the tile into a *fraction* of the SM's
        shared-memory carveout (``machine.on_chip_bytes /
        machine.target_ctas``), because latency hiding comes from multiple
        resident CTAs per SM, not tile size; W is excluded from the
        per-CTA budget (read once, served from L2).  Warp alignment
        (``machine.row_align`` = 32 rows), capped low to keep the CTA
        count >= SMs.

    ``vmem_budget`` remains as a deprecated TPU-path override; prefer
    passing a ``machine``.
    """
    if machine is None:
        machine = machine_for_backend(backend)
    per_row = (in_len + out_len + 2 * avg_deg * in_len) * dtype_bytes
    if machine.kind == "gpu":
        warp = machine.row_align
        budget = machine.tile_budget()
        m = max(warp, int(budget / max(per_row, 1)))
        m = (m // warp) * warp
        return int(max(warp, min(256, m)))
    align = machine.row_align
    budget = machine.tile_budget() if vmem_budget is None else vmem_budget
    w = in_len * out_len * dtype_bytes
    m = max(align, int((budget - w) / max(per_row, 1)))
    return int(max(align, min(4096, (m // align) * align)))


def fused_gcn_layer(bg: BlockedGraph, x: jnp.ndarray, w: jnp.ndarray,
                    bias: Optional[jnp.ndarray] = None, *, agg_op: str = "mean",
                    in_deg: Optional[jnp.ndarray] = None,
                    backend: str = "xla") -> jnp.ndarray:
    """Aggregate-then-combine per vertex block; intermediate never spans V.

    Semantics: combine(aggregate(x))  == aggregate_first with single matmul;
    by linearity identical to combine_first, so this is a pure execution-
    granularity change (the paper's point).

    x: (V, F_in) padded to block multiple internally.  w: (F_in, F_out).
    """
    if is_pallas(backend):
        from repro.kernels import ops as kops
        out = kops.fused_agg_combine(bg.src, bg.dstl, bg.mask, x, w,
                                     tile_m=bg.tile_m, backend=backend)
    else:
        def body(carry, blk):
            src, dstl, mask = blk
            rows = jnp.take(x, src, axis=0) * mask[:, None]      # gather
            agg = jax.ops.segment_sum(rows, dstl, num_segments=bg.tile_m)
            out_blk = agg @ w                                     # fuse: GEMM now
            return carry, out_blk
        _, blocks = jax.lax.scan(body, 0, (bg.src, bg.dstl, bg.mask))
        out = blocks.reshape(bg.nblocks * bg.tile_m, w.shape[1])

    out = out[: bg.num_vertices]
    # self contribution + mean normalization (linear, applied post-GEMM;
    # reciprocal-multiply keeps eager == compiled bitwise -- see
    # phases.aggregate).  The self matmul goes through phases._mm so bf16
    # plan operands accumulate f32; f32 inputs take the identical `@`.
    from repro.core.phases import _mm
    if agg_op == "mean":
        assert in_deg is not None
        self_term = _mm(x[: bg.num_vertices], w)
        norm_dtype = jnp.promote_types(out.dtype, self_term.dtype)
        out = (out.astype(norm_dtype) + self_term) * (
            1.0 / (in_deg.astype(norm_dtype) + 1.0))[:, None]
    elif agg_op == "sum_self":
        out = out + _mm(x[: bg.num_vertices], w)
    if bias is not None:
        out = out + bias
    return out
