"""Paper models: GCN/GIN/SAGE vs dense oracles; PageRank; MLP baseline."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import CORA, reduced_graph
from repro.graph.datasets import (load_dataset, make_features, make_labels,
                                  make_synthetic_graph)
from repro.graph.structure import to_dense_adj
from repro.models.gcn import PAPER_MODELS, GCNModel, make_paper_model
from repro.models.mlp import apply_mlp, init_mlp, mlp_cost, synthetic_mnist
from repro.models.pagerank import pagerank, pagerank_cost, pagerank_reference


@pytest.fixture(scope="module")
def data():
    spec = reduced_graph(CORA, 256, 32)
    g = make_synthetic_graph(spec)
    return spec, g, make_features(spec), make_labels(spec)


def test_gcn_forward_matches_dense(data):
    spec, g, x, _ = data
    m = make_paper_model("gcn", spec)
    p = m.init(jax.random.PRNGKey(0))
    out = m.convs[0].apply(p["conv0"], g, x)
    a = np.asarray(to_dense_adj(g))
    xn, w = np.asarray(x), np.asarray(p["conv0"]["lin"]["w"])
    b = np.asarray(p["conv0"]["lin"]["b"])
    ref = (a @ (xn @ w) + xn @ w) / (np.asarray(g.in_deg)[:, None] + 1) + b
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-5)


def test_gin_forward_matches_dense(data):
    spec, g, x, _ = data
    m = make_paper_model("gin", spec)
    p = m.init(jax.random.PRNGKey(1))
    out = m.convs[0].apply(p["conv0"], g, x)
    a = np.asarray(to_dense_adj(g))
    xn = np.asarray(x)
    h = a @ xn + xn
    h = np.maximum(h @ np.asarray(p["conv0"]["mlp1"]["w"]) +
                   np.asarray(p["conv0"]["mlp1"]["b"]), 0)
    ref = h @ np.asarray(p["conv0"]["mlp2"]["w"]) + \
        np.asarray(p["conv0"]["mlp2"]["b"])
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-4)


def test_sage_same_rule_as_gcn(data):
    spec, g, x, _ = data
    mg = make_paper_model("gcn", spec)
    ms = make_paper_model("sage", spec)
    p = mg.init(jax.random.PRNGKey(2))
    np.testing.assert_allclose(
        np.asarray(mg.convs[0].apply(p["conv0"], g, x)),
        np.asarray(ms.convs[0].apply(p["conv0"], g, x)), rtol=1e-6)


def test_gcn_training_reduces_loss(data):
    spec, g, x, y = data
    m = make_paper_model("gcn", spec)
    p = m.init(jax.random.PRNGKey(3))
    loss0 = float(m.loss_fn(p, g, x, y))
    lr = 0.1
    grad_fn = jax.jit(jax.grad(lambda pp: m.loss_fn(pp, g, x, y)))
    for _ in range(60):
        gr = grad_fn(p)
        p = jax.tree.map(lambda a, b: a - lr * b, p, gr)
    loss1 = float(m.loss_fn(p, g, x, y))
    # random labels over a smoothing model: any reliable decrease counts
    # (threshold calibrated to the seeded run, which lands at ~0.048)
    assert loss1 < loss0 - 0.02, (loss0, loss1)


def test_paper_table1_configs():
    assert PAPER_MODELS["gcn"].hidden_dims == (128,)
    assert PAPER_MODELS["gin"].hidden_dims == (128, 128)
    assert PAPER_MODELS["gin"].aggregator == "sum"
    assert PAPER_MODELS["sage"].aggregator == "mean"


def test_ordering_auto_resolution(data):
    spec, g, x, _ = data
    m = make_paper_model("gcn", spec)
    # in=32 -> hidden=128 expands: aggregate_first is cheaper
    assert m.convs[0].resolve_order(g) == "aggregate_first"
    big = dataclasses.replace(spec, feature_len=602)
    m2 = make_paper_model("gcn", big)
    assert m2.convs[0].resolve_order(g) == "combine_first"
    # GIN always aggregate_first
    m3 = make_paper_model("gin", spec)
    assert m3.convs[0].resolve_order(g) == "aggregate_first"


def test_pagerank_vs_dense_reference(data):
    _, g, _, _ = data
    r = pagerank(g, iters=25)
    ref = pagerank_reference(g, iters=25)
    np.testing.assert_allclose(np.asarray(r), np.asarray(ref), rtol=1e-4,
                               atol=1e-7)
    assert float(r.sum()) == pytest.approx(1.0, abs=1e-3)


def test_pagerank_cost_scalar_features(data):
    _, g, _, _ = data
    c = pagerank_cost(g)
    # one scalar per vertex: arithmetic intensity far below any GCN layer
    assert c["arithmetic_intensity"] < 0.2


def test_mlp_baseline():
    key = jax.random.PRNGKey(0)
    p = init_mlp(key)
    x, _ = synthetic_mnist(key)
    out = apply_mlp(p, x)
    assert out.shape == (1000, 128)
    assert mlp_cost()["param_reuse"] == 1000


def test_layer_costs_structure(data):
    spec, g, x, _ = data
    m = make_paper_model("gcn", spec)
    c = m.layer_costs(g)
    assert {"order", "aggregation", "combination", "ordering_cost"} <= set(c)
    assert c["aggregation"]["bytes"] > 0
